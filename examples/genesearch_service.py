"""End-to-end LIVE gene-search serving: boot a 2-replica fleet on a base
archive that is missing four genomes, watch those queries miss (recall
0/4), then stream the genomes in through the cluster write path — the
fleet answers 4/4 WITHOUT a restart, every result stamped with the
``(version, delta_seq)`` coordinates that served it and orderable against
the write acks (read-your-writes). Finally fold the accumulated deltas
into a new base version under the same fleet: the answers don't change,
and the compile counters prove the compaction cost zero recompiles.

    PYTHONPATH=src python examples/genesearch_service.py
"""

import os
import tempfile
import time

import numpy as np

from repro.core import idl
from repro.data import genome
from repro.index import BitSlicedIndex, ingest
from repro.serving import LiveReplicaRouter, RouterConfig, ServiceConfig


def main() -> None:
    n_files = 64
    live_ids = [3, 17, 40, 59]            # these genomes arrive LIVE
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=3, m=1 << 20)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000, seed=42)

    print(f"indexing {n_files - len(live_ids)} of {n_files} genome files "
          f"(holding back {live_ids}) ...")
    # the streaming archive builder: every genome is chopped into read_len
    # windows overlapping by k-1 (no kmer lost), batched in chunks and fed
    # to the cached InsertPlan — one compile per window length
    t0 = time.perf_counter()
    eng = BitSlicedIndex.build(cfg, "idl", n_files=n_files)
    eng = ingest.build_archive(
        eng, [f for f in archive if f.file_id not in live_ids],
        read_len=230, chunk_reads=64)
    eng.state.block_until_ready()
    print(f"  base built in {time.perf_counter() - t0:.1f}s "
          f"({eng.state.nbytes / 1e6:.1f} MB bit-sliced IndexState)")

    # ragged query stream for the held-back genomes: full reads and
    # amplicon-length fragments — submit() returns futures immediately,
    # the background flushers batch each kmer bucket on its deadline
    queries = []
    for i, fid in enumerate(live_ids):
        read = archive[fid].reads(230, 6)[5]
        queries.append(np.asarray(read[:(80, 120, 160, 230)[i % 4]]))

    def search(router):
        futures = [router.submit(q) for q in queries]
        router.drain()
        return [f.result() for f in futures]

    with tempfile.TemporaryDirectory() as tmp:
        # the live fleet: each replica serves base + delta through the
        # exact two-probe merge; every write is journaled (write-ahead,
        # CRC-framed) before any replica's delta absorbs it
        router = LiveReplicaRouter(
            eng, ServiceConfig(theta=1.0, max_batch=8),
            RouterConfig(n_replicas=2, policy="bucket_affinity"),
            journal_path=os.path.join(tmp, "wal.bin"))
        print("  2-replica live router booted (write-ahead journal on)")

        results = search(router)
        hits = sum(fid in r.file_ids for fid, r in zip(live_ids, results))
        print(f"before live ingest: recall {hits}/{len(live_ids)} "
              f"(the genomes aren't indexed yet)")

        # the cluster write path: chop each held-back genome into k-1
        # overlapping windows (same rule as the offline builder) and
        # insert through the router — one journal append, then the batch
        # fans to every replica's flusher; all acks resolved = the write
        # is searchable fleet-wide
        t0 = time.perf_counter()
        acks = []
        for fid in live_ids:
            windows = genome.window_reads(archive[fid].genome, 230, cfg.k)
            fids = np.full(windows.shape[0], fid, dtype=np.int32)
            acks += router.insert(windows, fids)
        last = max(a.result().delta_seq for a in acks)
        print(f"streamed {len(live_ids)} genomes in "
              f"{time.perf_counter() - t0:.2f}s; last ack at delta_seq "
              f"{last} ({router.delta_batches()} delta batches pending)")

        results = search(router)
        hits = 0
        for fid, r, q in zip(live_ids, results, queries):
            hits += int(fid in r.file_ids)
            print(f"query from file {fid:2d} (len {len(q)}, bucket "
                  f"{r.bucket}, v{r.version} seq {r.delta_seq}): "
                  f"matched {list(r.file_ids)}")
        print(f"after live ingest: recall {hits}/{len(live_ids)} — "
              f"no restart, every result's delta_seq >= {last} (saw the "
              f"writes)")

        # background-style compaction under the same fleet: fold every
        # replica's delta into a new base version; same geometry in and
        # out, so the compiled steps are all reused
        compiles_before = dict(router.compile_counts())
        version = router.compact()
        results = search(router)
        hits = sum(fid in r.file_ids for fid, r in zip(live_ids, results))
        print(f"compacted -> base v{version} "
              f"({router.delta_batches()} delta batches left); recall "
              f"still {hits}/{len(live_ids)} at v{results[0].version}; "
              f"compiles unchanged: "
              f"{dict(router.compile_counts()) == compiles_before}")
        router.close()


if __name__ == "__main__":
    main()

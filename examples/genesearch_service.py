"""End-to-end gene-search service, serving-v2 edition: stream an archive
into a bit-sliced MSMT index (shared ingest layer), snapshot it to disk
(versioned store), boot a :class:`GeneSearchService` straight from the
snapshot, and serve a RAGGED query stream — reads of many lengths — through
pow2 shape buckets, so the whole stream compiles once per bucket instead of
once per length.

    PYTHONPATH=src python examples/genesearch_service.py
"""

import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import idl
from repro.data import genome
from repro.index import BitSlicedIndex, ingest, store
from repro.serving import GeneSearchService, ServiceConfig


def main() -> None:
    n_files = 64
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=3, m=1 << 20)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000, seed=42)

    print(f"indexing {n_files} genome files ...")
    # the streaming archive builder: every genome is chopped into read_len
    # windows overlapping by k-1 (no kmer lost), batched in chunks and fed
    # to the cached InsertPlan — one compile per window length
    t0 = time.perf_counter()
    eng = BitSlicedIndex.build(cfg, "idl", n_files=n_files)
    eng = ingest.build_archive(eng, archive, read_len=230, chunk_reads=64)
    state = eng.state
    state.block_until_ready()
    print(f"  index built in {time.perf_counter() - t0:.1f}s "
          f"({state.nbytes / 1e6:.1f} MB bit-sliced IndexState)")

    # persistence: versioned snapshot -> disk -> snapshot-backed service
    with tempfile.TemporaryDirectory() as snap_dir:
        store.save(state, snap_dir)
        svc = GeneSearchService.from_snapshot(
            snap_dir, ServiceConfig(theta=1.0, max_batch=8))
        print(f"  snapshot saved + service booted from {snap_dir!r}")

        # ragged query stream: full reads, amplicon-length fragments and
        # poisoned decoys — the service buckets them by kmer count
        true_ids = [3, 17, 40, 59]
        queries, labels = [], []
        for i, fid in enumerate(true_ids):
            read = archive[fid].reads(230, 6)[5]
            frag_len = (80, 120, 160, 230)[i % 4]
            queries.append(np.asarray(read[:frag_len]))
            labels.append(fid)
        decoys = [np.asarray(d) for d in
                  genome.poison_queries(np.stack([q[:80] for q in queries]),
                                        seed=7)]

        results = svc.search(queries + decoys)
        hits = fps = decoy_hits = 0
        for i, fid in enumerate(labels):
            got = results[i].file_ids
            hits += int(fid in got)
            fps += len(got) - int(fid in got)
            got_d = results[len(labels) + i].file_ids
            decoy_hits += len(got_d)
            print(f"query from file {fid:2d} (len {len(queries[i])}, "
                  f"bucket {results[i].bucket}): matched {list(got)}; "
                  f"poisoned -> {list(got_d)}")
        print(f"recall {hits}/{len(labels)}, false positives {fps}, "
              f"poisoned matches {decoy_hits}")

        # serving telemetry: one compile per (bucket, backend), occupancy,
        # per-request latency
        lat = np.asarray(svc.request_latencies_ms())
        print(f"buckets/compiles: {svc.compile_counts()} "
              f"(ragged stream, compiled once per bucket)")
        print(f"occupancy {svc.occupancy():.2f}, "
              f"latency p50 {np.percentile(lat, 50):.1f} ms "
              f"p95 {np.percentile(lat, 95):.1f} ms")

        # the direct engine view answers identically (bit-exact parity)
        view = store.load_engine(snap_dir)
        q0 = jnp.asarray(queries[0])[None]
        same = bool(np.all(np.asarray(view.msmt(q0))[0]
                           == np.asarray(results[0].matches)))
        print(f"snapshot engine view agrees with the service: {same}")


if __name__ == "__main__":
    main()

"""End-to-end gene-search serving, cluster edition: stream an archive into
a bit-sliced MSMT index (shared ingest layer), snapshot it to disk
(versioned store), boot a 2-replica :class:`ReplicaRouter` straight from
the snapshot, and serve a RAGGED query stream through futures — requests
batch per pow2 kmer bucket on a background deadline flusher, sharded over
replicas, one compile per (bucket, backend) per replica. Then publish a
NEW snapshot version and hot-swap it under traffic: zero dropped futures,
every result stamped with the state version that served it.

    PYTHONPATH=src python examples/genesearch_service.py
"""

import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import idl
from repro.data import genome
from repro.index import BitSlicedIndex, ingest, store
from repro.serving import ReplicaRouter, RouterConfig, ServiceConfig

def main() -> None:
    n_files = 64
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=3, m=1 << 20)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000, seed=42)

    print(f"indexing {n_files} genome files ...")
    # the streaming archive builder: every genome is chopped into read_len
    # windows overlapping by k-1 (no kmer lost), batched in chunks and fed
    # to the cached InsertPlan — one compile per window length
    t0 = time.perf_counter()
    eng = BitSlicedIndex.build(cfg, "idl", n_files=n_files)
    eng = ingest.build_archive(eng, archive, read_len=230, chunk_reads=64)
    state = eng.state
    state.block_until_ready()
    print(f"  index built in {time.perf_counter() - t0:.1f}s "
          f"({state.nbytes / 1e6:.1f} MB bit-sliced IndexState)")

    with tempfile.TemporaryDirectory() as snap_v0, \
            tempfile.TemporaryDirectory() as snap_v1:
        # persistence: versioned snapshot -> disk -> snapshot-booted FLEET
        store.save(state, snap_v0)
        router = ReplicaRouter.from_snapshot(
            snap_v0, ServiceConfig(theta=1.0, max_batch=8),
            RouterConfig(n_replicas=2, policy="bucket_affinity"))
        print(f"  snapshot saved; 2-replica router booted from {snap_v0!r}")

        # ragged query stream: full reads, amplicon-length fragments and
        # poisoned decoys — submit() returns futures immediately, the
        # background flushers batch each kmer bucket on its deadline
        true_ids = [3, 17, 40, 59]
        queries, labels = [], []
        for i, fid in enumerate(true_ids):
            read = archive[fid].reads(230, 6)[5]
            frag_len = (80, 120, 160, 230)[i % 4]
            queries.append(np.asarray(read[:frag_len]))
            labels.append(fid)
        decoys = [np.asarray(d) for d in
                  genome.poison_queries(np.stack([q[:80] for q in queries]),
                                        seed=7)]
        futures = [router.submit(q) for q in queries + decoys]
        router.drain()
        results = [f.result() for f in futures]

        hits = fps = decoy_hits = 0
        for i, fid in enumerate(labels):
            got = results[i].file_ids
            hits += int(fid in got)
            fps += len(got) - int(fid in got)
            got_d = results[len(labels) + i].file_ids
            decoy_hits += len(got_d)
            print(f"query from file {fid:2d} (len {len(queries[i])}, "
                  f"bucket {results[i].bucket}, v{results[i].version}): "
                  f"matched {list(got)}; poisoned -> {list(got_d)}")
        print(f"recall {hits}/{len(labels)}, false positives {fps}, "
              f"poisoned matches {decoy_hits}")

        # cluster telemetry: per-replica compile-once, flush reasons,
        # occupancy, queue delay
        stats = router.cluster_stats()
        print(f"replica/bucket compiles: {router.compile_counts()} "
              f"(one per bucket per replica)")
        print(f"occupancy {router.occupancy():.2f}; flush reasons "
              f"{sorted({s.flush_reason for s in stats})}; queue p95 "
              f"{np.percentile([s.queue_ms for s in stats], 95):.1f} ms")

        # hot snapshot swap under the same fleet: load a FRESH engine from
        # the v0 snapshot (the served replicas' own buffers are never
        # touched), index one more genome into it, publish v1, swap —
        # replicas pause one at a time, traffic keeps flowing, and
        # same-geometry states reuse every compiled step (zero recompiles)
        extra = genome.synth_archive(n_files=1, genome_len=3_000, seed=99)[0]
        read_new = extra.reads(230, 1)[0]
        eng_v1 = store.load_engine(snap_v0).insert_batch(
            jnp.asarray(read_new)[None], np.asarray([0]))
        store.save(eng_v1, snap_v1)
        new_version = router.swap_snapshot(snap_v1)
        res = router.submit(np.asarray(read_new)).result()
        print(f"hot-swapped to snapshot v{new_version}: new read -> files "
              f"{list(res.file_ids)} (served at v{res.version}); compiles "
              f"unchanged: {router.compile_counts()}")
        router.close()


if __name__ == "__main__":
    main()

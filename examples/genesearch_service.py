"""End-to-end gene-search service: stream an archive of genome files into
a bit-sliced MSMT index through the shared ingest layer (one loop of
jit-compiled, donated, chunked inserts — the same builder that handles
FASTA archives of any size), then serve batched queries (the paper's COBS
workload, via the TPU-lowerable serve_step).

    PYTHONPATH=src python examples/genesearch_service.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import genome
from repro.serving import genesearch as gs


def main() -> None:
    cfg = gs.GeneSearchConfig(
        n_files=64, m=1 << 20, k=31, t=16, L=1 << 12, eta=3, read_len=230,
        scheme="idl")
    archive = genome.synth_archive(n_files=cfg.n_files, genome_len=3_000,
                                   seed=42)

    print(f"indexing {cfg.n_files} genome files ...")
    # the streaming archive builder: every genome is chopped into
    # read_len windows overlapping by k-1 (no kmer lost), batched in
    # chunks and fed to the cached InsertPlan — no per-read Python loop,
    # no per-file full-matrix copy, one compile per window length
    t0 = time.perf_counter()
    index = gs.build_archive(cfg, archive, chunk_reads=64)
    index.block_until_ready()
    print(f"  index built in {time.perf_counter() - t0:.1f}s "
          f"({index.nbytes / 1e6:.1f} MB bit-sliced, streamed build_archive)")

    # batched MSMT: queries are reads from known files + poisoned decoys
    true_ids = [3, 17, 40, 59]
    queries, labels = [], []
    for fid in true_ids:
        read = archive[fid].reads(cfg.read_len, 6)[5]
        queries.append(read)
        labels.append(fid)
    decoys = genome.poison_queries(np.stack(queries), seed=7)

    serve = jax.jit(lambda i, q: gs.serve_step(i, q, cfg))
    out = serve(index, jnp.asarray(np.stack(queries)))
    out_decoy = serve(index, jnp.asarray(decoys))

    hits = misses = fps = 0
    for i, fid in enumerate(labels):
        got = gs.match_file_ids(np.asarray(out[i]))
        hits += int(fid in got)
        fps += len(got) - int(fid in got)
        got_d = gs.match_file_ids(np.asarray(out_decoy[i]))
        misses += len(got_d)
        print(f"query from file {fid:2d}: matched {got}; poisoned -> {got_d}")
    print(f"recall {hits}/{len(labels)}, false positives {fps}, "
          f"poisoned matches {misses}")

    t0 = time.perf_counter()
    serve(index, jnp.asarray(np.stack(queries))).block_until_ready()
    print(f"serve_step latency (batch=4): "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms")


if __name__ == "__main__":
    main()

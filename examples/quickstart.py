"""Quickstart: index a genome with an IDL Bloom filter and query reads.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import bloom, cache_model, idl
from repro.data import genome


def main() -> None:
    # 1. synthesize a genome and build the IDL-BF over its 31-mers
    g = genome.synthesize_genome(50_000, seed=0)
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=1 << 24)
    bf = bloom.BloomFilter(cfg=cfg, scheme="idl").insert_sequence(jnp.asarray(g))
    print(f"indexed {len(g) - cfg.k + 1} kmers into a {cfg.m // 8 // 1024} KiB "
          f"IDL-BF (fill = {float(bf.fill_fraction):.3f})")

    # 2. genuine reads pass Membership Testing; 1-poisoned reads fail
    reads = genome.extract_reads(g, 230, 5, seed=1)
    poisoned = genome.poison_queries(reads, seed=2)
    for i in range(3):
        ok = bool(bf.membership(jnp.asarray(reads[i])))
        bad = bool(bf.membership(jnp.asarray(poisoned[i])))
        print(f"read {i}: genuine -> {ok}, 1-poisoned -> {bad}")

    # 3. the paper's locality claim, measured
    locs_idl = np.asarray(idl.idl_locations_rolling(cfg, jnp.asarray(reads[0])))
    locs_rh = np.asarray(idl.rh_locations_rolling(cfg, jnp.asarray(reads[0])))
    for name, locs in (("IDL", locs_idl), ("RH", locs_rh)):
        d = cache_model.count_block_dmas_partitioned(locs, cfg.L)
        print(f"{name}: {d['switches']} block DMAs for {d['accesses']} probes "
              f"({d['switches'] / d['accesses']:.2%} per probe)")


if __name__ == "__main__":
    main()

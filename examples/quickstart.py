"""Quickstart: index a genome with an IDL Bloom filter and query reads,
through the unified `GeneIndex` API (`repro.index`).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import cache_model, idl
from repro.data import genome
from repro.index import PackedBloomIndex, registry


def main() -> None:
    # 1. synthesize a genome and build the IDL-BF over its 31-mers
    g = genome.synthesize_genome(50_000, seed=0)
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=1 << 24)
    bf = PackedBloomIndex.build(cfg, scheme="idl").insert_batch(jnp.asarray(g))
    print(f"indexed {len(g) - cfg.k + 1} kmers into a {cfg.m // 8 // 1024} KiB "
          f"IDL-BF (fill = {float(bf.fill_fraction):.3f})")

    # 2. genuine reads pass Membership Testing; 1-poisoned reads fail —
    #    both checked for the whole batch in one query_batch call
    reads = genome.extract_reads(g, 230, 5, seed=1)
    poisoned = genome.poison_queries(reads, seed=2)
    ok = bf.msmt(jnp.asarray(np.stack(reads)))
    bad = bf.msmt(jnp.asarray(poisoned))
    for i in range(3):
        print(f"read {i}: genuine -> {bool(ok[i])}, 1-poisoned -> {bool(bad[i])}")

    # 3. the paper's locality claim, measured per registered scheme
    for name in ("idl", "rh"):
        locs = np.asarray(registry.locations(cfg, jnp.asarray(reads[0]), name))
        d = cache_model.count_block_dmas_partitioned(locs, cfg.L)
        print(f"{name.upper()}: {d['switches']} block DMAs for {d['accesses']} "
              f"probes ({d['switches'] / d['accesses']:.2%} per probe)")

    # 4. the same membership through the planned Pallas probe backend and
    #    the sharded (shard_map) backend — one shared query layer
    batch = jnp.asarray(np.stack(reads))
    member = bf.query_batch(batch)
    member_kernel = bf.query_batch(batch, backend="idl_probe")
    member_sharded = bf.query_batch(batch, backend="sharded")
    print(f"idl_probe backend agrees: "
          f"{bool(jnp.all(member_kernel == member))}")
    print(f"sharded backend agrees:   "
          f"{bool(jnp.all(member_sharded == member))}")


if __name__ == "__main__":
    main()

"""Quickstart: index a genome with an IDL Bloom filter and query reads,
through the unified `GeneIndex` API (`repro.index`).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

import repro.index as index
from repro.core import cache_model, idl
from repro.data import genome
from repro.index import PackedBloomIndex, registry


def main() -> None:
    # 1. synthesize a genome and build the IDL-BF over its 31-mers through
    #    the streaming archive builder (chunked, jit-compiled donated
    #    inserts — the same call scales to whole FASTA archives)
    g = genome.synthesize_genome(50_000, seed=0)
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=1 << 24)
    bf = PackedBloomIndex.build(cfg, scheme="idl")
    bf = index.build_archive(bf, [(0, g)], read_len=230, chunk_reads=64)
    print(f"indexed {len(g) - cfg.k + 1} kmers into a {cfg.m // 8 // 1024} KiB "
          f"IDL-BF (fill = {float(bf.fill_fraction):.3f})")

    # 2. genuine reads pass Membership Testing; 1-poisoned reads fail —
    #    both checked for the whole batch in one query_batch call
    reads = genome.extract_reads(g, 230, 5, seed=1)
    poisoned = genome.poison_queries(reads, seed=2)
    ok = bf.msmt(jnp.asarray(np.stack(reads)))
    bad = bf.msmt(jnp.asarray(poisoned))
    for i in range(3):
        print(f"read {i}: genuine -> {bool(ok[i])}, 1-poisoned -> {bool(bad[i])}")

    # 3. the paper's locality claim, measured per registered scheme
    for name in ("idl", "rh"):
        locs = np.asarray(registry.locations(cfg, jnp.asarray(reads[0]), name))
        d = cache_model.count_block_dmas_partitioned(locs, cfg.L)
        print(f"{name.upper()}: {d['switches']} block DMAs for {d['accesses']} "
              f"probes ({d['switches'] / d['accesses']:.2%} per probe)")

    # 4. the same membership through the planned Pallas probe backend and
    #    the sharded (shard_map) backend — one shared query layer
    batch = jnp.asarray(np.stack(reads))
    member = bf.query_batch(batch)
    member_kernel = bf.query_batch(batch, backend="idl_probe")
    member_sharded = bf.query_batch(batch, backend="sharded")
    print(f"idl_probe backend agrees: "
          f"{bool(jnp.all(member_kernel == member))}")
    print(f"sharded backend agrees:   "
          f"{bool(jnp.all(member_sharded == member))}")

    # 5. ... and the write side has the same backend choice: the planned
    #    Pallas insert kernel builds a bit-identical filter
    bf2 = PackedBloomIndex.build(cfg, scheme="idl")
    bf2 = index.build_archive(bf2, [(0, g)], read_len=230, chunk_reads=64,
                              backend="idl_insert")
    print(f"idl_insert backend agrees: "
          f"{bool(jnp.all(bf2.words == bf.words))}")

    # 6. protocol v2: the engine is a thin view over a pytree IndexState —
    #    snapshot it to disk and serve ragged-length queries through the
    #    dynamic-batching service (one compile per pow2 kmer bucket)
    import tempfile

    from repro.index import store
    from repro.serving import GeneSearchService, ServiceConfig

    with tempfile.TemporaryDirectory() as snap:
        store.save(bf.state, snap)                 # versioned snapshot
        svc = GeneSearchService.from_snapshot(snap, ServiceConfig())
        ragged = [np.asarray(reads[0]), np.asarray(reads[1][:120]),
                  np.asarray(reads[2][:90])]
        results = svc.search(ragged)
        print("served ragged lengths "
              f"{[len(q) for q in ragged]} -> matches "
              f"{[bool(r.matches) for r in results]} "
              f"(buckets/compiles: {svc.compile_counts()})")


if __name__ == "__main__":
    main()

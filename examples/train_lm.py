"""End-to-end driver: train a ~100M-param granite-style LM for a few hundred
steps on the host devices, with the IDL-BF dedup pipeline, checkpointing and
fault-tolerance hooks — the full production loop at laptop scale.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

import jax

from repro.data import lm_pipeline
from repro.models import transformer as tf
from repro.train import loop, optimizer as opt_mod


def build_config() -> tf.LMConfig:
    # ~100M params: 12L x 512d x 8H, vocab 8192
    return tf.LMConfig(
        name="granite-100m", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=2048, vocab=8192, act="silu", gated_mlp=True,
        remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="runs/train_lm_ckpt")
    args = ap.parse_args()

    cfg = build_config()
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params / 1e6:.0f}M params)")

    pipe = lm_pipeline.LMPipeline(lm_pipeline.LMPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        dedup=True, dedup_scheme="idl"))

    params = tf.lm_init(jax.random.PRNGKey(0), cfg)
    lcfg = loop.LoopConfig(
        total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
        log_every=10, grad_clip=1.0)

    result = loop.run(
        lambda p, b: tf.lm_loss(p, b, cfg, loss_chunks=8),
        params, opt_mod.adamw(3e-4),
        lambda: {k: jax.numpy.asarray(v) for k, v in pipe.next_batch().items()},
        lcfg,
        pipeline_state=pipe.state_dict,
        restore_pipeline=pipe.load_state_dict,
    )

    first = result.history[0]["loss"]
    last = result.history[-1]["loss"]
    print(f"\nstep {result.history[-1]['step']}: loss {first:.3f} -> {last:.3f}"
          f" (dedup dropped {pipe.dropped} docs)")
    if result.resumed_from:
        print(f"(resumed from checkpoint step {result.resumed_from})")
    assert last < first, "loss must decrease"
    print("ok")


if __name__ == "__main__":
    main()

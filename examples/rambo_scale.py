"""IDL-RAMBO at archive scale: sub-linear MSMT over 100 files with B·R
bucketed Bloom filters (paper §7.3, scaled to the CPU harness).

    PYTHONPATH=src python examples/rambo_scale.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import idl, rambo
from repro.data import genome


def main() -> None:
    n_files = 100
    archive = genome.synth_archive(n_files=n_files, genome_len=5_000, seed=3)
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=4, m=1 << 21)

    for scheme in ("rh", "idl"):
        r = rambo.Rambo.build(n_files, cfg, scheme=scheme, B=20, R=2)
        t0 = time.perf_counter()
        for f in archive:
            r = r.insert_sequence(f.file_id, jnp.asarray(f.genome))
        r.filters.block_until_ready()
        t_index = time.perf_counter() - t0

        hits, total, fp = 0, 0, 0
        t0 = time.perf_counter()
        for f in archive[:20]:
            read = f.reads(230, 1)[0]
            got = np.asarray(r.msmt(jnp.asarray(read)))
            hits += int(got[f.file_id])
            fp += int(got.sum()) - int(got[f.file_id])
            total += 1
        t_query = (time.perf_counter() - t0) / total
        print(f"{scheme:3s}: {r.R}x{r.B} filters, {r.total_bits / 8e6:.1f} MB, "
              f"index {t_index:.1f}s, query {t_query * 1e3:.1f} ms/read, "
              f"recall {hits}/{total}, fp/query {fp / total:.2f}")


if __name__ == "__main__":
    main()

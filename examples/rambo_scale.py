"""IDL-RAMBO at archive scale: sub-linear MSMT over 100 files with B·R
bucketed Bloom filters (paper §7.3), built through the unified `GeneIndex`
API — the whole archive is indexed with one batched, donated insert.

    PYTHONPATH=src python examples/rambo_scale.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import idl
from repro.data import genome
from repro.index import RamboIndex


def main() -> None:
    n_files = 100
    archive = genome.synth_archive(n_files=n_files, genome_len=5_000, seed=3)
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=4, m=1 << 21)
    genomes = jnp.asarray(np.stack([np.asarray(f.genome) for f in archive]))
    file_ids = np.asarray([f.file_id for f in archive], dtype=np.int32)

    for scheme in ("rh", "idl"):
        r = RamboIndex.build(n_files, cfg, scheme=scheme, B=20, R=2)
        t0 = time.perf_counter()
        r = r.insert_batch(genomes, file_ids)
        r.words.block_until_ready()
        t_index = time.perf_counter() - t0

        reads = jnp.asarray(np.stack(
            [f.reads(230, 1)[0] for f in archive[:20]]))
        t0 = time.perf_counter()
        got = np.asarray(r.msmt(reads))
        t_query = (time.perf_counter() - t0) / len(reads)
        hits = int(got[np.arange(20), file_ids[:20]].sum())
        fp = int(got.sum()) - hits
        print(f"{scheme:3s}: {r.n_rep}x{r.n_buckets} filters, "
              f"{r.total_bits / 8e6:.1f} MB, index {t_index:.1f}s "
              f"(one insert_batch), query {t_query * 1e3:.1f} ms/read, "
              f"recall {hits}/{len(reads)}, fp/query {fp / len(reads):.2f}")


if __name__ == "__main__":
    main()

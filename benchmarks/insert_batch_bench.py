"""Batched donated insert vs the seed's per-read loop.

Measures the original acceptance-criterion path: 64 reads inserted into a
partitioned IDL-BF as ONE jit-compiled, donated, dedup'd scatter (the
``jnp`` backend of `repro.index.ingest`) against the seed semantics of one
`bf.at[locs].set(1)` full-array copy per read. See ``ingest_bench.py`` for
the full per-backend ingest matrix.

    PYTHONPATH=src python -m benchmarks.insert_batch_bench
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom, idl
from repro.index import PackedBloomIndex, ingest, registry


def main() -> None:
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=1 << 26)
    rng = np.random.default_rng(0)
    reads = jnp.asarray(rng.integers(0, 4, size=(64, 230), dtype=np.uint8))

    # --- new path: one jit call for the whole batch, donated buffer -------
    eng = PackedBloomIndex.build(cfg, "idl")
    eng.insert_batch(reads).words.block_until_ready()      # compile
    ingest._execute_jnp.clear_cache()
    t0 = time.perf_counter()
    out = PackedBloomIndex.build(cfg, "idl").insert_batch(reads)
    out.words.block_until_ready()
    t_batch_cold = time.perf_counter() - t0
    assert ingest._execute_jnp._cache_size() == 1          # ONE jit call
    t0 = time.perf_counter()
    out2 = PackedBloomIndex.build(cfg, "idl").insert_batch(reads)
    out2.words.block_until_ready()
    t_batch = time.perf_counter() - t0

    # --- seed path: per-read python loop, full-array copy per read --------
    insert_one = jax.jit(
        lambda bits, codes: bloom.insert_locations(
            bits, registry.locations(cfg, codes, "idl")))
    bits = bloom.empty_filter(cfg.m)
    bits = insert_one(bits, reads[0]).block_until_ready()  # compile
    bits = bloom.empty_filter(cfg.m)
    t0 = time.perf_counter()
    for r in reads:
        bits = insert_one(bits, r)
    bits.block_until_ready()
    t_loop = time.perf_counter() - t0

    np.testing.assert_array_equal(
        np.asarray(out.bits), np.asarray(bits))            # bit-identical
    print(f"m=2^26 bits, 64 reads x 200 kmers x eta={cfg.eta}:")
    print(f"  batched donated insert (1 jit call): {t_batch * 1e3:8.1f} ms "
          f"(cold {t_batch_cold * 1e3:.1f} ms)")
    print(f"  per-read loop (64 jit calls):        {t_loop * 1e3:8.1f} ms")
    print(f"  speedup: {t_loop / t_batch:.1f}x")


if __name__ == "__main__":
    main()

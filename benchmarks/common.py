"""Shared benchmark harness utilities.

Metrics reported per paper experiment:
  * FPR           — paper's quality metric (identical definitions).
  * page-miss     — LRU cache model at 4-KiB fetch granularity (the unit of
                    the paper's locality mechanism; see DESIGN.md §2).
  * line-miss     — same model at 64-B lines (the paper's Valgrind setting).
  * block DMAs    — TPU metric: HBM→VMEM tile fetches the Pallas probe
                    kernel would issue for the trace (1 resident tile/rep).
  * wall time     — wall-clock of the jitted JAX implementation on this CPU
                    (reported for completeness; the locality effect on real
                    hardware is captured by the miss/DMA columns, which is
                    what Valgrind measured in the paper too).
"""

from __future__ import annotations

import os
import platform
import time

import jax
import numpy as np

from repro.core import cache_model


def bench_metadata() -> dict:
    """Host/build provenance stamped into every ``BENCH_*.json``.

    Scaling numbers are meaningless without the hardware they ran on —
    a 2-worker fabric on a 1-core CI box CANNOT beat one interpreter,
    and the record has to say so. First slice of the cross-arch harness
    (ROADMAP: same benches, many boxes, keyed by this metadata).
    """
    return {
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "numpy": np.__version__,
        "jax_backend": jax.default_backend(),
        "jax_device_count": jax.device_count(),
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


def overlapping_stream(pool, n_requests: int, seed: int,
                       n_regions: int = 4, read_len: int = 120,
                       region_len: int | None = None):
    """Deep-coverage shotgun stream: every request is a random window
    into one of ``n_regions`` source regions, so consecutive requests
    re-probe mostly the same kmers — the regime intra-batch dedup and
    the serving membership cache are built for. Shared by the serving
    benches so their cache-on/cache-off numbers describe one workload.

    Regions default to single pool reads; pass ``region_len`` to build
    longer loci by concatenating pool reads, so full-length
    (``read_len``-sized) requests still overlap each other instead of
    all being the same read.
    """
    rng = np.random.default_rng(seed)
    if region_len is None:
        regions = [np.asarray(pool[i % len(pool)]) for i in range(n_regions)]
    else:
        regions = []
        for i in range(n_regions):
            parts, j, total = [], i, 0
            while total < region_len:
                part = np.asarray(pool[j % len(pool)])
                parts.append(part)
                total += part.shape[0]
                j += n_regions
            regions.append(np.concatenate(parts)[:region_len])
    out = []
    for _ in range(n_requests):
        g = regions[int(rng.integers(0, n_regions))]
        s = int(rng.integers(0, max(1, g.shape[0] - read_len + 1)))
        out.append(g[s:s + read_len])
    return out


def timeit(fn, *args, repeats: int = 7, warmup: int = 2) -> float:
    """Median wall seconds of fn(*args) after jit warmup.

    Two warmup calls (the first compiles, the second settles allocator and
    cache state) and median-of-7 by default: medians of too few repeats on
    a noisy shared CPU were the dominant error in early BENCH_query.json
    numbers. Raise ``repeats`` further for sub-ms kernels.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def timeit_ms(fn, *args, repeats: int = 7, warmup: int = 2) -> float:
    """Median wall milliseconds of fn(*args) after warmup."""
    return timeit(fn, *args, repeats=repeats, warmup=warmup) * 1e3


def locality_metrics(locs: np.ndarray, L: int,
                     l1_bytes: int = 2 << 20) -> dict[str, float]:
    trace = cache_model.probe_trace_from_locations(locs)
    page_miss, _ = cache_model.two_level_miss_rates(
        trace, l1_bytes=l1_bytes, line_bytes=4096)
    line_miss, _ = cache_model.two_level_miss_rates(
        trace, l1_bytes=l1_bytes, line_bytes=64)
    dmas = cache_model.count_block_dmas_partitioned(locs, L)
    return {
        "page_miss": page_miss,
        "line_miss": line_miss,
        "dma_switches": dmas["switches"],
        "dma_per_probe": dmas["switches"] / max(dmas["accesses"], 1),
    }


class Csv:
    def __init__(self, name: str, cols: list[str]):
        self.name = name
        self.cols = cols
        print(f"\n== {name} ==")
        print(",".join(cols))

    def row(self, *vals):
        out = []
        for v in vals:
            if isinstance(v, float):
                out.append(f"{v:.6g}")
            else:
                out.append(str(v))
        print(",".join(out))

"""Serving-v2 benchmark: requests/sec and latency under ragged streams.

Drives :class:`repro.serving.service.GeneSearchService` over a bit-sliced
archive index with a mixed-length request stream (short amplicon reads up
to full-length reads, three pow2 kmer buckets) and reports:

* **throughput** — requests/sec of the steady-state stream (median wall of
  the whole stream via the hardened ``benchmarks.common.timeit`` harness);
* **latency** — per-request p50/p95 in ms (each request charged the wall
  of the batch that served it — what a caller actually waits);
* **batching** — bucket occupancy, padding waste, and the compile-once
  proof: each (bucket, backend) pair must show exactly ONE compiled
  executable after the whole ragged stream.

A ``kmer_cache`` section re-times a deep-coverage **overlapping** stream
with the versioned membership cache on vs off (parity asserted in-bench,
lifetime hit rate recorded honestly — cold misses included).

``--smoke`` (CI) runs a small config and asserts the service is
bit-identical to direct engine ``msmt`` for both the ``jnp`` and
``idl_probe`` backends — and with the membership cache on vs off (with
hit_rate > 0) — so serving can't silently drift from the engines.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]

Writes ``BENCH_serve.json`` (full mode) next to the repo root.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_metadata, overlapping_stream, timeit
from repro import obs
from repro.core import idl
from repro.data import genome
from repro.index import BitSlicedIndex, ingest, lsm
from repro.index import state as state_mod
from repro.obs import metrics as obs_metrics
from repro.serving import GeneSearchService, KmerCacheConfig, ServiceConfig
from repro.serving.live import LiveGeneSearchService


def _build_index(m: int, n_files: int, genome_len: int) -> BitSlicedIndex:
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=3, m=m)
    eng = BitSlicedIndex.build(cfg, "idl", n_files=n_files)
    archive = genome.synth_archive(n_files=n_files, genome_len=genome_len,
                                   seed=42)
    return ingest.build_archive(eng, archive, read_len=230, chunk_reads=64)


def _request_stream(archive_reads, n_requests: int, seed: int):
    """Ragged stream: read lengths drawn from amplicon-to-full mix."""
    rng = np.random.default_rng(seed)
    lens = rng.choice([70, 110, 150, 230], size=n_requests,
                      p=[0.3, 0.3, 0.2, 0.2])
    picks = rng.integers(0, len(archive_reads), size=n_requests)
    return [np.asarray(archive_reads[p][:n]) for p, n in zip(picks, lens)]


def run(m: int, n_files: int, n_requests: int, iters: int,
        backend: str) -> dict:
    eng = _build_index(m, n_files, genome_len=3_000)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000, seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    stream = _request_stream(pool, n_requests, seed=7)
    svc = GeneSearchService(eng, ServiceConfig(backend=backend, max_batch=16))

    def serve_stream():
        svc.search(stream)
        return svc.state.words[0]          # block target for the harness

    stream_s = timeit(serve_stream, repeats=iters, warmup=2)
    lat = np.asarray(svc.request_latencies_ms()[-n_requests:])
    buckets = sorted({s.bucket for s in svc.batch_stats})
    compiles = svc.compile_counts()
    assert all(c == 1 for c in compiles.values()), (
        f"a bucket recompiled: {compiles}")
    return {
        "config": {
            "engine": "bitsliced", "scheme": "idl", "m": m,
            "n_files": n_files, "n_requests": n_requests,
            "backend": backend, "max_batch": 16,
            "device": jax.default_backend(),
        },
        "throughput_rps": round(n_requests / stream_s, 1),
        "latency_ms": {
            "p50": round(float(np.percentile(lat, 50)), 3),
            "p95": round(float(np.percentile(lat, 95)), 3),
            "mean": round(float(lat.mean()), 3),
        },
        "batching": {
            "buckets": buckets,
            "compiles_per_bucket": {str(b): c for b, c in compiles.items()},
            "occupancy": round(svc.occupancy(), 3),
            "batches": len(svc.batch_stats),
        },
    }


def run_cache(m: int, n_files: int, n_requests: int, iters: int) -> dict:
    """Membership cache on vs off over a deep-coverage overlapping stream.

    Parity is asserted in-bench before anything is timed (cache on ==
    cache off, bit for bit), and the reported hit rate is the cache's
    lifetime counter — cold-start misses included, nothing reset
    between passes.
    """
    eng = _build_index(m, n_files, genome_len=3_000)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000, seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    stream = overlapping_stream(pool, n_requests, seed=11,
                                read_len=230, region_len=600)

    plain = GeneSearchService(eng, ServiceConfig(max_batch=32))
    cached = GeneSearchService(
        eng, ServiceConfig(max_batch=32,
                           kmer_cache=KmerCacheConfig(capacity=1 << 17)))
    for a, b in zip(plain.search(stream), cached.search(stream)):
        np.testing.assert_array_equal(np.asarray(a.matches),
                                      np.asarray(b.matches))

    def serve(svc):
        def f():
            svc.search(stream)
            return svc.state.words[0]
        return f

    off_s = timeit(serve(plain), repeats=iters, warmup=1)
    off_p50 = float(np.percentile(
        np.asarray(plain.request_latencies_ms()[-n_requests:]), 50))
    on_s = timeit(serve(cached), repeats=iters, warmup=1)
    on_p50 = float(np.percentile(
        np.asarray(cached.request_latencies_ms()[-n_requests:]), 50))
    st = cached.cache_stats()
    assert st["hits"] > 0, st
    assert all(c == 1 for c in cached.compile_counts().values())
    return {
        "config": {
            "engine": "bitsliced", "scheme": "idl", "m": m,
            "n_files": n_files, "n_requests": n_requests,
            "stream": ("overlapping read_len=230 windows into 4 "
                       "concatenated 600bp regions"),
            "max_batch": 32, "cache_capacity": 1 << 17,
            "device": jax.default_backend(),
        },
        "throughput_rps": {
            "cache_off": round(n_requests / off_s, 1),
            "cache_on": round(n_requests / on_s, 1),
        },
        "latency_p50_ms": {
            "cache_off": round(off_p50, 3),
            "cache_on": round(on_p50, 3),
        },
        "speedup": round(off_s / on_s, 2),
        "hit_rate": round(st["hit_rate"], 4),
        "cache": st,
        "note": ("parity asserted in-bench before timing (cache on == "
                 "cache off, bit for bit); hit_rate is the cache's "
                 "lifetime counter — cold-start misses included"),
    }


def run_obs_overhead(m: int, n_files: int, n_requests: int,
                     iters: int) -> dict:
    """Observability tax: the same ragged stream served with the obs
    plane on (default) vs off (``obs.set_enabled(False)`` — every bound
    handle becomes a branch-and-return no-op).

    Parity is asserted BEFORE anything is timed: obs-on answers are bit-
    identical to obs-off answers, so instrumentation provably cannot
    change results. The overhead target (< 5% throughput) is the
    documented contract for every serving tier; it is asserted here so a
    hot-path regression fails the bench, not a production stream.
    """
    eng = _build_index(m, n_files, genome_len=3_000)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000,
                                   seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    stream = _request_stream(pool, n_requests, seed=7)
    # idl_probe so the timed path includes the planner's locality
    # recording — the heaviest per-batch instrumentation we ship
    svc = GeneSearchService(eng, ServiceConfig(backend="idl_probe",
                                               max_batch=16))

    obs.set_enabled(True)
    res_on = svc.search(stream)
    obs.set_enabled(False)
    res_off = svc.search(stream)
    obs.set_enabled(True)
    for a, b in zip(res_on, res_off):
        np.testing.assert_array_equal(np.asarray(a.matches),
                                      np.asarray(b.matches))

    def pass_s(enabled: bool):
        """One timed pass: (wall_s, cpu_s). CPU time drives the overhead
        ratio (immune to preemption by other processes — on a contended
        box single-pass wall carries ~±10% scheduler jitter that no
        amount of pairing fully cancels); wall reports throughput."""
        obs.set_enabled(enabled)
        w0 = time.perf_counter()
        c0 = time.process_time()
        svc.search(stream)
        _ = svc.state.words[0]
        return time.perf_counter() - w0, time.process_time() - c0

    # paired interleaved protocol: this box's wall clock drifts 2-3x
    # between runs, so back-to-back on/off pairs (whose drift cancels)
    # are the only honest estimator at this timescale — never
    # on-block-then-off-block. ONE service instance serves both sides
    # (set_enabled flips live handles), so instance asymmetry (compile
    # caches, memory layout) can't read as overhead, pair order
    # alternates to cancel order bias, and GC is held off so collection
    # pauses don't land on one side. The estimator is a 20%-trimmed
    # mean of per-pair CPU-time ratios; on a breach one extra round is
    # pooled in before judging, so a single unlucky batch of pairs
    # can't fail a hot path whose true cost is microbenchmark-verified
    # at a few microseconds per request.
    import gc

    def measure_pairs(n_pairs: int) -> list:
        out = []
        gc.collect()
        gc.disable()
        try:
            for i in range(n_pairs):
                if i % 2 == 0:
                    on_i = pass_s(True)
                    off_i = pass_s(False)
                else:
                    off_i = pass_s(False)
                    on_i = pass_s(True)
                out.append((on_i, off_i))
        finally:
            gc.enable()
            obs.set_enabled(True)
        return out

    def trimmed_mean_ratio(pairs: list) -> float:
        ratios = sorted(on_i[1] / off_i[1] for on_i, off_i in pairs)
        k = len(ratios) // 5
        kept = ratios[k:len(ratios) - k] if len(ratios) > 2 * k else ratios
        return sum(kept) / len(kept)

    for _ in range(4):                           # warm
        pass_s(True)
        pass_s(False)
    pairs = measure_pairs(max(7 * iters, 21))
    overhead_pct = 100.0 * (trimmed_mean_ratio(pairs) - 1.0)
    if overhead_pct >= 5.0:                      # pool one re-measure round
        pairs += measure_pairs(max(7 * iters, 21))
        overhead_pct = 100.0 * (trimmed_mean_ratio(pairs) - 1.0)
    on_s = float(np.median([p[0][0] for p in pairs]))
    off_s = float(np.median([p[1][0] for p in pairs]))
    assert overhead_pct < 5.0, (
        f"obs overhead {overhead_pct:.2f}% breached the 5% contract "
        f"(trimmed-mean paired CPU ratio over {len(pairs)} pairs; "
        f"wall on={on_s:.4f}s off={off_s:.4f}s)")
    return {
        "config": {
            "engine": "bitsliced", "scheme": "idl", "m": m,
            "n_files": n_files, "n_requests": n_requests,
            "backend": "idl_probe", "max_batch": 16,
            "device": jax.default_backend(),
        },
        "throughput_rps": {
            "obs_on": round(n_requests / on_s, 1),
            "obs_off": round(n_requests / off_s, 1),
        },
        "overhead_pct": round(overhead_pct, 2),
        "target_pct": 5.0,
        "note": ("parity asserted in-bench before timing (obs-on answers "
                 "== obs-off, bit for bit); overhead is a trimmed mean of "
                 "back-to-back paired on/off CPU-time ratios on one "
                 "service (drift- and preemption-immune), negative = "
                 "noise floor; throughput_rps reports wall medians"),
    }


def run_live_locality(m: int, n_files: int, n_requests: int) -> dict:
    """Live IDL vs RH locality, measured at the serving source.

    Two identical live services (base + delta, ``idl_probe`` backend)
    serve the SAME interleaved stream of queries and writes — one over an
    IDL-hashed index, one over RH. The planner records the planned tile
    bytes of every executed batch into the obs registry
    (``locality.planned_tile_bytes``), so the ratio below is what the
    serving stack actually planned to fetch, not an offline model: IDL's
    co-located probes coalesce into a few long runs where RH scatters
    into near-one-probe runs.
    """
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000,
                                   seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    stream = _request_stream(pool, n_requests, seed=7)
    per = {}
    for scheme in ("idl", "rh"):
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=3, m=m)
        eng = BitSlicedIndex.build(cfg, scheme, n_files=n_files)
        eng = ingest.build_archive(eng, archive, read_len=230,
                                   chunk_reads=64)
        svc = LiveGeneSearchService(
            lsm.LiveIndex(state_mod.from_engine(eng)),
            ServiceConfig(backend="idl_probe", max_batch=16))
        obs_metrics.reset()
        # a LIVE stream: writes land between query batches, so the
        # recorded locality includes post-write (base + delta) probes
        step = max(1, len(stream) // 4)
        for i, lo in enumerate(range(0, len(stream), step)):
            svc.search(stream[lo:lo + step])
            src = archive[i % n_files]
            svc.apply_insert(np.asarray(src.genome)[None, :230],
                             np.asarray([src.file_id], dtype=np.int32))
        snap = obs_metrics.DEFAULT.snapshot()
        where = {"scheme": scheme, "op": "query"}
        tb = obs_metrics.counter_total(
            snap, "locality.planned_tile_bytes", where=where)
        probes = obs_metrics.counter_total(
            snap, "locality.probes", where=where)
        per[scheme] = {
            "planned_tile_bytes": int(tb),
            "probes": int(probes),
            "probe_runs": int(obs_metrics.counter_total(
                snap, "locality.probe_runs", where=where)),
            "batches": int(obs_metrics.counter_total(
                snap, "locality.batches", where=where)),
            "bytes_per_probe": round(tb / max(probes, 1), 1),
        }
    ratio = (per["rh"]["planned_tile_bytes"]
             / per["idl"]["planned_tile_bytes"])
    assert ratio >= 2.0, (
        f"live IDL should touch >= 2x fewer planned tile bytes than RH, "
        f"got {ratio:.2f}x: {per}")
    return {
        "config": {
            "engine": "bitsliced", "m": m, "n_files": n_files,
            "n_requests": n_requests, "backend": "idl_probe",
            "max_batch": 16, "writes": 4,
            "device": jax.default_backend(),
        },
        "idl": per["idl"],
        "rh": per["rh"],
        "tile_bytes_ratio_rh_over_idl": round(ratio, 2),
        "note": ("same interleaved query+write stream over live (base + "
                 "delta) services; tile bytes read from the obs "
                 "registry's locality.planned_tile_bytes counters — the "
                 "bytes the executed probe plans fetch, per scheme"),
    }


def _assert_parity(m: int) -> None:
    """Service answers == direct engine msmt, jnp and idl_probe backends."""
    eng = _build_index(m, n_files=16, genome_len=1_200)
    archive = genome.synth_archive(n_files=16, genome_len=1_200, seed=42)
    stream = _request_stream([f.reads(230, 2)[0] for f in archive], 12,
                             seed=3)
    for backend in ("jnp", "idl_probe"):
        svc = GeneSearchService(eng, ServiceConfig(backend=backend,
                                                   max_batch=4))
        for q, res in zip(stream, svc.search(stream)):
            want = np.asarray(eng.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)
        assert all(c == 1 for c in svc.compile_counts().values())
    print("parity: service == engine msmt (jnp, idl_probe); "
          "one compile per bucket")


def _assert_cache_parity(m: int) -> None:
    """Cache on == cache off on an overlapping stream, hits observed."""
    eng = _build_index(m, n_files=16, genome_len=1_200)
    archive = genome.synth_archive(n_files=16, genome_len=1_200, seed=42)
    pool = [f.reads(230, 2)[0] for f in archive]
    stream = overlapping_stream(pool, 24, seed=11)
    plain = GeneSearchService(eng, ServiceConfig(max_batch=4))
    cached = GeneSearchService(
        eng, ServiceConfig(max_batch=4,
                           kmer_cache=KmerCacheConfig(capacity=1 << 14)))
    for _ in range(2):                 # pass 2 answers from cached rows
        for a, b in zip(plain.search(stream), cached.search(stream)):
            np.testing.assert_array_equal(np.asarray(a.matches),
                                          np.asarray(b.matches))
    st = cached.cache_stats()
    assert st["hits"] > 0 and st["hit_rate"] > 0, st
    assert all(c == 1 for c in cached.compile_counts().values())
    print(f"cache parity: membership cache on == off (bit-identical); "
          f"hit_rate={st['hit_rate']:.2f} > 0; one compile per bucket")


def _assert_obs_smoke(m: int) -> None:
    """Obs-on answers == obs-off answers on a small stream, and the obs
    plane actually records: pipeline spans emitted, locality counters
    fed by the planner."""
    from repro.obs import trace as obs_trace
    eng = _build_index(m, n_files=16, genome_len=1_200)
    archive = genome.synth_archive(n_files=16, genome_len=1_200, seed=42)
    stream = _request_stream([f.reads(230, 2)[0] for f in archive], 12,
                             seed=3)
    svc_cfg = ServiceConfig(backend="idl_probe", max_batch=4)
    obs.reset()
    on = GeneSearchService(eng, svc_cfg).search(stream)
    snap = obs_metrics.DEFAULT.snapshot()
    assert obs_metrics.counter_total(
        snap, "serving.requests") >= len(stream), snap["counters"]
    assert obs_metrics.counter_total(
        snap, "locality.planned_tile_bytes", where={"op": "query"}) > 0
    names = {r["name"] for r in obs_trace.DEFAULT.records()}
    assert {"request", "queue_wait", "assemble", "execute",
            "finalize"} <= names, names
    obs.set_enabled(False)
    try:
        off = GeneSearchService(eng, svc_cfg).search(stream)
    finally:
        obs.set_enabled(True)
    for a, b in zip(on, off):
        np.testing.assert_array_equal(np.asarray(a.matches),
                                      np.asarray(b.matches))
    print("obs smoke: obs-on == obs-off (bit-identical); pipeline spans "
          "+ locality counters recorded")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small config; assert parity; no JSON written")
    args = ap.parse_args()

    if args.smoke:
        _assert_parity(m=1 << 18)
        _assert_cache_parity(m=1 << 18)
        _assert_obs_smoke(m=1 << 18)
        res = run(m=1 << 18, n_files=16, n_requests=24, iters=2,
                  backend="jnp")
        print("smoke:", json.dumps(res["latency_ms"]))
        return

    _assert_parity(m=1 << 20)
    _assert_cache_parity(m=1 << 20)
    _assert_obs_smoke(m=1 << 20)
    res = {
        backend: run(m=1 << 21, n_files=64, n_requests=96, iters=3,
                     backend=backend)
        for backend in ("jnp", "idl_probe")
    }
    res["kmer_cache"] = run_cache(m=1 << 21, n_files=256, n_requests=192,
                                  iters=3)
    res["obs_overhead"] = run_obs_overhead(m=1 << 20, n_files=256,
                                           n_requests=96, iters=3)
    res["live_locality"] = run_live_locality(m=1 << 20, n_files=32,
                                             n_requests=64)
    res["host"] = bench_metadata()
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    out_path.write_text(json.dumps(res, indent=2) + "\n")
    print(json.dumps(res, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

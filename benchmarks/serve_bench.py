"""Serving-v2 benchmark: requests/sec and latency under ragged streams.

Drives :class:`repro.serving.service.GeneSearchService` over a bit-sliced
archive index with a mixed-length request stream (short amplicon reads up
to full-length reads, three pow2 kmer buckets) and reports:

* **throughput** — requests/sec of the steady-state stream (median wall of
  the whole stream via the hardened ``benchmarks.common.timeit`` harness);
* **latency** — per-request p50/p95 in ms (each request charged the wall
  of the batch that served it — what a caller actually waits);
* **batching** — bucket occupancy, padding waste, and the compile-once
  proof: each (bucket, backend) pair must show exactly ONE compiled
  executable after the whole ragged stream.

A ``kmer_cache`` section re-times a deep-coverage **overlapping** stream
with the versioned membership cache on vs off (parity asserted in-bench,
lifetime hit rate recorded honestly — cold misses included).

``--smoke`` (CI) runs a small config and asserts the service is
bit-identical to direct engine ``msmt`` for both the ``jnp`` and
``idl_probe`` backends — and with the membership cache on vs off (with
hit_rate > 0) — so serving can't silently drift from the engines.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]

Writes ``BENCH_serve.json`` (full mode) next to the repo root.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_metadata, overlapping_stream, timeit
from repro.core import idl
from repro.data import genome
from repro.index import BitSlicedIndex, ingest
from repro.serving import GeneSearchService, KmerCacheConfig, ServiceConfig


def _build_index(m: int, n_files: int, genome_len: int) -> BitSlicedIndex:
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=3, m=m)
    eng = BitSlicedIndex.build(cfg, "idl", n_files=n_files)
    archive = genome.synth_archive(n_files=n_files, genome_len=genome_len,
                                   seed=42)
    return ingest.build_archive(eng, archive, read_len=230, chunk_reads=64)


def _request_stream(archive_reads, n_requests: int, seed: int):
    """Ragged stream: read lengths drawn from amplicon-to-full mix."""
    rng = np.random.default_rng(seed)
    lens = rng.choice([70, 110, 150, 230], size=n_requests,
                      p=[0.3, 0.3, 0.2, 0.2])
    picks = rng.integers(0, len(archive_reads), size=n_requests)
    return [np.asarray(archive_reads[p][:n]) for p, n in zip(picks, lens)]


def run(m: int, n_files: int, n_requests: int, iters: int,
        backend: str) -> dict:
    eng = _build_index(m, n_files, genome_len=3_000)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000, seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    stream = _request_stream(pool, n_requests, seed=7)
    svc = GeneSearchService(eng, ServiceConfig(backend=backend, max_batch=16))

    def serve_stream():
        svc.search(stream)
        return svc.state.words[0]          # block target for the harness

    stream_s = timeit(serve_stream, repeats=iters, warmup=2)
    lat = np.asarray(svc.request_latencies_ms()[-n_requests:])
    buckets = sorted({s.bucket for s in svc.batch_stats})
    compiles = svc.compile_counts()
    assert all(c == 1 for c in compiles.values()), (
        f"a bucket recompiled: {compiles}")
    return {
        "config": {
            "engine": "bitsliced", "scheme": "idl", "m": m,
            "n_files": n_files, "n_requests": n_requests,
            "backend": backend, "max_batch": 16,
            "device": jax.default_backend(),
        },
        "throughput_rps": round(n_requests / stream_s, 1),
        "latency_ms": {
            "p50": round(float(np.percentile(lat, 50)), 3),
            "p95": round(float(np.percentile(lat, 95)), 3),
            "mean": round(float(lat.mean()), 3),
        },
        "batching": {
            "buckets": buckets,
            "compiles_per_bucket": {str(b): c for b, c in compiles.items()},
            "occupancy": round(svc.occupancy(), 3),
            "batches": len(svc.batch_stats),
        },
    }


def run_cache(m: int, n_files: int, n_requests: int, iters: int) -> dict:
    """Membership cache on vs off over a deep-coverage overlapping stream.

    Parity is asserted in-bench before anything is timed (cache on ==
    cache off, bit for bit), and the reported hit rate is the cache's
    lifetime counter — cold-start misses included, nothing reset
    between passes.
    """
    eng = _build_index(m, n_files, genome_len=3_000)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000, seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    stream = overlapping_stream(pool, n_requests, seed=11,
                                read_len=230, region_len=600)

    plain = GeneSearchService(eng, ServiceConfig(max_batch=32))
    cached = GeneSearchService(
        eng, ServiceConfig(max_batch=32,
                           kmer_cache=KmerCacheConfig(capacity=1 << 17)))
    for a, b in zip(plain.search(stream), cached.search(stream)):
        np.testing.assert_array_equal(np.asarray(a.matches),
                                      np.asarray(b.matches))

    def serve(svc):
        def f():
            svc.search(stream)
            return svc.state.words[0]
        return f

    off_s = timeit(serve(plain), repeats=iters, warmup=1)
    off_p50 = float(np.percentile(
        np.asarray(plain.request_latencies_ms()[-n_requests:]), 50))
    on_s = timeit(serve(cached), repeats=iters, warmup=1)
    on_p50 = float(np.percentile(
        np.asarray(cached.request_latencies_ms()[-n_requests:]), 50))
    st = cached.cache_stats()
    assert st["hits"] > 0, st
    assert all(c == 1 for c in cached.compile_counts().values())
    return {
        "config": {
            "engine": "bitsliced", "scheme": "idl", "m": m,
            "n_files": n_files, "n_requests": n_requests,
            "stream": ("overlapping read_len=230 windows into 4 "
                       "concatenated 600bp regions"),
            "max_batch": 32, "cache_capacity": 1 << 17,
            "device": jax.default_backend(),
        },
        "throughput_rps": {
            "cache_off": round(n_requests / off_s, 1),
            "cache_on": round(n_requests / on_s, 1),
        },
        "latency_p50_ms": {
            "cache_off": round(off_p50, 3),
            "cache_on": round(on_p50, 3),
        },
        "speedup": round(off_s / on_s, 2),
        "hit_rate": round(st["hit_rate"], 4),
        "cache": st,
        "note": ("parity asserted in-bench before timing (cache on == "
                 "cache off, bit for bit); hit_rate is the cache's "
                 "lifetime counter — cold-start misses included"),
    }


def _assert_parity(m: int) -> None:
    """Service answers == direct engine msmt, jnp and idl_probe backends."""
    eng = _build_index(m, n_files=16, genome_len=1_200)
    archive = genome.synth_archive(n_files=16, genome_len=1_200, seed=42)
    stream = _request_stream([f.reads(230, 2)[0] for f in archive], 12,
                             seed=3)
    for backend in ("jnp", "idl_probe"):
        svc = GeneSearchService(eng, ServiceConfig(backend=backend,
                                                   max_batch=4))
        for q, res in zip(stream, svc.search(stream)):
            want = np.asarray(eng.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)
        assert all(c == 1 for c in svc.compile_counts().values())
    print("parity: service == engine msmt (jnp, idl_probe); "
          "one compile per bucket")


def _assert_cache_parity(m: int) -> None:
    """Cache on == cache off on an overlapping stream, hits observed."""
    eng = _build_index(m, n_files=16, genome_len=1_200)
    archive = genome.synth_archive(n_files=16, genome_len=1_200, seed=42)
    pool = [f.reads(230, 2)[0] for f in archive]
    stream = overlapping_stream(pool, 24, seed=11)
    plain = GeneSearchService(eng, ServiceConfig(max_batch=4))
    cached = GeneSearchService(
        eng, ServiceConfig(max_batch=4,
                           kmer_cache=KmerCacheConfig(capacity=1 << 14)))
    for _ in range(2):                 # pass 2 answers from cached rows
        for a, b in zip(plain.search(stream), cached.search(stream)):
            np.testing.assert_array_equal(np.asarray(a.matches),
                                          np.asarray(b.matches))
    st = cached.cache_stats()
    assert st["hits"] > 0 and st["hit_rate"] > 0, st
    assert all(c == 1 for c in cached.compile_counts().values())
    print(f"cache parity: membership cache on == off (bit-identical); "
          f"hit_rate={st['hit_rate']:.2f} > 0; one compile per bucket")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small config; assert parity; no JSON written")
    args = ap.parse_args()

    if args.smoke:
        _assert_parity(m=1 << 18)
        _assert_cache_parity(m=1 << 18)
        res = run(m=1 << 18, n_files=16, n_requests=24, iters=2,
                  backend="jnp")
        print("smoke:", json.dumps(res["latency_ms"]))
        return

    _assert_parity(m=1 << 20)
    _assert_cache_parity(m=1 << 20)
    res = {
        backend: run(m=1 << 21, n_files=64, n_requests=96, iters=3,
                     backend=backend)
        for backend in ("jnp", "idl_probe")
    }
    res["kmer_cache"] = run_cache(m=1 << 21, n_files=256, n_requests=192,
                                  iters=3)
    res["host"] = bench_metadata()
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    out_path.write_text(json.dumps(res, indent=2) + "\n")
    print(json.dumps(res, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

"""Ingest-path benchmark: the shared InsertPlan backends vs the seed loop.

Mirror of ``query_batch_bench.py`` for the write side of the acceptance
criteria: 64 reads × 200 kmers inserted into a partitioned IDL-BF at
m=2^26, measured per backend of :mod:`repro.index.ingest`:

* ``per_read_loop`` — the seed semantics: one jit'd full-array
  ``bf.at[locs].set(1)`` copy per read;
* ``jnp``        — ONE jit-compiled, donated, sort-dedup'd scatter for the
  whole batch (the single body that replaced the three packed.py scatters);
* ``idl_insert`` — the planned backend: host sort/dedup/run-length planner
  + the generalized run-coalesced ``insert_runs`` executor (the Pallas
  kernel on accelerators; its fused jnp oracle on CPU, where Mosaic is
  unavailable — same plan, bit-identical);
* ``sharded``    — collective-free ``shard_map`` over the default 1-D mesh.

Also reports the insert planner's locality metrics — run count, touched
tiles, mean run length and DMA bytes (2 × n_tiles × tile_bytes: each
touched block is read+written once per batch, the TPU HBM-traffic / CPU
cache-miss proxy the paper minimizes) — for IDL vs the RH baseline, plus
the wall time of a streaming ``build_archive`` over a synthetic archive.

    PYTHONPATH=src python -m benchmarks.ingest_bench [--smoke]

Writes ``BENCH_ingest.json`` (full mode) next to the repo root.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_metadata, timeit, timeit_ms
from repro.core import bloom, idl
from repro.data import genome
from repro.index import BitSlicedIndex, PackedBloomIndex, ingest, registry


def _cpu_executor_kw() -> dict:
    # no Mosaic target on CPU: execute the SAME plan with the kernel's
    # fused jnp oracle instead of the (python-stepped) Pallas interpreter
    return {"use_ref": True} if jax.default_backend() == "cpu" else {}


def run(m: int, n_reads: int, iters: int, archive_files: int) -> dict:
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=m)
    rng = np.random.default_rng(0)
    reads = jnp.asarray(rng.integers(0, 4, size=(n_reads, 230), dtype=np.uint8))

    want = np.asarray(
        PackedBloomIndex.build(cfg, "idl").insert_batch(reads).words)

    def bench_backend(backend: str, **kw) -> float:
        def body():
            # build-from-empty each call: inserts donate the destination
            return PackedBloomIndex.build(cfg, "idl").insert_batch(
                reads, backend=backend, **kw).words

        np.testing.assert_array_equal(np.asarray(body()), want)
        return timeit_ms(body, repeats=iters)

    # seed semantics: one jit'd full-array uint8 scatter-set per read
    insert_one = jax.jit(
        lambda bits, codes: bloom.insert_locations(
            bits, registry.locations(cfg, codes, "idl")))

    def per_read_loop():
        bits = bloom.empty_filter(cfg.m)
        for r in reads:
            bits = insert_one(bits, r)
        return bits

    timings = {
        "per_read_loop": timeit_ms(per_read_loop, repeats=max(iters // 4, 2)),
        "jnp": bench_backend("jnp"),
        "idl_insert": bench_backend("idl_insert", **_cpu_executor_kw()),
        "sharded": bench_backend("sharded"),
    }

    # Planner locality, in two regimes. The planner dedups + sorts each
    # batch, so its tile count is that batch's *spatial footprint*:
    #  * "stream_chunks" — one genome file built the way build_archive
    #    streams it: window batches of 8, one plan per chunk, DMA summed
    #    over the build. A small chunk's kmers share few MinHash windows,
    #    so IDL's footprint stays tiny while RH scatters every chunk over
    #    ~every tile: this is the paper's ingest-locality claim, at the
    #    granularity the streaming builder actually executes.
    #  * "full_batch" — all n_reads reads planned as ONE batch: enough
    #    deduped inserts to touch ~all m/L tiles under BOTH schemes.
    #    Reported honestly: the sorted single-launch planner makes a
    #    full-batch build DMA-near-optimal for ANY scheme (each touched
    #    tile is read+written once), which is itself an ingest win.
    file_windows = genome.window_reads(
        genome.synthesize_genome(
            230 + 200 * (n_reads - 1), seed=5, repeat_fraction=0.0),
        230, cfg.k)
    chunks = [jnp.asarray(file_windows[i:i + 8])
              for i in range(0, len(file_windows), 8)]
    locality = {"stream_chunks": {}, "full_batch": {}}
    for scheme in ("idl", "rh"):
        agg = {"n_runs": 0, "n_tiles": 0, "n_locs": 0,
               "planner_dma_bytes": 0}
        for chunk in chunks:
            plan = ingest.plan_insert(
                cfg, scheme, tuple(chunk.shape), (cfg.m // 32, 1),
                kind="bits")
            rplan = plan.plan_runs(chunk)
            agg["n_runs"] += rplan.n_runs
            agg["n_tiles"] += rplan.n_tiles
            agg["n_locs"] += rplan.n_locs
            agg["planner_dma_bytes"] += plan.run_dma_bytes(rplan)
        agg["mean_run_len"] = round(agg["n_locs"] / agg["n_runs"], 2)
        locality["stream_chunks"][scheme] = agg

        plan = ingest.plan_insert(
            cfg, scheme, tuple(reads.shape), (cfg.m // 32, 1), kind="bits")
        rplan = plan.plan_runs(reads)
        locality["full_batch"][scheme] = {
            "n_runs": int(rplan.n_runs),
            "n_tiles": int(rplan.n_tiles),
            "n_locs": int(rplan.n_locs),
            "mean_run_len": round(rplan.n_locs / rplan.n_runs, 2),
            "planner_dma_bytes": int(plan.run_dma_bytes(rplan)),
        }

    # streaming archive build (bit-sliced serving layout, jnp backend)
    archive = genome.synth_archive(
        n_files=archive_files, genome_len=2000, seed=7)
    acfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=3, m=1 << 20)

    def build():
        eng = BitSlicedIndex.build(acfg, "idl", n_files=archive_files)
        return ingest.build_archive(eng, archive, read_len=230,
                                    chunk_reads=n_reads).words

    archive_s = timeit(build, repeats=max(iters // 8, 2), warmup=1)
    archive_kmers = sum(f.n_kmers for f in archive)

    plan = ingest.plan_insert(
        cfg, "idl", tuple(reads.shape), (cfg.m // 32, 1), kind="bits")
    out = {
        "config": {
            "m": m, "L": cfg.L, "eta": cfg.eta, "n_reads": n_reads,
            "read_len": 230, "n_kmers": 200, "scheme": "idl",
            "device": jax.default_backend(),
            "tile_bytes": plan.block_bytes,
        },
        "ms_per_batch": {k: round(v, 3) for k, v in timings.items()},
        "ms_per_read": {k: round(v / n_reads, 4) for k, v in timings.items()},
        "planner_locality": locality,
        "archive_build": {
            "n_files": archive_files, "genome_len": 2000,
            "total_kmers": int(archive_kmers),
            "wall_s": round(archive_s, 3),
            "kmers_per_s": int(archive_kmers / archive_s),
        },
        "speedups": {
            "batched_jnp_vs_per_read_loop": round(
                timings["per_read_loop"] / timings["jnp"], 2),
            "planned_vs_per_read_loop": round(
                timings["per_read_loop"] / timings["idl_insert"], 2),
            "planned_vs_batched_jnp": round(
                timings["jnp"] / timings["idl_insert"], 2),
            "idl_vs_rh_run_reduction_stream": round(
                locality["stream_chunks"]["rh"]["n_runs"]
                / locality["stream_chunks"]["idl"]["n_runs"], 2),
            "idl_vs_rh_dma_reduction_stream": round(
                locality["stream_chunks"]["rh"]["planner_dma_bytes"]
                / locality["stream_chunks"]["idl"]["planner_dma_bytes"], 2),
            "idl_vs_rh_dma_reduction_full_batch": round(
                locality["full_batch"]["rh"]["planner_dma_bytes"]
                / locality["full_batch"]["idl"]["planner_dma_bytes"], 2),
        },
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small config; assert backend parity; no JSON")
    args = ap.parse_args()

    if args.smoke:
        res = run(m=1 << 20, n_reads=8, iters=2, archive_files=4)
        print("smoke:", json.dumps(res["ms_per_batch"]))
        loc = res["planner_locality"]["stream_chunks"]
        print("stream-chunk tiles idl/rh:",
              loc["idl"]["n_tiles"], loc["rh"]["n_tiles"])
        return

    res = run(m=1 << 26, n_reads=64, iters=9, archive_files=32)
    res["host"] = bench_metadata()
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_ingest.json"
    out_path.write_text(json.dumps(res, indent=2) + "\n")
    print(json.dumps(res, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

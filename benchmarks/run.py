"""Benchmark harness: one function per paper table/figure + the roofline
report. ``PYTHONPATH=src python -m benchmarks.run [name ...]``"""

import sys

from benchmarks import paper_tables, roofline_report


def main() -> None:
    names = sys.argv[1:]
    table = {fn.__name__: fn for fn in paper_tables.ALL}
    table["roofline"] = roofline_report.report
    run = names or list(table)
    for name in run:
        if name not in table:
            print(f"unknown benchmark {name!r}; have {sorted(table)}")
            sys.exit(2)
        table[name]()


if __name__ == "__main__":
    main()

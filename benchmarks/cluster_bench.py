"""Serving-cluster benchmark: sync service vs async scheduler vs router.

Drives the same ragged Poisson request stream through three serving tiers
over one bit-sliced archive index, with *serving* semantics: a caller
wants each answer as its request completes, not at end-of-stream.

* **sync** — the PR-4 :class:`GeneSearchService` as a synchronous server.
  With no background flusher, a sync caller only gets timely results by
  flushing per request (otherwise results appear at end-of-stream — an
  unbounded latency, not serving). Configured at its per-request optimum
  (``max_batch=1``: the smallest compiled step per bucket).
* **async** — one :class:`AsyncScheduler` over the same index: futures +
  deadline flusher + double-buffered pipeline. Requests batch up to 16
  WITHOUT blocking the caller — the thing a synchronous API cannot do.
* **router** — :class:`ReplicaRouter` over 2 scheduler-fronted
  ``IndexState`` replicas (closed-loop rps recorded for every routing
  policy).

Metrics: closed-loop **throughput** (requests/sec to answer the whole
stream, median via ``benchmarks.common.timeit``) and open-loop **latency**
(p50/p99 of completion − *scheduled* Poisson arrival at a fixed offered
rate — coordinated-omission-safe, so a tier that falls behind the arrival
process shows its real queueing delay).

Context for reading the numbers: this CI box has 2 cores and one XLA:CPU
device, where concurrent replica steps contend (device execution is
in-order per device); the async tier therefore tops closed-loop
throughput and the router's replica scaling pays off only on multi-core /
multi-device hosts. The acceptance bar — router ≥ 1.5x the synchronous
single service on the same stream — holds with a wide margin because the
cluster tiers batch; the sync tier cannot.

``--smoke`` (CI) asserts the cluster cannot drift from the engines: the
router answers bit-identically to a direct single-service run across
2 engines × {idl, rh} schemes, with compile counts per (bucket, backend)
== 1 per replica, plus a live hot-swap with zero dropped futures.

``--procs N`` switches to the **process fabric** benchmark: the same
stream served by :class:`ProcessFabric` fleets of 1..N mmap-booted worker
processes behind one gateway, recorded as a per-worker-count scaling
curve against the in-process router baseline, with gateway-vs-in-process
parity and a zero-drop rolling restart asserted in-bench. A
``kmer_cache`` section re-serves a deep-coverage overlapping stream with
per-worker membership caches off vs on (parity asserted for both fleets,
gateway-merged hit rate > 0 asserted). Writes ``BENCH_fabric.json`` (in
``--smoke`` too — CI uploads it; the smoke record is marked
``"smoke": true``).

    PYTHONPATH=src python -m benchmarks.cluster_bench [--smoke]
    PYTHONPATH=src python -m benchmarks.cluster_bench --procs 2 [--smoke]

Writes ``BENCH_cluster.json`` (full mode) next to the repo root.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_metadata, overlapping_stream, timeit
from repro.core import idl
from repro.data import genome
from repro.index import BitSlicedIndex, CobsIndex, ingest, store
from repro.serving import (
    AsyncScheduler,
    FabricConfig,
    GeneSearchService,
    KmerCacheConfig,
    ProcessFabric,
    ReplicaRouter,
    RouterConfig,
    SchedulerConfig,
    ServiceConfig,
)


def _build_index(m: int, n_files: int, genome_len: int) -> BitSlicedIndex:
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=3, m=m)
    eng = BitSlicedIndex.build(cfg, "idl", n_files=n_files)
    archive = genome.synth_archive(n_files=n_files, genome_len=genome_len,
                                   seed=42)
    return ingest.build_archive(eng, archive, read_len=230, chunk_reads=64)


def _poisson_stream(archive_reads, n_requests: int, rps: float, seed: int):
    """Ragged lengths + exponential inter-arrival gaps (open-loop replay)."""
    rng = np.random.default_rng(seed)
    lens = rng.choice([70, 110, 150, 230], size=n_requests,
                      p=[0.3, 0.3, 0.2, 0.2])
    picks = rng.integers(0, len(archive_reads), size=n_requests)
    gaps = rng.exponential(1.0 / rps, size=n_requests)
    reads = [np.asarray(archive_reads[p][:n]) for p, n in zip(picks, lens)]
    return reads, gaps


class _Tier:
    """Uniform closed-loop / paced-replay facade over the serving tiers."""

    def __init__(self, kind: str, eng, backend: str, n_replicas: int = 2,
                 policy: str = "round_robin"):
        self.kind = kind
        if kind == "sync":
            # per-request flush is how a synchronous caller actually gets
            # answers under live traffic; max_batch=1 is its best config
            # (smallest compiled step — padding 15/16 rows would be worse)
            self.svc = GeneSearchService(
                eng, ServiceConfig(backend=backend, max_batch=1))
        elif kind == "async":
            self.sched = AsyncScheduler(
                GeneSearchService(
                    eng, ServiceConfig(backend=backend, max_batch=16)),
                SchedulerConfig(max_delay_ms=2.0))
        elif kind == "router":
            self.router = ReplicaRouter(
                eng, ServiceConfig(backend=backend, max_batch=16),
                RouterConfig(n_replicas=n_replicas, policy=policy,
                             scheduler=SchedulerConfig(max_delay_ms=2.0)))
        else:
            raise KeyError(kind)

    # -- closed loop: answer the whole stream as fast as possible ----------
    def serve_closed_loop(self, stream) -> None:
        if self.kind == "sync":
            svc = self.svc
            for q in stream:
                svc.result(svc.submit(q))      # auto-flush at max_batch=1
            return
        target = self.sched if self.kind == "async" else self.router
        futures = [target.submit(q) for q in stream]
        target.drain()
        for f in futures:
            f.result()

    # -- open loop: Poisson replay at the offered rate ----------------------
    def serve_paced(self, stream, gaps) -> np.ndarray:
        """Per-request latency (ms) = completion - SCHEDULED arrival.

        Scheduled (not actual) arrivals avoid coordinated omission: a tier
        that falls behind the Poisson process is charged its queueing
        delay instead of silently slowing the arrival clock.
        """
        lat = np.zeros(len(stream))
        t0 = time.perf_counter()
        sched_t = t0
        if self.kind == "sync":
            svc = self.svc
            for i, (q, gap) in enumerate(zip(stream, gaps)):
                sched_t += gap
                now = time.perf_counter()
                if now < sched_t:
                    time.sleep(sched_t - now)
                svc.result(svc.submit(q))      # executes inline
                lat[i] = (time.perf_counter() - sched_t) * 1e3
            return lat
        target = self.sched if self.kind == "async" else self.router
        futures = []
        for i, (q, gap) in enumerate(zip(stream, gaps)):
            sched_t += gap
            now = time.perf_counter()
            if now < sched_t:
                time.sleep(sched_t - now)
            fut = target.submit(q)
            fut.add_done_callback(
                lambda f, i=i, s=sched_t: lat.__setitem__(
                    i, (time.perf_counter() - s) * 1e3))
            futures.append(fut)
        target.drain()
        for f in futures:
            f.result()
        return lat

    def compile_counts(self):
        if self.kind == "sync":
            return {0: self.svc.compile_counts()}
        if self.kind == "async":
            return {0: self.sched.compile_counts()}
        return self.router.compile_counts()

    def close(self) -> None:
        if self.kind == "async":
            self.sched.close()
        elif self.kind == "router":
            self.router.close()


def _measure(tier: _Tier, stream, gaps, iters: int) -> dict:
    stream_s = timeit(lambda: tier.serve_closed_loop(stream),
                      repeats=iters, warmup=2)
    lat = tier.serve_paced(stream, gaps)
    counts = tier.compile_counts()
    for per_replica in counts.values():
        assert all(c == 1 for c in per_replica.values()), (
            f"{tier.kind}: a bucket recompiled: {counts}")
    return {
        "throughput_rps": round(len(stream) / stream_s, 1),
        "latency_ms": {
            "p50": round(float(np.percentile(lat, 50)), 3),
            "p99": round(float(np.percentile(lat, 99)), 3),
        },
        "compiles_per_bucket": {
            str(rid): {str(b): c for b, c in per.items()}
            for rid, per in counts.items()},
    }


def run(m: int, n_files: int, n_requests: int, iters: int, rps: float,
        n_replicas: int, backend: str) -> dict:
    eng = _build_index(m, n_files, genome_len=3_000)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000, seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    stream, gaps = _poisson_stream(pool, n_requests, rps, seed=7)

    out: dict = {
        "config": {
            "engine": "bitsliced", "scheme": "idl", "m": m,
            "n_files": n_files, "n_requests": n_requests,
            "backend": backend, "max_batch": 16, "offered_rps": rps,
            "n_replicas": n_replicas, "device": jax.default_backend(),
            "note": ("sync = per-request flush (serving semantics; "
                     "max_batch=1 is its best config — results at "
                     "end-of-stream is not serving); cluster tiers batch "
                     "up to 16 without blocking callers"),
        },
        "tiers": {},
    }
    for kind in ("sync", "async", "router"):
        tier = _Tier(kind, eng, backend, n_replicas)
        try:
            out["tiers"][kind] = _measure(tier, stream, gaps, iters)
        finally:
            tier.close()
    # closed-loop rps of the other routing policies (policy ablation)
    for policy in ("bucket_affinity", "least_outstanding"):
        tier = _Tier("router", eng, backend, n_replicas, policy=policy)
        try:
            stream_s = timeit(lambda: tier.serve_closed_loop(stream),
                              repeats=iters, warmup=2)
            out["tiers"].setdefault("router_policies_rps", {})[
                "round_robin"] = out["tiers"]["router"]["throughput_rps"]
            out["tiers"]["router_policies_rps"][policy] = round(
                n_requests / stream_s, 1)
        finally:
            tier.close()
    sync_rps = out["tiers"]["sync"]["throughput_rps"]
    out["speedup_vs_sync"] = {
        kind: round(out["tiers"][kind]["throughput_rps"] / sync_rps, 2)
        for kind in ("async", "router")
    }
    return out


# ---------------------------------------------------------------------------
# Smoke: router == direct single service, 2 engines x {idl, rh}, + hot swap.
# ---------------------------------------------------------------------------

def _build_smoke_engine(engine: str, scheme: str, m: int):
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)
    rng = np.random.default_rng(5)
    reads = jnp.asarray(rng.integers(0, 4, size=(3, 150), dtype=np.uint8))
    fids = np.arange(3)
    if engine == "cobs":
        eng = CobsIndex.build([120, 240, 170], cfg, scheme=scheme,
                              n_groups=2).insert_batch(reads, fids)
    else:
        eng = BitSlicedIndex.build(cfg, scheme, n_files=24).insert_batch(
            reads, fids)
    return eng, reads


def _assert_parity(m: int) -> None:
    rng = np.random.default_rng(9)
    for engine in ("bitsliced", "cobs"):
        for scheme in ("idl", "rh"):
            eng, reads = _build_smoke_engine(engine, scheme, m)
            lens = rng.choice([50, 90, 111, 150], size=14)
            stream = [np.asarray(reads[i % 3][:n])
                      for i, n in enumerate(lens)]
            svc_cfg = ServiceConfig(max_batch=4)
            ref = GeneSearchService(eng, svc_cfg).search(stream)
            with ReplicaRouter(eng, svc_cfg,
                               RouterConfig(n_replicas=2)) as router:
                got = router.search(stream)
                for r, want in zip(got, ref):
                    np.testing.assert_array_equal(np.asarray(r.matches),
                                                  np.asarray(want.matches))
                for per in router.compile_counts().values():
                    assert all(c == 1 for c in per.values())
    print("parity: router == direct service "
          "(bitsliced+cobs x idl+rh); one compile per bucket per replica")


def _assert_hot_swap(m: int, tmp: pathlib.Path) -> None:
    eng, reads = _build_smoke_engine("bitsliced", "idl", m)
    snap0 = store.save(eng, str(tmp / "v0"))
    rng = np.random.default_rng(11)
    new_read = np.asarray(rng.integers(0, 4, size=150, dtype=np.uint8))
    from repro.index import state as state_mod
    eng1 = state_mod.to_engine(store.load(snap0)).insert_batch(
        jnp.asarray(new_read)[None], np.asarray([7]))
    snap1 = store.save(eng1, str(tmp / "v1"))
    with ReplicaRouter.from_snapshot(snap0, ServiceConfig(max_batch=4),
                                     RouterConfig(n_replicas=2)) as router:
        futures = [router.submit(np.asarray(reads[i % 3]))
                   for i in range(24)]
        assert router.swap_snapshot(snap1) == 1
        futures += [router.submit(new_read) for _ in range(8)]
        router.drain()
        results = [f.result(timeout=60) for f in futures]   # zero dropped
        assert all(7 in r.file_ids for r in results[-8:])
        assert all(r.version == 1 for r in results[-8:])
    print("hot swap under load: zero dropped futures, "
          "post-swap results on the new version")


# ---------------------------------------------------------------------------
# Process fabric: per-worker-count scaling curve + parity + zero-drop swap.
# ---------------------------------------------------------------------------

def _fabric_closed_loop(fab: ProcessFabric, stream) -> None:
    futures = [fab.submit(q) for q in stream]
    for f in futures:
        f.result(timeout=300)


def _fabric_paced(fab: ProcessFabric, stream, gaps) -> np.ndarray:
    """CO-safe paced replay through the gateway (see _Tier.serve_paced)."""
    lat = np.zeros(len(stream))
    sched_t = time.perf_counter()
    futures = []
    for i, (q, gap) in enumerate(zip(stream, gaps)):
        sched_t += gap
        now = time.perf_counter()
        if now < sched_t:
            time.sleep(sched_t - now)
        fut = fab.submit(q)
        fut.add_done_callback(
            lambda f, i=i, s=sched_t: lat.__setitem__(
                i, (time.perf_counter() - s) * 1e3))
        futures.append(fut)
    fab.drain()
    for f in futures:
        f.result(timeout=300)
    return lat


def _assert_fabric_swap(fab: ProcessFabric, stream, ref) -> dict:
    """Rolling restart under traffic: zero dropped futures, version+1."""
    in_flight = [fab.submit(q) for q in stream]
    old_version = fab.version
    new_version = fab.rolling_restart()           # same snapshot, v+1
    after = [fab.submit(q) for q in stream[:8]]
    results = [f.result(timeout=300) for f in in_flight + after]
    for got, want in zip(results, list(ref) + list(ref[:8])):
        np.testing.assert_array_equal(np.asarray(got.matches),
                                      np.asarray(want.matches))
    assert new_version == old_version + 1
    assert all(r.version == new_version for r in
               results[len(in_flight):])
    return {"in_flight": len(in_flight) + len(after),
            "dropped": 0, "new_version": new_version}


def run_fabric(max_procs: int, m: int, n_files: int, n_requests: int,
               iters: int, rps: float, backend: str,
               smoke: bool) -> dict:
    eng = _build_index(m, n_files, genome_len=3_000)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000,
                                   seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    stream, gaps = _poisson_stream(pool, n_requests, rps, seed=7)
    svc_cfg = ServiceConfig(backend=backend, max_batch=16)
    sched_cfg = SchedulerConfig(max_delay_ms=2.0)

    # in-process references: bit-exact answers + the single-interpreter
    # router the fabric has to beat once it has cores to scale across
    ref = GeneSearchService(eng, svc_cfg).search(stream)
    router_tier = _Tier("router", eng, backend, n_replicas=2)
    try:
        router_s = timeit(lambda: router_tier.serve_closed_loop(stream),
                          repeats=iters, warmup=1)
    finally:
        router_tier.close()

    tmp = tempfile.mkdtemp(prefix="fabric_bench_")
    curve: dict = {}
    swap: dict = {}
    try:
        snap = store.save(eng, str(pathlib.Path(tmp) / "snap"))
        for n in range(1, max_procs + 1):
            fab = ProcessFabric(snap, FabricConfig(
                n_workers=n, service=svc_cfg, scheduler=sched_cfg))
            try:
                # warmup pass: each worker compiles its buckets, and the
                # answers double as the gateway-vs-in-process parity check
                futures = [fab.submit(q) for q in stream]
                for got, want in zip(
                        [f.result(timeout=300) for f in futures], ref):
                    np.testing.assert_array_equal(
                        np.asarray(got.matches), np.asarray(want.matches))
                stream_s = timeit(
                    lambda: _fabric_closed_loop(fab, stream),
                    repeats=iters, warmup=1)
                lat = _fabric_paced(fab, stream, gaps)
                curve[str(n)] = {
                    "throughput_rps": round(n_requests / stream_s, 1),
                    "latency_ms": {
                        "p50": round(float(np.percentile(lat, 50)), 3),
                        "p99": round(float(np.percentile(lat, 99)), 3),
                    },
                }
                if n == max_procs:
                    swap = _assert_fabric_swap(fab, stream, ref)
            finally:
                fab.close()

        # per-worker membership caches over a deep-coverage overlapping
        # stream: cache-off vs cache-on fleets at max_procs, parity vs
        # the in-process reference asserted on both, gateway-merged hit
        # rate recorded (smoke included — CI gates on these asserts)
        overlap = overlapping_stream(pool, n_requests, seed=11,
                                     read_len=230, region_len=600)
        ref_overlap = GeneSearchService(eng, svc_cfg).search(overlap)
        cache_rec: dict = {}
        for label, cfg in (
                ("cache_off", svc_cfg),
                ("cache_on", dataclasses.replace(
                    svc_cfg, kmer_cache=KmerCacheConfig(capacity=1 << 17)))):
            fab = ProcessFabric(snap, FabricConfig(
                n_workers=max_procs, service=cfg, scheduler=sched_cfg))
            try:
                # warmup pass doubles as the parity gate
                futures = [fab.submit(q) for q in overlap]
                for got, want in zip(
                        [f.result(timeout=300) for f in futures],
                        ref_overlap):
                    np.testing.assert_array_equal(
                        np.asarray(got.matches), np.asarray(want.matches))
                secs = timeit(lambda: _fabric_closed_loop(fab, overlap),
                              repeats=iters, warmup=1)
                cache_rec[label + "_rps"] = round(n_requests / secs, 1)
                if label == "cache_on":
                    cs = fab.cache_stats()
                    assert cs is not None and cs["hits"] > 0, cs
                    cache_rec["hit_rate"] = round(cs["hit_rate"], 4)
                    cache_rec["cache"] = cs
                else:
                    assert fab.cache_stats() is None
            finally:
                fab.close()
        cache_rec["speedup"] = round(
            cache_rec["cache_on_rps"] / cache_rec["cache_off_rps"], 2)
        cache_rec["note"] = (
            "overlapping read_len=230 windows into 4 concatenated "
            "600bp regions at "
            f"{max_procs} workers; per-worker caches ride the pickled "
            "ServiceConfig; parity vs the in-process service asserted "
            "for BOTH fleets; hit_rate is the gateway-merged lifetime "
            "counter, cold misses included")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    rps_1 = curve["1"]["throughput_rps"]
    return {
        "host": bench_metadata(),
        "smoke": smoke,
        "config": {
            "engine": "bitsliced", "scheme": "idl", "m": m,
            "n_files": n_files, "n_requests": n_requests,
            "backend": backend, "max_batch": 16, "offered_rps": rps,
            "device": jax.default_backend(),
        },
        "in_process_router_rps": round(n_requests / router_s, 1),
        "procs": curve,
        "scaling_vs_1proc": {
            n: round(c["throughput_rps"] / rps_1, 2)
            for n, c in curve.items()},
        "rolling_swap": swap,
        "kmer_cache": cache_rec,
        "parity": ("gateway == in-process service, bit-identical, at "
                   "every worker count (asserted in-bench)"),
        "notes": [
            "workers are separate interpreters mmap-ing one snapshot: "
            "no GIL or XLA:CPU device shared between them — the fabric "
            "scales with cores, which host.cpu_count records",
            "on a 1-core host the curve is flat-to-negative (worker "
            "processes time-slice one core and pay IPC on top); the "
            "in-process router is the right tier there — read the curve "
            "against host.cpu_count, never bare",
            "rolling_swap: futures submitted before and during the "
            "restart all resolved bit-identically (zero dropped), and "
            "post-swap results carry the new fleet version",
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small config; assert parity + swap; no JSON "
                         "(except --procs mode, which always writes "
                         "BENCH_fabric.json)")
    ap.add_argument("--procs", type=int, default=0, metavar="N",
                    help="benchmark the process fabric at 1..N worker "
                         "processes; writes BENCH_fabric.json")
    args = ap.parse_args()

    root = pathlib.Path(__file__).resolve().parent.parent

    if args.procs:
        if args.smoke:
            res = run_fabric(max_procs=args.procs, m=1 << 18, n_files=16,
                             n_requests=32, iters=2, rps=2000,
                             backend="jnp", smoke=True)
        else:
            res = run_fabric(max_procs=args.procs, m=1 << 22, n_files=64,
                             n_requests=128, iters=3, rps=2000,
                             backend="jnp", smoke=False)
        out_path = root / "BENCH_fabric.json"
        out_path.write_text(json.dumps(res, indent=2) + "\n")
        print(json.dumps(res, indent=2))
        print(f"\nwrote {out_path}")
        return

    if args.smoke:
        _assert_parity(m=1 << 16)
        with tempfile.TemporaryDirectory() as tmp:
            _assert_hot_swap(m=1 << 16, tmp=pathlib.Path(tmp))
        res = run(m=1 << 18, n_files=16, n_requests=48, iters=2, rps=2000,
                  n_replicas=2, backend="jnp")
        print("smoke:", json.dumps(res["speedup_vs_sync"]))
        return

    res = run(m=1 << 22, n_files=64, n_requests=256, iters=5, rps=2000,
              n_replicas=2, backend="jnp")
    res["host"] = bench_metadata()
    out_path = root / "BENCH_cluster.json"
    out_path.write_text(json.dumps(res, indent=2) + "\n")
    print(json.dumps(res, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

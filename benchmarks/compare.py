"""Compare bench records: per-metric deltas between ``BENCH_*.json`` runs.

Every bench writes a ``BENCH_*.json`` stamped with
:func:`benchmarks.common.bench_metadata` (the ``host`` section). This tool
loads two or more such records — a baseline and one or more candidates —
joins them on that host key, and prints a per-metric delta table over
every shared numeric leaf (dotted paths, ``config``/``host``/``note``
subtrees excluded).

Scaling numbers are meaningless across different hardware, so records
whose host keys disagree are still compared but loudly flagged: the join
column says which fields differ (a 2-worker fabric on a 1-core CI box
cannot beat one interpreter, and the table has to say so).

``--fail-on-regression PCT`` makes the exit code a CI gate: metrics whose
names classify as higher-is-better (throughput, speedup, hit_rate, ...)
or lower-is-better (latency, miss rates, overhead, tile bytes, ...) fail
the run when the candidate is worse than the baseline by more than PCT
percent. Unclassified metrics are reported but never gate.

    PYTHONPATH=src python -m benchmarks.compare BASE.json NEW.json \
        [--fail-on-regression 10]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple

# Substrings that classify a metric path's direction. Checked in order:
# the first list that matches wins, so "latency_ms.p50" is lower-is-better
# even though it contains no higher-is-better marker.
HIGHER_IS_BETTER = ("throughput", "speedup", "hit_rate", "recall",
                    "ratio_rh_over_idl", "_rps", "rps_")
LOWER_IS_BETTER = ("latency", "_ms", "overhead", "miss", "_bytes",
                   "bytes_per", "wall_s", "dma", "_s_")

# host fields that define "same box" for the join; the rest of
# bench_metadata (timestamps, library patch versions) may drift freely
HOST_KEY_FIELDS = ("platform", "machine", "cpu_count", "jax_backend",
                   "jax_device_count")


def host_key(doc: dict) -> Tuple:
    """The identity :func:`benchmarks.common.bench_metadata` gives a run's
    hardware — the join key across records."""
    host = doc.get("host", {})
    return tuple(host.get(f) for f in HOST_KEY_FIELDS)


def numeric_leaves(doc: dict, prefix: str = "") -> Dict[str, float]:
    """Flatten a bench record to ``{dotted.path: value}`` over its numeric
    scalars; provenance subtrees (``config``/``host``/``note``) are not
    metrics and are skipped."""
    out: Dict[str, float] = {}
    for k, v in doc.items():
        if k in ("config", "host", "note"):
            continue
        path = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, bool):
            continue
        if isinstance(v, (int, float)):
            out[path] = float(v)
        elif isinstance(v, dict):
            out.update(numeric_leaves(v, path))
    return out


def direction(path: str) -> int:
    """+1 higher-is-better, -1 lower-is-better, 0 unclassified."""
    low = path.lower()
    for marker in HIGHER_IS_BETTER:
        if marker in low:
            return 1
    for marker in LOWER_IS_BETTER:
        if marker in low:
            return -1
    return 0


def compare(base: dict, cand: dict) -> List[dict]:
    """Per-metric rows for every numeric leaf the two records share."""
    b, c = numeric_leaves(base), numeric_leaves(cand)
    rows = []
    for path in sorted(set(b) & set(c)):
        old, new = b[path], c[path]
        delta_pct = (100.0 * (new - old) / abs(old)) if old else (
            0.0 if new == old else float("inf"))
        d = direction(path)
        regressed_pct = (-delta_pct if d > 0 else
                         delta_pct if d < 0 else 0.0)
        rows.append({"metric": path, "base": old, "cand": new,
                     "delta_pct": delta_pct, "direction": d,
                     "regressed_pct": regressed_pct})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("records", nargs="+",
                    help="two or more BENCH_*.json files; the first is "
                         "the baseline")
    ap.add_argument("--fail-on-regression", type=float, default=None,
                    metavar="PCT",
                    help="exit 1 if any direction-classified metric is "
                         "worse than the baseline by more than PCT%%")
    args = ap.parse_args()
    if len(args.records) < 2:
        ap.error("need a baseline and at least one candidate record")

    docs = []
    for p in args.records:
        path = pathlib.Path(p)
        docs.append((path.name, json.loads(path.read_text())))
    base_name, base = docs[0]
    base_key = host_key(base)

    failed = False
    for cand_name, cand in docs[1:]:
        cand_key = host_key(cand)
        print(f"\n== {base_name} (base) vs {cand_name} ==")
        if cand_key != base_key:
            diff = [f for f, a, b in zip(HOST_KEY_FIELDS, base_key,
                                         cand_key) if a != b]
            print(f"!! host mismatch on {diff} — cross-hardware deltas "
                  f"describe the boxes, not the code")
        else:
            print(f"host: {dict(zip(HOST_KEY_FIELDS, base_key))}")
        rows = compare(base, cand)
        if not rows:
            print("no shared numeric metrics")
            continue
        width = max(len(r["metric"]) for r in rows)
        print(f"{'metric':<{width}}  {'base':>12}  {'cand':>12}  "
              f"{'delta':>8}")
        for r in rows:
            flag = ""
            if args.fail_on_regression is not None and \
                    r["regressed_pct"] > args.fail_on_regression:
                flag = "  << REGRESSION"
                failed = True
            arrow = {1: "+", -1: "-", 0: " "}[r["direction"]]
            print(f"{r['metric']:<{width}}  {r['base']:>12.4g}  "
                  f"{r['cand']:>12.4g}  {r['delta_pct']:>+7.1f}%"
                  f" {arrow}{flag}")
        print("(direction: '+' higher is better, '-' lower is better, "
              "' ' informational)")

    if failed:
        print(f"\nFAIL: regression(s) beyond "
              f"{args.fail_on_regression}% vs {base_name}")
        sys.exit(1)


if __name__ == "__main__":
    main()

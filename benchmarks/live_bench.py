"""Live-index benchmark: ingest-while-serving under a mixed Poisson stream.

The new workload the LSM subsystem opens: a :class:`LiveReplicaRouter`
(2 replicas, write-ahead journal, base+delta per replica) serves a
**90/10 read/write** Poisson stream. Measured:

* **insert-to-searchable latency** — scheduled write arrival to the moment
  the write's ``InsertAck`` has resolved on EVERY replica (at which point
  the read is answerable fleet-wide: acks carry the ``(base_version,
  delta_seq)`` watermark the result stamps prove). Coordinated-omission-
  safe: latency is measured against the scheduled Poisson arrival, so a
  write path that falls behind is charged its queueing delay.
* **query p50 under the mixed stream** — same CO-safe accounting, while
  10% of arrivals are writes mutating every replica's delta.
* **before/after compaction** — the same query-only probe stream timed
  with the delta holding all absorbed writes, then again after
  ``router.compact()`` folds delta into base (delta empty, version+1).
  Compile counts are asserted ==1 per (bucket, backend) per replica
  across the compaction — the publish must not recompile anything.

Honest contention notes (recorded in the JSON): this box has one XLA:CPU
device, so (a) the two-probe merged query pays its extra probe on the
same device the writes scatter into — insert and query latencies contend
end-to-end; (b) the compaction merge is "off the hot path" logically
(queries keep answering from the frozen pair) but physically shares the
device, so mid-compaction latencies bulge; (c) insert-to-searchable
includes the scheduler flusher tick (``max_delay_ms``), which dominates
when the box is idle. Wall-clock on this host swings 2-3x run-to-run;
medians over the whole stream, not single shots.

A ``kmer_cache`` section re-times a deep-coverage overlapping stream —
served with a non-empty delta, so cached BASE rows merge with a fresh
delta probe every batch — with the membership cache on vs off (parity
asserted in-bench, lifetime hit rate recorded honestly).

``--smoke`` (CI) asserts, with no JSON written: the live fleet answers
bit-identically to a single-index oracle holding the union of all inserts
(including queries racing a mid-stream compaction), zero dropped futures,
and zero recompiles across the compaction swap — with the membership
cache off AND on (hit_rate > 0, compaction publishes invalidate).

    PYTHONPATH=src python -m benchmarks.live_bench [--smoke]

Writes ``BENCH_live.json`` (full mode) next to the repo root.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_metadata, overlapping_stream, timeit
from repro.core import idl
from repro.data import genome
from repro.index import BitSlicedIndex, ingest
from repro.serving import (
    GeneSearchService,
    KmerCacheConfig,
    LiveReplicaRouter,
    RouterConfig,
    SchedulerConfig,
    ServiceConfig,
)


# p50 insert-to-searchable from the checked-in BENCH_live.json recorded
# BEFORE LiveIndex.insert donated its delta into the scatter (every write
# paid a defensive whole-delta copy so a concurrent compaction plan could
# keep the old buffer; plan_compaction now takes its own copy instead —
# one copy per compaction, not one per write).
_P50_BEFORE_DONATION_MS = 132.986


def _build_base(m: int, n_files: int, genome_len: int) -> BitSlicedIndex:
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=3, m=m)
    eng = BitSlicedIndex.build(cfg, "idl", n_files=n_files)
    archive = genome.synth_archive(n_files=n_files, genome_len=genome_len,
                                   seed=42)
    return ingest.build_archive(eng, archive, read_len=230, chunk_reads=64)


def _mixed_stream(pool, fresh_reads, n_requests: int, write_frac: float,
                  rps: float, seed: int):
    """(kind, payload, gap) replay: ragged queries + single-read writes."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rps, size=n_requests)
    events = []
    w = 0
    for i in range(n_requests):
        if rng.random() < write_frac and w < len(fresh_reads):
            events.append(("write", fresh_reads[w], gaps[i]))
            w += 1
        else:
            n = int(rng.choice([70, 110, 150, 230],
                               p=[0.3, 0.3, 0.2, 0.2]))
            q = pool[int(rng.integers(0, len(pool)))]
            events.append(("query", np.asarray(q[:n]), gaps[i]))
    return events


def _replay_timed(router: LiveReplicaRouter, events) -> dict:
    """CO-safe paced replay with per-write searchable stamps."""
    q_lat, w_done, w_sched, futures = [], {}, [], []
    sched_t = time.perf_counter()
    for kind, payload, gap in events:
        sched_t += gap
        now = time.perf_counter()
        if now < sched_t:
            time.sleep(sched_t - now)
        if kind == "query":
            slot = len(q_lat)
            q_lat.append(np.nan)
            fut = router.submit(payload)
            fut.add_done_callback(
                lambda f, i=slot, s=sched_t: q_lat.__setitem__(
                    i, (time.perf_counter() - s) * 1e3))
            futures.append(fut)
        else:
            reads, fids = payload
            wid = len(w_sched)
            w_sched.append(sched_t)
            acks = router.insert(reads, fids)
            stamps = w_done.setdefault(wid, [])
            for a in acks:
                # list.append is atomic under the GIL; searchable = the
                # LAST replica's ack, resolved as max() after the drain
                a.add_done_callback(
                    lambda f, s=stamps: s.append(time.perf_counter()))
            futures.extend(acks)
    router.drain()
    for f in futures:
        f.result(timeout=120)          # zero dropped — raises otherwise
    w_ms = np.asarray([(max(w_done[i]) - s) * 1e3
                       for i, s in enumerate(w_sched)])
    return {"query_ms": np.asarray(q_lat), "write_ms": w_ms}


def _pcts(a: np.ndarray) -> dict:
    return {"p50": round(float(np.percentile(a, 50)), 3),
            "p99": round(float(np.percentile(a, 99)), 3)}


def _assert_compile_once(router) -> dict:
    counts = router.compile_counts()
    for per in counts.values():
        assert all(c == 1 for c in per.values()), (
            f"a bucket recompiled: {counts}")
    return {str(rid): {str(b): c for b, c in per.items()}
            for rid, per in counts.items()}


def run(m: int, n_files: int, n_requests: int, rps: float,
        n_replicas: int) -> dict:
    eng = _build_base(m, n_files, genome_len=3_000)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000,
                                   seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    # fresh material: reads the base has never seen, written into
    # existing file columns under traffic
    fresh = genome.synth_archive(n_files=8, genome_len=3_000, seed=77)
    rng = np.random.default_rng(3)
    fresh_reads = [
        (np.asarray(f.reads(230, 1)[0])[None],
         np.asarray([int(rng.integers(0, n_files))], dtype=np.int32))
        for f in fresh for _ in range(6)]

    with tempfile.TemporaryDirectory() as tmp:
        router = LiveReplicaRouter(
            eng, ServiceConfig(max_batch=16),
            RouterConfig(n_replicas=n_replicas,
                         scheduler=SchedulerConfig(max_delay_ms=2.0)),
            journal_path=str(pathlib.Path(tmp) / "wal.bin"))
        try:
            # warmup compiles (all query buckets + the write path)
            for q in (pool[0][:70], pool[0][:110], pool[0][:150],
                      pool[0][:230]):
                router.submit(np.asarray(q)).result(timeout=120)
            for a in router.insert(*fresh_reads[0]):
                a.result(timeout=120)

            events = _mixed_stream(pool, fresh_reads[1:], n_requests,
                                   write_frac=0.1, rps=rps, seed=7)
            mixed = _replay_timed(router, events)

            # before/after compaction: identical query-only probe stream
            probe = _mixed_stream(pool, [], n_requests // 2,
                                  write_frac=0.0, rps=rps, seed=8)
            pre = _replay_timed(router, probe)
            delta_before = router.delta_batches()
            t0 = time.perf_counter()
            version = router.compact()
            compact_s = time.perf_counter() - t0
            post = _replay_timed(router, probe)
            compiles = _assert_compile_once(router)
        finally:
            router.close()

    return {
        "config": {
            "engine": "bitsliced", "scheme": "idl", "m": m,
            "n_files": n_files, "n_requests": n_requests,
            "write_frac": 0.1, "offered_rps": rps,
            "n_replicas": n_replicas, "max_batch": 16,
            "max_delay_ms": 2.0, "device": jax.default_backend(),
        },
        "mixed_stream_90_10": {
            "query_ms": _pcts(mixed["query_ms"]),
            "insert_to_searchable_ms": _pcts(mixed["write_ms"]),
            "n_queries": int(len(mixed["query_ms"])),
            "n_writes": int(len(mixed["write_ms"])),
        },
        "delta_donation": {
            "insert_to_searchable_p50_ms_before": _P50_BEFORE_DONATION_MS,
            "insert_to_searchable_p50_ms_after": _pcts(
                mixed["write_ms"])["p50"],
            "note": ("before = the last record with the non-donated write "
                     "path (every insert copied the whole delta); after = "
                     "this run, with LiveIndex.insert donating the delta "
                     "into the scatter under the single-writer flusher"),
        },
        "compaction": {
            "delta_batches_folded": delta_before,
            "published_version": version,
            "compact_wall_s": round(compact_s, 3),
            "query_ms_before": _pcts(pre["query_ms"]),
            "query_ms_after": _pcts(post["query_ms"]),
            "compiles_per_bucket": compiles,
        },
        "notes": [
            "single XLA:CPU device: the delta probe and the write scatter "
            "share the serving device, so insert and query latencies "
            "contend end-to-end; on a multi-device host the delta is "
            "replica-local and the merge runs off-device",
            "insert-to-searchable = scheduled Poisson arrival -> last "
            "replica's InsertAck; includes the 2ms flusher tick, which "
            "dominates at low offered load",
            "compaction merge shares the device with serving on this box "
            "('off the hot path' is logical, not physical here) — the "
            "before/after query p50 gap, not mid-compaction latency, is "
            "the stable signal; wall-clock swings 2-3x run-to-run",
            "offered_rps sits below this box's saturation point (both "
            "replicas' scatters serialize on the one device); the write "
            "path now donates the delta into the scatter — the "
            "whole-delta defensive copy is gone (see delta_donation; "
            "plan_compaction snapshots its own copy, once per fold, not "
            "once per write); past saturation, CO-safe accounting "
            "correctly reports seconds of queueing delay rather than "
            "service latency",
        ],
    }


def run_cache(m: int, n_files: int, n_requests: int, iters: int) -> dict:
    """Membership cache on vs off on the LIVE router.

    The stream is deep-coverage overlapping windows served with a
    non-empty delta, so the cached path serves merged base|delta rows
    from the front cache keyed (version, delta_seq), with the
    version-keyed base-row cache behind it. Parity (cache on == cache
    off, bit for bit) is asserted before anything is timed; hit rate is
    the caches' lifetime counter — cold misses included.
    """
    eng = _build_base(m, n_files, genome_len=3_000)
    archive = genome.synth_archive(n_files=n_files, genome_len=3_000,
                                   seed=42)
    pool = [f.reads(230, 4)[i % 4] for i, f in enumerate(archive)]
    stream = overlapping_stream(pool, n_requests, seed=11,
                                read_len=460, region_len=600)
    fresh = genome.synth_archive(n_files=4, genome_len=3_000, seed=77)
    rng = np.random.default_rng(3)
    writes = [(np.asarray(f.reads(230, 1)[0])[None],
               np.asarray([int(rng.integers(0, n_files))], dtype=np.int32))
              for f in fresh]

    def drive(svc_cfg):
        with tempfile.TemporaryDirectory() as tmp:
            # One replica: on this 1-core box two worker threads
            # interleaving on the same CPU add ~2x wall-clock noise,
            # which is larger than the effect being measured. The cache
            # mechanics are identical at any fleet size (asserted by
            # tests/test_kmer_cache.py across a 2-replica router).
            router = LiveReplicaRouter(
                eng, svc_cfg,
                RouterConfig(n_replicas=1,
                             scheduler=SchedulerConfig(max_delay_ms=2.0)),
                journal_path=str(pathlib.Path(tmp) / "wal.bin"))
            try:
                for r, f in writes:        # delta live: two-probe path
                    for a in router.insert(r, f):
                        a.result(timeout=120)

                # Pause dispatch while submitting so batch formation is
                # identical for both configs: a fast (cached) execute
                # otherwise outruns the single submitting thread, and the
                # deadline flusher serves tiny batches whose fixed
                # per-batch cost swamps the probe savings being measured.
                scheds = [rep.scheduler for rep in router._replicas]

                def closed_loop():
                    for s in scheds:
                        s.pause()
                    futs = [router.submit(q) for q in stream]
                    for s in scheds:
                        s.resume()
                    router.drain()
                    for fu in futs:
                        fu.result(timeout=120)

                secs = timeit(closed_loop, repeats=iters, warmup=1)
                results = router.search(stream)
                _assert_compile_once(router)
                return secs, results, router.cache_stats()
            finally:
                router.close()

    off_s, ref, cs_off = drive(ServiceConfig(max_batch=32))
    assert cs_off is None
    on_s, got, cs_on = drive(ServiceConfig(
        max_batch=32, kmer_cache=KmerCacheConfig(capacity=1 << 17)))
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a.matches),
                                      np.asarray(b.matches))
    assert cs_on["hits"] > 0, cs_on
    return {
        "config": {
            "engine": "bitsliced", "scheme": "idl", "m": m,
            "n_files": n_files, "n_requests": n_requests,
            "n_replicas": 1, "n_delta_writes": len(writes),
            "stream": ("overlapping read_len=460 windows into 4 "
                       "concatenated 600bp regions"),
            "max_batch": 32, "cache_capacity": 1 << 17,
            "device": jax.default_backend(),
        },
        "throughput_rps": {
            "cache_off": round(n_requests / off_s, 1),
            "cache_on": round(n_requests / on_s, 1),
        },
        "speedup": round(off_s / on_s, 2),
        "hit_rate": round(cs_on["hit_rate"], 4),
        "cache": cs_on,
        "note": ("served with a non-empty delta: the front cache holds "
                 "merged base|delta rows keyed (version, delta_seq); a "
                 "write drops only those, and the version-keyed base-row "
                 "cache backfills without re-probing — parity vs "
                 "cache-off asserted in-bench before timing; hit_rate is "
                 "lifetime over both stores, cold misses included; one "
                 "replica and paused-submit batching so a 1-core host "
                 "measures serving capacity, not thread interleaving"),
    }


# ---------------------------------------------------------------------------
# Smoke: live fleet == union-index oracle, mid-compaction, zero recompiles.
# ---------------------------------------------------------------------------

def _smoke(m: int = 1 << 16) -> None:
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)
    rng = np.random.default_rng(5)
    base_reads = jnp.asarray(rng.integers(0, 4, size=(3, 150),
                                          dtype=np.uint8))
    write_reads = [rng.integers(0, 4, size=(1, 150), dtype=np.uint8)
                   for _ in range(6)]
    write_fids = [np.asarray([int(rng.integers(0, 24))], dtype=np.int32)
                  for _ in range(6)]
    eng = BitSlicedIndex.build(cfg, "idl", n_files=24).insert_batch(
        base_reads, np.arange(3))

    # oracle: ONE index holding the union of base + every write
    oracle = BitSlicedIndex.build(cfg, "idl", n_files=24).insert_batch(
        base_reads, np.arange(3))
    for r, f in zip(write_reads, write_fids):
        oracle = oracle.insert_batch(jnp.asarray(r), f)
    queries = [np.asarray(base_reads[i % 3][:n])
               for i, n in enumerate((70, 110, 150, 150, 70, 110))]
    queries += [w[0] for w in write_reads]
    want = GeneSearchService(oracle, ServiceConfig(max_batch=4)
                             ).search(queries)

    def drive(svc_cfg):
        """The interleaved write+query stream with a mid-stream fold;
        returns the router's merged cache stats (None = cache off)."""
        with tempfile.TemporaryDirectory() as tmp:
            router = LiveReplicaRouter(
                eng, svc_cfg,
                RouterConfig(n_replicas=2,
                             scheduler=SchedulerConfig(max_delay_ms=0.5)),
                journal_path=str(pathlib.Path(tmp) / "wal.bin"))
            try:
                futures = []
                # concurrent write+query load, compact mid-stream
                for i, (r, f) in enumerate(zip(write_reads, write_fids)):
                    futures += [router.submit(q) for q in queries[:3]]
                    futures += router.insert(r, f)
                    if i == 3:
                        assert router.compact() == 1   # mid-stream fold
                router.drain()
                for fut in futures:
                    fut.result(timeout=120)            # zero dropped
                got = router.search(queries)           # writes absorbed
                for g, w in zip(got, want):
                    np.testing.assert_array_equal(np.asarray(g.matches),
                                                  np.asarray(w.matches))
                assert router.compact() == 2           # fold the rest
                got = router.search(queries)
                for g, w in zip(got, want):
                    np.testing.assert_array_equal(np.asarray(g.matches),
                                                  np.asarray(w.matches))
                _assert_compile_once(router)
                assert router.delta_batches() == 0
                return router.cache_stats()
            finally:
                router.close()

    assert drive(ServiceConfig(max_batch=4)) is None
    cs = drive(ServiceConfig(max_batch=4,
                             kmer_cache=KmerCacheConfig(capacity=1 << 14)))
    assert cs["hits"] > 0 and cs["hit_rate"] > 0, cs
    assert cs["invalidations"] >= 2, cs   # both publishes flushed caches
    print("smoke: live fleet == union-index oracle (incl. mid-compaction), "
          "membership cache on AND off; zero dropped futures; one compile "
          "per bucket per replica across 2 compactions; cache hit_rate="
          f"{cs['hit_rate']:.2f} with {cs['invalidations']} compaction "
          "invalidations")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="parity vs oracle + zero-drop + compile-once; "
                         "no JSON")
    args = ap.parse_args()

    if args.smoke:
        _smoke()
        return

    res = run(m=1 << 22, n_files=64, n_requests=256, rps=25,
              n_replicas=2)
    res["kmer_cache"] = run_cache(m=1 << 21, n_files=256, n_requests=768,
                                  iters=7)
    res["host"] = bench_metadata()
    out_path = pathlib.Path(
        __file__).resolve().parent.parent / "BENCH_live.json"
    out_path.write_text(json.dumps(res, indent=2) + "\n")
    print(json.dumps(res, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

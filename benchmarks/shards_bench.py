"""Sharded-archive benchmark: parallel distributed build + scatter-gather.

Exercises the PR-9 subsystem end-to-end and records the evidence for its
claims ledger rows:

* **parity sweep** (smoke + full): for every engine x scheme x theta in
  {bit-sliced, cobs, flat BF, rambo} x {idl, rh} x {1.0, 0.6}, a
  2-shard archive is built with :func:`ingest.build_sharded_archive`
  (thread-per-shard over the donated insert planner) and served through
  an in-process :class:`ScatterGatherRouter`; every answer is asserted
  bit-identical to one :class:`GeneSearchService` over the joined
  (unsharded) index BEFORE anything is recorded. 16 combos, all exact.
* **proc parity** (smoke + full): the same check through REAL shard
  worker processes (2 mmap-booted shards behind one gateway), one
  engine per partition axis.
* **shard kill** (smoke + full): kill -9 one shard process mid-stream.
  Row-probe (bit-sliced): every future resolves, late answers name the
  dead shard's files in ``missing_files`` and stay exact on the
  surviving files. Bit-probe (rambo): affected futures raise
  ``ShardDeadError`` — loud, never a silently-inflated answer. The
  recorded ``shard_kill.dropped`` counts futures that neither resolved
  nor raised: it must be 0.
* **build scaling** (full only): wall-clock of the parallel sharded
  build vs the serial ``build_archive`` over the same files. Read the
  curve with ``host.cpu_count`` in hand — shard builds contend for one
  XLA:CPU device on this box, so the honest expectation here is ~flat;
  the mechanism (independent per-shard insert streams) is what the
  number validates, the scaling needs real parallel hardware.
* **scatter throughput** (full only): closed-loop requests/sec of the
  in-process router at 1 vs 2 shards, same caveat.

    PYTHONPATH=src python -m benchmarks.shards_bench [--smoke]

Writes ``BENCH_shards.json`` next to the repo root (in ``--smoke`` too —
CI uploads it; the smoke record is marked ``"smoke": true``).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import tempfile
import time

import numpy as np

from benchmarks.common import bench_metadata, timeit
from repro.core import idl
from repro.data import genome
from repro.index import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
    ingest,
    shards,
)
from repro.serving import (
    GeneSearchService,
    ScatterConfig,
    ScatterGatherRouter,
    ServiceConfig,
    ShardDeadError,
)

ROOT = pathlib.Path(__file__).resolve().parent.parent
ENGINES = ("bitsliced", "cobs", "bloom", "rambo")
SCHEMES = ("idl", "rh")
THETAS = (1.0, 0.6)
N_FILES = 70


def _cfg(m: int = 1 << 14) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)


def _fresh_index(engine: str, scheme: str, file_sizes):
    if engine == "bitsliced":
        return BitSlicedIndex.build(_cfg(), scheme=scheme,
                                    n_files=len(file_sizes))
    if engine == "cobs":
        return CobsIndex.build(list(file_sizes), _cfg(), scheme=scheme,
                               n_groups=3)
    if engine == "rambo":
        return RamboIndex.build(len(file_sizes), _cfg(), scheme=scheme)
    return PackedBloomIndex.build(_cfg(), scheme=scheme)


def _corpus(seed: int = 9, n_files: int = N_FILES):
    rng = np.random.default_rng(seed)
    files = [rng.integers(0, 4, size=720, dtype=np.uint8)
             for _ in range(n_files)]
    queries = [rng.integers(0, 4, size=int(n), dtype=np.uint8)
               for n in rng.integers(40, 110, size=8)]
    queries[0] = files[3][40:120].copy()          # true positives on both
    queries[1] = files[n_files - 5][100:170].copy()   # sides of the cut
    return files, queries


def _items(engine: str, files):
    if engine == "bloom":
        return [(0, np.concatenate(files[:4]))]
    return list(enumerate(files))


def _assert_results_equal(want, got, label: str) -> None:
    for w, g in zip(want, got):
        assert np.array_equal(np.asarray(w.matches),
                              np.asarray(g.matches)), (
            f"{label}: sharded answer drifted from the unsharded oracle")
        assert w.file_ids == g.file_ids, label
        assert g.missing_files == (), label


def parity_sweep(files, queries, tmp: str) -> dict:
    """Build sharded, serve scattered, assert bit-identity. 16 combos."""
    combos = 0
    for engine in ENGINES:
        for scheme in SCHEMES:
            set_dir = f"{tmp}/{engine}-{scheme}"
            spec, states = ingest.build_sharded_archive(
                _fresh_index(engine, scheme, [f.size for f in files]),
                _items(engine, files), n_shards=2, out_dir=set_dir,
                read_len=240, chunk_reads=8)
            full = shards.join_states(spec, states)
            for theta in THETAS:
                svc_cfg = ServiceConfig(theta=theta, max_batch=4)
                want = GeneSearchService(full, svc_cfg).search(queries)
                with ScatterGatherRouter(
                        set_dir,
                        ScatterConfig(service=svc_cfg)) as router:
                    got = router.search(queries)
                _assert_results_equal(
                    want, got, f"{engine}/{scheme} theta={theta}")
                combos += 1
                print(f"  parity {engine}/{scheme} theta={theta} OK")
    return {"combos": combos, "all_equal": True,
            "engines": list(ENGINES), "schemes": list(SCHEMES),
            "thetas": list(THETAS), "n_shards": 2}


def proc_parity(files, queries, tmp: str) -> dict:
    """The same answers through real shard worker processes."""
    out = {}
    for engine, theta in (("bitsliced", 1.0), ("rambo", 0.6)):
        set_dir = f"{tmp}/{engine}-idl"        # reuse the sweep's set
        _, states = shards.load_shard_set(set_dir)
        sm = shards.read_set_meta(set_dir)
        full = shards.join_states(sm.spec, states)
        svc_cfg = ServiceConfig(theta=theta, max_batch=4)
        want = GeneSearchService(full, svc_cfg).search(queries)
        with ScatterGatherRouter(set_dir, ScatterConfig(
                procs=True, service=svc_cfg)) as router:
            got = router.search(queries)
            _assert_results_equal(want, got, f"procs {engine}")
        out[engine] = {"n_shards": sm.spec.n_shards, "axis": sm.spec.axis,
                       "equal": True, "theta": theta}
        print(f"  proc parity {engine} (axis={sm.spec.axis}) OK")
    return out


def shard_kill(files, queries, tmp: str) -> dict:
    """kill -9 one shard process mid-stream on each partition axis and
    account for EVERY submitted future: resolved exactly, resolved with
    named missing files, or raised ShardDeadError. dropped must be 0."""
    stream = [queries[i % len(queries)] for i in range(24)]
    out = {"submitted": 0, "resolved": 0, "loud_errors": 0, "dropped": 0}

    # row-probe axis: partial truth, honestly labeled
    set_dir = f"{tmp}/bitsliced-idl"
    sm = shards.read_set_meta(set_dir)
    lost = shards.shard_files(sm.spec, 1)
    kept = sorted(set(range(sm.spec.meta.n_files)) - set(lost))
    _, states = shards.load_shard_set(set_dir)
    oracle = GeneSearchService(
        shards.join_states(sm.spec, states),
        ServiceConfig(max_batch=4))
    want = oracle.search(stream)
    row = {"with_missing_files": 0}
    with ScatterGatherRouter(set_dir, ScatterConfig(
            procs=True, service=ServiceConfig(max_batch=4))) as router:
        router.search(queries[:2])             # warm both shards
        futures = [router.submit(q) for q in stream]
        router.kill_shard(1)
        for w, f in zip(want, futures):
            res = f.result(timeout=120)        # raises if dropped
            out["resolved"] += 1
            if res.missing_files:
                assert res.missing_files == lost
                row["with_missing_files"] += 1
            gm = np.asarray(res.matches)
            assert np.array_equal(gm[kept], np.asarray(w.matches)[kept])
        out["submitted"] += len(futures)
    row["lost_files"] = len(lost)
    print(f"  row-probe kill: {row['with_missing_files']}/"
          f"{len(stream)} answers carried missing_files, 0 dropped")

    # bit-probe axis: fail loud, never inflate the FPR
    set_dir = f"{tmp}/rambo-idl"
    bit = {"loud_errors": 0}
    with ScatterGatherRouter(set_dir, ScatterConfig(
            procs=True, service=ServiceConfig(max_batch=4))) as router:
        router.search(queries[:2])
        futures = [router.submit(q) for q in stream]
        router.kill_shard(0)
        for f in futures:
            try:
                f.result(timeout=120)
                out["resolved"] += 1
            except ShardDeadError:
                out["loud_errors"] += 1
                bit["loud_errors"] += 1
        out["submitted"] += len(futures)
    assert bit["loud_errors"] > 0, \
        "kill landed after the whole stream resolved; nothing asserted"
    print(f"  bit-probe kill: {bit['loud_errors']}/{len(stream)} "
          f"futures failed loud, 0 dropped")

    out["dropped"] = out["submitted"] - out["resolved"] \
        - out["loud_errors"]
    assert out["dropped"] == 0, out
    out["row_probe"] = row
    out["bit_probe"] = bit
    return out


def build_scaling(repeats: int) -> dict:
    """Parallel sharded build vs serial build_archive, same files."""
    n_files = 128
    archive = genome.synth_archive(n_files=n_files, genome_len=2_000,
                                   seed=42)
    cfg = _cfg(1 << 16)

    def serial():
        ingest.build_archive(
            BitSlicedIndex.build(cfg, "idl", n_files=n_files), archive,
            read_len=230, chunk_reads=32)

    def sharded(n):
        ingest.build_sharded_archive(
            BitSlicedIndex.build(cfg, "idl", n_files=n_files), archive,
            n_shards=n, read_len=230, chunk_reads=32)

    out = {"n_files": n_files,
           "serial_s": timeit(serial, repeats=repeats, warmup=1)}
    for n in (2, 4):
        out[f"sharded_{n}_s"] = timeit(lambda: sharded(n),
                                       repeats=repeats, warmup=1)
    out["speedup_2_shards"] = out["serial_s"] / out["sharded_2_s"]
    print(f"  build: serial {out['serial_s']:.2f}s, "
          f"2 shards {out['sharded_2_s']:.2f}s, "
          f"4 shards {out['sharded_4_s']:.2f}s")
    return out


def scatter_throughput(files, queries, tmp: str, repeats: int) -> dict:
    """Closed-loop rps of the in-process router at 1 vs 2 shards."""
    stream = [queries[i % len(queries)] for i in range(64)]
    out = {"n_requests": len(stream)}
    for n in (1, 2):
        set_dir = f"{tmp}/tp-{n}"
        ingest.build_sharded_archive(
            _fresh_index("bitsliced", "idl", [f.size for f in files]),
            _items("bitsliced", files), n_shards=n, out_dir=set_dir,
            read_len=240, chunk_reads=8)
        with ScatterGatherRouter(set_dir, ScatterConfig(
                service=ServiceConfig(max_batch=8))) as router:
            router.search(stream[:8])          # warm compiles
            s = timeit(lambda: router.search(stream), repeats=repeats,
                       warmup=1)
            out[f"shards_{n}_rps"] = len(stream) / s
    print(f"  scatter: {out['shards_1_rps']:.0f} rps unsharded, "
          f"{out['shards_2_rps']:.0f} rps at 2 shards")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: parity + proc parity + shard-kill "
                         "asserts only (still rewrites BENCH_shards.json, "
                         'marked "smoke": true)')
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    files, queries = _corpus()
    res = {"host": bench_metadata(), "smoke": bool(args.smoke)}
    with tempfile.TemporaryDirectory(prefix="shards_bench_") as tmp:
        print("parity sweep (sharded build + scatter-gather vs oracle):")
        res["parity"] = parity_sweep(files, queries, tmp)
        print("proc-mode parity (real shard worker processes):")
        res["proc_parity"] = proc_parity(files, queries, tmp)
        print("shard kill -9 mid-stream:")
        res["shard_kill"] = shard_kill(files, queries, tmp)
        if not args.smoke:
            print("build scaling:")
            res["build"] = build_scaling(args.repeats)
            print("scatter throughput:")
            res["throughput"] = scatter_throughput(files, queries, tmp,
                                                   args.repeats)
    res["notes"] = [
        "parity/proc_parity/shard_kill are exactness gates asserted "
        "before this record is written — the numbers that matter are "
        "the counts (combos, dropped), not wall-clock",
        "build and throughput wall-clock run on host.cpu_count cores "
        "with ONE in-order XLA:CPU device: shard threads contend for "
        "it, so ~flat curves here are honest — the per-shard scaling "
        "the partition exists for needs one device per shard",
        "wall-clock on this box swings 2-3x run-to-run; recorded "
        "values are medians over --repeats runs",
    ]
    out_path = ROOT / "BENCH_shards.json"
    out_path.write_text(json.dumps(res, indent=2) + "\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()

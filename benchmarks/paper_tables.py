"""One benchmark per paper table/figure (scaled to this CPU harness; same
structure, same comparisons, same claims checked).

All index construction and querying goes through the unified ``GeneIndex``
API: engines from :mod:`repro.index`, hash families by name from
:mod:`repro.index.registry` (including the ``idl-bbf`` composition — no
string-dispatch ladders here)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import jax

from benchmarks.common import Csv, locality_metrics, timeit
from repro.core import idl, kmers, theory
from repro.data import genome
from repro.index import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
    ingest,
    registry,
)


# --------------------------------------------------------------------------
# Table 2: assumption 1 — faraway kmers have Jaccard 0
# --------------------------------------------------------------------------

def table2_assumptions() -> None:
    csv = Csv("table2_assumption1", ["genome_len", "P(J=0 | far)", "pairs"])
    for glen in (20_000, 100_000, 300_000):
        g = genome.synthesize_genome(glen, seed=glen)
        k, t = 31, 16
        subk = kmers.pack_kmers_np(g, t)
        # J(far pair)=0 <=> the two kmers' sub-kmer SETS are disjoint;
        # estimate over random far pairs
        rng = np.random.default_rng(0)
        n_pairs, zero = 2000, 0
        w = k - t + 1
        for _ in range(n_pairs):
            i = int(rng.integers(0, len(subk) - 2 * k))
            j = int(rng.integers(i + k, len(subk) - k))
            si = set(subk[i : i + w].tolist())
            sj = set(subk[j : j + w].tolist())
            zero += int(not (si & sj))
        csv.row(glen, zero / n_pairs, n_pairs)


def _fpr_on_poisoned(eng: PackedBloomIndex, g: np.ndarray,
                     queries: np.ndarray) -> float:
    """FPR over poisoned kmers that are NOT in the genome (batched query)."""
    hits = np.asarray(eng.query_batch(jnp.asarray(queries)))
    gk = kmers.pack_kmers_np(g, eng.cfg.k)
    fp, n_neg = 0, 0
    for row, q in zip(hits, queries):
        truth = np.isin(kmers.pack_kmers_np(q, eng.cfg.k), gk)
        fp += int((row & ~truth).sum())
        n_neg += int((~truth).sum())
    return fp / max(n_neg, 1)


# --------------------------------------------------------------------------
# Fig 5: BF vs IDL-BF across sizes m — FPR, misses, times
# --------------------------------------------------------------------------

def fig5_idlbf() -> None:
    csv = Csv("fig5_bf_vs_idlbf",
              ["m_bits", "scheme", "fpr", "page_miss", "line_miss",
               "dma_per_probe", "query_ms", "index_ms"])
    g = genome.synthesize_genome(60_000, seed=1, repeat_fraction=0.0)
    reads = genome.extract_reads(g, 230, 400, seed=2)      # ~30x coverage
    queries = genome.poison_queries(reads, seed=3)
    gj = jnp.asarray(g)
    # m spans ~2 to ~1000 bits/kmer so the small end shows the FPR curve and
    # the large end the miss-rate divergence (paper Fig 5 covers both)
    for logm in (17, 19, 21, 24, 26):
        for scheme in ("rh", "idl"):
            cfg = idl.IDLConfig(k=31, t=16, L=1 << 13, eta=4, m=1 << logm)
            # build fresh per call: insert_batch donates the engine's buffer,
            # so a pre-insert engine must not be reused across timing repeats
            t_index = timeit(
                lambda codes: PackedBloomIndex.build(cfg, scheme)
                .insert_batch(codes).words, gj)
            eng = PackedBloomIndex.build(cfg, scheme).insert_batch(gj)
            qbatch = jnp.asarray(queries[:100])
            t_query = timeit(lambda q: eng.query_batch(q), qbatch)
            fpr = _fpr_on_poisoned(eng, g, queries[:100])
            locs = np.asarray(
                registry.locations(cfg, jnp.asarray(queries[0]), scheme))
            loc_m = locality_metrics(locs, cfg.L)
            csv.row(cfg.m, scheme, fpr, loc_m["page_miss"],
                    loc_m["line_miss"], loc_m["dma_per_probe"],
                    1e3 * t_query, 1e3 * t_index)


# --------------------------------------------------------------------------
# Fig 6: pareto — time proxy vs FPR across configs (iso-FPR comparison)
# --------------------------------------------------------------------------

def fig6_pareto() -> None:
    csv = Csv("fig6_pareto",
              ["scheme", "m_bits", "eta", "fpr", "dma_per_probe", "query_ms"])
    g = genome.synthesize_genome(40_000, seed=5, repeat_fraction=0.0)
    neg = genome.poison_queries(genome.extract_reads(g, 230, 200, seed=6),
                                seed=7)
    gj = jnp.asarray(g)
    for scheme in ("rh", "idl"):
        for logm in (22, 24):
            for eta in (2, 4, 6):
                cfg = idl.IDLConfig(k=31, t=16, L=1 << 14, eta=eta,
                                    m=1 << logm)
                eng = PackedBloomIndex.build(cfg, scheme).insert_batch(gj)
                fpr = _fpr_on_poisoned(eng, g, neg[:60])
                t_q = timeit(lambda q: eng.query_batch(q),
                             jnp.asarray(neg[:60]))
                locs = np.asarray(
                    registry.locations(cfg, jnp.asarray(neg[0]), scheme))
                lm = locality_metrics(locs, cfg.L)
                csv.row(scheme, cfg.m, eta, fpr, lm["dma_per_probe"],
                        1e3 * t_q)


# --------------------------------------------------------------------------
# Fig 7: COBS vs IDL-COBS (MSMT over 10 files)
# --------------------------------------------------------------------------

def fig7_cobs() -> None:
    csv = Csv("fig7_cobs",
              ["scheme", "total_bits", "msmt_fpr", "recall", "query_ms",
               "page_miss"])
    archive = genome.synth_archive(n_files=10, genome_len=20_000, seed=9)
    sizes = [f.n_kmers for f in archive]
    genomes = jnp.asarray(np.stack([np.asarray(f.genome) for f in archive]))
    file_ids = np.asarray([f.file_id for f in archive], dtype=np.int32)
    for scheme in ("rh", "idl"):
        base_cfg = idl.IDLConfig(k=31, t=16, L=1 << 13, eta=3, m=1 << 22)
        c = CobsIndex.build(sizes, base_cfg, scheme=scheme, n_groups=2)
        c = c.insert_batch(genomes, file_ids)      # whole archive, batched
        qreads = jnp.asarray(np.stack(
            [f.reads(230, 1)[0] for f in archive[:6]]))
        t_q = timeit(lambda r: c.query_batch(r), qreads, repeats=1) / 6
        got = np.asarray(c.msmt(qreads))
        recall = int(got[np.arange(6), file_ids[:6]].sum())
        fp = int(got.sum()) - recall
        locs = np.asarray(registry.locations(
            c.groups[0].cfg, qreads[0], scheme))
        lm = locality_metrics(locs, c.groups[0].cfg.L)
        csv.row(scheme, c.total_bits, fp / (6 * (len(archive) - 1)),
                recall / 6, 1e3 * t_q, lm["page_miss"])


# --------------------------------------------------------------------------
# Table 3: RAMBO vs IDL-RAMBO (B=20, R=2, 100 files)
# --------------------------------------------------------------------------

def table3_rambo() -> None:
    csv = Csv("table3_rambo",
              ["scheme", "L_bits", "m_per_bucket", "fpr", "recall",
               "query_ms", "page_miss"])
    archive = genome.synth_archive(n_files=100, genome_len=4_000, seed=13)
    genomes = jnp.asarray(np.stack([np.asarray(f.genome) for f in archive]))
    file_ids = np.asarray([f.file_id for f in archive], dtype=np.int32)
    for scheme in ("rh", "idl"):
        for L in (1 << 11, 1 << 12):          # paper's 2k / 4k ablation
            cfg = idl.IDLConfig(k=31, t=16, L=L, eta=4, m=1 << 21)
            r = RamboIndex.build(100, cfg, scheme=scheme, B=20, R=2)
            r = r.insert_batch(genomes, file_ids)
            qreads = jnp.asarray(np.stack(
                [f.reads(230, 1)[0] for f in archive[:8]]))
            t_q = timeit(lambda q: r.msmt(q), qreads, repeats=1) / 8
            got = np.asarray(r.msmt(qreads))
            recall = int(got[np.arange(8), file_ids[:8]].sum())
            fp = int(got.sum()) - recall
            locs = np.asarray(registry.locations(cfg, qreads[0], scheme))
            lm = locality_metrics(locs, cfg.L)
            csv.row(scheme, L, cfg.m, fp / (8 * 99), recall / 8,
                    1e3 * t_q, lm["page_miss"])


# --------------------------------------------------------------------------
# Table 4: MinHash (LSH) vs RH vs IDL — cache wins vs FPR blowup
# --------------------------------------------------------------------------

def table4_lsh() -> None:
    csv = Csv("table4_lsh_vs_rh_vs_idl",
              ["hash", "fpr", "page_miss", "line_miss", "dma_per_probe"])
    g = genome.synthesize_genome(40_000, seed=17, repeat_fraction=0.0)
    neg = genome.poison_queries(genome.extract_reads(g, 230, 150, seed=18),
                                seed=19)
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 14, eta=4, m=1 << 24)
    gj = jnp.asarray(g)
    for scheme in ("lsh", "rh", "idl"):
        eng = PackedBloomIndex.build(cfg, scheme).insert_batch(gj)
        fpr = _fpr_on_poisoned(eng, g, neg[:80])
        locs = np.asarray(registry.locations(cfg, jnp.asarray(neg[0]), scheme))
        lm = locality_metrics(locs, cfg.L)
        csv.row(scheme, fpr, lm["page_miss"], lm["line_miss"],
                lm["dma_per_probe"])


# --------------------------------------------------------------------------
# Fig 8: ablation — m, eta, t, L
# --------------------------------------------------------------------------

def fig8_ablation() -> None:
    csv = Csv("fig8_ablation",
              ["param", "value", "fpr", "dma_per_probe", "query_ms"])
    g = genome.synthesize_genome(30_000, seed=21, repeat_fraction=0.0)
    neg = genome.poison_queries(genome.extract_reads(g, 230, 100, seed=22),
                                seed=23)
    gj = jnp.asarray(g)
    base = dict(k=31, t=16, L=1 << 14, eta=4, m=1 << 23)

    def run(cfg: idl.IDLConfig):
        eng = PackedBloomIndex.build(cfg, "idl").insert_batch(gj)
        fpr = _fpr_on_poisoned(eng, g, neg[:40])
        t_q = timeit(lambda q: eng.query_batch(q), jnp.asarray(neg[:40]))
        locs = np.asarray(registry.locations(cfg, jnp.asarray(neg[0]), "idl"))
        lm = locality_metrics(locs, cfg.L)
        return fpr, lm["dma_per_probe"], 1e3 * t_q

    for logm in (21, 23, 25):
        cfg = idl.IDLConfig(**{**base, "m": 1 << logm})
        csv.row("m", 1 << logm, *run(cfg))
    for eta in (2, 4, 8):
        cfg = idl.IDLConfig(**{**base, "eta": eta})
        csv.row("eta", eta, *run(cfg))
    for t in (12, 16, 20, 24):
        cfg = idl.IDLConfig(**{**base, "t": t})
        csv.row("t", t, *run(cfg))
    for logL in (10, 12, 14, 16):
        cfg = idl.IDLConfig(**{**base, "L": 1 << logL})
        csv.row("L", 1 << logL, *run(cfg))


# --------------------------------------------------------------------------
# Theorem 2 check: empirical FPR under the bound
# --------------------------------------------------------------------------

def theory_check() -> None:
    csv = Csv("theorem2_check",
              ["m_bits", "eta", "L_bits", "empirical_fpr", "thm2_bound",
               "holds"])
    g = genome.synthesize_genome(20_000, seed=29, repeat_fraction=0.0)
    gj = jnp.asarray(g)
    rng = np.random.default_rng(30)
    neg = jnp.asarray(rng.integers(0, 4, size=100_000, dtype=np.uint8))
    n = len(g) - 31 + 1
    for logm, eta, logL in ((22, 4, 12), (23, 4, 14), (24, 6, 14),
                            (21, 2, 12)):
        cfg = idl.IDLConfig(k=31, t=16, L=1 << logL, eta=eta, m=1 << logm)
        eng = PackedBloomIndex.build(cfg, "idl").insert_batch(gj)
        fpr = float(jnp.mean(eng.query_batch(neg)[0]))
        bound = theory.idl_bf_fpr_bound(cfg.m, n, cfg.eta, cfg.L, cfg.k, cfg.t)
        csv.row(cfg.m, eta, cfg.L, fpr, bound, fpr <= bound + 1e-6)


# --------------------------------------------------------------------------
# §6 sweep: measured vs predicted FPR across fill fractions × schemes.
# RH is a classic BF -> eq. (5) is an (asymptotically tight) estimate;
# IDL and the idl-bbf composition are gated by the Theorem 2 upper bound.
# Gated by tests/test_fpr_sweep.py (tolerance assertions over these rows).
# --------------------------------------------------------------------------

def fpr_sweep_rows(
    m: int = 1 << 20,
    loads: tuple = (0.05, 0.125, 0.25),
    schemes: tuple = ("rh", "idl", "idl-bbf"),
    eta: int = 4,
    L: int = 1 << 12,
    n_neg: int = 150_000,
    seed: int = 101,
) -> list:
    """Measured + §6-predicted FPR rows across load factors n/m × schemes.

    ``load`` = inserted kmers / filter bits; the resulting *fill fraction*
    (measured from the filter itself) spans the paper's operating range.
    Negatives are iid random codes — a random 31-mer collides with an
    indexed one w.p. ~n/4^31, so every query kmer counts as a negative.
    """
    rows = []
    rng = np.random.default_rng(seed)
    neg = jnp.asarray(rng.integers(0, 4, size=n_neg, dtype=np.uint8))
    k, t = 31, 16
    for load in loads:
        n = int(load * m)
        g = genome.synthesize_genome(n + k - 1, seed=seed + n,
                                     repeat_fraction=0.0)
        gj = jnp.asarray(g)
        for scheme in schemes:
            cfg = idl.IDLConfig(k=k, t=t, L=L, eta=eta, m=m)
            eng = PackedBloomIndex.build(cfg, scheme).insert_batch(gj)
            measured = float(jnp.mean(eng.query_batch(neg)[0]))
            fill = float(np.asarray(eng.fill_fraction))
            if scheme == "rh":
                predicted, kind = theory.bf_fpr(m, n, eta), "eq5"
            else:
                predicted = theory.idl_bf_fpr_bound(m, n, eta, L, k, t)
                kind = "thm2_bound"
            rows.append({
                "scheme": scheme, "m": m, "n": n, "load": load,
                "fill": fill, "measured": measured,
                "predicted": predicted, "kind": kind,
                "n_neg_kmers": n_neg - k + 1,
            })
    return rows


def fpr_sweep() -> None:
    csv = Csv("fpr_sweep_measured_vs_theory",
              ["scheme", "m_bits", "load", "fill_frac", "measured_fpr",
               "predicted", "prediction_kind", "within"])
    for r in fpr_sweep_rows(m=1 << 22, loads=(0.02, 0.05, 0.125, 0.25),
                            n_neg=200_000):
        ok = (0.5 * r["predicted"] <= r["measured"] <= 2.0 * r["predicted"]
              if r["kind"] == "eq5" else r["measured"] <= r["predicted"])
        csv.row(r["scheme"], r["m"], r["load"], r["fill"], r["measured"],
                r["predicted"], r["kind"], ok)


# --------------------------------------------------------------------------
# §3.3: Blocked-BF × IDL composition (beyond the paper's experiments — the
# paper states the two are orthogonal and integrable; we measure it).
# "idl-bbf" is an ordinary registry scheme: the engine needs no special case.
# --------------------------------------------------------------------------

def bbf_compose() -> None:
    csv = Csv("bbf_x_idl_composition",
              ["scheme", "fpr", "page_miss", "line_miss"])
    g = genome.synthesize_genome(30_000, seed=33, repeat_fraction=0.0)
    gj = jnp.asarray(g)
    rng = np.random.default_rng(34)
    neg_codes = jnp.asarray(rng.integers(0, 4, size=40_000, dtype=np.uint8))
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 14, eta=4, m=1 << 23)

    for scheme in ("rh", "idl", "idl-bbf"):
        eng = PackedBloomIndex.build(cfg, scheme).insert_batch(gj)
        fpr = float(jnp.mean(eng.query_batch(neg_codes)[0]))
        locs = np.asarray(
            registry.locations(cfg, neg_codes[:2000], scheme))
        lm = locality_metrics(locs, cfg.L)
        csv.row(scheme, fpr, lm["page_miss"], lm["line_miss"])


# --------------------------------------------------------------------------
# Minimizer quality curve: window_min sweep — recall/FPR vs index size.
# The paper claims densification knobs don't compromise quality; this
# measures it. Inserts keep only window-w minimizers (expected density
# 2/(w+1)), queries probe every kmer, so the coverage threshold is scaled
# to the expected surviving density. Gated by
# tests/test_minimizer_quality.py; row summarized in docs/CLAIMS.md.
# --------------------------------------------------------------------------

def minimizer_quality_rows(
    w_values: tuple = (1, 4, 8, 16),
    n_files: int = 8,
    genome_len: int = 4_000,
    m: int = 1 << 19,
    eta: int = 3,
    read_len: int = 230,
    theta_margin: float = 0.6,
    seed: int = 41,
) -> list:
    """Recall / decoy-FPR / set-bit count rows across minimizer windows.

    ``w = 1`` is the dense baseline (every kmer inserted). For ``w > 1``
    inserts keep only the window-``w`` minimizers; a true-positive read
    then covers ~``2/(w+1)`` of its kmers, so MSMT runs at
    ``theta = theta_margin * 2/(w+1)`` — recall at that threshold measures
    whether sub-sampling compromised quality, the decoy rate whether the
    lowered threshold let noise through, and the popcount of the index
    words measures the size actually bought.
    """
    archive = genome.synth_archive(n_files=n_files, genome_len=genome_len,
                                   seed=seed)
    file_ids = np.arange(n_files)
    qreads = jnp.asarray(np.stack(
        [np.asarray(f.reads(read_len, 1)[0]) for f in archive]))
    # true negatives: iid random reads sharing no kmers with the archive
    # (poisoned copies of indexed reads keep enough intact kmers to match
    # their source file at theta < 1 — residual signal, not noise)
    decoys = jnp.asarray(np.random.default_rng(seed + 1).integers(
        0, 4, size=(n_files, read_len), dtype=np.uint8))
    rows = []
    for w in w_values:
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=eta, m=m)
        eng = BitSlicedIndex.build(cfg, "idl", n_files=n_files)
        eng = ingest.build_archive(eng, archive, read_len=read_len,
                                   window_min=None if w <= 1 else w)
        density = 1.0 if w <= 1 else 2.0 / (w + 1)
        theta = max(0.05, theta_margin * density)
        got = np.asarray(eng.msmt(qreads, theta=theta))
        recall = float(got[file_ids, file_ids].mean())
        fp = int(got.sum()) - int(got[file_ids, file_ids].sum())
        decoy_hits = int(np.asarray(eng.msmt(decoys, theta=theta)).sum())
        bits_set = int(jax.lax.population_count(eng.words).sum())
        rows.append({
            "w": w, "theta": theta, "recall": recall,
            "fp_rate": fp / (n_files * (n_files - 1)),
            "decoy_rate": decoy_hits / (n_files * n_files),
            "bits_set": bits_set,
        })
    base_bits = rows[0]["bits_set"]
    for r in rows:
        r["rel_size"] = r["bits_set"] / base_bits
    return rows


def minimizer_quality() -> None:
    csv = Csv("minimizer_quality_window_min",
              ["window_min", "theta", "recall", "fp_rate", "decoy_rate",
               "bits_set", "rel_size"])
    for r in minimizer_quality_rows(m=1 << 21, n_files=12,
                                    genome_len=10_000):
        csv.row(r["w"], r["theta"], r["recall"], r["fp_rate"],
                r["decoy_rate"], r["bits_set"], r["rel_size"])


ALL = [table2_assumptions, fig5_idlbf, fig6_pareto, fig7_cobs, table3_rambo,
       table4_lsh, fig8_ablation, theory_check, fpr_sweep, bbf_compose,
       minimizer_quality]

"""One benchmark per paper table/figure (scaled to this CPU harness; same
structure, same comparisons, same claims checked)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, locality_metrics, timeit
from repro.core import bloom, cobs, idl, kmers, minhash, rambo, theory
from repro.data import genome


# --------------------------------------------------------------------------
# Table 2: assumption 1 — faraway kmers have Jaccard 0
# --------------------------------------------------------------------------

def table2_assumptions() -> None:
    csv = Csv("table2_assumption1", ["genome_len", "P(J=0 | far)", "pairs"])
    for glen in (20_000, 100_000, 300_000):
        g = genome.synthesize_genome(glen, seed=glen)
        k, t = 31, 16
        subk = kmers.pack_kmers_np(g, t)
        h = jnp.asarray(subk)
        # J(far pair)=0 <=> the two kmers' sub-kmer SETS are disjoint;
        # estimate over random far pairs
        rng = np.random.default_rng(0)
        n_pairs, zero = 2000, 0
        w = k - t + 1
        for _ in range(n_pairs):
            i = int(rng.integers(0, len(subk) - 2 * k))
            j = int(rng.integers(i + k, len(subk) - k))
            si = set(subk[i : i + w].tolist())
            sj = set(subk[j : j + w].tolist())
            zero += int(not (si & sj))
        csv.row(glen, zero / n_pairs, n_pairs)


# --------------------------------------------------------------------------
# Fig 5: BF vs IDL-BF across sizes m — FPR, misses, times
# --------------------------------------------------------------------------

def fig5_idlbf() -> None:
    csv = Csv("fig5_bf_vs_idlbf",
              ["m_bits", "scheme", "fpr", "page_miss", "line_miss",
               "dma_per_probe", "query_ms", "index_ms"])
    g = genome.synthesize_genome(60_000, seed=1, repeat_fraction=0.0)
    reads = genome.extract_reads(g, 230, 400, seed=2)      # ~30x coverage
    queries = genome.poison_queries(reads, seed=3)
    gj = jnp.asarray(g)
    # m spans ~2 to ~1000 bits/kmer so the small end shows the FPR curve and
    # the large end the miss-rate divergence (paper Fig 5 covers both)
    for logm in (17, 19, 21, 24, 26):
        for scheme in ("rh", "idl"):
            cfg = idl.IDLConfig(k=31, t=16, L=1 << 13, eta=4, m=1 << logm)
            bf = bloom.BloomFilter(cfg=cfg, scheme=scheme)
            index_fn = jax.jit(
                lambda codes: bloom.insert_locations(
                    bloom.empty_filter(cfg.m),
                    idl.locations(cfg, codes, scheme)))
            t_index = timeit(index_fn, gj)
            bf = dataclasses.replace(bf, bits=index_fn(gj))
            qbatch = jnp.asarray(queries[:100].reshape(-1))
            query_fn = jax.jit(
                lambda codes: bloom.query_locations(
                    bf.bits, idl.locations(cfg, codes, scheme)))
            t_query = timeit(query_fn, qbatch)
            # FPR on poisoned kmers that are NOT in the genome
            fp, n_neg = 0, 0
            for q in queries[:100]:
                hits = np.asarray(bf.query_sequence(jnp.asarray(q)))
                qk = kmers.pack_kmers_np(q, cfg.k)
                truth = np.isin(qk, kmers.pack_kmers_np(g, cfg.k))
                fp += int((hits & ~truth).sum())
                n_neg += int((~truth).sum())
            locs = np.asarray(idl.locations(cfg, jnp.asarray(queries[0]), scheme))
            loc_m = locality_metrics(locs, cfg.L)
            csv.row(cfg.m, scheme, fp / max(n_neg, 1), loc_m["page_miss"],
                    loc_m["line_miss"], loc_m["dma_per_probe"],
                    1e3 * t_query, 1e3 * t_index)


# --------------------------------------------------------------------------
# Fig 6: pareto — time proxy vs FPR across configs (iso-FPR comparison)
# --------------------------------------------------------------------------

def fig6_pareto() -> None:
    csv = Csv("fig6_pareto",
              ["scheme", "m_bits", "eta", "fpr", "dma_per_probe", "query_ms"])
    g = genome.synthesize_genome(40_000, seed=5, repeat_fraction=0.0)
    neg = genome.poison_queries(genome.extract_reads(g, 230, 200, seed=6),
                                seed=7)
    gj = jnp.asarray(g)
    for scheme in ("rh", "idl"):
        for logm in (22, 24):
            for eta in (2, 4, 6):
                cfg = idl.IDLConfig(k=31, t=16, L=1 << 14, eta=eta,
                                    m=1 << logm)
                bits = bloom.insert_locations(
                    bloom.empty_filter(cfg.m),
                    idl.locations(cfg, gj, scheme))
                bf = bloom.BloomFilter(cfg=cfg, scheme=scheme, bits=bits)
                fp, n_neg = 0, 0
                for q in neg[:60]:
                    hits = np.asarray(bf.query_sequence(jnp.asarray(q)))
                    qk = kmers.pack_kmers_np(q, cfg.k)
                    truth = np.isin(qk, kmers.pack_kmers_np(g, cfg.k))
                    fp += int((hits & ~truth).sum())
                    n_neg += int((~truth).sum())
                query_fn = jax.jit(
                    lambda codes: bloom.query_locations(
                        bf.bits, idl.locations(cfg, codes, scheme)))
                t_q = timeit(query_fn, jnp.asarray(neg[:60].reshape(-1)))
                locs = np.asarray(
                    idl.locations(cfg, jnp.asarray(neg[0]), scheme))
                lm = locality_metrics(locs, cfg.L)
                csv.row(scheme, cfg.m, eta, fp / max(n_neg, 1),
                        lm["dma_per_probe"], 1e3 * t_q)


# --------------------------------------------------------------------------
# Fig 7: COBS vs IDL-COBS (MSMT over 10 files)
# --------------------------------------------------------------------------

def fig7_cobs() -> None:
    csv = Csv("fig7_cobs",
              ["scheme", "total_bits", "msmt_fpr", "recall", "query_ms",
               "page_miss"])
    archive = genome.synth_archive(n_files=10, genome_len=20_000, seed=9)
    sizes = [f.n_kmers for f in archive]
    for scheme in ("rh", "idl"):
        base_cfg = idl.IDLConfig(k=31, t=16, L=1 << 13, eta=3, m=1 << 22)
        c = cobs.Cobs.build(sizes, base_cfg, scheme=scheme, n_groups=2)
        for f in archive:
            c = c.insert_sequence(f.file_id, jnp.asarray(f.genome))
        recall, fp, total = 0, 0, 0
        t_q = 0.0
        for f in archive[:6]:
            read = f.reads(230, 1)[0]
            t_q += timeit(lambda r: c.query_sequence(r), jnp.asarray(read),
                          repeats=1)
            got = np.asarray(c.msmt(jnp.asarray(read)))
            recall += int(got[f.file_id])
            fp += int(got.sum() - got[f.file_id])
            total += 1
        locs = np.asarray(idl.locations(
            c.groups[0].cfg, jnp.asarray(archive[0].reads(230, 1)[0]), scheme))
        lm = locality_metrics(locs, c.groups[0].cfg.L)
        csv.row(scheme, c.total_bits, fp / (total * (len(archive) - 1)),
                recall / total, 1e3 * t_q / total, lm["page_miss"])


# --------------------------------------------------------------------------
# Table 3: RAMBO vs IDL-RAMBO (B=20, R=2, 100 files)
# --------------------------------------------------------------------------

def table3_rambo() -> None:
    csv = Csv("table3_rambo",
              ["scheme", "L_bits", "m_per_bucket", "fpr", "recall",
               "query_ms", "page_miss"])
    archive = genome.synth_archive(n_files=100, genome_len=4_000, seed=13)
    for scheme in ("rh", "idl"):
        for L in (1 << 11, 1 << 12):          # paper's 2k / 4k ablation
            cfg = idl.IDLConfig(k=31, t=16, L=L, eta=4, m=1 << 21)
            r = rambo.Rambo.build(100, cfg, scheme=scheme, B=20, R=2)
            for f in archive:
                r = r.insert_sequence(f.file_id, jnp.asarray(f.genome))
            recall, fp, total = 0, 0, 0
            t_q = 0.0
            for f in archive[:8]:
                read = f.reads(230, 1)[0]
                t_q += timeit(lambda q: r.msmt(q), jnp.asarray(read),
                              repeats=1)
                got = np.asarray(r.msmt(jnp.asarray(read)))
                recall += int(got[f.file_id])
                fp += int(got.sum()) - int(got[f.file_id])
                total += 1
            locs = np.asarray(idl.locations(
                cfg, jnp.asarray(archive[0].reads(230, 1)[0]), scheme))
            lm = locality_metrics(locs, cfg.L)
            csv.row(scheme, L, cfg.m, fp / (total * 99), recall / total,
                    1e3 * t_q / total, lm["page_miss"])


# --------------------------------------------------------------------------
# Table 4: MinHash (LSH) vs RH vs IDL — cache wins vs FPR blowup
# --------------------------------------------------------------------------

def table4_lsh() -> None:
    csv = Csv("table4_lsh_vs_rh_vs_idl",
              ["hash", "fpr", "page_miss", "line_miss", "dma_per_probe"])
    g = genome.synthesize_genome(40_000, seed=17, repeat_fraction=0.0)
    neg = genome.poison_queries(genome.extract_reads(g, 230, 150, seed=18),
                                seed=19)
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 14, eta=4, m=1 << 24)
    gj = jnp.asarray(g)
    for scheme in ("lsh", "rh", "idl"):
        bits = bloom.insert_locations(
            bloom.empty_filter(cfg.m), idl.locations(cfg, gj, scheme))
        bf = bloom.BloomFilter(cfg=cfg, scheme=scheme, bits=bits)
        fp, n_neg = 0, 0
        for q in neg[:80]:
            hits = np.asarray(bf.query_sequence(jnp.asarray(q)))
            qk = kmers.pack_kmers_np(q, cfg.k)
            truth = np.isin(qk, kmers.pack_kmers_np(g, cfg.k))
            fp += int((hits & ~truth).sum())
            n_neg += int((~truth).sum())
        locs = np.asarray(idl.locations(cfg, jnp.asarray(neg[0]), scheme))
        lm = locality_metrics(locs, cfg.L)
        csv.row(scheme, fp / max(n_neg, 1), lm["page_miss"],
                lm["line_miss"], lm["dma_per_probe"])


# --------------------------------------------------------------------------
# Fig 8: ablation — m, eta, t, L
# --------------------------------------------------------------------------

def fig8_ablation() -> None:
    csv = Csv("fig8_ablation",
              ["param", "value", "fpr", "dma_per_probe", "query_ms"])
    g = genome.synthesize_genome(30_000, seed=21, repeat_fraction=0.0)
    neg = genome.poison_queries(genome.extract_reads(g, 230, 100, seed=22),
                                seed=23)
    gj = jnp.asarray(g)
    base = dict(k=31, t=16, L=1 << 14, eta=4, m=1 << 23)

    def run(cfg: idl.IDLConfig):
        bits = bloom.insert_locations(
            bloom.empty_filter(cfg.m), idl.locations(cfg, gj, "idl"))
        bf = bloom.BloomFilter(cfg=cfg, scheme="idl", bits=bits)
        fp, n_neg = 0, 0
        for q in neg[:40]:
            hits = np.asarray(bf.query_sequence(jnp.asarray(q)))
            qk = kmers.pack_kmers_np(q, cfg.k)
            truth = np.isin(qk, kmers.pack_kmers_np(g, cfg.k))
            fp += int((hits & ~truth).sum())
            n_neg += int((~truth).sum())
        fn = jax.jit(lambda codes: bloom.query_locations(
            bf.bits, idl.locations(cfg, codes, "idl")))
        t_q = timeit(fn, jnp.asarray(neg[:40].reshape(-1)))
        locs = np.asarray(idl.locations(cfg, jnp.asarray(neg[0]), "idl"))
        lm = locality_metrics(locs, cfg.L)
        return fp / max(n_neg, 1), lm["dma_per_probe"], 1e3 * t_q

    for logm in (21, 23, 25):
        cfg = idl.IDLConfig(**{**base, "m": 1 << logm})
        csv.row("m", 1 << logm, *run(cfg))
    for eta in (2, 4, 8):
        cfg = idl.IDLConfig(**{**base, "eta": eta})
        csv.row("eta", eta, *run(cfg))
    for t in (12, 16, 20, 24):
        cfg = idl.IDLConfig(**{**base, "t": t})
        csv.row("t", t, *run(cfg))
    for logL in (10, 12, 14, 16):
        cfg = idl.IDLConfig(**{**base, "L": 1 << logL})
        csv.row("L", 1 << logL, *run(cfg))


# --------------------------------------------------------------------------
# Theorem 2 check: empirical FPR under the bound
# --------------------------------------------------------------------------

def theory_check() -> None:
    csv = Csv("theorem2_check",
              ["m_bits", "eta", "L_bits", "empirical_fpr", "thm2_bound",
               "holds"])
    g = genome.synthesize_genome(20_000, seed=29, repeat_fraction=0.0)
    gj = jnp.asarray(g)
    rng = np.random.default_rng(30)
    neg = jnp.asarray(rng.integers(0, 4, size=100_000, dtype=np.uint8))
    n = len(g) - 31 + 1
    for logm, eta, logL in ((22, 4, 12), (23, 4, 14), (24, 6, 14),
                            (21, 2, 12)):
        cfg = idl.IDLConfig(k=31, t=16, L=1 << logL, eta=eta, m=1 << logm)
        bits = bloom.insert_locations(
            bloom.empty_filter(cfg.m), idl.locations(cfg, gj, "idl"))
        bf = bloom.BloomFilter(cfg=cfg, scheme="idl", bits=bits)
        fpr = float(jnp.mean(bf.query_sequence(neg)))
        bound = theory.idl_bf_fpr_bound(cfg.m, n, cfg.eta, cfg.L, cfg.k, cfg.t)
        csv.row(cfg.m, eta, cfg.L, fpr, bound, fpr <= bound + 1e-6)


# --------------------------------------------------------------------------
# §3.3: Blocked-BF × IDL composition (beyond the paper's experiments — the
# paper states the two are orthogonal and integrable; we measure it)
# --------------------------------------------------------------------------

def bbf_compose() -> None:
    csv = Csv("bbf_x_idl_composition",
              ["scheme", "fpr", "page_miss", "line_miss"])
    g = genome.synthesize_genome(30_000, seed=33, repeat_fraction=0.0)
    gj = jnp.asarray(g)
    rng = np.random.default_rng(34)
    neg_codes = jnp.asarray(rng.integers(0, 4, size=40_000, dtype=np.uint8))
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 14, eta=4, m=1 << 23)

    def loc_fn(scheme):
        if scheme == "idl+bbf":
            return lambda c: idl.idl_bbf_locations_rolling(cfg, c)
        return lambda c: idl.locations(cfg, c, scheme)

    for scheme in ("rh", "idl", "idl+bbf"):
        fn = loc_fn(scheme)
        bits = bloom.insert_locations(bloom.empty_filter(cfg.m), fn(gj))
        fpr = float(jnp.mean(bloom.query_locations(bits, fn(neg_codes))))
        locs = np.asarray(fn(jnp.asarray(neg_codes[:2000])))
        lm = locality_metrics(locs, cfg.L)
        csv.row(scheme, fpr, lm["page_miss"], lm["line_miss"])


ALL = [table2_assumptions, fig5_idlbf, fig6_pareto, fig7_cobs, table3_rambo,
       table4_lsh, fig8_ablation, theory_check, bbf_compose]

"""Roofline report: reads runs/dryrun/*.json and prints the §Roofline table."""

from __future__ import annotations

import glob
import json
import os

from repro.roofline import analysis


def load_rows(dirpath: str = "runs/dryrun") -> list[analysis.Roofline]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        rows.append(analysis.Roofline(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            chips=r["chips"], flops_per_chip=r["flops_per_chip"],
            bytes_per_chip=r["bytes_per_chip"],
            coll_bytes_per_chip=r["coll_bytes_per_chip"],
            coll_breakdown=r.get("coll_breakdown", {}),
            model_flops=r.get("model_flops"),
            memory_stats=r.get("memory_stats"),
            matmul_flops_f32=r.get("matmul_flops_f32", 0.0),
            matmul_flops_lp=r.get("matmul_flops_lp", 0.0),
        ))
    return rows


def report(dirpath: str = "runs/dryrun") -> None:
    rows = load_rows(dirpath)
    if not rows:
        print(f"(no dry-run records in {dirpath} — run "
              f"`python -m repro.launch.dryrun --all` first)")
        return
    for mesh in ("single", "multi"):
        sel = [r for r in rows if r.mesh == mesh]
        if not sel:
            continue
        print(f"\n== roofline ({mesh}-pod, {sel[0].chips} chips) ==")
        print(analysis.format_table(sel))


if __name__ == "__main__":
    report()

"""Query-path benchmark: the shared planner/executor vs the per-kmer gather.

Mirrors ``insert_batch_bench.py`` for the query side of the acceptance
criteria: 64 reads × 200 kmers against a partitioned IDL-BF at m=2^26,
measured per backend of :mod:`repro.index.query`:

* ``per_kmer_gather_loop`` — the seed semantics every engine used before
  the unified layer: one jit'd per-read packed gather per read (the path
  the CI smoke run guards against regressing to);
* ``jnp``       — the batched pure-XLA reference gather (one jit call);
* ``idl_probe`` — the planned backend: host run-length planner + the
  generalized run-coalesced executor (the Pallas ``probe_rows`` kernel on
  accelerators; its fused jnp oracle on CPU, where Mosaic is unavailable —
  same plan, bit-identical results);
* ``sharded``   — ``shard_map`` over the default 1-D device mesh.

Also reports the planner's locality metrics — run count, mean run length
and DMA bytes (n_runs × block_bytes, the TPU HBM-traffic / CPU cache-miss
proxy the paper minimizes) — for IDL vs the RH baseline.

    PYTHONPATH=src python -m benchmarks.query_batch_bench [--smoke]

Writes ``BENCH_query.json`` (full mode) next to the repo root.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_metadata, timeit_ms
from repro.core import bloom, idl
from repro.index import PackedBloomIndex, query, registry


def _time(fn, *, iters: int, result=None) -> float:
    """Median wall ms per call via the hardened warmup+median harness."""
    if result is not None:
        np.testing.assert_array_equal(np.asarray(fn()), result)
    return timeit_ms(fn, repeats=iters, warmup=2)


def run(m: int, n_reads: int, iters: int) -> dict:
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=m)
    rng = np.random.default_rng(0)
    reads = jnp.asarray(rng.integers(0, 4, size=(n_reads, 230), dtype=np.uint8))
    eng = PackedBloomIndex.build(cfg, "idl").insert_batch(reads[: n_reads // 8])

    want = np.asarray(eng.query_batch(reads, backend="jnp"))

    # seed semantics: one jit'd (η, n_k) packed gather per read
    per_read = jax.jit(
        lambda w, r: bloom.query_packed(
            w, registry.locations(cfg, r, "idl").astype(jnp.uint32)))

    def gather_loop():
        return jnp.stack([per_read(eng.words, r) for r in reads])

    timings = {
        "per_kmer_gather_loop": _time(gather_loop, iters=iters, result=want),
        "jnp": _time(lambda: eng.query_batch(reads, backend="jnp"),
                     iters=iters),
        "idl_probe": _time(
            lambda: eng.query_batch(reads, backend="idl_probe",
                                    **_cpu_executor_kw()),
            iters=iters, result=want),
        "sharded": _time(lambda: eng.query_batch(reads, backend="sharded"),
                         iters=iters, result=want),
    }

    plan = eng._plan(reads)
    locality = {}
    for scheme in ("idl", "rh"):
        sp = PackedBloomIndex.build(cfg, scheme)._plan(reads)
        rplan, _ = sp.plan_runs(reads)
        locality[scheme] = {
            "n_runs": int(rplan.n_runs),
            "n_probes": int(rplan.n_probes),
            "mean_run_len": round(rplan.n_probes / rplan.n_runs, 2),
            "planner_dma_bytes": int(sp.run_dma_bytes(rplan)),
        }

    out = {
        "config": {
            "m": m, "L": cfg.L, "eta": cfg.eta, "n_reads": n_reads,
            "read_len": 230, "n_kmers": 200, "scheme": "idl",
            "device": jax.default_backend(), "block_bytes": plan.block_bytes,
        },
        "ms_per_batch": {k: round(v, 3) for k, v in timings.items()},
        "ms_per_read": {k: round(v / n_reads, 4) for k, v in timings.items()},
        "planner_locality": locality,
        "speedups": {
            "planned_vs_per_kmer_gather": round(
                timings["per_kmer_gather_loop"] / timings["idl_probe"], 2),
            "batched_jnp_vs_per_kmer_gather": round(
                timings["per_kmer_gather_loop"] / timings["jnp"], 2),
            "planned_vs_batched_jnp": round(
                timings["jnp"] / timings["idl_probe"], 2),
            "idl_vs_rh_run_reduction": round(
                locality["rh"]["n_runs"] / locality["idl"]["n_runs"], 2),
        },
    }
    return out


def _cpu_executor_kw() -> dict:
    # no Mosaic target on CPU: execute the SAME plan with the kernel's
    # fused jnp oracle instead of the (python-stepped) Pallas interpreter
    return {"use_ref": True} if jax.default_backend() == "cpu" else {}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small config; assert parity; no JSON written")
    args = ap.parse_args()

    if args.smoke:
        res = run(m=1 << 20, n_reads=8, iters=2)
        print("smoke:", json.dumps(res["ms_per_batch"]))
        print("runs idl/rh:",
              res["planner_locality"]["idl"]["n_runs"],
              res["planner_locality"]["rh"]["n_runs"])
        return

    res = run(m=1 << 26, n_reads=64, iters=25)
    res["host"] = bench_metadata()
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_query.json"
    out_path.write_text(json.dumps(res, indent=2) + "\n")
    print(json.dumps(res, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

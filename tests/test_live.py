"""Live-index acceptance: merged base+delta answers bit-identical to a
single union index across 4 engines × {idl, rh} × {jnp, idl_probe,
sharded} × theta ∈ {1.0, 0.6} — including mid-compaction; compaction
under traffic drops zero futures and triggers zero recompiles; the delta
journal survives a crash between append and compaction publish."""

import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idl
from repro.index import lsm, store
from repro.index.engines import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
)
from repro.serving import (
    AsyncScheduler,
    GeneSearchService,
    LiveGeneSearchService,
    LiveReplicaRouter,
    RouterConfig,
    SchedulerConfig,
    ServiceConfig,
)

ENGINES = ["bloom", "cobs", "rambo", "bitsliced"]


def _cfg(m: int = 1 << 16) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)


@pytest.fixture(scope="module")
def reads(rng):
    return jnp.asarray(rng.integers(0, 4, size=(6, 120), dtype=np.uint8))


@pytest.fixture(scope="module")
def queries(reads):
    """Mixed-length stream over base AND delta-inserted reads — prefixes
    of indexed reads are guaranteed hits at theta=1, and the lengths span
    three kmer buckets (the padded valid=/need= plumbing under test)."""
    lens = [120, 100, 77, 120, 61, 99]
    return [np.asarray(reads[i][:n]) for i, n in enumerate(lens)]


def _build_base(name: str, reads, scheme: str = "idl"):
    """Base index over reads[:3] (the immutable LSM base)."""
    if name == "bloom":
        return PackedBloomIndex.build(_cfg(), scheme).insert_batch(reads[:3])
    if name == "cobs":
        return CobsIndex.build(
            [100, 200, 150], _cfg(), scheme=scheme, n_groups=2
        ).insert_batch(reads[:3], np.arange(3))
    if name == "rambo":
        return RamboIndex.build(
            5, _cfg(1 << 14), scheme=scheme, B=2, R=2
        ).insert_batch(reads[:3], np.arange(3))
    if name == "bitsliced":
        return BitSlicedIndex.build(
            _cfg(), scheme, n_files=40
        ).insert_batch(reads[:3], np.asarray([0, 9, 39]))
    raise KeyError(name)


# streaming writes: two batches over reads[3:], per-engine file ids
_WRITES = {
    "bloom": [((3, 5), None), ((5, 6), None)],
    "cobs": [((3, 5), [1, 2]), ((5, 6), [0])],
    "rambo": [((3, 5), [3, 4]), ((5, 6), [1])],
    "bitsliced": [((3, 5), [5, 17]), ((5, 6), [23])],
}


def _oracle(name: str, reads, scheme: str = "idl"):
    """The hypothetical single merged index: base + every write batch."""
    eng = _build_base(name, reads, scheme)
    for (a, b), fids in _WRITES[name]:
        eng = eng.insert_batch(
            reads[a:b], None if fids is None else np.asarray(fids))
    return eng


def _live_service(name: str, reads, scheme: str = "idl",
                  **svc_kw) -> LiveGeneSearchService:
    """Live service over the base with both write batches absorbed."""
    live = lsm.LiveIndex(_build_base(name, reads, scheme))
    svc = LiveGeneSearchService(
        live, ServiceConfig(max_batch=4, **svc_kw))
    for (a, b), fids in _WRITES[name]:
        svc.apply_insert(np.asarray(reads[a:b]), fids)
    return svc


class TestMergedQueryParity:
    """The acceptance matrix: two-probe merged serving == single union
    index, bit for bit, through the padded-bucket service front-end."""

    @pytest.mark.parametrize("theta", [1.0, 0.6])
    @pytest.mark.parametrize("backend", ["jnp", "idl_probe", "sharded"])
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("engine", ENGINES)
    def test_bit_identical_to_union_index(self, reads, queries, engine,
                                          scheme, backend, theta):
        svc = _live_service(engine, reads, scheme,
                            backend=backend, theta=theta)
        oracle = _oracle(engine, reads, scheme)
        for q, res in zip(queries, svc.search(queries)):
            want = np.asarray(oracle.msmt(jnp.asarray(q)[None],
                                          theta=theta))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)
            assert res.delta_seq == len(_WRITES[engine])

    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("engine", ENGINES)
    def test_exact_mid_compaction(self, reads, queries, engine, scheme):
        """A write lands between plan and publish: queries stay exact the
        whole way through, and the same-geometry publish reuses every
        compiled executable (zero recompiles)."""
        live = lsm.LiveIndex(_build_base(engine, reads, scheme))
        svc = LiveGeneSearchService(live, ServiceConfig(max_batch=4))
        (a, b), fids = _WRITES[engine][0]
        svc.apply_insert(np.asarray(reads[a:b]), fids)
        svc.search(queries)                      # warm every bucket
        counts0 = svc.compile_counts()
        assert all(c == 1 for c in counts0.values())

        plan = live.plan_compaction()
        merged = lsm.LiveIndex.compact(plan)     # compactor working...
        (a, b), fids = _WRITES[engine][1]
        svc.apply_insert(np.asarray(reads[a:b]), fids)   # ...write lands

        oracle = _oracle(engine, reads, scheme)
        for q, res in zip(queries, svc.search(queries)):   # mid-compaction
            want = np.asarray(oracle.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)

        version = svc.publish(merged, plan.upto_seq)
        assert version == 1
        for q, res in zip(queries, svc.search(queries)):   # post-publish
            want = np.asarray(oracle.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)
            assert res.version == 1
            assert res.delta_seq == plan.upto_seq + 1      # the late write
        assert svc.compile_counts() == counts0             # zero recompiles

    def test_second_compaction_absorbs_late_write(self, reads, queries):
        svc = _live_service("bitsliced", reads)
        svc.compact()
        assert svc.live.delta_batches() == 0
        oracle = _oracle("bitsliced", reads)
        for q, res in zip(queries, svc.search(queries)):
            want = np.asarray(oracle.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)


class TestDeltaGeometry:
    """Smaller-m deltas (bit-probe engines) and the geometry gates."""

    @pytest.mark.parametrize("engine", ["bloom", "rambo"])
    def test_small_m_delta_is_exact(self, reads, queries, engine):
        delta_cfg = _cfg(1 << 12)
        live = lsm.LiveIndex(_build_base(engine, reads),
                             delta_cfg=delta_cfg)
        for (a, b), fids in _WRITES[engine]:
            live.insert(np.asarray(reads[a:b]), fids)
        oracle = _oracle(engine, reads)
        for q in queries:
            np.testing.assert_array_equal(
                np.asarray(live.msmt(jnp.asarray(q)[None])),
                np.asarray(oracle.msmt(jnp.asarray(q)[None])))
        # different word shapes: compaction takes the replay path, and the
        # merged result still carries the BASE geometry
        live.compact_now()
        assert live.delta_batches() == 0
        for q in queries:
            np.testing.assert_array_equal(
                np.asarray(live.msmt(jnp.asarray(q)[None])),
                np.asarray(oracle.msmt(jnp.asarray(q)[None])))

    @pytest.mark.parametrize("engine", ["cobs", "bitsliced"])
    def test_row_probe_engines_reject_delta_cfg(self, reads, engine):
        with pytest.raises(ValueError, match="row geometry"):
            lsm.LiveIndex(_build_base(engine, reads),
                          delta_cfg=_cfg(1 << 12))

    def test_delta_kmer_size_must_match(self, reads):
        bad = idl.IDLConfig(k=21, t=16, L=1 << 10, eta=2, m=1 << 12)
        with pytest.raises(ValueError, match="kmer size"):
            lsm.LiveIndex(_build_base("bloom", reads), delta_cfg=bad)

    def test_publish_rejects_foreign_geometry(self, reads):
        live = lsm.LiveIndex(_build_base("bloom", reads))
        foreign = lsm.empty_delta(live.base, _cfg(1 << 12))
        with pytest.raises(ValueError, match="geometry"):
            live.publish(foreign, live.delta_seq)


class TestWriteAdmission:
    """Scheduler/router write path: acks, gating, ordering, staleness."""

    def test_static_service_is_not_writable(self, reads):
        svc = GeneSearchService(_build_base("bitsliced", reads))
        with AsyncScheduler(svc) as sched:
            with pytest.raises(TypeError, match="not writable"):
                sched.submit_insert(np.asarray(reads[3:5]),
                                    np.asarray([5, 17]))

    def test_ack_watermark_gives_read_your_writes(self, reads):
        svc = _live_service("bitsliced", reads)    # seq 1, 2 absorbed
        with AsyncScheduler(svc) as sched:
            ack = sched.submit_insert(
                np.asarray(reads[5:6]), np.asarray([30])).result(timeout=30)
            assert (ack.base_version, ack.delta_seq) == (0, 3)
            assert ack.n_reads == 1
            res = sched.submit(np.asarray(reads[5])).result(timeout=30)
            # the query dispatched after the ack resolved, so its
            # watermark covers the write — and the write is visible
            assert (res.version, res.delta_seq) >= (0, 3)
            assert 30 in res.file_ids

    def test_pause_gates_writes(self, reads):
        svc = _live_service("bitsliced", reads)
        sched = AsyncScheduler(svc)
        try:
            sched.pause()
            fut = sched.submit_insert(np.asarray(reads[5:6]),
                                      np.asarray([30]))
            time.sleep(0.05)
            assert not fut.done()          # the hot-swap window holds writes
            sched.resume()
            assert fut.result(timeout=30).delta_seq == 3
        finally:
            sched.close()

    def test_closed_scheduler_rejects_writes(self, reads):
        svc = _live_service("bitsliced", reads)
        sched = AsyncScheduler(svc)
        sched.close()
        with pytest.raises(RuntimeError, match="closed"):
            sched.submit_insert(np.asarray(reads[5:6]), np.asarray([30]))

    def test_redelivered_seq_after_publish_is_noop(self, reads):
        """A write the base already contains (explicit fleet seq <= the
        published compaction watermark) must not re-enter the delta or
        move the watermark — the laggard-replica alignment rule."""
        live = lsm.LiveIndex(_build_base("bitsliced", reads))
        (a, b), fids = _WRITES["bitsliced"][0]
        assert live.insert(np.asarray(reads[a:b]), fids, seq=1) == 1
        live.compact_now()
        assert live.insert(np.asarray(reads[a:b]), fids, seq=1) == 1
        assert live.delta_seq == 1
        assert live.delta_batches() == 0         # nothing re-applied
        (a, b), fids = _WRITES["bitsliced"][1]
        assert live.insert(np.asarray(reads[a:b]), fids, seq=2) == 2
        assert live.delta_seq == 2

    def test_lagging_replica_stays_aligned_across_compaction(
            self, reads, queries):
        """A replica that publishes a compaction while fanned writes are
        still queued must keep its watermark equal to the fleet journal
        seq (the queued writes no-op on late delivery, never re-applying
        under locally invented sequence numbers)."""
        rt = LiveReplicaRouter(
            _build_base("bitsliced", reads), ServiceConfig(max_batch=4),
            RouterConfig(n_replicas=2, policy="round_robin"))
        with rt:
            laggard = rt._replicas[1]
            laggard.scheduler.pause()        # fanned writes queue, unapplied
            futs = []
            for (a, b), fids in _WRITES["bitsliced"]:
                futs.extend(rt.insert(np.asarray(reads[a:b]),
                                      np.asarray(fids)))
            for f in futs[0::2]:             # lead replica applied both
                f.result(timeout=30)
            assert rt.compact() == 1         # publish; laggard still lags
            acks = [f.result(timeout=30) for f in futs]
            assert [a.delta_seq for a in acks[0::2]] == [1, 2]
            assert [a.delta_seq for a in acks[1::2]] == [1, 2]   # aligned
            for rep in rt._replicas:
                assert rep.service.live.delta_seq == rt.wal_seq == 2
            oracle = _oracle("bitsliced", reads)
            for q, res in zip(queries * 2, rt.search(queries * 2)):
                want = np.asarray(oracle.msmt(jnp.asarray(q)[None]))[0]
                np.testing.assert_array_equal(np.asarray(res.matches), want)
                assert res.delta_seq == 2

    def test_sustained_writes_do_not_starve_queries(self, reads):
        """Write preference is bounded: with a deep write backlog and an
        overdue query waiting, the query flushes between write bursts
        instead of after the entire backlog drains."""
        from repro.serving import scheduler as scheduler_mod

        svc = _live_service("bitsliced", reads)
        n_writes = 4 * scheduler_mod._WRITE_BURST
        sched = AsyncScheduler(svc, SchedulerConfig(max_delay_ms=0.0))
        try:
            sched.pause()                    # build the backlog atomically
            write_done = []
            wfuts = []
            for _ in range(n_writes):
                f = sched.submit_insert(np.asarray(reads[5:6]),
                                        np.asarray([30]))
                f.add_done_callback(lambda _: write_done.append(1))
                wfuts.append(f)
            writes_done_at_query = []
            qfut = sched.submit(np.asarray(reads[0]))
            qfut.add_done_callback(
                lambda _: writes_done_at_query.append(len(write_done)))
            sched.resume()
            qfut.result(timeout=60)
            for f in wfuts:
                f.result(timeout=60)
            # strict priority would ack ALL writes before the query even
            # dispatched; bounded bursts resolve it well before that
            assert writes_done_at_query[0] < n_writes
        finally:
            sched.close()

    def test_router_fans_writes_to_every_replica(self, reads, queries):
        rt = LiveReplicaRouter(
            _build_base("bitsliced", reads), ServiceConfig(max_batch=4),
            RouterConfig(n_replicas=2, policy="round_robin"))
        with rt:
            for (a, b), fids in _WRITES["bitsliced"]:
                acks = [f.result(timeout=30) for f in
                        rt.insert(np.asarray(reads[a:b]), np.asarray(fids))]
                assert len(acks) == 2
                assert len({a.delta_seq for a in acks}) == 1   # aligned
            oracle = _oracle("bitsliced", reads)
            # round_robin over 2 replicas: every replica must answer the
            # union (duplicate the stream so both serve every query)
            for q, res in zip(queries * 2, rt.search(queries * 2)):
                want = np.asarray(oracle.msmt(jnp.asarray(q)[None]))[0]
                np.testing.assert_array_equal(np.asarray(res.matches), want)

    def test_scaled_out_replica_replays_the_tail(self, reads, queries):
        rt = LiveReplicaRouter(
            _build_base("bitsliced", reads), ServiceConfig(max_batch=4),
            RouterConfig(n_replicas=1, policy="round_robin"))
        with rt:
            for (a, b), fids in _WRITES["bitsliced"]:
                for f in rt.insert(np.asarray(reads[a:b]), np.asarray(fids)):
                    f.result(timeout=30)
            rt.scale_to(2)                 # day-two replica: tail replay
            oracle = _oracle("bitsliced", reads)
            for q, res in zip(queries * 2, rt.search(queries * 2)):
                want = np.asarray(oracle.msmt(jnp.asarray(q)[None]))[0]
                np.testing.assert_array_equal(np.asarray(res.matches), want)

    def test_live_router_swap_state_is_closed_off(self, reads):
        rt = LiveReplicaRouter(_build_base("bitsliced", reads),
                               ServiceConfig(max_batch=4),
                               RouterConfig(n_replicas=1))
        with rt:
            with pytest.raises(NotImplementedError, match="compact"):
                rt.swap_state(_build_base("bitsliced", reads))


class TestCompactionUnderTraffic:
    def test_zero_drop_zero_recompile(self, reads, queries):
        """Queries stream while writes land and the fleet compacts twice:
        every future resolves, answers are exact for their stamped
        watermark, and no same-geometry publish recompiles anything."""
        rt = LiveReplicaRouter(
            _build_base("bitsliced", reads), ServiceConfig(max_batch=4),
            RouterConfig(n_replicas=2, policy="round_robin",
                         scheduler=SchedulerConfig(max_delay_ms=0.5)))
        futures = []
        stop = threading.Event()

        def submitter():
            i = 0
            while not stop.is_set():
                futures.append((i % 6, rt.submit(queries[i % 6])))
                i += 1
                time.sleep(0.0005)

        with rt:
            rt.search(queries)                       # warm every bucket
            thread = threading.Thread(target=submitter)
            thread.start()
            try:
                time.sleep(0.02)
                for f in rt.insert(np.asarray(reads[3:5]),
                                   np.asarray([5, 17])):
                    f.result(timeout=30)             # write 1: seq 1
                assert rt.compact() == 1
                time.sleep(0.02)
                for f in rt.insert(np.asarray(reads[5:6]),
                                   np.asarray([23])):
                    f.result(timeout=30)             # write 2: seq 2
                assert rt.compact() == 2
                time.sleep(0.02)
            finally:
                stop.set()
                thread.join()
            rt.drain()
            results = [(src, f.result(timeout=30)) for src, f in futures]
            assert len(results) == len(futures)      # zero dropped futures
            base_fid = {0: 0, 1: 9, 2: 39}
            write_fid = {3: 5, 4: 17, 5: 23}
            write_seq = {3: 1, 4: 2, 5: 2}
            for src, res in results:
                if src in base_fid:                  # base reads: always hit
                    assert base_fid[src] in res.file_ids, (src, res)
                elif res.version * 100 + res.delta_seq >= write_seq[src] \
                        and (res.version >= write_seq[src]
                             or res.delta_seq >= write_seq[src]):
                    # the serving watermark covers this read's write
                    assert write_fid[src] in res.file_ids, (src, res)
            versions = {res.version for _, res in results}
            assert versions <= {0, 1, 2}
            # both compactions published under traffic, zero recompiles
            counts = rt.compile_counts()
            assert all(c == 1 for per in counts.values()
                       for c in per.values()), counts


class TestCrashRecovery:
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("engine", ENGINES)
    def test_reboot_matches_no_crash_run(self, tmp_path, reads, queries,
                                         engine, scheme):
        """Writer dies after the compactor computed its merge but BEFORE
        publish/journal-truncate: reboot from snapshot+journal answers
        bit-identical to the run that never crashed."""
        snap = store.save(_build_base(engine, reads, scheme),
                          str(tmp_path / "snap"))
        wal = str(tmp_path / "delta.wal")
        live = lsm.LiveIndex.open(snap, journal_path=wal)
        for (a, b), fids in _WRITES[engine]:
            live.insert(np.asarray(reads[a:b]), fids)
        plan = live.plan_compaction()
        merged = lsm.LiveIndex.compact(plan)
        del merged                       # crash: merge lost, WAL untouched
        live.close()

        reboot = lsm.LiveIndex.open(snap, journal_path=wal)
        assert reboot.delta_seq == len(_WRITES[engine])
        oracle = _oracle(engine, reads, scheme)   # == the no-crash run
        for theta in (1.0, 0.6):
            for q in queries:
                np.testing.assert_array_equal(
                    np.asarray(reboot.msmt(jnp.asarray(q)[None],
                                           theta=theta)),
                    np.asarray(oracle.msmt(jnp.asarray(q)[None],
                                           theta=theta)))
        reboot.compact_now()             # recovery compacts cleanly too
        for q in queries:
            np.testing.assert_array_equal(
                np.asarray(reboot.msmt(jnp.asarray(q)[None])),
                np.asarray(oracle.msmt(jnp.asarray(q)[None])))
        reboot.close()

    def test_torn_tail_record_is_dropped(self, tmp_path, reads, queries):
        snap = store.save(_build_base("bitsliced", reads),
                          str(tmp_path / "snap"))
        wal = str(tmp_path / "delta.wal")
        live = lsm.LiveIndex.open(snap, journal_path=wal)
        (a, b), fids = _WRITES["bitsliced"][0]
        live.insert(np.asarray(reads[a:b]), fids)
        want = [np.asarray(live.msmt(jnp.asarray(q)[None]))
                for q in queries]
        live.close()
        with open(wal, "ab") as fh:      # crash mid-append: torn record
            fh.write(b"\x07half-a-record-then-power-loss")
        reboot = lsm.LiveIndex.open(snap, journal_path=wal)
        assert reboot.delta_seq == 1     # acked batch survives, tear doesn't
        for q, w in zip(queries, want):
            np.testing.assert_array_equal(
                np.asarray(reboot.msmt(jnp.asarray(q)[None])), w)
        # the truncated journal accepts new appends cleanly
        (a, b), fids = _WRITES["bitsliced"][1]
        assert reboot.insert(np.asarray(reads[a:b]), fids) == 2
        reboot.close()

    def test_unsaved_compaction_keeps_acked_writes_durable(
            self, tmp_path, reads, queries):
        """Crash AFTER a compaction whose merged base never reached the
        snapshot store: the journal must still hold every acked write, so
        a reboot from the stale snapshot + journal equals the oracle."""
        snap = store.save(_build_base("bitsliced", reads),
                          str(tmp_path / "snap"))
        wal = str(tmp_path / "delta.wal")
        live = lsm.LiveIndex.open(snap, journal_path=wal)
        for (a, b), fids in _WRITES["bitsliced"]:
            live.insert(np.asarray(reads[a:b]), fids)
        live.compact_now()               # no save_dir: merged base RAM-only
        assert live.delta_batches() == 0
        live.close()                     # crash: merged base lost

        reboot = lsm.LiveIndex.open(snap, journal_path=wal)
        assert reboot.delta_seq == len(_WRITES["bitsliced"])  # WAL intact
        oracle = _oracle("bitsliced", reads)
        for q in queries:
            np.testing.assert_array_equal(
                np.asarray(reboot.msmt(jnp.asarray(q)[None])),
                np.asarray(oracle.msmt(jnp.asarray(q)[None])))
        reboot.close()

    def test_saved_compaction_truncates_journal(self, tmp_path, reads,
                                                queries):
        """With the merged base written through the snapshot store, the
        journal may (and does) drop the folded records — and the saved
        snapshot + truncated journal still reboot to the oracle."""
        snap = store.save(_build_base("bitsliced", reads),
                          str(tmp_path / "snap"))
        wal = str(tmp_path / "delta.wal")
        live = lsm.LiveIndex.open(snap, journal_path=wal)
        for (a, b), fids in _WRITES["bitsliced"]:
            live.insert(np.asarray(reads[a:b]), fids)
        snap2 = str(tmp_path / "snap2")
        live.compact_now(save_dir=snap2)
        live.close()
        assert lsm.DeltaJournal(wal).records() == []     # reclaimed
        reboot = lsm.LiveIndex.open(snap2, journal_path=wal)
        oracle = _oracle("bitsliced", reads)
        for q in queries:
            np.testing.assert_array_equal(
                np.asarray(reboot.msmt(jnp.asarray(q)[None])),
                np.asarray(oracle.msmt(jnp.asarray(q)[None])))
        reboot.close()

    def test_save_base_reclaims_journal(self, tmp_path, reads):
        """A snapshot save AFTER an in-memory compaction reclaims exactly
        the records the saved base contains; later writes stay journaled."""
        snap = store.save(_build_base("bitsliced", reads),
                          str(tmp_path / "snap"))
        wal = str(tmp_path / "delta.wal")
        live = lsm.LiveIndex.open(snap, journal_path=wal)
        (a, b), fids = _WRITES["bitsliced"][0]
        live.insert(np.asarray(reads[a:b]), fids)
        live.compact_now()               # journal keeps seq 1 (unsaved)
        assert [r.seq for r in lsm.DeltaJournal(wal).records()] == [1]
        (a, b), fids = _WRITES["bitsliced"][1]
        live.insert(np.asarray(reads[a:b]), fids)        # seq 2, uncompacted
        live.save_base(str(tmp_path / "snap2"))          # base holds seq 1
        assert [r.seq for r in lsm.DeltaJournal(wal).records()] == [2]
        live.close()

    def test_service_level_reboot(self, tmp_path, reads, queries):
        snap = store.save(_build_base("bitsliced", reads),
                          str(tmp_path / "snap"))
        wal = str(tmp_path / "delta.wal")
        svc = LiveGeneSearchService.open(snap, ServiceConfig(max_batch=4),
                                         journal_path=wal)
        for (a, b), fids in _WRITES["bitsliced"]:
            svc.apply_insert(np.asarray(reads[a:b]), fids)
        svc.live.close()                 # crash before any compaction
        svc2 = LiveGeneSearchService.open(snap, ServiceConfig(max_batch=4),
                                          journal_path=wal)
        oracle = _oracle("bitsliced", reads)
        for q, res in zip(queries, svc2.search(queries)):
            want = np.asarray(oracle.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)
        svc2.live.close()


class TestDeltaJournal:
    def _records(self, reads):
        return [
            (np.asarray(reads[0:2], dtype=np.uint8), np.asarray([3, 4])),
            (np.asarray(reads[2:3], dtype=np.uint8), None),
        ]

    def test_round_trip(self, tmp_path, reads):
        path = str(tmp_path / "j.wal")
        j = lsm.DeltaJournal(path)
        for i, (r, f) in enumerate(self._records(reads)):
            j.append(i + 1, r, f)
        j.close()
        back = lsm.DeltaJournal(path).records()
        assert [r.seq for r in back] == [1, 2]
        np.testing.assert_array_equal(back[0].reads,
                                      np.asarray(reads[0:2]))
        np.testing.assert_array_equal(back[0].file_ids, [3, 4])
        assert back[1].file_ids is None

    def test_truncate_through_keeps_late_records(self, tmp_path, reads):
        path = str(tmp_path / "j.wal")
        j = lsm.DeltaJournal(path)
        for seq in (1, 2, 3):
            j.append(seq, np.asarray(reads[0:1]), None)
        j.truncate_through(2)
        assert [r.seq for r in j.records()] == [3]
        j.append(4, np.asarray(reads[1:2]), None)   # appends continue
        assert [r.seq for r in j.records()] == [3, 4]
        j.close()

    def test_corrupt_record_stops_replay(self, tmp_path, reads):
        path = str(tmp_path / "j.wal")
        j = lsm.DeltaJournal(path)
        j.append(1, np.asarray(reads[0:1]), None)
        j.append(2, np.asarray(reads[1:2]), None)
        j.close()
        size = os.path.getsize(path)
        with open(path, "r+b") as fh:    # flip a payload byte of record 2
            fh.seek(size - 10)
            byte = fh.read(1)
            fh.seek(size - 10)
            fh.write(bytes([byte[0] ^ 0xFF]))
        assert [r.seq for r in lsm.DeltaJournal(path).records()] == [1]

    def test_mid_file_corruption_rejected(self, tmp_path, reads):
        """A flipped byte in the MIDDLE of the journal (valid acked
        records after it) is not a torn tail: opening must raise instead
        of silently truncating the acked writes that follow."""
        path = str(tmp_path / "j.wal")
        j = lsm.DeltaJournal(path)
        j.append(1, np.asarray(reads[0:1]), None)
        end_of_rec1 = os.path.getsize(path)
        j.append(2, np.asarray(reads[1:2]), None)
        j.close()
        with open(path, "r+b") as fh:    # flip a payload byte of record 1
            fh.seek(end_of_rec1 - 10)
            byte = fh.read(1)
            fh.seek(end_of_rec1 - 10)
            fh.write(bytes([byte[0] ^ 0xFF]))
        with pytest.raises(lsm.JournalError, match="corrupt"):
            lsm.DeltaJournal(path)

    def test_foreign_file_rejected(self, tmp_path):
        path = str(tmp_path / "not-a-journal")
        with open(path, "wb") as fh:
            fh.write(b"PK\x03\x04 definitely a zip")
        with pytest.raises(lsm.JournalError, match="magic"):
            lsm.DeltaJournal(path)

    def test_future_version_rejected(self, tmp_path):
        import struct

        path = str(tmp_path / "future.wal")
        with open(path, "wb") as fh:
            fh.write(struct.pack("<4sI", b"IDLJ", 99))
        with pytest.raises(lsm.JournalError, match="version"):
            lsm.DeltaJournal(path)


class TestDonatedWritePath:
    """The live write path donates the delta scatter (the per-insert word
    copy used to dominate insert-to-searchable latency); plan_compaction
    copies the delta it freezes so the merge inputs survive later
    donating inserts."""

    def test_insert_donates_the_prior_delta(self, reads):
        from repro.index import state as state_mod
        live = lsm.LiveIndex(_build_base("bitsliced", reads))
        stale = live.delta
        (a, b), fids = _WRITES["bitsliced"][0]
        live.insert(np.asarray(reads[a:b]), fids)
        with pytest.raises(state_mod.StaleIndexError):
            state_mod.query(stale, np.asarray(reads[:1]))

    def test_donate_false_keeps_prior_delta_live(self, reads):
        live = lsm.LiveIndex(_build_base("bitsliced", reads))
        held = live.delta
        (a, b), fids = _WRITES["bitsliced"][0]
        live.insert(np.asarray(reads[a:b]), fids, donate=False)
        np.asarray(held.words[0])                  # opt-out: still readable

    def test_plan_survives_post_plan_donating_inserts(self, reads, queries):
        """The regression the plan-time copy prevents: an insert after
        plan_compaction donates the live delta; the frozen plan must own
        its bytes or compact() reads freed buffers."""
        live = lsm.LiveIndex(_build_base("bitsliced", reads))
        (a, b), fids = _WRITES["bitsliced"][0]
        live.insert(np.asarray(reads[a:b]), fids)
        plan = live.plan_compaction()
        (a, b), fids = _WRITES["bitsliced"][1]
        live.insert(np.asarray(reads[a:b]), fids)  # donates the live delta
        merged = lsm.LiveIndex.compact(plan)       # plan's copy still live
        live.publish(merged, plan.upto_seq)
        oracle = _oracle("bitsliced", reads)
        for q in queries:
            want = np.asarray(oracle.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(
                np.asarray(live.msmt(jnp.asarray(q)[None]))[0], want)

"""FPR-vs-theory gate (ROADMAP "paper fidelity"): the measured FPR of the
benchmarks/paper_tables.py §6 sweep must track its prediction across fill
fractions × schemes — eq. (5) two-sided for the classic-BF RH scheme,
the Theorem 2 upper bound for IDL and the idl-bbf blocked composition."""

import numpy as np
import pytest

from benchmarks.paper_tables import fpr_sweep_rows
from repro.core import theory


@pytest.fixture(scope="module")
def rows():
    # small-m instance of the same sweep the benchmark prints; loads span
    # ~18% to ~63% fill at eta=4
    return fpr_sweep_rows(m=1 << 20, loads=(0.05, 0.125, 0.25),
                          n_neg=150_000, seed=101)


def test_sweep_covers_the_matrix(rows):
    assert {r["scheme"] for r in rows} == {"rh", "idl", "idl-bbf"}
    assert len({r["load"] for r in rows}) == 3
    # fill really sweeps: the classic-BF fill matches 1 - e^{-eta n / m}
    for r in rows:
        if r["scheme"] == "rh":
            want = 1.0 - np.exp(-4 * r["n"] / r["m"])
            assert abs(r["fill"] - want) < 0.02


def test_rh_matches_eq5_two_sided(rows):
    """Classic BF: eq. (5) is an estimate, not a bound — measured FPR must
    bracket it (x2 tolerance, plus Poisson slack when hits are scarce)."""
    for r in rows:
        if r["scheme"] != "rh":
            continue
        expected_hits = r["predicted"] * r["n_neg_kmers"]
        slack = 5.0 * np.sqrt(max(expected_hits, 1.0)) / r["n_neg_kmers"]
        assert r["measured"] <= 2.0 * r["predicted"] + slack, r
        if expected_hits >= 50:
            assert r["measured"] >= 0.5 * r["predicted"] - slack, r


def test_idl_and_bbf_under_thm2_bound(rows):
    """IDL (and the §3.3 blocked composition) must sit under the Theorem 2
    upper bound at every fill fraction."""
    for r in rows:
        if r["kind"] != "thm2_bound":
            continue
        slack = 5.0 / np.sqrt(r["n_neg_kmers"])
        assert r["measured"] <= r["predicted"] + slack, r


def test_idl_tracks_rh_fpr_scaling(rows):
    """The paper's claim: IDL trades locality for (bounded) extra FPR —
    same order of magnitude as the classic BF, not a blowup."""
    by = {(r["scheme"], r["load"]): r for r in rows}
    for load in (0.125, 0.25):
        rh = by[("rh", load)]["measured"]
        idl_m = by[("idl", load)]["measured"]
        if rh > 1e-4:
            assert idl_m <= 30.0 * rh + 1e-3, (load, rh, idl_m)


def test_bound_is_monotone_in_fill():
    """Sanity on the theory side: the Thm 2 bound rises with load."""
    bounds = [theory.idl_bf_fpr_bound(1 << 20, int(f * (1 << 20)), 4, 1 << 12)
              for f in (0.05, 0.125, 0.25)]
    assert bounds == sorted(bounds)

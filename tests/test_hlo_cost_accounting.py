"""Accounting-specific tests for the HLO cost analyzer: aliasing-aware
bytes, sparse-access fusions, widening-convert collectives, trip counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_cost


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze(c.as_text()), c


class TestBytesAccounting:
    def test_scan_accumulator_not_counted_whole(self):
        """A scan writing one row per step must cost ~N rows, not N whole
        accumulators."""
        n, d = 64, 128
        x = jax.ShapeDtypeStruct((n, d), jnp.float32)

        def f(xs):
            def body(c, row):
                return c, row * 2.0
            _, out = jax.lax.scan(body, 0.0, xs)
            return out

        cost, _ = _cost(f, x)
        whole = n * d * 4
        # row-wise DUS: ~2 bytes-touched x total rows, plus boundary slack —
        # far below n x whole-accumulator
        assert cost.bytes < 20 * whole, cost.bytes

    def test_gather_fusion_charges_result_not_table(self):
        table = jax.ShapeDtypeStruct((1 << 20, 4), jnp.float32)  # 16 MB
        idx = jax.ShapeDtypeStruct((64,), jnp.int32)

        def f(t, i):
            return jnp.take(t, i, axis=0) * 2.0

        cost, _ = _cost(f, table, idx)
        assert cost.bytes < 1e6, cost.bytes  # << the 16 MB table

    def test_dot_flops_with_batch_dims(self):
        a = jax.ShapeDtypeStruct((8, 32, 64), jnp.float32)
        b = jax.ShapeDtypeStruct((8, 64, 16), jnp.float32)
        cost, _ = _cost(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
        assert cost.flops == pytest.approx(2 * 8 * 32 * 64 * 16, rel=0.01)

    def test_nested_scan_trip_counts_multiply(self):
        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

        def f(m):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ ci, None
                c, _ = jax.lax.scan(inner, c, None, length=3)
                return c, None
            c, _ = jax.lax.scan(outer, m, None, length=5)
            return c

        cost, _ = _cost(f, x)
        assert cost.flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.05)


class TestCollectiveAccounting:
    def test_widening_convert_charged_narrow(self):
        txt = """
%conv_comp (p0: bf16[1024]) -> f32[1024] {
  %p0 = bf16[1024]{0} parameter(0)
  ROOT %cv = f32[1024]{0} convert(%p0)
}

ENTRY %main (a: bf16[1024]) -> f32[1024] {
  %a = bf16[1024]{0} parameter(0)
  %convert_fusion = f32[1024]{0} fusion(%a), kind=kLoop, calls=%conv_comp
  ROOT %ag = f32[1024]{0} all-gather(%convert_fusion), replica_groups={}
}
"""
        cost = hlo_cost.analyze(txt)
        # charged at bf16 (2 bytes), not f32 (4)
        assert cost.coll_bytes["all-gather"] == 1024 * 2

    def test_plain_f32_collective_charged_full(self):
        txt = """
ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%a), replica_groups={}
}
"""
        cost = hlo_cost.analyze(txt)
        assert cost.coll_bytes["all-reduce"] == 1024 * 4

    def test_collective_inside_while_trip_multiplied(self):
        txt = """
%body (p: (s32[], f32[256])) -> (s32[], f32[256]) {
  %p = (s32[], f32[256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[256]{0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %ag = f32[256]{0} all-gather(%x), replica_groups={}
  ROOT %t = (s32[], f32[256]) tuple(%ni, %ag)
}

%cond (p: (s32[], f32[256])) -> pred[] {
  %p = (s32[], f32[256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[256]) -> (s32[], f32[256]) {
  %a = f32[256]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[256]) tuple(%z, %a)
  ROOT %w = (s32[], f32[256]) while(%t0), condition=%cond, body=%body
}
"""
        cost = hlo_cost.analyze(txt)
        assert cost.coll_bytes["all-gather"] == 12 * 256 * 4
        assert cost.coll_count == 12

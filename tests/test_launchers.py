"""Launcher CLIs run end-to-end (subprocess: fresh jax state per run)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m"] + args,
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)


@pytest.mark.slow
def test_train_launcher_recsys():
    p = _run(["repro.launch.train", "--arch", "fm", "--steps", "10",
              "--batch", "32"])
    assert p.returncode == 0, p.stderr[-1500:]
    assert "done: fm" in p.stdout


@pytest.mark.slow
def test_train_launcher_lm_with_checkpoint(tmp_path):
    p = _run(["repro.launch.train", "--arch", "granite-20b", "--steps", "6",
              "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path)])
    assert p.returncode == 0, p.stderr[-1500:]
    assert any(f.startswith("ckpt_") for f in os.listdir(tmp_path))


@pytest.mark.slow
def test_serve_launcher():
    p = _run(["repro.launch.serve", "--files", "32", "--batch", "4",
              "--requests", "2"])
    assert p.returncode == 0, p.stderr[-1500:]
    assert "recall 8/8" in p.stdout


@pytest.mark.slow
def test_serve_launcher_sharded():
    p = _run(["repro.launch.serve", "--files", "64", "--shards", "2",
              "--batch", "4", "--requests", "2"])
    assert p.returncode == 0, p.stderr[-1500:]
    assert "2 shards over the 'files' axis" in p.stdout
    assert "recall 8/8" in p.stdout

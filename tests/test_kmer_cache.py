"""Versioned membership cache (``serving.kmer_cache``) acceptance.

The invalidation contract under test, end to end:

* cache on == cache off, bit for bit (static and live services);
* a **base swap** (``swap_state`` / compaction publish) changes the
  generation and drops every entry;
* a **live write** drops no BASE entry — base rows are keyed by version
  only, delta rows live in a separate memo keyed ``(version,
  delta_seq)``, so a kmer whose cached base row says "miss" flips
  positive the moment ``router.insert`` lands it in the delta (the
  fine-grained half of the contract);
* per-batch attribution reaches ``ClusterStats.cache_hits`` /
  ``cache_lookups`` and fleet aggregation via ``merge_cache_stats``.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idl
from repro.index import lsm
from repro.index.engines import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
)
from repro.serving import (
    AsyncScheduler,
    GeneSearchService,
    KmerCache,
    KmerCacheConfig,
    LiveGeneSearchService,
    LiveReplicaRouter,
    RouterConfig,
    SchedulerConfig,
    ServiceConfig,
    merge_cache_stats,
    pack_codes,
)

ENGINES = ["bloom", "cobs", "rambo", "bitsliced"]
CACHE = KmerCacheConfig(capacity=1 << 14)


def _cfg(m: int = 1 << 16) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)


@pytest.fixture(scope="module")
def reads(rng):
    return jnp.asarray(rng.integers(0, 4, size=(6, 120), dtype=np.uint8))


@pytest.fixture(scope="module")
def queries(reads):
    lens = [120, 100, 77, 120, 61, 99]
    return [np.asarray(reads[i][:n]) for i, n in enumerate(lens)]


def _build_base(name: str, reads, scheme: str = "idl"):
    if name == "bloom":
        return PackedBloomIndex.build(_cfg(), scheme).insert_batch(reads[:3])
    if name == "cobs":
        return CobsIndex.build(
            [100, 200, 150], _cfg(), scheme=scheme, n_groups=2
        ).insert_batch(reads[:3], np.arange(3))
    if name == "rambo":
        return RamboIndex.build(
            5, _cfg(1 << 14), scheme=scheme, B=2, R=2
        ).insert_batch(reads[:3], np.arange(3))
    if name == "bitsliced":
        return BitSlicedIndex.build(
            _cfg(), scheme, n_files=40
        ).insert_batch(reads[:3], np.asarray([0, 9, 39]))
    raise KeyError(name)


def _assert_matches(results, oracle, queries, theta=None):
    kw = {} if theta is None else {"theta": theta}
    for q, res in zip(queries, results):
        want = np.asarray(oracle.msmt(jnp.asarray(q)[None], **kw))[0]
        np.testing.assert_array_equal(np.asarray(res.matches), want)


# ---------------------------------------------------------------------------
# The data structure itself.
# ---------------------------------------------------------------------------

class TestKmerCacheUnit:
    def _keys(self, *vals) -> np.ndarray:
        return np.asarray(vals, dtype=np.uint64)

    def _rows(self, *vals) -> np.ndarray:
        return np.asarray([[v, v, v] for v in vals], dtype=np.uint32)

    def test_least_recently_hit_is_evicted(self):
        c = KmerCache(2)
        c.begin(0)
        rows, hit = c.lookup(self._keys(10, 20))
        assert rows is None and not hit.any()
        c.insert(self._keys(10, 20), self._rows(1, 2))
        rows, hit = c.lookup(self._keys(10))     # refresh: 20 is now LRU
        assert hit.all() and rows[0, 0] == 1
        c.insert(self._keys(30), self._rows(3))  # past capacity -> evict 20
        rows, hit = c.lookup(self._keys(10, 20, 30))
        assert list(hit) == [True, False, True]
        np.testing.assert_array_equal(rows[0], self._rows(1)[0])
        np.testing.assert_array_equal(rows[2], self._rows(3)[0])
        assert not rows[1].any()                 # miss rows zero-filled
        assert c.evictions == 1 and len(c) == 2

    def test_generation_change_drops_everything(self):
        c = KmerCache(8)
        c.begin(0)
        c.insert(self._keys(1, 2), self._rows(1, 2))
        c.begin(0)                               # same generation: no-op
        assert len(c) == 2 and c.invalidations == 0
        c.begin(1)                               # base swapped: flush
        assert len(c) == 0 and c.invalidations == 1
        c.begin(2)                               # empty flush is not counted
        assert c.invalidations == 1

    def test_counters_and_stats_shape(self):
        c = KmerCache(8)
        c.begin(0)
        rows, hit = c.lookup(self._keys(7, 8))   # two misses
        assert rows is None and not hit.any()
        c.insert(self._keys(7), self._rows(1))
        rows, hit = c.lookup(self._keys(7, 8))   # one hit, one miss
        assert list(hit) == [True, False]
        st = c.stats()
        assert st["hits"] == 1 and st["misses"] == 3
        assert st["lookups"] == 4 and st["hit_rate"] == 0.25
        assert st["entries"] == 1 and st["capacity"] == 8
        assert c.lookups == c.hits + c.misses

    def test_nursery_folds_into_main_tier(self):
        """Entries stay findable across the nursery -> main merge and the
        store never exceeds capacity."""
        c = KmerCache(16)
        c.begin(0)
        for start in range(0, 64, 8):            # 8 inserts of 8 keys each
            keys = np.arange(start, start + 8, dtype=np.uint64)
            c.lookup(keys)
            c.insert(keys, self._rows(*range(start, start + 8)))
            assert len(c) <= 16
        rows, hit = c.lookup(np.arange(56, 64, dtype=np.uint64))
        assert hit.all()                         # newest insert survives
        assert rows[0, 0] == 56
        assert c.evictions == 64 - 16

    def test_pack_codes_is_exact_2bit_packing(self):
        rng = np.random.default_rng(0)
        reads = rng.integers(0, 4, size=(5, 47), dtype=np.uint8)
        for k in (1, 2, 5, 31, 32):
            codes = pack_codes(reads, k)
            wins = np.lib.stride_tricks.sliding_window_view(reads, k, axis=1)
            weights = (np.uint64(1)
                       << (np.uint64(2) * np.arange(k, dtype=np.uint64)))
            ref = (wins.astype(np.uint64) * weights).sum(
                -1, dtype=np.uint64)
            np.testing.assert_array_equal(codes, ref)
        # injective: every distinct kmer gets a distinct code
        all3 = np.stack(np.meshgrid(*[np.arange(4, dtype=np.uint8)] * 3),
                        axis=-1).reshape(-1, 3)
        assert len(np.unique(pack_codes(all3, 3))) == len(all3)
        with pytest.raises(ValueError):
            pack_codes(reads, 33)

    def test_pack_codes_k32_fills_the_key_exactly(self):
        # k=32 is the boundary: 64 of 64 key bits carry bases, zero to
        # spare — all-T reads must produce the all-ones key, and codes
        # must stay injective with the mask wide open
        allT = np.full((1, 40), 3, dtype=np.uint8)
        codes = pack_codes(allT, 32)
        assert codes.shape == (1, 9)
        assert (codes == np.uint64(0xFFFFFFFFFFFFFFFF)).all()
        rng = np.random.default_rng(3)
        reads = rng.integers(0, 4, size=(1, 64), dtype=np.uint8)
        assert len(np.unique(pack_codes(reads, 32))) == 64 - 32 + 1

    def test_pack_codes_rejects_k_over_32_by_name(self):
        reads = np.zeros((2, 40), dtype=np.uint8)
        with pytest.raises(ValueError, match=r"k <= 32 \(got k=33\)"):
            pack_codes(reads, 33)
        # the overflow guard fires before any length math
        with pytest.raises(ValueError, match="k <= 32"):
            pack_codes(np.zeros((2, 4), dtype=np.uint8), 64)
        # in-range k but reads too short fails on the length, by name
        with pytest.raises(ValueError, match="no 32-mers"):
            pack_codes(np.zeros((2, 20), dtype=np.uint8), 32)

    def test_capacity_is_validated(self):
        with pytest.raises(ValueError):
            KmerCache(0)
        with pytest.raises(ValueError):
            KmerCacheConfig(capacity=0)

    def test_merge_cache_stats(self):
        assert merge_cache_stats([]) is None
        assert merge_cache_stats([None, None]) is None
        c = KmerCache(4)
        c.begin(0)
        c.lookup(self._keys(9))                  # miss
        c.insert(self._keys(9), self._rows(1))
        c.lookup(self._keys(9))                  # hit
        merged = merge_cache_stats([c.stats(), None, c.stats()])
        assert merged["hits"] == 2 and merged["lookups"] == 4
        assert merged["hit_rate"] == 0.5
        assert merged["entries"] == 2            # summed, per-member view

    def test_merge_cache_stats_edge_cases(self):
        # an idle fleet has a 0.0 hit rate, not a ZeroDivisionError
        idle = KmerCache(4).stats()
        merged = merge_cache_stats([idle, idle])
        assert merged["lookups"] == 0 and merged["hit_rate"] == 0.0
        # short dicts (older workers) contribute 0 for absent counters,
        # and the merged view is a copy — mutating it can't corrupt a
        # member's live stats
        part = {"hits": 3, "misses": 1, "lookups": 4, "entries": 2,
                "capacity": 8, "evictions": 0, "invalidations": 0}
        merged = merge_cache_stats([part, {"hits": 1, "lookups": 1}])
        assert merged["hits"] == 4 and merged["lookups"] == 5
        assert merged["hit_rate"] == 0.8
        merged["hits"] = 999
        assert part["hits"] == 3


# ---------------------------------------------------------------------------
# Static serving: parity, reuse, swap invalidation.
# ---------------------------------------------------------------------------

class TestStaticServiceCache:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_cache_on_equals_cache_off(self, reads, queries, engine):
        eng = _build_base(engine, reads)
        plain = GeneSearchService(eng, ServiceConfig(max_batch=4))
        cached = GeneSearchService(
            eng, ServiceConfig(max_batch=4, kmer_cache=CACHE))
        for _ in range(2):                       # pass 2 re-probes pass 1
            for a, b in zip(plain.search(queries), cached.search(queries)):
                np.testing.assert_array_equal(np.asarray(a.matches),
                                              np.asarray(b.matches))
        st = cached.cache_stats()
        assert st["hits"] > 0
        assert st["lookups"] == st["hits"] + st["misses"]
        assert plain.cache_stats() is None
        # one compile per bucket, cache or no cache
        assert all(c == 1 for c in cached.compile_counts().values())

    def test_rh_scheme_parity(self, reads, queries):
        eng = _build_base("bitsliced", reads, scheme="rh")
        plain = GeneSearchService(eng, ServiceConfig(max_batch=4))
        cached = GeneSearchService(
            eng, ServiceConfig(max_batch=4, kmer_cache=CACHE))
        for a, b in zip(plain.search(queries), cached.search(queries)):
            np.testing.assert_array_equal(np.asarray(a.matches),
                                          np.asarray(b.matches))

    def test_swap_state_invalidates_by_generation(self, reads, queries):
        base = _build_base("bitsliced", reads)
        grown = base.insert_batch(jnp.asarray(reads[3:5]),
                                  np.asarray([5, 17]), donate=False)
        svc = GeneSearchService(
            base, ServiceConfig(max_batch=4, kmer_cache=CACHE))
        _assert_matches(svc.search(queries), base, queries)      # warm v0
        assert svc.cache_stats()["invalidations"] == 0
        svc.swap_state(grown)
        # stale rows MUST NOT answer for the new base
        _assert_matches(svc.search(queries), grown, queries)
        assert svc.cache_stats()["invalidations"] >= 1
        # ...and the cache re-warms under the new generation
        _assert_matches(svc.search(queries), grown, queries)
        assert svc.cache_stats()["hits"] > 0


# ---------------------------------------------------------------------------
# Live serving: writes flip cached answers WITHOUT invalidation;
# compaction invalidates by generation.
# ---------------------------------------------------------------------------

class TestLiveCacheSemantics:
    def test_write_flips_cached_base_miss(self, reads):
        """The fine-grained half of the contract: a kmer whose cached
        BASE row is a miss goes positive the instant a write lands it in
        the delta — the write drops only the front cache's merged rows;
        the base-row cache supplies the base half without re-probing and
        a delta probe of just those kmers supplies the flip."""
        base = _build_base("bitsliced", reads)
        svc = LiveGeneSearchService(
            lsm.LiveIndex(base),
            ServiceConfig(max_batch=4, kmer_cache=CACHE))
        probe = np.asarray(reads[3])             # absent from the base
        pre = svc.search([probe])[0]
        assert not np.asarray(pre.matches)[5]    # file 5 untouched in base
        svc.search([probe])                      # warm merged front rows
        hits_before = svc.kmer_cache.hits
        assert hits_before > 0
        svc.apply_insert(np.asarray(reads[3:5]), [5, 17])
        post = svc.search([probe])[0]
        assert np.asarray(post.matches)[5]       # the flip
        assert post.delta_seq == 1
        # the write dropped merged rows ONLY: the base cache kept every
        # entry and served the base half of the re-merge as pure hits
        assert svc.kmer_cache.invalidations == 1
        assert svc._base_cache.invalidations == 0
        assert svc._base_cache.hits > 0
        union = base.insert_batch(jnp.asarray(reads[3:5]),
                                  np.asarray([5, 17]), donate=False)
        _assert_matches(svc.search([probe]), union, [probe])

    def test_router_insert_flips_on_every_replica(self, reads, queries):
        """Same flip through ``LiveReplicaRouter``: both replicas hold a
        cached negative, the fanned write flips both, and the merged
        fleet stats still show reuse — each replica paid exactly one
        front-cache drop for the write, never a base-row drop."""
        base = _build_base("bitsliced", reads)
        rt = LiveReplicaRouter(
            base, ServiceConfig(max_batch=4, kmer_cache=CACHE),
            RouterConfig(n_replicas=2, policy="round_robin"))
        with rt:
            probe = np.asarray(reads[3])
            for res in rt.search([probe, probe]):    # one per replica
                assert not np.asarray(res.matches)[5]
            for f in rt.insert(np.asarray(reads[3:5]),
                               np.asarray([5, 17])):
                f.result(timeout=60)
            for res in rt.search([probe, probe]):
                assert np.asarray(res.matches)[5]
            union = base.insert_batch(jnp.asarray(reads[3:5]),
                                      np.asarray([5, 17]), donate=False)
            _assert_matches(rt.search(queries * 2), union, queries * 2)
            cs = rt.cache_stats()
            assert cs is not None and cs["hits"] > 0
            # one front-cache drop per replica for the fanned write; the
            # per-replica base caches never invalidate
            assert cs["invalidations"] == 2
            for svc in rt._replicas:
                assert svc.service._base_cache.invalidations == 0

    def test_compaction_publish_invalidates(self, reads, queries):
        base = _build_base("bitsliced", reads)
        svc = LiveGeneSearchService(
            lsm.LiveIndex(base),
            ServiceConfig(max_batch=4, kmer_cache=CACHE))
        svc.apply_insert(np.asarray(reads[3:5]), [5, 17])
        union = base.insert_batch(jnp.asarray(reads[3:5]),
                                  np.asarray([5, 17]), donate=False)
        _assert_matches(svc.search(queries), union, queries)     # warm
        svc.compact()                            # folds delta into the base
        # rows cached against the OLD base are gone; answers stay exact
        _assert_matches(svc.search(queries), union, queries)
        st = svc.cache_stats()
        assert st["invalidations"] >= 1
        _assert_matches(svc.search(queries), union, queries)     # re-warm
        assert st["hits"] < svc.cache_stats()["hits"]

    def test_scheduler_batches_carry_cache_counters(self, reads, queries):
        svc = GeneSearchService(
            _build_base("bitsliced", reads),
            ServiceConfig(max_batch=4, kmer_cache=CACHE))
        sched = AsyncScheduler(svc, SchedulerConfig(max_delay_ms=0.0))
        try:
            futs = [sched.submit(q) for q in queries * 3]
            for f in futs:
                f.result(timeout=60)
            recs = list(sched.stats)
            assert sum(r.cache_lookups for r in recs) > 0
            assert sum(r.cache_hits for r in recs) > 0
            assert all(r.cache_hits <= r.cache_lookups for r in recs)
            # all lookups happen on the flusher thread inside _execute,
            # so per-batch attribution sums to the cache's own totals
            st = sched.cache_stats()
            assert st["lookups"] == sum(r.cache_lookups for r in recs)
            assert st["hits"] == sum(r.cache_hits for r in recs)
        finally:
            sched.close()

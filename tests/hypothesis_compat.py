"""Optional-hypothesis shim.

``hypothesis`` is a test-only extra (see pyproject.toml). When it is not
installed, importing this module instead of ``hypothesis`` keeps collection
alive: ``@given(...)`` turns into a skip marker for just the property tests,
while every plain test in the same module still runs. Modules that are
property-tests-only should call ``pytest.importorskip("hypothesis")``
directly instead.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: any call returns None."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

"""Golden parity: the new ``repro.index`` engines must be bit-identical to
the seed semantics (uint8 scatter/gather primitives + per-read loops), for
all registered schemes × ``align`` × theta; plus the backend-parity matrix
(``idl_probe`` and ``sharded`` bit-identical to ``jnp`` for all four
engines), adapter deprecation warnings, and the one-jit-call
batched-insert guarantee."""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bloom, cobs as cobs_mod, idl, rambo as rambo_mod
from repro.data import genome
from repro.index import (
    BitSlicedIndex,
    CobsIndex,
    GeneIndex,
    PackedBloomIndex,
    RamboIndex,
    packed,
    query,
    registry,
)
from repro.serving import genesearch as gs


def _cfg(align: bool) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=3, m=1 << 20, align=align)


@pytest.fixture(scope="module")
def reads(rng):
    g = genome.synthesize_genome(4000, seed=100, repeat_fraction=0.0)
    return jnp.asarray(np.stack(genome.extract_reads(g, 230, 6, seed=101)))


class TestBloomEngineParity:
    @pytest.mark.parametrize("scheme", ["idl", "rh", "lsh", "idl-bbf"])
    @pytest.mark.parametrize("align", [True, False])
    def test_bit_identical_to_seed_primitives(self, reads, scheme, align):
        cfg = _cfg(align)
        eng = PackedBloomIndex.build(cfg, scheme).insert_batch(reads)
        # seed semantics: per-read uint8 scatter-set, uint8 gather + AND
        bits = bloom.empty_filter(cfg.m)
        for r in reads:
            bits = bloom.insert_locations(bits, registry.locations(cfg, r, scheme))
        np.testing.assert_array_equal(np.asarray(eng.bits), np.asarray(bits))
        want = np.stack([
            np.asarray(bloom.query_locations(bits, registry.locations(cfg, r, scheme)))
            for r in reads
        ])
        np.testing.assert_array_equal(np.asarray(eng.query_batch(reads)), want)
        assert want.all()  # inserted reads are members

    @pytest.mark.parametrize("theta", [1.0, 0.6])
    def test_msmt_threshold_matches_seed_rule(self, reads, theta):
        cfg = _cfg(True)
        eng = PackedBloomIndex.build(cfg, "idl").insert_batch(reads[:3])
        member = np.asarray(eng.query_batch(reads))
        need = int(np.ceil(theta * member.shape[1] - 1e-9))  # seed integer rule
        np.testing.assert_array_equal(
            np.asarray(eng.msmt(reads, theta=theta)), member.sum(axis=1) >= need
        )


class TestKernelBackend:
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("align", [True, False])
    def test_kernel_equals_jnp_backend(self, reads, scheme, align):
        cfg = _cfg(align)
        eng = PackedBloomIndex.build(cfg, scheme).insert_batch(reads[:4])
        got_jnp = np.asarray(eng.query_batch(reads, backend="jnp"))
        got_kernel = np.asarray(eng.query_batch(reads, backend="kernel"))
        np.testing.assert_array_equal(got_kernel, got_jnp)
        # and both equal the packed-word oracle
        for i, r in enumerate(reads):
            locs = registry.locations(cfg, r, scheme)
            oracle = bloom.query_packed(eng.words, locs.astype(jnp.uint32))
            np.testing.assert_array_equal(got_jnp[i], np.asarray(oracle))


def _matrix_cfg(m: int = 1 << 16) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)


def _build_matrix_engine(name: str, scheme: str, reads) -> GeneIndex:
    fids = np.arange(reads.shape[0])
    if name == "bloom":
        return PackedBloomIndex.build(_matrix_cfg(), scheme).insert_batch(
            reads[:2])
    if name == "cobs":
        return CobsIndex.build(
            [100, 200, 150], _matrix_cfg(), scheme=scheme, n_groups=2
        ).insert_batch(reads, fids)
    if name == "rambo":
        return RamboIndex.build(
            reads.shape[0] + 2, _matrix_cfg(1 << 14), scheme=scheme, B=2, R=2
        ).insert_batch(reads, fids)
    if name == "bitsliced":
        return BitSlicedIndex.build(
            _matrix_cfg(), scheme, n_files=40
        ).insert_batch(reads, np.asarray([0, 9, 39]))
    raise KeyError(name)


class TestBackendParityMatrix:
    """Acceptance matrix: every engine × scheme, ``idl_probe`` and
    ``sharded`` bit-identical to ``jnp`` (sharded on the default 1-device
    mesh here; the >1-device case is skip-guarded below)."""

    @pytest.fixture(scope="class")
    def qreads(self):
        r = np.random.default_rng(7).integers(0, 4, size=(3, 120),
                                              dtype=np.uint8)
        return jnp.asarray(r)

    @pytest.mark.parametrize("engine", ["bloom", "cobs", "rambo", "bitsliced"])
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    def test_backends_bit_identical(self, qreads, engine, scheme):
        eng = _build_matrix_engine(engine, scheme, qreads)
        want = np.asarray(eng.query_batch(qreads, backend="jnp"))
        got_planned = np.asarray(eng.query_batch(qreads, backend="idl_probe"))
        got_sharded = np.asarray(eng.query_batch(qreads, backend="sharded"))
        np.testing.assert_array_equal(got_planned, want)
        np.testing.assert_array_equal(got_sharded, want)

    @pytest.mark.parametrize("scheme", ["lsh", "idl-bbf"])
    def test_rolling_only_schemes_on_flat_engine(self, qreads, scheme):
        eng = _build_matrix_engine("bloom", scheme, qreads)
        want = np.asarray(eng.query_batch(qreads, backend="jnp"))
        for backend in ("idl_probe", "sharded"):
            np.testing.assert_array_equal(
                np.asarray(eng.query_batch(qreads, backend=backend)), want)

    @pytest.mark.parametrize("theta", [1.0, 0.6])
    @pytest.mark.parametrize("engine", ["bloom", "cobs", "rambo", "bitsliced"])
    def test_msmt_backend_passthrough(self, qreads, engine, theta):
        eng = _build_matrix_engine(engine, "idl", qreads)
        want = np.asarray(eng.msmt(qreads, theta=theta))
        for backend in ("idl_probe", "sharded"):
            np.testing.assert_array_equal(
                np.asarray(eng.msmt(qreads, theta=theta, backend=backend)),
                want)

    @pytest.mark.parametrize("theta", [1.0, 0.6])
    def test_serve_geometry_query_backends(self, qreads, theta):
        # the serve-layout probe (survivor plan helpers of the removed v1
        # serve_step) stays bit-identical across query backends
        cfg = gs.GeneSearchConfig(n_files=64, m=1 << 16, L=1 << 10,
                                  read_len=120, eta=2, theta=theta)
        idx = jnp.zeros((cfg.m, cfg.file_words), dtype=jnp.uint32)
        idx = gs.insert_plan(cfg, qreads.shape[0], idx.shape).execute(
            idx, qreads, jnp.asarray([0, 31, 63], dtype=jnp.int32))
        plan = gs.query_plan(cfg, qreads.shape[0], idx.shape)

        def serve(backend):
            per_kmer = plan.execute(idx, qreads, backend=backend)
            return np.asarray(query.file_match_mask(per_kmer, cfg.theta))

        want = serve("jnp")
        for backend in ("idl_probe", "sharded"):
            np.testing.assert_array_equal(serve(backend), want)

    def test_plans_are_cached(self, qreads):
        query.clear_plan_cache()
        eng = _build_matrix_engine("bloom", "idl", qreads)
        eng.query_batch(qreads)
        assert query.plan_cache_info().currsize == 1
        eng.query_batch(qreads, backend="sharded")   # same geometry
        eng.query_batch(qreads, backend="idl_probe")
        assert query.plan_cache_info().currsize == 1
        assert query.plan_cache_info().hits >= 2

    @pytest.mark.skipif(jax.device_count() < 2,
                        reason="needs a multi-device mesh")
    @pytest.mark.parametrize("engine", ["bloom", "cobs", "rambo", "bitsliced"])
    def test_sharded_multi_device(self, qreads, engine):
        from jax.sharding import Mesh

        mesh = Mesh(np.asarray(jax.devices()), (query.MESH_AXIS,))
        eng = _build_matrix_engine(engine, "idl", qreads)
        want = np.asarray(eng.query_batch(qreads, backend="jnp"))
        got = np.asarray(
            eng.query_batch(qreads, backend="sharded", mesh=mesh))
        np.testing.assert_array_equal(got, want)


class TestDeprecatedAdapters:
    def test_adapter_constructors_warn(self):
        cfg = _cfg(True)
        with pytest.warns(DeprecationWarning, match="PackedBloomIndex"):
            bloom.BloomFilter(cfg=cfg)
        with pytest.warns(DeprecationWarning, match="CobsIndex"):
            cobs_mod.Cobs.build([100, 200], cfg)
        with pytest.warns(DeprecationWarning, match="RamboIndex"):
            rambo_mod.Rambo.build(4, cfg, B=2, R=2)

    def test_new_engines_do_not_warn(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            PackedBloomIndex.build(_cfg(True), "idl")
            RamboIndex.build(4, _cfg(True), B=2, R=2)


def _seed_cobs_reference(file_sizes, base_cfg, scheme, genomes, theta):
    """The seed Cobs algorithm, verbatim: uint8 group matrices, python loops."""
    order = np.argsort(file_sizes)
    chunks = np.array_split(order, 3)
    groups = []  # (cfg, file_ids, matrix)
    for chunk in chunks:
        if len(chunk) == 0:
            continue
        biggest = max(int(file_sizes[i]) for i in chunk)
        m_g = -(-int(10.0 * biggest) // (1 << 12)) * (1 << 12)
        m_g = max(m_g, base_cfg.eta * (base_cfg.L * 2))
        cfg = dataclasses.replace(base_cfg, m=m_g)
        fids = [int(i) for i in chunk]
        groups.append([cfg, fids, jnp.zeros((m_g, len(fids)), dtype=jnp.uint8)])
    for fid, codes in enumerate(genomes):
        for grp in groups:
            if fid in grp[1]:
                locs = registry.locations(grp[0], codes, scheme)
                grp[2] = grp[2].at[locs.reshape(-1), grp[1].index(fid)].set(
                    np.uint8(1))
    n_kmers = genomes.shape[1] - base_cfg.k + 1
    out = np.zeros((len(genomes), n_kmers, len(file_sizes)), dtype=bool)
    for q, codes in enumerate(genomes):
        for cfg, fids, mat in groups:
            locs = registry.locations(cfg, codes, scheme)
            rows = mat[locs]
            out[q][:, np.asarray(fids)] = np.asarray(
                jnp.all(rows == np.uint8(1), axis=0))
    hits = out.sum(axis=1)
    need = int(np.ceil(theta * n_kmers - 1e-9))
    return out, hits >= need


class TestCobsEngineParity:
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("theta", [1.0, 0.6])
    def test_bit_identical_to_seed_algorithm(self, rng, scheme, theta):
        base_cfg = _cfg(True)
        genomes = jnp.asarray(rng.integers(0, 4, size=(6, 400), dtype=np.uint8))
        sizes = [370, 120, 800, 240, 500, 310]
        want_slices, want_msmt = _seed_cobs_reference(
            sizes, base_cfg, scheme, genomes, theta)
        eng = CobsIndex.build(sizes, base_cfg, scheme=scheme, n_groups=3)
        eng = eng.insert_batch(genomes, np.arange(6))
        np.testing.assert_array_equal(
            np.asarray(eng.query_batch(genomes)), want_slices)
        np.testing.assert_array_equal(
            np.asarray(eng.msmt(genomes, theta=theta)), want_msmt)

    def test_build_validates_inputs(self):
        with pytest.raises(ValueError):
            CobsIndex.build([], _cfg(True))
        mixed = dataclasses.replace(_cfg(True), k=25)
        good = CobsIndex.build([100, 200], _cfg(True))
        with pytest.raises(ValueError):
            dataclasses.replace(good, k=mixed.k)  # top-level k must match groups


def _seed_rambo_reference(n_files, cfg, scheme, genomes, B, R, theta):
    """The seed Rambo algorithm: uint8 stacked filters, per-rep python loop."""
    from repro.index.engines import rambo_assignment

    assignment = rambo_assignment(n_files, B, R)
    filt = jnp.zeros((R * B, cfg.m), dtype=jnp.uint8)
    for fid, codes in enumerate(genomes):
        locs = registry.locations(cfg, codes, scheme).reshape(-1)
        for r in range(R):
            row = r * B + int(assignment[r, fid])
            filt = filt.at[row, locs].set(np.uint8(1))
    outs = []
    for codes in genomes:
        locs = registry.locations(cfg, codes, scheme)
        bits = filt[:, locs]
        hit = jnp.all(bits == np.uint8(1), axis=1)
        grid = hit.T.reshape(-1, R, B)
        assign = jnp.asarray(assignment)
        per_rep = jnp.take_along_axis(
            grid, assign.T[None, :, :].transpose(0, 2, 1), axis=2)
        present = jnp.all(per_rep, axis=1)
        hits = jnp.sum(present.astype(jnp.int32), axis=0)
        need = int(np.ceil(theta * present.shape[0] - 1e-9))
        outs.append(np.asarray(hits >= need))
    return np.stack(outs)


class TestRamboEngineParity:
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("theta", [1.0, 0.6])
    def test_bit_identical_to_seed_algorithm(self, rng, scheme, theta):
        cfg = _cfg(True)
        genomes = jnp.asarray(rng.integers(0, 4, size=(7, 400), dtype=np.uint8))
        want = _seed_rambo_reference(7, cfg, scheme, genomes, B=3, R=2,
                                     theta=theta)
        eng = RamboIndex.build(7, cfg, scheme=scheme, B=3, R=2)
        eng = eng.insert_batch(genomes, np.arange(7))
        np.testing.assert_array_equal(
            np.asarray(eng.msmt(genomes, theta=theta)), want)


def _seed_insert_read(index, cfg, file_id, codes):
    """The seed's insert_read, verbatim: per-file column read-modify-write."""
    locs = registry.locations32(cfg.idl_config(), codes, cfg.scheme).reshape(-1)
    word = file_id // 32
    bit = jnp.uint32(1) << jnp.uint32(file_id % 32)
    col = index[:, word].at[locs].set(index[locs, word] | bit)
    return index.at[:, word].set(col)


class TestBitSlicedEngineParity:
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("theta", [1.0, 0.6])
    def test_matches_seed_reference(self, rng, scheme, theta):
        cfg = gs.GeneSearchConfig(n_files=64, m=1 << 18, L=1 << 10,
                                  read_len=120, eta=2, scheme=scheme,
                                  theta=theta)
        reads = jnp.asarray(rng.integers(0, 4, size=(5, 120), dtype=np.uint8))
        fids = np.asarray([0, 9, 31, 32, 63])
        eng = BitSlicedIndex.build(cfg.idl_config(), scheme, cfg.n_files)
        eng = eng.insert_batch(reads, fids)
        # independent seed oracle: per-read column scatter into the raw matrix
        index = jnp.zeros((cfg.m, cfg.file_words), dtype=jnp.uint32)
        for f, r in zip(fids, reads):
            index = _seed_insert_read(index, cfg, int(f), r)
        np.testing.assert_array_equal(np.asarray(eng.words), np.asarray(index))
        # the serve-layout probe over the raw matrix agrees with engine msmt
        per_kmer = gs.query_plan(cfg, reads.shape[0], index.shape).execute(
            index, reads)
        served = query.file_match_mask(per_kmer, cfg.theta)
        want = np.asarray(packed.unpack_file_bits(served, cfg.n_files))
        np.testing.assert_array_equal(
            np.asarray(eng.msmt(reads, theta=theta)), want)


class TestBatchedInsert:
    def test_64_reads_one_jit_call_and_sequential_parity(self, rng):
        from repro.index import ingest

        cfg = _cfg(True)
        reads = jnp.asarray(rng.integers(0, 4, size=(64, 230), dtype=np.uint8))
        ingest._execute_jnp.clear_cache()
        eng = PackedBloomIndex.build(cfg, "idl").insert_batch(reads)
        assert ingest._execute_jnp._cache_size() == 1  # one compilation
        eng2 = PackedBloomIndex.build(cfg, "idl").insert_batch(reads[:32])
        eng2 = eng2.insert_batch(reads[32:])
        assert ingest._execute_jnp._cache_size() == 2  # new shape only
        np.testing.assert_array_equal(np.asarray(eng.words),
                                      np.asarray(eng2.words))
        # and equals one-read-at-a-time insertion
        seq = PackedBloomIndex.build(cfg, "idl")
        for r in reads:
            seq = seq.insert_batch(r)
        np.testing.assert_array_equal(np.asarray(eng.words),
                                      np.asarray(seq.words))

    def test_dedup_drops_duplicate_locations(self):
        words = jnp.zeros((4,), dtype=jnp.uint32)
        locs = jnp.asarray([0, 0, 1, 33, 33, 127], dtype=jnp.uint32)
        got = packed.scatter_or(words, locs)
        np.testing.assert_array_equal(
            np.asarray(got), [0b11, 1 << 1, 0, 1 << 31])


class TestProtocol:
    def test_all_engines_satisfy_gene_index(self, rng):
        cfg = _cfg(True)
        engines_list = [
            PackedBloomIndex.build(cfg, "idl"),
            CobsIndex.build([100, 200], cfg),
            RamboIndex.build(4, cfg, B=2, R=2),
            BitSlicedIndex.build(cfg, "idl", n_files=32),
        ]
        for e in engines_list:
            assert isinstance(e, GeneIndex)

    def test_registry_rejects_unknown_scheme(self):
        with pytest.raises(ValueError, match="unknown hash scheme"):
            registry.get("murmur")
        assert set(registry.names()) >= {"idl", "rh", "lsh", "idl-bbf"}

"""Unit + property tests for hashing, kmers, minhash — the paper's substrate."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import hashing, kmers, minhash


class TestHashing:
    def test_hash_to_range_in_range(self):
        x = jnp.arange(10000, dtype=jnp.uint64)
        for m in (7, 64, 1000, 1 << 20):
            h = hashing.hash_to_range(x, 42, m)
            assert int(h.max()) < m
            assert int(h.min()) >= 0

    def test_seeds_decorrelate(self):
        x = jnp.arange(10000, dtype=jnp.uint64)
        h1 = hashing.hash_to_range(x, 1, 1 << 16)
        h2 = hashing.hash_to_range(x, 2, 1 << 16)
        assert float(jnp.mean((h1 == h2).astype(jnp.float32))) < 0.01

    def test_uniformity(self):
        """Chi-square-ish: bucket counts close to uniform."""
        x = jnp.arange(1 << 16, dtype=jnp.uint64)
        h = np.asarray(hashing.hash_to_range(x, 7, 256))
        counts = np.bincount(h, minlength=256)
        expected = (1 << 16) / 256
        assert np.abs(counts - expected).max() < 5 * np.sqrt(expected)

    def test_np_mirror_matches_jax(self):
        x = np.arange(1000, dtype=np.uint64)
        got_np = hashing.np_hash_to_range(x, 9, 1 << 20)
        got_jx = np.asarray(hashing.hash_to_range(jnp.asarray(x), 9, 1 << 20))
        np.testing.assert_array_equal(got_np, got_jx)

    def test_pair32_determinism_and_range(self):
        hi = jnp.arange(1000, dtype=jnp.uint32)
        lo = jnp.arange(1000, 2000, dtype=jnp.uint32)
        a = hashing.hash_pair32_to_range(hi, lo, 3, 4096)
        b = hashing.hash_pair32_to_range(hi, lo, 3, 4096)
        assert jnp.all(a == b)
        assert int(a.max()) < 4096

    @given(st.integers(2, 1 << 30))
    @settings(max_examples=30, deadline=None)
    def test_hash32_range_property(self, m):
        h = jnp.arange(0, 1 << 16, 97, dtype=jnp.uint32) * jnp.uint32(2654435761)
        r = hashing.hash32_to_range(h, m)
        assert int(r.max()) < m


class TestKmers:
    def test_pack_matches_manual(self):
        codes = jnp.asarray([0, 1, 2, 3, 0, 1], dtype=jnp.uint8)
        got = kmers.pack_kmers(codes, 3)
        # kmer 0 = (0,1,2) -> 0b000110 = 6
        want = [0b000110, 0b011011, 0b101100, 0b110001]
        np.testing.assert_array_equal(np.asarray(got), want)

    def test_pack_np_matches_jax(self, rng):
        codes = rng.integers(0, 4, size=500, dtype=np.uint8)
        np.testing.assert_array_equal(
            kmers.pack_kmers_np(codes, 31),
            np.asarray(kmers.pack_kmers(jnp.asarray(codes), 31)),
        )

    def test_pair32_matches_u64(self, rng):
        codes = jnp.asarray(rng.integers(0, 4, size=300, dtype=np.uint8))
        k = 31
        full = np.asarray(kmers.pack_kmers(codes, k))
        hi, lo = kmers.pack_kmers_pair32(codes, k)
        rebuilt = (np.asarray(hi, dtype=np.uint64) << np.uint64(32)) | np.asarray(
            lo, dtype=np.uint64
        )
        np.testing.assert_array_equal(full, rebuilt)

    def test_unpack_roundtrip(self, rng):
        codes = rng.integers(0, 4, size=40, dtype=np.uint8)
        packed = kmers.pack_kmers_np(codes, 31)
        s = kmers.decode_bases(codes)
        assert kmers.unpack_kmer(int(packed[0]), 31) == s[:31]
        assert kmers.unpack_kmer(int(packed[5]), 31) == s[5 : 5 + 31]

    def test_encode_decode(self):
        s = "ACGTACGTNNGG"
        codes = kmers.encode_bases(s)
        assert kmers.decode_bases(codes) == "ACGTACGTAAGG"  # N -> A

    @given(st.integers(1, 31), st.integers(0, 2**32))
    @settings(max_examples=50, deadline=None)
    def test_pack_roundtrip_property(self, k, seed):
        r = np.random.default_rng(seed)
        codes = r.integers(0, 4, size=k + 10, dtype=np.uint8)
        packed = kmers.pack_kmers_np(codes, k)
        for i in (0, len(packed) - 1):
            assert kmers.unpack_kmer(int(packed[i]), k) == kmers.decode_bases(
                codes[i : i + k]
            )


class TestSlidingWindowMin:
    def naive(self, a, w):
        return np.array([a[i : i + w].min() for i in range(len(a) - w + 1)])

    @pytest.mark.parametrize("n,w", [(10, 1), (10, 3), (100, 16), (1000, 7),
                                     (64, 64), (65, 64), (129, 16)])
    def test_matches_naive(self, rng, n, w):
        a = rng.integers(0, 2**63, size=n, dtype=np.uint64)
        got = np.asarray(minhash.sliding_window_min(jnp.asarray(a), w))
        np.testing.assert_array_equal(got, self.naive(a, w))

    @given(st.integers(1, 64), st.integers(0, 2**32))
    @settings(max_examples=50, deadline=None)
    def test_property(self, w, seed):
        r = np.random.default_rng(seed)
        n = w + int(r.integers(0, 100))
        a = r.integers(0, 2**32, size=n, dtype=np.uint64)
        got = np.asarray(minhash.sliding_window_min(jnp.asarray(a), w))
        np.testing.assert_array_equal(got, self.naive(a, w))


class TestMinHash:
    def test_rolling_equals_batch(self, rng):
        """Rolling MinHash over a sequence == per-kmer batch MinHash."""
        codes = jnp.asarray(rng.integers(0, 4, size=400, dtype=np.uint8))
        k, t, eta = 31, 16, 4
        subk = kmers.pack_kmers(codes, t)
        roll = minhash.doph_minhash(subk, k - t + 1, eta)
        kmer_arr = kmers.pack_kmers(codes, k)
        batch = minhash.minhash_kmer_batch(kmer_arr, k, t, eta)
        np.testing.assert_array_equal(np.asarray(roll), np.asarray(batch))

    def test_exact_mode_rolling_equals_batch(self, rng):
        codes = jnp.asarray(rng.integers(0, 4, size=300, dtype=np.uint8))
        k, t = 31, 12
        seeds = [11, 22, 33]
        subk = kmers.pack_kmers(codes, t)
        roll = minhash.minhash_exact(subk, k - t + 1, seeds)
        kmer_arr = kmers.pack_kmers(codes, k)
        batch = minhash.minhash_kmer_batch(
            kmer_arr, k, t, len(seeds), mode="exact", seeds=seeds
        )
        np.testing.assert_array_equal(np.asarray(roll), np.asarray(batch))

    def test_collision_prob_tracks_jaccard(self, rng):
        """MinHash collision rate ≈ Jaccard similarity (eq. 4)."""
        k, t = 31, 16
        n_pairs, hits, jac = 400, 0, 0.0
        codes = rng.integers(0, 4, size=n_pairs + k + 1, dtype=np.uint8)
        kmer_arr = kmers.pack_kmers_np(codes, k)
        mh = np.asarray(
            minhash.minhash_kmer_batch(
                jnp.asarray(kmer_arr), k, t, 1, mode="exact", seeds=[5]
            )
        )[0]
        for i in range(n_pairs):
            jac += minhash.jaccard_subkmers(kmer_arr[i], kmer_arr[i + 1], k, t)
            hits += int(mh[i] == mh[i + 1])
        emp, expect = hits / n_pairs, jac / n_pairs
        assert abs(emp - expect) < 0.08

    def test_doph_densifies_all_bins(self, rng):
        codes = jnp.asarray(rng.integers(0, 4, size=200, dtype=np.uint8))
        subk = kmers.pack_kmers(codes, 16)
        mh = minhash.doph_minhash(subk, 16, 8)
        assert not bool(jnp.any(mh == minhash.UINT64_MAX))

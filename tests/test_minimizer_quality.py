"""Minimizer quality gate (ROADMAP "minimizer quality curve"): the
benchmarks/paper_tables.py window_min sweep must show sub-sampled inserts
shrinking the index without compromising retrieval quality — recall stays
perfect at the density-scaled threshold and the lowered threshold does not
let decoys through. Summarized as a measured row in docs/CLAIMS.md."""

import pytest

from benchmarks.paper_tables import minimizer_quality_rows


@pytest.fixture(scope="module")
def rows():
    # small-m instance of the same sweep the benchmark prints
    return minimizer_quality_rows(w_values=(1, 4, 8, 16), n_files=6,
                                  genome_len=3_000, m=1 << 18, seed=41)


def test_sweep_covers_the_windows(rows):
    assert [r["w"] for r in rows] == [1, 4, 8, 16]
    assert rows[0]["theta"] == 1.0 * 0.6 or rows[0]["theta"] <= 1.0


def test_recall_survives_subsampling(rows):
    """'Without compromising quality': every file still retrieves its own
    read at the density-scaled coverage threshold, for every window."""
    for r in rows:
        assert r["recall"] == 1.0, r


def test_no_noise_through_lowered_threshold(rows):
    """The scaled-down theta must not open the door to cross-file or
    poisoned-decoy matches."""
    for r in rows:
        assert r["fp_rate"] <= 0.05, r
        assert r["decoy_rate"] <= 0.05, r


def test_index_size_shrinks_with_window(rows):
    """The knob actually buys size: set bits strictly decrease with w and
    w=16 keeps well under half the dense baseline's bits (expected
    minimizer density is 2/(w+1) ~ 12%)."""
    bits = [r["bits_set"] for r in rows]
    assert bits == sorted(bits, reverse=True)
    assert all(b1 > b2 for b1, b2 in zip(bits, bits[1:]))
    assert rows[-1]["rel_size"] < 0.5
    assert rows[0]["rel_size"] == 1.0

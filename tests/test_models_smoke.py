"""Per-architecture smoke tests: every assigned arch's REDUCED config runs
one forward/train step on CPU with finite outputs and sane shapes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs import base as cfg_base
from repro.data import graph_pipeline, recsys_pipeline
from repro.models import equiformer as eq, recsys, transformer as tf
from repro.serving import genesearch as gs
from repro.train import optimizer as opt_mod, train_state as ts

KEY = jax.random.PRNGKey(0)
LM_ARCHS = ["arctic-480b", "granite-moe-1b-a400m", "granite-20b",
            "nemotron-4-340b", "internlm2-20b"]


def test_all_archs_registered():
    assert set(configs.all_archs()) == {
        "arctic-480b", "granite-moe-1b-a400m", "granite-20b",
        "nemotron-4-340b", "internlm2-20b", "equiformer-v2",
        "sasrec", "fm", "two-tower-retrieval", "mind", "idl-genesearch",
    }


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestLMSmoke:
    def test_train_step(self, arch, rng):
        cfg = configs.get(arch).make_smoke_config()
        params = tf.lm_init(KEY, cfg)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16), np.int32)),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16), np.int32)),
        }
        step = ts.make_train_step(
            lambda p, b: tf.lm_loss(p, b, cfg, loss_chunks=4),
            opt_mod.adamw(1e-3))
        state = ts.TrainState.create(params, opt_mod.adamw(1e-3))
        state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(metrics["loss"])
        assert int(state.step) == 1

    def test_prefill_decode_consistency(self, arch, rng):
        """Prefill then one decode step == forward on the extended sequence.

        MoE capacity is raised so no token drops occur — with drops the
        equality is not expected (different T between prefill and forward
        changes the routing capacity; standard GShard semantics)."""
        cfg = configs.get(arch).make_smoke_config()
        if cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
        params = tf.lm_init(KEY, cfg)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8), np.int32))
        logits_p, cache = tf.lm_prefill(params, toks, cfg)
        # pad cache to max_len
        full = tf.init_kv_cache(cfg, 2, 16, dtype=jnp.bfloat16)
        full["k"] = full["k"].at[:, :, :8].set(cache["k"])
        full["v"] = full["v"].at[:, :, :8].set(cache["v"])
        full["len"] = cache["len"]
        nxt = jnp.asarray(rng.integers(0, cfg.vocab, (2,), np.int32))
        logits_d, _ = tf.lm_decode_step(params, full, nxt, cfg)
        ext = jnp.concatenate([toks, nxt[:, None]], axis=1)
        logits_f, _ = tf.lm_forward(params, ext, cfg)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(logits_f[:, -1, :]),
            rtol=0.05, atol=0.05)  # bf16 cache quantization

    def test_full_config_exact_numbers(self, arch, rng):
        cfg = configs.get(arch).make_config()
        expect = {
            "arctic-480b": (35, 7168, 56, 8, 32000),
            "granite-moe-1b-a400m": (24, 1024, 16, 8, 49155),
            "granite-20b": (52, 6144, 48, 1, 49152),
            "nemotron-4-340b": (96, 18432, 96, 8, 256000),
            "internlm2-20b": (48, 6144, 48, 8, 92544),
        }[arch]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads,
                cfg.n_kv_heads, cfg.vocab) == expect


class TestEquiformerSmoke:
    def test_train_step_and_equivariance(self, rng):
        cfg = configs.get("equiformer-v2").make_smoke_config()
        ccfg = dataclasses.replace(cfg, n_classes=4)
        params = eq.equiformer_init(KEY, ccfg)
        g = graph_pipeline.synth_graph(24, 48, n_classes=4, seed=1)
        batch = {k: jnp.asarray(v) for k, v in
                 graph_pipeline.full_batch(g).items()}
        loss, _ = jax.jit(lambda p, b: eq.equiformer_loss(p, b, ccfg))(
            params, batch)
        assert np.isfinite(float(loss))

    def test_rotation_invariance(self, rng):
        """Scalar outputs must be invariant under a global rotation of the
        input positions — THE correctness property of the eSCN backbone."""
        cfg = dataclasses.replace(
            configs.get("equiformer-v2").make_smoke_config(), n_classes=3)
        params = eq.equiformer_init(KEY, cfg)
        g = graph_pipeline.synth_graph(16, 40, n_classes=3, seed=2)
        batch = {k: jnp.asarray(v) for k, v in
                 graph_pipeline.full_batch(g).items()}
        out1 = eq.equiformer_forward(params, batch, cfg)
        # random rotation (QR of a gaussian, det fixed to +1)
        q, r = np.linalg.qr(np.random.default_rng(5).normal(size=(3, 3)))
        q = q * np.sign(np.diag(r))
        if np.linalg.det(q) < 0:
            q[:, 0] *= -1
        batch2 = dict(batch)
        batch2["positions"] = batch["positions"] @ jnp.asarray(
            q.T.astype(np.float32))
        out2 = eq.equiformer_forward(params, batch2, cfg)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=2e-3, atol=2e-3)

    def test_molecule_regression(self, rng):
        cfg = dataclasses.replace(
            configs.get("equiformer-v2").make_smoke_config(), n_classes=0)
        params = eq.equiformer_init(KEY, cfg)
        batch = {k: jnp.asarray(v) for k, v in
                 graph_pipeline.molecule_batch(4, 10, 20, seed=3).items()}
        loss, _ = eq.equiformer_loss(params, batch, cfg)
        assert np.isfinite(float(loss))


class TestRecsysSmoke:
    def setup_method(self, _):
        self.gen = recsys_pipeline.SessionGenerator(
            recsys_pipeline.RecsysSynthConfig(n_items=1 << 10, n_users=1 << 10,
                                              session_len=12))

    def test_sasrec(self):
        cfg = configs.get("sasrec").make_smoke_config()
        params = recsys.sasrec_init(KEY, cfg)
        batch = {k: jnp.asarray(v) for k, v in
                 self.gen.sasrec_batch(4).items()}
        loss, _ = jax.jit(lambda p, b: recsys.sasrec_loss(p, b, cfg))(
            params, batch)
        assert np.isfinite(float(loss))

    def test_fm(self):
        cfg = configs.get("fm").make_smoke_config()
        params = recsys.fm_init(KEY, cfg)
        batch = {k: jnp.asarray(v) for k, v in
                 self.gen.fm_batch(8, cfg.n_sparse, cfg.vocab_per_field).items()}
        loss, _ = jax.jit(lambda p, b: recsys.fm_loss(p, b, cfg))(params, batch)
        assert np.isfinite(float(loss))

    def test_fm_sum_square_trick_matches_naive(self, rng):
        """FM O(nk) identity == explicit pairwise sum (Rendle eq. 1)."""
        cfg = configs.get("fm").make_smoke_config()
        params = recsys.fm_init(KEY, cfg)
        feats = jnp.asarray(rng.integers(0, cfg.vocab_per_field,
                                         (4, cfg.n_sparse), np.int32))
        got = recsys.fm_forward(params, feats, cfg)
        field_offset = jnp.arange(cfg.n_sparse, dtype=feats.dtype) * cfg.vocab_per_field
        rows = (feats + field_offset[None]) % params["tables"].shape[0]
        v = np.asarray(jnp.take(params["tables"], rows, axis=0))
        lin = np.asarray(jnp.take(params["linear"], rows, axis=0))[..., 0].sum(-1)
        pair = np.zeros(4)
        for i in range(cfg.n_sparse):
            for j in range(i + 1, cfg.n_sparse):
                pair += (v[:, i] * v[:, j]).sum(-1)
        np.testing.assert_allclose(np.asarray(got), lin + pair, rtol=2e-3,
                                   atol=2e-3)

    def test_two_tower(self):
        cfg = configs.get("two-tower-retrieval").make_smoke_config()
        params = recsys.twotower_init(KEY, cfg)
        batch = {k: jnp.asarray(v) for k, v in
                 self.gen.twotower_batch(8).items()}
        loss, _ = jax.jit(lambda p, b: recsys.twotower_loss(p, b, cfg))(
            params, batch)
        assert np.isfinite(float(loss))
        cand = {k: jnp.asarray(v) for k, v in
                self.gen.retrieval_batch(64).items()}
        scores = recsys.twotower_score_candidates(params, cand, cfg)
        assert scores.shape == (64,)

    def test_mind(self):
        cfg = configs.get("mind").make_smoke_config()
        params = recsys.mind_init(KEY, cfg)
        batch = {k: jnp.asarray(v) for k, v in
                 self.gen.mind_batch(4).items()}
        loss, _ = jax.jit(lambda p, b: recsys.mind_loss(p, b, cfg))(
            params, batch)
        assert np.isfinite(float(loss))

    def test_embedding_bag_matches_torch_semantics(self, rng):
        table = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
        ids = jnp.asarray([1, 2, 3, 10, 11, 60], dtype=jnp.int32)
        offsets = jnp.asarray([0, 3, 5, 6], dtype=jnp.int32)
        out = recsys.embedding_bag(table, ids, offsets, mode="sum")
        want = np.stack([
            np.asarray(table)[[1, 2, 3]].sum(0),
            np.asarray(table)[[10, 11]].sum(0),
            np.asarray(table)[[60]].sum(0),
        ])
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)

    def test_idl_hash_rows_locality(self, rng):
        """IDL row assignment co-locates neighbouring ids without colliding."""
        ids = jnp.arange(0, 2048, dtype=jnp.int64)
        rows_idl = np.asarray(recsys.hash_rows(ids, 1 << 18, "idl", L=1024))
        rows_rh = np.asarray(recsys.hash_rows(ids, 1 << 18, "rh"))
        blk_idl = rows_idl // 1024
        blk_rh = rows_rh // 1024
        assert float(np.mean(blk_idl[1:] == blk_idl[:-1])) > 0.9
        assert float(np.mean(blk_rh[1:] == blk_rh[:-1])) < 0.05
        assert len(np.unique(rows_idl)) > 0.95 * len(rows_idl)


class TestGenesearchSmoke:
    def test_smoke_config_serves(self, rng):
        from repro.index import BitSlicedIndex

        cfg = configs.get("idl-genesearch").make_smoke_config()
        read = jnp.asarray(rng.integers(0, 4, cfg.read_len, dtype=np.uint8))
        eng = BitSlicedIndex.build(cfg.idl_config(), cfg.scheme, cfg.n_files)
        eng = eng.insert_batch(read[None], np.asarray([3], dtype=np.int32))
        out = np.asarray(eng.msmt(read[None], theta=cfg.theta))
        assert out[0, 3]


class TestAbstractCells:
    """Every non-skipped cell must build ShapeDtypeStruct state + inputs."""

    @pytest.mark.parametrize("arch", configs.all_archs())
    def test_cells_construct(self, arch):
        spec = configs.get(arch)
        cfg = spec.make_config()
        for name, cell in spec.cells():
            if cell.skip_reason:
                continue
            ins = spec.input_specs(cfg, cell)
            st = spec.abstract_state(cfg, cell)
            assert ins and st is not None
            fn = spec.step_fn(cfg, cell)
            assert callable(fn)

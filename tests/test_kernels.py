"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles, all in interpret=True mode (kernel body executes on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bloom, idl, minhash
from repro.kernels.idl_insert import ops as ins_ops
from repro.kernels.idl_probe import ops as probe_ops, ref as probe_ref
from repro.kernels.window_min import kernel as wm_kernel


class TestWindowMinKernel:
    @pytest.mark.parametrize("n,w,tile", [
        (1000, 16, 256), (4096, 16, 512), (5000, 7, 1024),
        (300, 2, 128), (2048, 16, 2048), (1025, 12, 256),
    ])
    def test_shapes_sweep(self, rng, n, w, tile):
        a = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))
        got = wm_kernel.window_min(a, w=w, tile=tile, interpret=True)
        want = minhash.sliding_window_min(a, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.float32])
    def test_dtypes(self, rng, dtype):
        if np.issubdtype(dtype, np.floating):
            a = jnp.asarray(rng.normal(size=777).astype(dtype))
        else:
            a = jnp.asarray(rng.integers(0, 1 << 30, size=777).astype(dtype))
        got = wm_kernel.window_min(a, w=9, tile=128, interpret=True)
        want = minhash.sliding_window_min(a, 9)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def _build_bf(rng, cfg, n=1500):
    codes = jnp.asarray(rng.integers(0, 4, size=n, dtype=np.uint8))
    bf = bloom.BloomFilter(cfg=cfg, scheme="idl").insert_sequence(codes)
    return codes, bf, bloom.pack_bits(bf.bits)


class TestProbeKernel:
    @pytest.mark.parametrize("L,eta,m,C", [
        (1 << 12, 4, 1 << 20, 128),
        (1 << 10, 2, 1 << 18, 64),
        (1 << 14, 8, 1 << 22, 256),
    ])
    def test_sweep_vs_ref(self, rng, L, eta, m, C):
        cfg = idl.IDLConfig(k=31, t=16, L=L, eta=eta, m=m)
        codes, bf, words = _build_bf(rng, cfg)
        locs = np.asarray(idl.idl_locations_rolling(cfg, codes))
        plan = probe_ops.plan_probe_runs(locs, block_bits=L, probes_per_run=C)
        got = probe_ops.probe_membership(words, plan, interpret=True)
        want = probe_ops.probe_membership(words, plan, use_ref=True)
        direct = bloom.query_packed(words, jnp.asarray(locs.astype(np.uint32)))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(direct))
        assert bool(jnp.all(got))  # inserted -> all present

    def test_negative_queries(self, rng):
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=4, m=1 << 20)
        codes, bf, words = _build_bf(rng, cfg)
        neg = jnp.asarray(rng.integers(0, 4, size=800, dtype=np.uint8))
        locs = np.asarray(idl.idl_locations_rolling(cfg, neg))
        plan = probe_ops.plan_probe_runs(locs, block_bits=cfg.L)
        got = probe_ops.probe_membership(words, plan, interpret=True)
        direct = bloom.query_packed(words, jnp.asarray(locs.astype(np.uint32)))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(direct))

    @pytest.mark.parametrize("n_rows,w,rpb,C", [
        (256, 3, 16, 32),      # odd word count (COBS group slice)
        (1 << 12, 1, 64, 128), # flat packed BF as a (m/32, 1) matrix
        (512, 8, 8, 64),       # RAMBO-transpose-like wide rows
    ])
    def test_probe_rows_sweep_vs_ref(self, rng, n_rows, w, rpb, C):
        """The generalized (rows, W) row-gather kernel: Pallas == ref ==
        direct numpy indexing, in probe order, for arbitrary matrices."""
        matrix = jnp.asarray(
            rng.integers(0, 2 ** 32, size=(n_rows, w), dtype=np.uint32))
        rows = rng.integers(0, n_rows, size=(3, 97))
        rows[1].sort()  # one stream with long block runs, two scattered
        plan = probe_ops.plan_probe_runs(rows, block_bits=rpb,
                                         probes_per_run=C)
        got = probe_ops.gather_planned_rows(matrix, plan, interpret=True)
        got_ref = probe_ops.gather_planned_rows(matrix, plan, use_ref=True)
        want = np.asarray(matrix)[rows.reshape(-1)]
        np.testing.assert_array_equal(np.asarray(got), want)
        np.testing.assert_array_equal(np.asarray(got_ref), want)

    def test_dma_savings_idl_vs_rh(self, rng):
        """The kernel's DMA count IS the paper's cache-miss metric on TPU:
        IDL's plan must need far fewer block DMAs than RH's."""
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=1 << 26)
        codes = jnp.asarray(rng.integers(0, 4, size=5000, dtype=np.uint8))
        locs_idl = np.asarray(idl.idl_locations_rolling(cfg, codes))
        locs_rh = np.asarray(idl.rh_locations_rolling(cfg, codes))
        p_idl = probe_ops.plan_probe_runs(locs_idl, cfg.L)
        p_rh = probe_ops.plan_probe_runs(locs_rh, cfg.L)
        assert p_rh.n_runs > 4 * p_idl.n_runs


class TestInsertKernel:
    @pytest.mark.parametrize("L,eta,m,C", [
        (1 << 12, 4, 1 << 20, 128),
        (1 << 10, 2, 1 << 18, 32),
    ])
    def test_sweep_vs_direct(self, rng, L, eta, m, C):
        cfg = idl.IDLConfig(k=31, t=16, L=L, eta=eta, m=m)
        codes = jnp.asarray(rng.integers(0, 4, size=1200, dtype=np.uint8))
        locs = np.asarray(idl.idl_locations_rolling(cfg, codes))
        plan = ins_ops.plan_insert_rounds(locs, block_bits=L, inserts_per_round=C)
        w0 = jnp.zeros((m // 32,), dtype=jnp.uint32)
        got = ins_ops.insert_with_plan(w0, plan, interpret=True)
        ref = ins_ops.insert_with_plan(w0, plan, use_ref=True)
        direct = bloom.pack_bits(
            bloom.BloomFilter(cfg=cfg, scheme="idl").insert_sequence(codes).bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(direct))

    def test_rounds_have_unique_blocks(self, rng):
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 10, eta=4, m=1 << 18)
        codes = jnp.asarray(rng.integers(0, 4, size=3000, dtype=np.uint8))
        locs = np.asarray(idl.idl_locations_rolling(cfg, codes))
        plan = ins_ops.plan_insert_rounds(locs, cfg.L, 64)
        for bids, _ in plan.rounds:
            assert len(np.unique(bids)) == len(bids)


def _set_bits_oracle(matrix: np.ndarray, flat_bits: np.ndarray) -> np.ndarray:
    out = matrix.copy().reshape(-1)
    for pos in flat_bits.reshape(-1):
        if pos >= 0:
            out[pos // 32] |= np.uint32(1) << np.uint32(pos % 32)
    return out.reshape(matrix.shape)


class TestInsertRunsKernel:
    """The generalized single-launch (rows, W) scatter-OR kernel: Pallas ==
    ref == direct numpy bit-setting, for arbitrary matrices (the ingest
    layer's idl_insert backend)."""

    @pytest.mark.parametrize("n_rows,w,rpb,C", [
        (256, 3, 16, 32),      # odd word count (COBS group slice)
        (1 << 12, 1, 64, 128), # flat packed BF as a (m/32, 1) matrix
        (512, 8, 8, 64),       # RAMBO-stack-like wide rows
        (96, 5, 1, 16),        # single-row tiles
    ])
    def test_sweep_vs_ref_and_oracle(self, rng, n_rows, w, rpb, C):
        matrix = jnp.asarray(
            rng.integers(0, 2 ** 32, size=(n_rows, w), dtype=np.uint32))
        flat = rng.integers(0, n_rows * w * 32, size=500, dtype=np.int64)
        flat[:40] = flat[40:80]  # force duplicates through the dedup path
        plan = ins_ops.plan_insert_runs(flat, block_bits=rpb * w * 32,
                                        inserts_per_run=C)
        want = _set_bits_oracle(np.asarray(matrix), flat)
        # the destination is donated — hand each executor its own copy
        got = ins_ops.insert_planned(jnp.array(matrix), plan, interpret=True)
        got_ref = ins_ops.insert_planned(jnp.array(matrix), plan, use_ref=True)
        np.testing.assert_array_equal(np.asarray(got), want)
        np.testing.assert_array_equal(np.asarray(got_ref), want)

    def test_plan_invariants(self, rng):
        flat = rng.integers(0, 1 << 16, size=900, dtype=np.int64)
        plan = ins_ops.plan_insert_runs(flat, block_bits=1 << 10,
                                        inserts_per_run=32)
        # runs and slots are nondecreasing (consecutive tile revisits)
        assert (np.diff(plan.block_ids) >= 0).all()
        assert (np.diff(plan.slot_ids) >= 0).all()
        # slots map 1:1 onto the sorted unique touched blocks; the slot
        # axis is pow2-padded with an out-of-range sentinel (dropped at
        # write-back) so the executor's compile cache stays small
        np.testing.assert_array_equal(
            plan.uniq_blocks[:plan.n_tiles], np.unique(flat // (1 << 10)))
        assert plan.n_slots == plan.uniq_blocks.shape[0]
        assert plan.n_slots & (plan.n_slots - 1) == 0
        assert (plan.uniq_blocks[plan.n_tiles:] == np.iinfo(np.int32).max).all()
        np.testing.assert_array_equal(
            plan.uniq_blocks[plan.slot_ids], plan.block_ids)
        # offsets are deduplicated: global (block, offset) pairs unique
        valid = plan.offsets >= 0
        pairs = (plan.block_ids[:, None].astype(np.int64) * (1 << 10)
                 + plan.offsets)[valid]
        assert len(np.unique(pairs)) == len(pairs) == plan.n_locs
        # pow2-padded run count; pad runs are all-pad no-ops
        assert plan.block_ids.shape[0] >= plan.n_runs
        assert (plan.offsets[plan.n_runs:] == -1).all()

    def test_empty_and_masked_plans(self):
        m = jnp.asarray(np.arange(8, dtype=np.uint32).reshape(4, 2))
        assert ins_ops.plan_insert_runs(
            np.asarray([-1, -1]), block_bits=64) is None
        out = ins_ops.insert_planned(m, None, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(m))

"""Exact intra-batch probe dedup (``QueryPlan.execute(dedup=True)``).

The correctness backbone: a kmer's hash locations are a pure function of
its own bases (each kmer's window-min runs over ITS w sub-kmers; DOPH
densification rolls along the η axis only), so probing each distinct
kmer once as a standalone length-k read and gathering membership back
through the inverse map is bit-identical to the naive per-position probe
— across every engine, scheme and backend. Also pins the bounded plan
caches: eviction is observable via ``plan_cache_info().evictions`` and
costs zero recompiles (the jitted executor keys on plan VALUE equality,
and an evicted plan rebuilds equal).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import idl
from repro.index import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
    ingest,
    query,
)

ENGINES = ["bloom", "cobs", "rambo", "bitsliced"]
K = 31


def _cfg(m: int = 1 << 16) -> idl.IDLConfig:
    return idl.IDLConfig(k=K, t=16, L=1 << 10, eta=2, m=m)


@pytest.fixture(scope="module")
def reads(rng):
    return jnp.asarray(rng.integers(0, 4, size=(3, 160), dtype=np.uint8))


@pytest.fixture(scope="module")
def overlapping(reads):
    """Sliding windows over the indexed reads — every adjacent pair of
    queries shares most of its kmers (the dedup win regime), and the
    batch also repeats one window verbatim (exact-duplicate rows)."""
    wins = [np.asarray(reads[i % 3])[s:s + 90]
            for i, s in enumerate([0, 10, 20, 30, 0, 45])]
    wins.append(wins[0])
    return np.stack(wins)


def _build(name: str, reads, scheme: str = "idl"):
    fids = np.arange(reads.shape[0])
    if name == "bloom":
        return PackedBloomIndex.build(_cfg(), scheme).insert_batch(reads[:2])
    if name == "cobs":
        return CobsIndex.build(
            [100, 200, 150], _cfg(), scheme=scheme, n_groups=2
        ).insert_batch(reads, fids)
    if name == "rambo":
        return RamboIndex.build(
            5, _cfg(1 << 14), scheme=scheme, B=2, R=2
        ).insert_batch(reads, fids)
    if name == "bitsliced":
        return BitSlicedIndex.build(
            _cfg(), scheme, n_files=40
        ).insert_batch(reads, np.asarray([0, 9, 39]))
    raise KeyError(name)


# ---------------------------------------------------------------------------
# The host-side factoring.
# ---------------------------------------------------------------------------

class TestFactorUniqueKmers:
    def test_reconstruction_is_exact(self, overlapping):
        uniq, inverse, (b, n_k) = query.factor_unique_kmers(overlapping, K)
        np.testing.assert_array_equal(
            uniq[inverse].reshape(b, n_k, K),
            np.asarray(query.read_kmers(overlapping, K)).reshape(b, n_k, K))

    def test_rows_are_distinct_and_deduped(self, overlapping):
        uniq, _, _ = query.factor_unique_kmers(overlapping, K)
        total = overlapping.shape[0] * (overlapping.shape[1] - K + 1)
        assert len(np.unique(uniq, axis=0)) == len(uniq)
        assert len(uniq) < total          # the overlap actually deduped

    def test_single_read_1d(self, reads):
        one = np.asarray(reads[0])
        uniq, inverse, (b, n_k) = query.factor_unique_kmers(one, K)
        assert (b, n_k) == (1, one.shape[0] - K + 1)
        assert inverse.shape == (n_k,)


# ---------------------------------------------------------------------------
# Bit-identity across the whole matrix.
# ---------------------------------------------------------------------------

class TestDedupParity:
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("engine", ENGINES)
    def test_engines_by_scheme(self, reads, overlapping, engine, scheme):
        eng = _build(engine, reads, scheme)
        naive = np.asarray(eng.query_batch(jnp.asarray(overlapping)))
        dedup = np.asarray(
            eng.query_batch(jnp.asarray(overlapping), dedup=True))
        np.testing.assert_array_equal(dedup, naive)

    @pytest.mark.parametrize("backend,kw", [
        ("jnp", {}),
        ("idl_probe", {"use_ref": True}),
        ("sharded", {}),
    ])
    def test_backends(self, reads, overlapping, backend, kw):
        eng = _build("bitsliced", reads)
        naive = np.asarray(eng.query_batch(jnp.asarray(overlapping)))
        dedup = np.asarray(eng.query_batch(
            jnp.asarray(overlapping), backend=backend, dedup=True, **kw))
        np.testing.assert_array_equal(dedup, naive)

    def test_msmt_end_to_end(self, reads, overlapping):
        for engine in ENGINES:
            eng = _build(engine, reads)
            want = np.asarray(eng.msmt(jnp.asarray(overlapping), theta=0.7))
            got = np.asarray(eng.msmt(
                jnp.asarray(overlapping), theta=0.7, dedup=True))
            np.testing.assert_array_equal(got, want)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestDedupProperty:
    """Random batches (duplicate rows included by construction: the
    strategy tiles a small alphabet of windows) stay bit-identical."""

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5),
           st.integers(K, K + 33))
    def test_dedup_equals_naive(self, seed, b, read_len):
        prng = np.random.default_rng(seed)
        base = prng.integers(0, 4, size=read_len + 8, dtype=np.uint8)
        starts = prng.integers(0, 9, size=b)
        batch = np.stack([base[s:s + read_len] for s in starts])
        eng = _BLOOM_FOR_PROPERTY
        naive = np.asarray(eng.query_batch(jnp.asarray(batch)))
        dedup = np.asarray(eng.query_batch(jnp.asarray(batch), dedup=True))
        np.testing.assert_array_equal(dedup, naive)
        uniq, inverse, (bb, n_k) = query.factor_unique_kmers(batch, K)
        np.testing.assert_array_equal(
            uniq[inverse].reshape(bb, n_k, K),
            np.asarray(query.read_kmers(batch, K)).reshape(bb, n_k, K))


_BLOOM_FOR_PROPERTY = PackedBloomIndex.build(
    idl.IDLConfig(k=K, t=16, L=1 << 8, eta=2, m=1 << 12), "idl"
).insert_batch(jnp.asarray(
    np.random.default_rng(7).integers(0, 4, size=(2, 80), dtype=np.uint8)))


# ---------------------------------------------------------------------------
# Bounded plan caches: evictions are counted and cost no recompiles.
# ---------------------------------------------------------------------------

class TestBoundedPlanCache:
    def test_caches_have_a_real_bound(self):
        assert query.plan_query.cache_info().maxsize == \
            query.PLAN_CACHE_SIZE
        assert ingest.plan_insert.cache_info().maxsize == \
            ingest.PLAN_CACHE_SIZE
        assert query._sharded_executor.cache_info().maxsize is not None

    def test_eviction_count_is_exact(self):
        query.clear_plan_cache()
        cfg = _cfg()
        n = query.PLAN_CACHE_SIZE + 40
        for b in range(1, n + 1):       # n distinct read shapes
            query.plan_query(cfg, "idl", (b, K), (cfg.m // 32, 1),
                             bit_probe=True)
        info = query.plan_cache_info()
        assert info.currsize == query.PLAN_CACHE_SIZE
        assert info.evictions == n - query.PLAN_CACHE_SIZE
        assert info.misses == n

    def test_compile_once_survives_eviction(self, reads, overlapping):
        """Plan eviction must be FREE: plans are value objects, the jit
        cache keys on their hash/eq, and a rebuilt plan compares equal —
        so flooding the plan cache cannot trigger a recompile."""
        query.clear_plan_cache()
        eng = _build("bloom", reads)
        eng.query_batch(jnp.asarray(overlapping))
        compiled0 = query._execute_jnp._cache_size()
        cfg = _cfg()
        for b in range(1, query.PLAN_CACHE_SIZE + 20):   # flood: evict all
            query.plan_query(cfg, "idl", (b, K), (cfg.m // 32, 1),
                             bit_probe=True)
        assert query.plan_cache_info().evictions > 0
        eng.query_batch(jnp.asarray(overlapping))        # plan rebuilds...
        assert query._execute_jnp._cache_size() == compiled0  # ...no compile

    def test_insert_plan_cache_bounded(self, rng):
        ingest.clear_plan_cache()
        cfg = _cfg()
        n = ingest.PLAN_CACHE_SIZE + 16
        for b in range(1, n + 1):
            ingest.plan_insert(cfg, "idl", (b, 64), (cfg.m // 32, 1),
                               kind="bits")
        info = ingest.plan_cache_info()
        assert info.currsize == ingest.PLAN_CACHE_SIZE
        assert info.evictions == n - ingest.PLAN_CACHE_SIZE

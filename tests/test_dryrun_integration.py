"""Integration: the dry-run launcher lowers+compiles real cells end-to-end.

Runs in a subprocess because the dry-run forces 512 host devices, which
must never leak into this test process (everything else sees 1 device).
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, mesh, tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--mesh", mesh,
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.load(open(tmp_path / f"{arch}__{shape}__{mesh}.json"))
    return rec


@pytest.mark.slow
def test_dryrun_cell_single_pod(tmp_path):
    rec = _run_cell("sasrec", "serve_p99", "single", tmp_path)
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["flops_per_chip"] > 0
    assert rec["bytes_per_chip"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert rec["memory_stats"] is not None


@pytest.mark.slow
def test_dryrun_cell_multi_pod(tmp_path):
    rec = _run_cell("fm", "serve_p99", "multi", tmp_path)
    assert rec["status"] == "ok"
    assert rec["chips"] == 512


@pytest.mark.slow
def test_dryrun_skip_recorded(tmp_path):
    rec = _run_cell("granite-20b", "long_500k", "single", tmp_path)
    assert rec["status"] == "skipped"
    assert "full-attention" in rec["skip_reason"]


def test_device_count_not_leaked():
    """This process must still see exactly one CPU device."""
    import jax
    assert len(jax.devices()) == 1

"""Ingest-layer acceptance: every engine's ``insert_batch`` (and
``serving.insert_read_batch``) routes through ``ingest.InsertPlan``; the
``idl_insert`` and ``sharded`` backends are bit-identical to the ``jnp``
reference across 4 engines × {idl, rh, lsh} under interleaved insert/query
rounds; the legacy ``packed.insert_batch_*`` entry points warn; streaming
archive builds are bit-identical to direct batch inserts; minimizer
sub-sampling inserts a strict subset."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idl
from repro.data import genome
from repro.index import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
    ingest,
    packed,
    query,
)
from repro.serving import genesearch as gs

ENGINES = ["bloom", "cobs", "rambo", "bitsliced"]
SCHEMES = ["idl", "rh", "lsh"]
BACKENDS = ["jnp", "idl_insert", "sharded"]


def _cfg(m: int = 1 << 16) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)


def _empty_engine(name: str, scheme: str, n_files: int):
    if name == "bloom":
        return PackedBloomIndex.build(_cfg(), scheme)
    if name == "cobs":
        return CobsIndex.build(
            [100, 200, 150, 90, 400, 250][:n_files], _cfg(), scheme=scheme,
            n_groups=2)
    if name == "rambo":
        return RamboIndex.build(n_files, _cfg(1 << 14), scheme=scheme,
                                B=2, R=2)
    if name == "bitsliced":
        return BitSlicedIndex.build(_cfg(), scheme, n_files=n_files)
    raise KeyError(name)


def _words_of(eng):
    if isinstance(eng, CobsIndex):
        return [np.asarray(g.words) for g in eng.groups]
    return [np.asarray(eng.words)]


@pytest.fixture(scope="module")
def reads():
    r = np.random.default_rng(11).integers(0, 4, size=(6, 120),
                                           dtype=np.uint8)
    return jnp.asarray(r)


class TestInsertBackendParityMatrix:
    """Acceptance matrix: 4 engines × {idl, rh, lsh} × {jnp, idl_insert,
    sharded}, bit-identical ``words`` after interleaved insert/query rounds
    (sharded on the default 1-device mesh here; the >1-device case is
    skip-guarded below)."""

    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("scheme", SCHEMES)
    @pytest.mark.parametrize("backend", ["idl_insert", "sharded"])
    def test_backends_bit_identical_interleaved(self, reads, engine, scheme,
                                                backend):
        if engine == "bitsliced" and scheme == "lsh":
            pytest.skip("lsh has no 32-bit lane path (bit-sliced engines "
                        "run on the lane32 serving path)")
        fids = np.arange(reads.shape[0], dtype=np.int32)
        ref = _empty_engine(engine, scheme, reads.shape[0])
        got = _empty_engine(engine, scheme, reads.shape[0])
        for lo, hi in ((0, 3), (3, 6)):     # interleaved insert/query rounds
            ref = ref.insert_batch(reads[lo:hi], fids[lo:hi])
            got = got.insert_batch(reads[lo:hi], fids[lo:hi],
                                   backend=backend)
            for a, b in zip(_words_of(got), _words_of(ref)):
                np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(
                np.asarray(got.query_batch(reads)),
                np.asarray(ref.query_batch(reads)))

    @pytest.mark.parametrize("engine", ENGINES)
    def test_idl_insert_ref_oracle_matches_kernel(self, reads, engine):
        fids = np.arange(reads.shape[0], dtype=np.int32)
        a = _empty_engine(engine, "idl", reads.shape[0]).insert_batch(
            reads, fids, backend="idl_insert")
        b = _empty_engine(engine, "idl", reads.shape[0]).insert_batch(
            reads, fids, backend="idl_insert", use_ref=True)
        for wa, wb in zip(_words_of(a), _words_of(b)):
            np.testing.assert_array_equal(wa, wb)

    def test_serving_insert_backends(self, reads):
        # the serve-geometry plan helper (survivor of the removed v1
        # surface) stays bit-identical across ingest backends
        cfg = gs.GeneSearchConfig(n_files=32, m=1 << 16, L=1 << 10,
                                  read_len=120, eta=2)
        fids = jnp.asarray([0, 7, 13, 21, 30, 31], dtype=jnp.int32)

        def build(backend):
            index = jnp.zeros((cfg.m, cfg.file_words), dtype=jnp.uint32)
            plan = gs.insert_plan(cfg, reads.shape[0], index.shape,
                                  read_len=reads.shape[1])
            return np.asarray(plan.execute(index, reads, fids,
                                           backend=backend))

        want = build("jnp")
        for backend in ("idl_insert", "sharded"):
            np.testing.assert_array_equal(build(backend), want)

    def test_unknown_backend_raises(self, reads):
        eng = _empty_engine("bloom", "idl", 1)
        with pytest.raises(ValueError, match="unknown ingest backend"):
            eng.insert_batch(reads, backend="sse2")

    def test_idl_insert_compile_cache_bounded(self):
        # both data-dependent sizes (run count, slot count) are pow2-padded,
        # so streaming many same-shaped batches through the planned backend
        # compiles a handful of bucket shapes, not one per batch
        from repro.kernels.idl_insert import ops as ins_ops

        cfg = _cfg()
        ins_ops._planned_insert.clear_cache()
        for seed in range(5):
            reads = jnp.asarray(np.random.default_rng(seed).integers(
                0, 4, size=(3, 120), dtype=np.uint8))
            PackedBloomIndex.build(cfg, "idl").insert_batch(
                reads, backend="idl_insert")
        assert ins_ops._planned_insert._cache_size() <= 3

    def test_plans_are_cached(self, reads):
        ingest.clear_plan_cache()
        eng = _empty_engine("bloom", "idl", 1)
        eng = eng.insert_batch(reads)
        assert ingest.plan_cache_info().currsize == 1
        eng = eng.insert_batch(reads, backend="sharded")  # same geometry
        eng = eng.insert_batch(reads, backend="idl_insert")
        assert ingest.plan_cache_info().currsize == 1
        assert ingest.plan_cache_info().hits >= 2

    @pytest.mark.skipif(jax.device_count() < 2,
                        reason="needs a multi-device mesh")
    @pytest.mark.parametrize("engine", ENGINES)
    def test_sharded_multi_device(self, reads, engine):
        from jax.sharding import Mesh

        mesh = Mesh(np.asarray(jax.devices()), (query.MESH_AXIS,))
        fids = np.arange(reads.shape[0], dtype=np.int32)
        ref = _empty_engine(engine, "idl", reads.shape[0]).insert_batch(
            reads, fids)
        got = _empty_engine(engine, "idl", reads.shape[0]).insert_batch(
            reads, fids, backend="sharded", mesh=mesh)
        for a, b in zip(_words_of(got), _words_of(ref)):
            np.testing.assert_array_equal(a, b)


class TestDeprecatedPackedEntryPoints:
    def test_legacy_insert_batch_removed(self, reads):
        # the three legacy jit entry points finished their deprecation
        # window: call-time ImportError stubs pointing at the ingest layer
        cfg = _cfg()
        with pytest.raises(ImportError, match="plan_insert"):
            packed.insert_batch_words(
                jnp.zeros((cfg.m // 32,), dtype=jnp.uint32), reads,
                cfg=cfg, scheme="idl")
        with pytest.raises(ImportError, match="plan_insert"):
            packed.insert_batch_bitsliced(
                jnp.zeros((cfg.m, 1), dtype=jnp.uint32), reads,
                jnp.arange(reads.shape[0], dtype=jnp.int32),
                cfg=cfg, scheme="idl")
        with pytest.raises(ImportError, match="plan_insert"):
            packed.insert_batch_rows(
                jnp.zeros((4, cfg.m // 32), dtype=jnp.uint32), reads,
                jnp.zeros((reads.shape[0], 2), dtype=jnp.int32),
                cfg=cfg, scheme="idl")

    def test_engine_path_does_not_warn(self, reads):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            _empty_engine("bloom", "idl", 1).insert_batch(reads)
            _empty_engine("rambo", "idl", reads.shape[0]).insert_batch(
                reads, np.arange(reads.shape[0]))

    def test_coverage_need_single_definition(self):
        assert packed.coverage_need is query.coverage_need


class TestBuildArchive:
    @pytest.fixture(scope="class")
    def archive(self):
        return genome.synth_archive(n_files=5, genome_len=700, seed=3)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_bit_identical_to_direct_insert(self, archive, backend):
        cfg = _cfg()
        genomes = jnp.asarray(np.stack([f.genome for f in archive]))
        want = BitSlicedIndex.build(cfg, "idl", n_files=5).insert_batch(
            genomes, np.arange(5))
        got = ingest.build_archive(
            BitSlicedIndex.build(cfg, "idl", n_files=5), archive,
            read_len=230, chunk_reads=4, backend=backend)
        np.testing.assert_array_equal(np.asarray(got.words),
                                      np.asarray(want.words))

    def test_every_engine_and_ragged_lengths(self, archive):
        # a short ragged file (no full window) + pairs as input items
        items = [(f.file_id, f.genome) for f in archive]
        items.append((5, genome.synthesize_genome(150, seed=99)))
        for name in ENGINES:
            eng = _empty_engine(name, "idl", 6)
            eng = ingest.build_archive(eng, items, read_len=230,
                                       chunk_reads=3)
            ref = _empty_engine(name, "idl", 6)
            for fid, codes in items:
                ref = ref.insert_batch(
                    jnp.asarray(codes)[None, :], np.asarray([fid]))
            for a, b in zip(_words_of(eng), _words_of(ref)):
                np.testing.assert_array_equal(a, b)

    def test_fasta_path_input(self, archive, tmp_path):
        cfg = _cfg()
        path = str(tmp_path / "arc.fasta")
        genome.write_fasta(
            path, {f"g{i}": f.genome for i, f in enumerate(archive[:2])})
        eng = ingest.build_archive(
            PackedBloomIndex.build(cfg, "idl"), [path], read_len=230)
        ref = PackedBloomIndex.build(cfg, "idl").insert_batch(
            jnp.asarray(np.stack([f.genome for f in archive[:2]])))
        np.testing.assert_array_equal(np.asarray(eng.words),
                                      np.asarray(ref.words))

    def test_window_reads_covers_all_kmers(self):
        codes = genome.synthesize_genome(1000, seed=5)
        wins = genome.window_reads(codes, 230, 31)
        got = set()
        for w in wins:
            for i in range(len(w) - 30):
                got.add(bytes(w[i:i + 31]))
        want = {bytes(codes[i:i + 31]) for i in range(len(codes) - 30)}
        assert got == want
        assert genome.window_reads(codes[:20], 230, 31).shape[0] == 0

    def test_window_min_subsamples(self, archive):
        cfg = _cfg()
        genomes = jnp.asarray(np.stack([f.genome for f in archive]))
        full = PackedBloomIndex.build(cfg, "idl").insert_batch(genomes)
        mini = PackedBloomIndex.build(cfg, "idl").insert_batch(
            genomes, window_min=8)
        wf, wm = np.asarray(full.words), np.asarray(mini.words)
        pop_f = int(np.unpackbits(wf.view(np.uint8)).sum())
        pop_m = int(np.unpackbits(wm.view(np.uint8)).sum())
        assert np.array_equal(wf & wm, wm)      # strict subset of the bits
        assert 0 < pop_m < pop_f
        # the subsample is deterministic and backend-independent
        for backend in ("idl_insert", "sharded"):
            again = PackedBloomIndex.build(cfg, "idl").insert_batch(
                genomes, window_min=8, backend=backend)
            np.testing.assert_array_equal(np.asarray(again.words), wm)


class TestInsertPlanMetrics:
    def test_idl_needs_fewer_tiles_than_rh(self):
        # paper-scale geometry (m/L = 2048 tiles): RH scatters a batch over
        # ~every tile, IDL's windows keep the touched-tile footprint small
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=1 << 26)
        reads = jnp.asarray(np.random.default_rng(0).integers(
            0, 4, size=(4, 230), dtype=np.uint8))
        plans = {}
        for scheme in ("idl", "rh"):
            p = ingest.plan_insert(cfg, scheme, reads.shape,
                                   (cfg.m // 32, 1), kind="bits")
            plans[scheme] = p.plan_runs(reads)
        assert plans["rh"].n_tiles > 4 * plans["idl"].n_tiles
        assert plans["rh"].dma_bytes > 4 * plans["idl"].dma_bytes

    def test_short_reads_keep_all_kmers_and_dma_accounting(self):
        cfg = _cfg()
        reads = jnp.asarray(np.random.default_rng(1).integers(
            0, 4, size=(1, 40), dtype=np.uint8))
        # minimizer window longer than the kmer count keeps everything
        plan = ingest.plan_insert(cfg, "idl", reads.shape,
                                  (cfg.m // 32, 1), kind="bits",
                                  window_min=1 << 10)
        rplan = plan.plan_runs(reads)
        assert rplan is not None and rplan.n_locs > 0
        assert plan.run_dma_bytes(rplan) == rplan.dma_bytes

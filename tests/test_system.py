"""End-to-end behaviour of the paper's system: index a genome archive,
serve queries, and verify the locality + quality story in one pass."""

import jax.numpy as jnp
import numpy as np

from repro.core import bloom, cache_model, idl
from repro.data import genome
from repro.kernels.idl_probe import ops as probe_ops


def test_end_to_end_gene_search_with_kernel_path():
    """Index -> plan -> Pallas probe kernel -> membership, IDL vs RH."""
    g = genome.synthesize_genome(20_000, seed=0, repeat_fraction=0.0)
    cfg = idl.IDLConfig(k=31, t=16, L=1 << 13, eta=4, m=1 << 23)
    bf = bloom.BloomFilter(cfg=cfg, scheme="idl").insert_sequence(jnp.asarray(g))
    words = bloom.pack_bits(bf.bits)

    reads = genome.extract_reads(g, 230, 16, seed=1)
    poisoned = genome.poison_queries(reads, seed=2)

    # genuine reads: every kmer present (no false negatives through the
    # kernel path); poisoned reads: the flipped kmers break membership
    for read, bad in zip(reads[:4], poisoned[:4]):
        locs = np.asarray(idl.idl_locations_rolling(cfg, jnp.asarray(read)))
        plan = probe_ops.plan_probe_runs(locs, cfg.L)
        member = probe_ops.probe_membership(words, plan, interpret=True)
        assert bool(jnp.all(member))
        locs_b = np.asarray(idl.idl_locations_rolling(cfg, jnp.asarray(bad)))
        plan_b = probe_ops.plan_probe_runs(locs_b, cfg.L)
        member_b = probe_ops.probe_membership(words, plan_b, interpret=True)
        assert not bool(jnp.all(member_b))

    # the system claim: IDL's plan needs far fewer tile DMAs than RH's
    locs_idl = np.asarray(idl.idl_locations_rolling(cfg, jnp.asarray(reads[0])))
    locs_rh = np.asarray(idl.rh_locations_rolling(cfg, jnp.asarray(reads[0])))
    n_idl = probe_ops.plan_probe_runs(locs_idl, cfg.L).n_runs
    n_rh = probe_ops.plan_probe_runs(locs_rh, cfg.L).n_runs
    assert n_rh > 4 * n_idl


def test_fpr_quality_parity_idl_vs_rh():
    """IDL preserves BF quality (paper Fig 5): FPRs within 2x of each other
    at a size where FPR is measurable."""
    g = genome.synthesize_genome(30_000, seed=3, repeat_fraction=0.0)
    rng = np.random.default_rng(4)
    neg = jnp.asarray(rng.integers(0, 4, size=60_000, dtype=np.uint8))
    fprs = {}
    for scheme in ("idl", "rh"):
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 11, eta=4, m=1 << 19)
        bf = bloom.BloomFilter(cfg=cfg, scheme=scheme).insert_sequence(
            jnp.asarray(g))
        fprs[scheme] = float(jnp.mean(bf.query_sequence(neg)))
    assert fprs["idl"] > 0  # measurable regime
    assert fprs["idl"] < 2.0 * fprs["rh"] + 1e-4
    assert fprs["rh"] < 2.0 * fprs["idl"] + 1e-4

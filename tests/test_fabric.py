"""Process-fabric acceptance: gateway answers == union-index oracle
through real worker processes; a kill -9'd worker's in-flight requests
re-route and the fleet stays exact; a crash during a rolling swap leaves
the fleet on the old version; a gateway reboot replays the WAL with zero
acked writes lost.

Each fleet boot spawns real interpreters (each re-imports jax), so the
suite keeps fleets small (2 workers) and shares one serving fleet across
the non-destructive tests.
"""

import os
import signal
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import idl
from repro.index import store
from repro.obs import export as obs_export
from repro.index.engines import BitSlicedIndex
from repro.serving import (
    FabricConfig,
    FabricError,
    KmerCacheConfig,
    ProcessFabric,
    ServiceConfig,
)

N_FILES = 40
BASE_FIDS = [0, 9, 39]
DELTA_FIDS = [5, 17]


def _cfg() -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=1 << 16)


@pytest.fixture(scope="module")
def reads(rng):
    return rng.integers(0, 4, size=(6, 120), dtype=np.uint8)


@pytest.fixture(scope="module")
def queries(reads):
    lens = [120, 100, 77, 120, 61, 99]
    return [np.asarray(reads[i][:n]) for i, n in enumerate(lens)]


@pytest.fixture(scope="module")
def base_engine(reads):
    return BitSlicedIndex.build(_cfg(), "idl", n_files=N_FILES
                                ).insert_batch(jnp.asarray(reads[:3]),
                                               np.asarray(BASE_FIDS))


@pytest.fixture(scope="module")
def oracle(base_engine, reads):
    """The hypothetical single merged index: base + the write batch
    (donate=False: the base keeps serving the other fixtures)."""
    return base_engine.insert_batch(jnp.asarray(reads[3:5]),
                                    np.asarray(DELTA_FIDS), donate=False)


@pytest.fixture(scope="module")
def snap(base_engine, tmp_path_factory):
    return store.save(base_engine,
                      str(tmp_path_factory.mktemp("fab") / "snap"))


def _fab_cfg(**kw) -> FabricConfig:
    kw.setdefault("n_workers", 2)
    kw.setdefault("service", ServiceConfig(max_batch=4))
    return FabricConfig(**kw)


def _assert_matches(results, oracle, queries):
    for q, res in zip(queries, results):
        want = np.asarray(oracle.msmt(jnp.asarray(q)[None]))[0]
        np.testing.assert_array_equal(np.asarray(res.matches), want)


class TestFabricServing:
    """One shared 2-worker fleet: parity, stamps, admission."""

    @pytest.fixture(scope="class")
    def fab(self, snap, tmp_path_factory):
        fab = ProcessFabric(
            snap, _fab_cfg(),
            journal_path=str(tmp_path_factory.mktemp("wal") / "wal.idlj"))
        yield fab
        fab.close()

    def test_parity_and_read_your_writes(self, fab, oracle, base_engine,
                                         reads, queries):
        # pre-write: fleet == base index
        _assert_matches(fab.search(queries), base_engine, queries)
        ack = fab.insert(reads[3:5], DELTA_FIDS).result(timeout=120)
        assert ack.delta_seq == 1 and ack.n_reads == 2
        # post-write: fleet == union oracle, on EVERY worker (round-robin
        # over both via repeated search), stamps prove read-your-writes
        for _ in range(2):
            results = fab.search(queries)
            _assert_matches(results, oracle, queries)
            for res in results:
                assert res.delta_seq >= ack.delta_seq
                assert res.version == ack.base_version

    def test_gateway_rejects_malformed_reads(self, fab):
        with pytest.raises(ValueError, match="one 1-D read"):
            fab.submit(np.zeros((2, 120), dtype=np.uint8))
        with pytest.raises(ValueError, match="has no 31-mers"):
            fab.submit(np.zeros((7,), dtype=np.uint8))

    def test_stats_reach_every_worker(self, fab):
        stats = fab.stats()
        assert len(stats) == 2
        assert sum(s["requests_served"] for s in stats.values()) > 0
        assert {s["version"] for s in stats.values()} == {0}


class TestFaultPaths:
    """Destructive tests: each boots (and tears down) its own fleet."""

    def test_kill9_worker_midstream(self, snap, oracle, reads, queries,
                                    tmp_path):
        """kill -9 one worker with requests in flight: the gateway
        re-routes them to the survivor and every answer still equals the
        union oracle — zero dropped futures."""
        fab = ProcessFabric(
            snap, _fab_cfg(policy="round_robin"),
            journal_path=str(tmp_path / "wal.idlj"))
        try:
            fab.insert(reads[3:5], DELTA_FIDS).result(timeout=120)
            fab.search(queries)                    # warm both workers
            stream = [queries[i % len(queries)] for i in range(24)]
            futures = [fab.submit(q) for q in stream]
            victim = sorted(fab.worker_pids().items())[0][1]
            os.kill(victim, signal.SIGKILL)
            results = [f.result(timeout=120) for f in futures]
            _assert_matches(results, oracle, stream)
            deadline = time.monotonic() + 30
            while fab.n_workers > 1 and time.monotonic() < deadline:
                time.sleep(0.05)
            assert fab.n_workers == 1
            # the fleet keeps serving — writes and reads — on the survivor
            fab.insert(reads[5:6], [23]).result(timeout=120)
            two = oracle.insert_batch(jnp.asarray(reads[5:6]),
                                      np.asarray([23]), donate=False)
            _assert_matches(fab.search(queries), two, queries)
        finally:
            fab.close()

    def test_worker_crash_during_rolling_swap(self, snap, base_engine,
                                              reads, queries, tmp_path):
        """A replacement that dies booting ABORTS the rollout: the fleet
        keeps serving the old snapshot at the old version — no mixed
        fleet, no dropped requests."""
        fab = ProcessFabric(snap, _fab_cfg())
        try:
            new_snap = store.save(base_engine, str(tmp_path / "snap2"))
            fab._test_flags["boot_fail_snapshot"] = new_snap
            with pytest.raises(FabricError, match="aborted"):
                fab.rolling_restart(new_snap)
            assert fab.version == 0
            assert fab.n_workers == 2
            stats = fab.stats()
            assert {s["version"] for s in stats.values()} == {0}
            results = fab.search(queries)
            _assert_matches(results, base_engine, queries)
            assert all(r.version == 0 for r in results)
        finally:
            fab.close()

    def test_rolling_restart_under_traffic(self, snap, base_engine,
                                           queries, tmp_path):
        """A healthy rolling swap: requests submitted before, during and
        after all resolve correctly; the fleet version advances only when
        every worker swapped."""
        fab = ProcessFabric(snap, _fab_cfg())
        try:
            fab.search(queries)                    # warm compile caches
            before = [fab.submit(q) for q in queries]
            version = fab.rolling_restart()        # same snapshot, v+1
            after = [fab.submit(q) for q in queries]
            assert version == 1 and fab.version == 1
            _assert_matches([f.result(timeout=120) for f in before],
                            base_engine, queries)
            results = [f.result(timeout=120) for f in after]
            _assert_matches(results, base_engine, queries)
            assert all(r.version == 1 for r in results)
            assert fab.n_workers == 2
        finally:
            fab.close()

    def test_gateway_reboot_replays_wal(self, snap, oracle, reads,
                                        queries, tmp_path):
        """Acked writes survive a gateway reboot: the new gateway's
        workers replay the WAL tail and answer == union oracle."""
        wal = str(tmp_path / "wal.idlj")
        fab = ProcessFabric(snap, _fab_cfg(n_workers=1), journal_path=wal)
        try:
            fab.insert(reads[3:5], DELTA_FIDS).result(timeout=120)
        finally:
            fab.close()
        reborn = ProcessFabric(snap, _fab_cfg(n_workers=1),
                               journal_path=wal)
        try:
            assert reborn.wal_seq == 1
            results = reborn.search(queries)
            _assert_matches(results, oracle, queries)
            assert all(r.delta_seq == 1 for r in results)
        finally:
            reborn.close()


class TestKmerCacheAcrossTheFleet:
    """Per-worker membership caches through the process boundary: the
    pickled ``ServiceConfig.kmer_cache`` fans out to every worker, the
    gateway aggregates their hit rates, a fanned write flips cached
    negatives fleet-wide, and the caches survive a zero-drop rolling
    restart (replacements boot cold, replay the WAL, and re-warm)."""

    def test_cache_survives_zero_drop_rolling_restart(
            self, snap, base_engine, oracle, reads, queries, tmp_path):
        fab = ProcessFabric(
            snap, _fab_cfg(service=ServiceConfig(
                max_batch=4, kmer_cache=KmerCacheConfig(capacity=1 << 14))),
            journal_path=str(tmp_path / "wal.idlj"))
        try:
            stream = [queries[i % len(queries)] for i in range(18)]
            # pass 1 warms every worker; pass 2 must reuse
            _assert_matches(fab.search(stream), base_engine, stream)
            _assert_matches(fab.search(stream), base_engine, stream)
            cs = fab.cache_stats()
            assert cs is not None and cs["hits"] > 0
            assert 0.0 < cs["hit_rate"] <= 1.0
            # a fanned write flips cached negatives on EVERY worker
            # (base rows stay cached; the delta is probed fresh)
            fab.insert(reads[3:5], DELTA_FIDS).result(timeout=120)
            _assert_matches(fab.search(stream), oracle, stream)
            # zero-drop rolling swap with caches on: requests in flight
            # before and after all resolve exactly; replacements boot
            # with cold caches and replay the WAL tail
            before = [fab.submit(q) for q in stream]
            version = fab.rolling_restart()
            after = [fab.submit(q) for q in stream]
            _assert_matches([f.result(timeout=120) for f in before],
                            oracle, stream)
            results = [f.result(timeout=120) for f in after]
            _assert_matches(results, oracle, stream)
            assert all(r.version == version for r in results)
            assert fab.n_workers == 2
            # the re-warmed fleet reuses again and still aggregates
            _assert_matches(fab.search(stream), oracle, stream)
            cs2 = fab.cache_stats()
            assert cs2 is not None and cs2["hits"] > 0
        finally:
            fab.close()

class TestObservabilityAcrossTheFleet:
    """End-to-end tracing through real process boundaries: the gateway's
    trace context rides the IPC frame, the worker opens child spans under
    it, and ``obs_snapshot()`` stitches one tree out of many pids. A
    kill -9 must error-close the dead worker's dispatch spans instead of
    leaking them, while the requests themselves still resolve."""

    def _stitched_traces(self, fab):
        """Traces in the fleet snapshot whose records span >1 process."""
        snap = fab.obs_snapshot()
        return {tid: recs
                for tid, recs in obs_export.traces_of(snap).items()
                if len({r["pid"] for r in recs}) > 1}

    def test_trace_stitches_across_processes(self, snap, base_engine,
                                             queries, tmp_path):
        obs.reset()
        fab = ProcessFabric(snap, _fab_cfg(),
                            journal_path=str(tmp_path / "wal.idlj"))
        try:
            _assert_matches(fab.search(queries), base_engine, queries)
            # root-closure callbacks and worker finalize can trail the
            # future resolution by a beat; poll the fleet snapshot
            deadline = time.monotonic() + 30
            stitched = self._stitched_traces(fab)
            while time.monotonic() < deadline and \
                    len(stitched) < len(queries):
                time.sleep(0.05)
                stitched = self._stitched_traces(fab)
            assert len(stitched) >= len(queries)
            gw_pid = os.getpid()
            for recs in stitched.values():
                by_name = {}
                for r in recs:
                    by_name.setdefault(r["name"], []).append(r)
                # gateway root, opened at submit, closed on the future
                (root,) = [r for r in by_name["request"]
                           if r["pid"] == gw_pid]
                assert root["parent"] is None
                assert root["status"] == "ok"
                assert root["attrs"]["tier"] == "gateway"
                # gateway-side dispatch span, child of the root
                (hop,) = by_name["worker_exec"]
                assert hop["pid"] == gw_pid
                assert hop["parent"] == root["span"]
                assert hop["status"] == "ok"
                # worker-side service chain, parented UNDER the dispatch
                # span minted in the gateway process
                (wreq,) = [r for r in by_name["request"]
                           if r["pid"] != gw_pid]
                assert wreq["parent"] == hop["span"]
                for stage in ("queue_wait", "assemble", "execute",
                              "finalize"):
                    (srec,) = by_name[stage]
                    assert srec["pid"] == wreq["pid"]
                    assert srec["parent"] == wreq["span"]
                # one trace id end to end — every record agrees
                assert len({r["trace"] for r in recs}) == 1
        finally:
            fab.close()

    def test_kill9_error_closes_orphaned_spans(self, snap, oracle, reads,
                                               queries, tmp_path):
        obs.reset()
        fab = ProcessFabric(snap, _fab_cfg(policy="round_robin"),
                            journal_path=str(tmp_path / "wal.idlj"))
        try:
            fab.insert(reads[3:5], DELTA_FIDS).result(timeout=120)
            fab.search(queries)                    # warm both workers
            stream = [queries[i % len(queries)] for i in range(24)]
            futures = [fab.submit(q) for q in stream]
            victim_id, victim_pid = sorted(fab.worker_pids().items())[0]
            os.kill(victim_pid, signal.SIGKILL)
            results = [f.result(timeout=120) for f in futures]
            _assert_matches(results, oracle, stream)

            def error_closed():
                return [r for r in obs_export.snapshot()["spans"]
                        if r["name"] == "worker_exec"
                        and r["status"] == "error"
                        and r.get("attrs", {}).get("error")
                        == f"worker {victim_id} died"]

            deadline = time.monotonic() + 30
            while time.monotonic() < deadline and not error_closed():
                time.sleep(0.05)
            orphans = error_closed()
            assert orphans, "kill -9 left dispatch spans open"
            # the re-dispatched requests stayed on their original traces:
            # each orphaned span's trace also has an ok worker_exec hop
            ok_hops = {r["trace"] for r in obs_export.snapshot()["spans"]
                       if r["name"] == "worker_exec"
                       and r["status"] == "ok"}
            assert any(r["trace"] in ok_hops for r in orphans)
        finally:
            fab.close()

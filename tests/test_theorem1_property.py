"""Property tests for Theorem 1's IDL sensitivity bounds, across random
configurations (hypothesis-driven)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-test-only module")
from hypothesis import given, settings, strategies as st

from repro.core import idl, kmers, minhash


@given(
    t=st.integers(10, 20),
    logL=st.integers(9, 13),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=15, deadline=None)
def test_theorem1_case1_lower_bound(t, logL, seed):
    """d(x,y) small (adjacent kmers, J=(w-1)/(w+1)): distinct values inside
    an L-window with prob >= J·(L-1)/L (Thm 1 case 1, MinHash p1 = J)."""
    rng = np.random.default_rng(seed)
    cfg = idl.IDLConfig(k=31, t=t, L=1 << logL, eta=1, m=1 << 22,
                        minhash_mode="exact")
    codes = jnp.asarray(rng.integers(0, 4, size=4000, dtype=np.uint8))
    locs = np.asarray(idl.idl_locations_rolling(cfg, codes))[0]
    blocks = locs // cfg.L
    same_window = blocks[1:] == blocks[:-1]
    distinct = locs[1:] != locs[:-1]
    ok = float(np.mean(same_window & distinct))
    w = cfg.w
    j = (w - 1) / (w + 1)
    p1_bound = j * (cfg.L - 1) / cfg.L
    # empirical mean over ~4k pairs: allow 4-sigma slack
    sigma = np.sqrt(p1_bound * (1 - p1_bound) / len(distinct))
    assert ok >= p1_bound - 4 * sigma - 0.02


@given(
    t=st.integers(12, 20),
    logL=st.integers(9, 12),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=15, deadline=None)
def test_theorem1_case2_upper_bound(t, logL, seed):
    """d(x,y) large (independent random kmers): P(within L) <= L/m' + p2
    with p2 ~ 0 for random kmers (J=0 whp)."""
    rng = np.random.default_rng(seed)
    cfg = idl.IDLConfig(k=31, t=t, L=1 << logL, eta=1, m=1 << 22,
                        minhash_mode="exact")
    a = jnp.asarray(rng.integers(0, 2**62, size=3000, dtype=np.uint64))
    b = jnp.asarray(rng.integers(0, 2**62, size=3000, dtype=np.uint64))
    mask = (np.uint64(1) << np.uint64(62)) - np.uint64(1)
    la = np.asarray(idl.idl_locations_kmer_batch(cfg, a & mask))[0]
    lb = np.asarray(idl.idl_locations_kmer_batch(cfg, b & mask))[0]
    near = float(np.mean(np.abs(la.astype(np.int64) - lb.astype(np.int64))
                         < cfg.L))
    bound = 2 * cfg.L / cfg.m_part + 0.01  # window overlap, both directions
    sigma = np.sqrt(max(bound * (1 - bound), 1e-6) / 3000)
    assert near <= bound + 4 * sigma + 0.01


@given(seed=st.integers(0, 2**31), eta=st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_doph_matches_exact_distribution(seed, eta):
    """Densified OPH MinHash collides adjacent kmers at ~the Jaccard rate,
    like exact per-seed MinHash (paper §5.3.3 correctness)."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 4, size=3000, dtype=np.uint8))
    k, t = 31, 16
    w = k - t + 1
    subk = kmers.pack_kmers(codes, t)
    mh = np.asarray(minhash.doph_minhash(subk, w, eta))
    j = (w - 1) / (w + 1)
    for rep in range(eta):
        rate = float(np.mean(mh[rep][1:] == mh[rep][:-1]))
        assert abs(rate - j) < 0.12

"""Observability plane: registry semantics, tracer semantics, export
round-trips, and the in-process span chains the serving tiers emit.

Cross-process stitching (gateway <-> worker over IPC) is asserted in
``test_fabric.py``; this file covers everything that doesn't need a
spawned interpreter: the metrics registry (pre-bound handles, log2
bucketing, snapshot/merge), the tracer (ids, parenting, idempotent
closure, the bounded ring), the export module (dump round-trip, the
registry-backed cache view), and the query/insert span trees emitted by
the sync service, the async scheduler and the live replica router.
"""

import json
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import idl
from repro.index.engines import BitSlicedIndex
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import (
    GeneSearchService,
    LiveReplicaRouter,
    RouterConfig,
    ServiceConfig,
)

N_FILES = 40


def _cfg() -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=1 << 16)


@pytest.fixture(scope="module")
def reads(rng):
    return rng.integers(0, 4, size=(6, 120), dtype=np.uint8)


@pytest.fixture(scope="module")
def queries(reads):
    lens = [120, 100, 77, 120, 61, 99]
    return [np.asarray(reads[i][:n]) for i, n in enumerate(lens)]


@pytest.fixture(scope="module")
def base_engine(reads):
    return BitSlicedIndex.build(_cfg(), "idl", n_files=N_FILES
                                ).insert_batch(jnp.asarray(reads[:3]),
                                               np.asarray([0, 9, 39]))


def _wait(pred, timeout=30.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.02)
    return pred()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestRegistry:

    def test_binding_dedupes_and_canonicalizes_labels(self):
        reg = obs_metrics.Registry()
        a = reg.counter("serving.requests", tier="service", replica=0)
        b = reg.counter("serving.requests", replica=0, tier="service")
        assert a is b                       # label order is canonicalized
        assert reg.counter("serving.requests", replica=1) is not a
        assert reg.gauge("x") is reg.gauge("x")
        assert reg.histogram("x") is reg.histogram("x")
        # same name, different instrument kind: independent tables
        assert reg.counter("x") is not reg.gauge("x")

    def test_parse_label_key_roundtrip(self):
        labels = {"tier": "service", "replica": "3", "scheme": "idl"}
        key = obs_metrics._label_key(labels)
        assert key == "replica=3,scheme=idl,tier=service"
        assert obs_metrics.parse_label_key(key) == labels
        assert obs_metrics.parse_label_key("") == {}

    def test_counter_and_gauge_values(self):
        reg = obs_metrics.Registry()
        c = reg.counter("c")
        c.inc()
        c.inc(2.5)
        g = reg.gauge("g")
        g.set(7)
        g.set(3)
        snap = reg.snapshot()
        assert snap["counters"]["c"][""] == 3.5
        assert snap["gauges"]["g"][""] == 3.0     # last write wins

    def test_histogram_log2_bucketing(self):
        reg = obs_metrics.Registry()
        h = reg.histogram("h")
        for v in (0.0, 0.5, 1.0, 2.0, 3.0, 4.0, 1000.0):
            h.observe(v)
        # bucket i counts int(v).bit_length() == i
        assert h.buckets[0] == 2                  # 0.0, 0.5
        assert h.buckets[1] == 1                  # 1.0
        assert h.buckets[2] == 2                  # 2.0, 3.0
        assert h.buckets[3] == 1                  # 4.0
        assert h.buckets[10] == 1                 # 1000 -> bit_length 10
        assert h.count == 7
        assert h.min == 0.0 and h.max == 1000.0
        assert h.sum == pytest.approx(1010.5)

    def test_histogram_clamps_to_top_bucket(self):
        reg = obs_metrics.Registry()
        h = reg.histogram("h")
        h.observe(float(2 ** 100))
        assert h.buckets[obs_metrics.N_BUCKETS - 1] == 1

    def test_observe_array_matches_scalar_path(self, rng):
        values = np.concatenate([
            rng.integers(0, 5000, size=200).astype(np.float64),
            np.array([0.0, 0.25, 1.0, 2.0**63]),
        ])
        reg = obs_metrics.Registry()
        scalar, bulk = reg.histogram("s"), reg.histogram("b")
        for v in values:
            scalar.observe(float(v))
        bulk.observe_array(values)
        assert bulk.buckets == scalar.buckets
        assert bulk.count == scalar.count
        assert bulk.sum == pytest.approx(scalar.sum)
        assert bulk.min == scalar.min and bulk.max == scalar.max
        bulk.observe_array(np.empty(0))           # no-op, no crash
        assert bulk.count == scalar.count

    def test_disabled_registry_is_a_noop(self):
        reg = obs_metrics.Registry()
        c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
        reg.enabled = False
        c.inc()
        g.set(5)
        h.observe(3)
        h.observe_array(np.arange(4))
        assert c.value == 0 and g.value == 0 and h.count == 0

    def test_reset_keeps_handles_valid(self):
        reg = obs_metrics.Registry()
        c, h = reg.counter("c"), reg.histogram("h")
        c.inc(5)
        h.observe(9)
        reg.reset()
        assert c.value == 0 and h.count == 0
        c.inc()                                   # same handle still live
        assert reg.snapshot()["counters"]["c"][""] == 1.0

    def test_snapshot_is_json_clean(self):
        reg = obs_metrics.Registry()
        reg.counter("c", tier="x").inc()
        reg.histogram("h").observe(2)
        doc = json.loads(json.dumps(reg.snapshot()))
        assert doc["hists"]["h"][""]["count"] == 1
        # empty histograms render finite min/max, not inf
        reg.histogram("empty")
        doc = reg.snapshot()["hists"]["empty"][""]
        assert doc["min"] == 0.0 and doc["max"] == 0.0


class TestMergeAndViews:

    def _snap(self, build):
        reg = obs_metrics.Registry()
        build(reg)
        return reg.snapshot()

    def test_merge_sums_counters_and_hists_lastwins_gauges(self):
        def one(reg):
            reg.counter("c", worker=0).inc(2)
            reg.gauge("g").set(1)
            h = reg.histogram("h")
            h.observe(1)
            h.observe(100)

        def two(reg):
            reg.counter("c", worker=0).inc(3)
            reg.counter("c", worker=1).inc(10)
            reg.gauge("g").set(9)
            reg.histogram("h").observe(4)

        merged = obs_metrics.merge([self._snap(one), self._snap(two)])
        assert merged["merged_from"] == 2
        assert merged["counters"]["c"]["worker=0"] == 5.0
        assert merged["counters"]["c"]["worker=1"] == 10.0
        assert merged["gauges"]["g"][""] == 9.0
        h = merged["hists"]["h"][""]
        assert h["count"] == 3
        assert h["min"] == 1.0 and h["max"] == 100.0
        assert sum(h["buckets"]) == 3
        # merging merged snapshots accumulates provenance
        again = obs_metrics.merge([merged, self._snap(one)])
        assert again["merged_from"] == 3

    def test_counter_total_filters_on_labels(self):
        def build(reg):
            reg.counter("n", scheme="idl", op="query").inc(4)
            reg.counter("n", scheme="rh", op="query").inc(8)
            reg.counter("n", scheme="idl", op="insert").inc(1)
            reg.gauge("cap", cache="a").set(10)
            reg.gauge("cap", cache="b").set(30)

        snap = self._snap(build)
        assert obs_metrics.counter_total(snap, "n") == 13.0
        assert obs_metrics.counter_total(
            snap, "n", where={"scheme": "idl"}) == 5.0
        assert obs_metrics.counter_total(
            snap, "n", where={"scheme": "idl", "op": "query"}) == 4.0
        assert obs_metrics.counter_total(snap, "absent") == 0.0
        assert obs_metrics.gauge_total(snap, "cap") == 40.0

    def test_cache_stats_view(self):
        def build(reg):
            reg.counter("kmer_cache.hits", cache=0).inc(30)
            reg.counter("kmer_cache.hits", cache=1).inc(10)
            reg.counter("kmer_cache.misses", cache=0).inc(10)
            reg.counter("kmer_cache.evictions", cache=0).inc(2)
            reg.counter("kmer_cache.invalidations", cache=1).inc(1)
            reg.gauge("kmer_cache.entries", cache=0).set(5)
            reg.gauge("kmer_cache.capacity", cache=0).set(64)

        view = obs_export.cache_stats_view({"metrics": self._snap(build)})
        assert view["hits"] == 40 and view["misses"] == 10
        assert view["lookups"] == 50
        assert view["hit_rate"] == pytest.approx(0.8)
        assert view["entries"] == 5 and view["capacity"] == 64
        assert view["evictions"] == 2 and view["invalidations"] == 1
        empty = obs_export.cache_stats_view(
            {"metrics": obs_metrics.Registry().snapshot()})
        assert empty["lookups"] == 0 and empty["hit_rate"] == 0.0


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

class TestTracer:

    def test_ids_are_pid_scoped_and_unique(self):
        trc = obs_trace.Tracer()
        ids = {trc.mint_trace() for _ in range(100)}
        assert len(ids) == 100
        span = trc.start("x")
        assert span.span_id.split(".")[0] == format(trc._pid, "x")
        span.end()

    def test_start_end_and_child_parenting(self):
        trc = obs_trace.Tracer()
        root = trc.start("request", tier="gateway")
        child = trc.start("worker_exec", trace=root.context(), worker=1)
        child.end()
        root.end(n=6)
        recs = {r["name"]: r for r in trc.records()}
        assert recs["request"]["parent"] is None
        assert recs["worker_exec"]["parent"] == recs["request"]["span"]
        assert recs["worker_exec"]["trace"] == recs["request"]["trace"]
        assert recs["request"]["attrs"] == {"tier": "gateway", "n": 6}
        assert recs["request"]["dur"] >= recs["worker_exec"]["dur"] >= 0

    def test_end_is_idempotent(self):
        trc = obs_trace.Tracer()
        span = trc.start("x")
        span.end()
        span.end(status="error")                  # late death-closure
        assert len(trc.records()) == 1
        assert trc.records()[0]["status"] == "ok"

    def test_context_manager_marks_errors(self):
        trc = obs_trace.Tracer()
        with pytest.raises(RuntimeError):
            with trc.start("boom"):
                raise RuntimeError("x")
        with trc.start("fine"):
            pass
        status = {r["name"]: r["status"] for r in trc.records()}
        assert status == {"boom": "error", "fine": "ok"}

    def test_close_open_spans(self):
        trc = obs_trace.Tracer()
        trc.start("a", worker=1)
        trc.start("b", worker=1)
        done = trc.start("c")
        done.end()
        assert trc.close_open_spans(error="worker 1 died") == 2
        assert trc.close_open_spans() == 0        # nothing left open
        errs = [r for r in trc.records() if r["status"] == "error"]
        assert len(errs) == 2
        assert all(r["attrs"]["error"] == "worker 1 died" for r in errs)

    def test_emit_fast_path_and_disabled(self):
        trc = obs_trace.Tracer()
        t0 = time.monotonic()
        tid = trc.mint_trace()
        root = trc.emit("request", tid, None, t0, t0 + 0.25)
        child = trc.emit("execute", tid, root, t0, t0 + 0.125,
                         attrs={"bucket": 128})
        assert root is not None and child is not None
        recs = trc.records()
        assert recs[1]["parent"] == root
        assert recs[0]["dur"] == pytest.approx(0.25)
        assert recs[1]["attrs"] == {"bucket": 128}
        trc.enabled = False
        assert trc.emit("x", tid, None, t0, t0) is None
        assert len(trc.records()) == 2

    def test_ring_is_bounded(self):
        trc = obs_trace.Tracer(capacity=4)
        t0 = time.monotonic()
        for i in range(10):
            trc.emit(f"s{i}", trc.mint_trace(), None, t0, t0)
        names = [r["name"] for r in trc.records()]
        assert names == ["s6", "s7", "s8", "s9"]  # oldest evicted

    def test_ingest_and_exports(self):
        worker = obs_trace.Tracer()
        gateway = obs_trace.Tracer()
        root = gateway.start("request")
        w = worker.start("request", trace=root.context())
        w.end()
        root.end()
        gateway.ingest(worker.records())          # stitch worker records
        exp = gateway.export()
        assert exp["n_spans"] == 2
        (spans,) = exp["traces"].values()         # ONE trace id
        assert {s["span"] for s in spans} == {root.span_id, w.span_id}
        chrome = gateway.export_chrome()
        assert len(chrome["traceEvents"]) == 2
        ev = chrome["traceEvents"][0]
        assert ev["ph"] == "X" and ev["tid"] == root.trace_id
        assert ev["args"]["span"] in (root.span_id, w.span_id)


# ---------------------------------------------------------------------------
# export module
# ---------------------------------------------------------------------------

class TestExport:

    def _private(self):
        reg, trc = obs_metrics.Registry(), obs_trace.Tracer()
        reg.counter("c").inc(2)
        span = trc.start("request")
        trc.start("child", trace=span.context()).end()
        span.end()
        return obs_export.snapshot(registry=reg, tracer=trc)

    def test_snapshot_merge_traces_of(self):
        a, b = self._private(), self._private()
        merged = obs_export.merge([a, b, None, {}])
        assert merged["metrics"]["counters"]["c"][""] == 4.0
        assert len(merged["spans"]) == 4
        t0s = [r["t0"] for r in merged["spans"]]
        assert t0s == sorted(t0s)
        traces = obs_export.traces_of(merged)
        assert len(traces) == 2                   # distinct trace ids kept
        for recs in traces.values():
            assert {r["name"] for r in recs} == {"request", "child"}

    def test_dump_round_trip(self, tmp_path):
        snap = self._private()
        out = tmp_path / "obs" / "dump.json"
        paths = obs_export.dump(snap, str(out))
        assert paths == [str(out), str(out.with_suffix(".chrome.json"))]
        doc = json.loads(out.read_text())
        assert doc["metrics"]["counters"]["c"][""] == 2.0
        (spans,) = doc["traces"].values()
        assert len(spans) == 2
        chrome = json.loads(out.with_suffix(".chrome.json").read_text())
        assert len(chrome["traceEvents"]) == 2
        assert chrome["displayTimeUnit"] == "ms"


# ---------------------------------------------------------------------------
# span chains through the serving tiers (single process)
# ---------------------------------------------------------------------------

class TestServingSpanChains:

    @pytest.fixture(autouse=True)
    def _clean_obs(self):
        obs.reset()
        yield
        obs.set_enabled(True)
        obs.reset()

    def test_sync_service_emits_request_chain(self, base_engine, queries):
        svc = GeneSearchService(
            base_engine, ServiceConfig(backend="idl_probe", max_batch=4))
        svc.search(queries)
        snap = obs_export.snapshot()
        traces = obs_export.traces_of(snap)
        chains = 0
        for recs in traces.values():
            by_name = {r["name"]: r for r in recs}
            if "request" not in by_name:
                continue
            root = by_name["request"]
            assert root["parent"] is None         # minted at admission
            assert root["status"] == "ok"
            for stage in ("queue_wait", "assemble", "execute", "finalize"):
                assert by_name[stage]["parent"] == root["span"]
                assert by_name[stage]["trace"] == root["trace"]
            chains += 1
        assert chains >= len(queries)
        # the registry saw the same traffic, including locality counters
        m = snap["metrics"]
        assert obs_metrics.counter_total(
            m, "serving.requests") >= len(queries)
        assert obs_metrics.counter_total(
            m, "locality.planned_tile_bytes", where={"scheme": "idl"}) > 0

    def test_disabled_obs_serves_identically_and_records_nothing(
            self, base_engine, queries):
        svc = GeneSearchService(
            base_engine, ServiceConfig(backend="idl_probe", max_batch=4))
        want = [np.asarray(r.matches) for r in svc.search(queries)]
        obs.reset()
        obs.set_enabled(False)
        got = [np.asarray(r.matches) for r in svc.search(queries)]
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, g)   # bit-identical
        snap = obs_export.snapshot()
        assert snap["spans"] == []
        assert obs_metrics.counter_total(
            snap["metrics"], "serving.requests") == 0.0

    def test_live_router_insert_span_tree(self, reads, queries):
        base = BitSlicedIndex.build(_cfg(), "idl", n_files=N_FILES
                                    ).insert_batch(jnp.asarray(reads[:3]),
                                                   np.asarray([0, 9, 39]))
        rt = LiveReplicaRouter(
            base, ServiceConfig(max_batch=4),
            RouterConfig(n_replicas=2, policy="round_robin"))
        with rt:
            for f in rt.insert(np.asarray(reads[3:5]),
                               np.asarray([5, 17])):
                f.result(timeout=60)
            rt.search(queries)

            def insert_tree():
                for recs in obs_export.traces_of(
                        obs_export.snapshot()).values():
                    names = {r["name"] for r in recs}
                    if "insert" in names and "replica_apply" in names:
                        return recs
                return None

            assert _wait(lambda: insert_tree() is not None)
            recs = insert_tree()
            by_name = {}
            for r in recs:
                by_name.setdefault(r["name"], []).append(r)
            (root,) = by_name["insert"]
            assert root["parent"] is None and root["status"] == "ok"
            assert root["attrs"]["tier"] == "router"
            assert root["attrs"]["n_reads"] == 2
            # ack closure stamps the fan-out width
            assert root["attrs"]["n_replicas"] == 2
            (journal,) = by_name["journal_append"]
            (fanout,) = by_name["fanout"]
            assert journal["parent"] == root["span"]
            assert fanout["parent"] == root["span"]
            # one apply per replica, all on the SAME trace as the root
            assert len(by_name["replica_apply"]) == 2
            for apply_rec in by_name["replica_apply"]:
                assert apply_rec["trace"] == root["trace"]
                assert apply_rec["parent"] == root["span"]
            # queries that followed the write carry their own traces
            q_traces = [recs for recs in obs_export.traces_of(
                obs_export.snapshot()).values()
                if any(r["name"] == "request" for r in recs)]
            assert len(q_traces) >= len(queries)

"""Serving-cluster acceptance: the async scheduler and the replica router
answer bit-identically to direct ``GeneSearchService.flush()`` across
engines × schemes × theta under a ragged Poisson stream; compile counts
stay at one per (bucket, backend) per replica; hot snapshot swap completes
under live traffic with zero dropped or mis-versioned futures; corrupt /
future-version snapshots are rejected while traffic keeps flowing; the
autoscale policies move their knobs in the right direction; telemetry is
ring-buffer bounded."""

import json
import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idl
from repro.index import BitSlicedIndex, CobsIndex, store
from repro.index import state as state_mod
from repro.index.store import SnapshotError
from repro.serving import (
    AdmissionPolicy,
    AsyncScheduler,
    AutoscaleConfig,
    GeneSearchService,
    ReplicaAutoscaler,
    ReplicaRouter,
    RouterConfig,
    SchedulerConfig,
    ServiceConfig,
)
from repro.serving.autoscale import EwmaRate
from repro.serving.scheduler import FLUSH_DEADLINE, FLUSH_FULL

ENGINES = ["bitsliced", "cobs"]


def _cfg(m: int = 1 << 16) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)


@pytest.fixture(scope="module")
def reads(rng):
    return jnp.asarray(rng.integers(0, 4, size=(3, 120), dtype=np.uint8))


def _build(name: str, reads, scheme: str = "idl"):
    fids = np.arange(reads.shape[0])
    if name == "cobs":
        return CobsIndex.build(
            [100, 200, 150], _cfg(), scheme=scheme, n_groups=2
        ).insert_batch(reads, fids)
    if name == "bitsliced":
        return BitSlicedIndex.build(
            _cfg(), scheme, n_files=40
        ).insert_batch(reads, np.asarray([0, 9, 39]))
    raise KeyError(name)


def _poisson_stream(reads, n_requests: int, seed: int):
    """Ragged Poisson stream: mixed-length reads + exponential gaps (s)."""
    rng = np.random.default_rng(seed)
    lens = rng.choice([44, 61, 77, 99, 100, 120], size=n_requests)
    gaps = rng.exponential(5e-4, size=n_requests)
    return ([np.asarray(reads[i % 3][:n]) for i, n in enumerate(lens)],
            gaps)


def _submit_paced(target, queries, gaps):
    """Submit with Poisson pacing so deadline flushes actually happen."""
    futures = []
    for q, gap in zip(queries, gaps):
        futures.append(target.submit(q))
        time.sleep(gap)
    return futures


class TestClusterParity:
    """The acceptance matrix: scheduler + router == direct service flush."""

    @pytest.mark.parametrize("theta", [1.0, 0.6])
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("engine", ENGINES)
    def test_bit_identical_to_direct_flush(self, reads, engine, scheme,
                                           theta):
        eng = _build(engine, reads, scheme)
        svc_cfg = ServiceConfig(theta=theta, max_batch=4)
        queries, gaps = _poisson_stream(reads, 24, seed=11)

        # the reference: direct synchronous service flush
        ref_svc = GeneSearchService(eng, svc_cfg)
        ref = ref_svc.search(queries)

        # async scheduler under a paced stream (deadline + full flushes)
        with AsyncScheduler(GeneSearchService(eng, svc_cfg),
                            SchedulerConfig(max_delay_ms=1.0)) as sched:
            futures = _submit_paced(sched, queries, gaps)
            sched.drain()
            got = [f.result() for f in futures]
            for r, want in zip(got, ref):
                np.testing.assert_array_equal(np.asarray(r.matches),
                                              np.asarray(want.matches))
                assert r.file_ids == want.file_ids
            assert all(c == 1 for c in sched.compile_counts().values())

        # 2-replica router under the same paced stream
        with ReplicaRouter(eng, svc_cfg,
                           RouterConfig(n_replicas=2)) as router:
            futures = _submit_paced(router, queries, gaps)
            router.drain()
            for f, want in zip(futures, ref):
                r = f.result()
                np.testing.assert_array_equal(np.asarray(r.matches),
                                              np.asarray(want.matches))
                assert r.file_ids == want.file_ids
            for counts in router.compile_counts().values():
                assert all(c == 1 for c in counts.values())


class TestSchedulerEventLoop:
    def test_deadline_flush_without_drain(self, reads):
        """A lone request on an idle bucket is flushed by the deadline
        thread — no drain(), no full batch."""
        eng = _build("bitsliced", reads)
        with AsyncScheduler(GeneSearchService(eng, ServiceConfig(max_batch=8)),
                            SchedulerConfig(max_delay_ms=5.0)) as sched:
            fut = sched.submit(np.asarray(reads[0]))
            res = fut.result(timeout=30)     # resolved without drain()
            want = np.asarray(eng.msmt(jnp.asarray(reads[0])[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)
            assert sched.stats[-1].flush_reason == FLUSH_DEADLINE
            assert sched.outstanding == 0

    def test_full_flush_reason_and_queue_ms(self, reads):
        eng = _build("bitsliced", reads)
        with AsyncScheduler(GeneSearchService(eng, ServiceConfig(max_batch=2)),
                            SchedulerConfig(max_delay_ms=500.0)) as sched:
            f1 = sched.submit(np.asarray(reads[0]))
            f2 = sched.submit(np.asarray(reads[1]))
            f1.result(timeout=30), f2.result(timeout=30)
            assert sched.stats[-1].flush_reason == FLUSH_FULL
            assert sched.stats[-1].n_requests == 2
            assert sched.stats[-1].queue_ms >= 0.0
            assert 0.0 < sched.stats[-1].occupancy <= 1.0

    def test_stats_ring_buffer_is_bounded(self, reads):
        """Soak runs cannot grow telemetry unboundedly (stats_window)."""
        eng = _build("bitsliced", reads)
        with AsyncScheduler(GeneSearchService(eng, ServiceConfig(max_batch=1)),
                            SchedulerConfig(stats_window=3)) as sched:
            sched.search([np.asarray(reads[0])] * 7)
            assert len(sched.stats) == 3
            assert sched.service.batch_stats.maxlen is not None

    def test_submit_after_close_raises(self, reads):
        sched = AsyncScheduler(GeneSearchService(_build("bitsliced", reads)))
        sched.close()
        with pytest.raises(RuntimeError, match="closed"):
            sched.submit(np.asarray(reads[0]))
        sched.close()                        # idempotent

    def test_invalid_read_fails_fast_not_in_future(self, reads):
        with AsyncScheduler(
                GeneSearchService(_build("bitsliced", reads))) as sched:
            with pytest.raises(ValueError, match="no 31-mers"):
                sched.submit(np.zeros(5, dtype=np.uint8))
            with pytest.raises(ValueError, match="one 1-D read"):
                sched.submit(np.asarray(reads))

    def test_duplicate_inflight_request_id_rejected(self, reads):
        """The PR-4 sync-service rule survives the async path: one live
        result per explicit request id."""
        from repro.serving import SearchRequest
        eng = _build("bitsliced", reads)
        with AsyncScheduler(GeneSearchService(eng, ServiceConfig(max_batch=8)),
                            SchedulerConfig(max_delay_ms=200.0)) as sched:
            fut = sched.submit(SearchRequest(read=np.asarray(reads[0]),
                                             request_id=7))
            with pytest.raises(ValueError, match="in flight"):
                sched.submit(SearchRequest(read=np.asarray(reads[1]),
                                           request_id=7))
            sched.drain()
            assert fut.result().request_id == 7
            # resolved: the id is free again
            assert sched.submit(SearchRequest(read=np.asarray(reads[1]),
                                              request_id=7)
                                ).result(timeout=30).request_id == 7

    def test_overdue_bucket_beats_full_bucket(self, reads):
        """A hot bucket must not starve a lone overdue request on another
        bucket (white-box: _pick prefers the overdue bucket)."""
        from repro.serving.scheduler import _Pending
        from concurrent.futures import Future
        eng = _build("bitsliced", reads)
        sched = AsyncScheduler(
            GeneSearchService(eng, ServiceConfig(max_batch=2)),
            SchedulerConfig(max_delay_ms=5.0))
        sched.pause()                      # flusher idle; queues are ours
        try:
            import collections as c
            now = time.monotonic()
            stale = _Pending(request=None, n_kmers=1, future=Future(),
                             t_enq=now - 1.0)       # 1 s overdue
            fresh = [_Pending(request=None, n_kmers=1, future=Future(),
                              t_enq=now) for _ in range(2)]
            with sched._lock:
                sched._queues = {128: c.deque(fresh),   # full (max_batch=2)
                                 32: c.deque([stale])}  # lone but overdue
                sched._paused = False
                pick = sched._pick(time.monotonic())
                sched._paused = True
                sched._queues = {}
            assert pick == (32, "deadline")
        finally:
            sched.resume()
            sched.close()


class TestRouterPolicies:
    def test_round_robin_spreads_over_replicas(self, reads):
        eng = _build("bitsliced", reads)
        with ReplicaRouter(eng, ServiceConfig(max_batch=2),
                           RouterConfig(n_replicas=2, policy="round_robin")
                           ) as router:
            router.search([np.asarray(reads[i % 3]) for i in range(8)])
            replicas = {s.replica for s in router.cluster_stats()}
            assert replicas == {0, 1}

    def test_bucket_affinity_pins_buckets(self, reads):
        """Every batch of one kmer bucket runs on ONE replica — its
        compile cache stays hot for exactly its buckets."""
        eng = _build("bitsliced", reads)
        with ReplicaRouter(eng, ServiceConfig(max_batch=2),
                           RouterConfig(n_replicas=2,
                                        policy="bucket_affinity")) as router:
            qs = [np.asarray(reads[i % 3][:n])
                  for i, n in enumerate([120, 44, 120, 44, 99, 120, 44, 99])]
            router.search(qs)
            by_bucket = {}
            for s in router.cluster_stats():
                by_bucket.setdefault(s.bucket, set()).add(s.replica)
            assert all(len(reps) == 1 for reps in by_bucket.values())
            assert len(by_bucket) >= 2       # stream really spans buckets

    def test_least_outstanding_balances(self, reads):
        eng = _build("bitsliced", reads)
        with ReplicaRouter(eng, ServiceConfig(max_batch=4),
                           RouterConfig(n_replicas=2,
                                        policy="least_outstanding")
                           ) as router:
            res = router.search([np.asarray(reads[i % 3])
                                 for i in range(16)])
            assert len(res) == 16
            assert router.requests_served() == 16

    def test_bad_policy_rejected(self):
        with pytest.raises(ValueError, match="routing policy"):
            RouterConfig(policy="random")
        with pytest.raises(ValueError, match="n_replicas"):
            RouterConfig(n_replicas=0)


class TestHotSwap:
    @pytest.fixture()
    def snapshots(self, tmp_path, reads, rng):
        """(snap_v0, snap_v1, new_read): v1 additionally indexes new_read
        into file 5 — a query for it distinguishes the two versions."""
        eng = _build("bitsliced", reads)
        snap0 = store.save(eng, str(tmp_path / "v0"))
        new_read = np.asarray(
            rng.integers(0, 4, size=120, dtype=np.uint8))
        eng1 = state_mod.to_engine(store.load(snap0)).insert_batch(
            jnp.asarray(new_read)[None], np.asarray([5]))
        snap1 = store.save(eng1, str(tmp_path / "v1"))
        return snap0, snap1, new_read

    def test_swap_under_live_traffic(self, snapshots, reads):
        """The acceptance bar: swap while a submitter thread is firing;
        zero dropped futures, zero mis-versioned results, compile-once."""
        snap0, snap1, new_read = snapshots
        ref0 = store.load_engine(snap0)
        ref1 = store.load_engine(snap1)
        queries = [np.asarray(reads[i % 3]) for i in range(3)] + [new_read]
        want = {
            0: [np.asarray(ref0.msmt(jnp.asarray(q)[None]))[0]
                for q in queries],
            1: [np.asarray(ref1.msmt(jnp.asarray(q)[None]))[0]
                for q in queries],
        }
        router = ReplicaRouter.from_snapshot(
            snap0, ServiceConfig(max_batch=4),
            RouterConfig(n_replicas=2),
        )
        futures = []                       # (query index, future)
        stop = threading.Event()

        def submitter():
            i = 0
            while not stop.is_set():
                futures.append((i % 4, router.submit(queries[i % 4])))
                i += 1
                time.sleep(0.001)

        t = threading.Thread(target=submitter)
        t.start()
        try:
            time.sleep(0.05)               # traffic flowing on v0
            new_version = router.swap_snapshot(snap1)
            assert new_version == 1
            time.sleep(0.05)               # traffic flowing on v1
        finally:
            stop.set()
            t.join()
        router.drain()
        n_submitted = len(futures)
        assert n_submitted > 20
        seen_versions = set()
        for qi, fut in futures:
            res = fut.result(timeout=30)   # zero dropped futures
            seen_versions.add(res.version)
            # zero mis-versioned results: the verdict must match the
            # reference engine of the version stamped on the result
            np.testing.assert_array_equal(
                np.asarray(res.matches), want[res.version][qi])
        assert seen_versions == {0, 1}     # swap really happened mid-stream
        # post-swap: everything serves v1, and it finds the new read
        res = router.submit(new_read).result(timeout=30)
        assert res.version == 1 and 5 in res.file_ids
        # same-geometry swap reuses every executable: still one compile
        # per (bucket, backend) per replica
        for counts in router.compile_counts().values():
            assert all(c == 1 for c in counts.values())
        router.close()

    def test_corrupt_snapshot_rejected_traffic_flows(self, snapshots,
                                                     reads, tmp_path):
        snap0, snap1, _ = snapshots
        bad = str(tmp_path / "bad")
        os.makedirs(bad)
        with open(os.path.join(bad, "manifest.json"), "w") as f:
            f.write("{not json")
        with ReplicaRouter.from_snapshot(
                snap0, ServiceConfig(max_batch=2),
                RouterConfig(n_replicas=2)) as router:
            with pytest.raises(SnapshotError):
                router.swap_snapshot(bad)
            assert router.version == 0     # fleet untouched
            # corrupt words payload: load-time CRC catches it
            corrupt = str(tmp_path / "corrupt")
            store.save(store.load(snap1), corrupt)
            words = os.path.join(corrupt, "words_0.npy")
            raw = bytearray(open(words, "rb").read())
            raw[-1] ^= 0xFF
            open(words, "wb").write(bytes(raw))
            with pytest.raises(SnapshotError, match="checksum"):
                router.swap_snapshot(corrupt)
            assert router.version == 0
            # traffic keeps flowing on the old version
            res = router.search([np.asarray(reads[0])])
            assert res[0].version == 0

    def test_future_version_snapshot_rejected(self, snapshots, reads,
                                              tmp_path):
        snap0, snap1, _ = snapshots
        futur = str(tmp_path / "future")
        store.save(store.load(snap1), futur)
        mpath = os.path.join(futur, "manifest.json")
        manifest = json.load(open(mpath))
        manifest["version"] = store.VERSION + 1
        json.dump(manifest, open(mpath, "w"))
        with ReplicaRouter.from_snapshot(
                snap0, ServiceConfig(max_batch=2),
                RouterConfig(n_replicas=2)) as router:
            with pytest.raises(SnapshotError, match="version"):
                router.swap_snapshot(futur)
            assert router.version == 0
            assert router.search([np.asarray(reads[0])])[0].version == 0

    def test_kmer_size_change_rejected(self, snapshots, reads):
        snap0, _, _ = snapshots
        other = BitSlicedIndex.build(
            idl.IDLConfig(k=21, t=12, L=1 << 10, eta=2, m=1 << 16),
            "idl", n_files=8)
        with ReplicaRouter.from_snapshot(snap0) as router:
            with pytest.raises(ValueError, match="kmer size"):
                router.swap_state(other)


class TestAutoscalePolicies:
    def test_ewma_rate_tracks_and_decays(self):
        r = EwmaRate(halflife_s=0.5)
        t = 100.0
        for i in range(2000):
            r.observe(t + i * 1e-3)        # 1 kHz for 2 s
        now = t + 2.0
        assert 700 <= r.rate(now) <= 1300  # converged near 1000/s
        assert r.rate(now + 2.0) < r.rate(now) * 0.1   # idle decay

    def test_admission_idle_bucket_flushes_immediately(self):
        p = AdmissionPolicy(AutoscaleConfig())
        assert p.target_batch(64, now=0.0, max_batch=16) == 1
        assert p.deadline_ms(64, now=0.0, max_batch=16) == \
            p.config.deadline_ms_min

    def test_admission_hot_bucket_batches_up(self):
        p = AdmissionPolicy(AutoscaleConfig())
        t = 0.0
        for i in range(5000):
            p.observe_arrival(64, t + i * 1e-4)      # 10 kHz stream
        now = t + 0.5
        assert p.target_batch(64, now, max_batch=16) == 16
        # deadline ~ fill time of a full batch: 16/10k = 1.6 ms
        dl = p.deadline_ms(64, now, max_batch=16)
        assert p.config.deadline_ms_min < dl < p.config.deadline_ms_max

    def test_admission_occupancy_feedback_shrinks_deadline(self):
        import dataclasses as dc
        from repro.serving.scheduler import ClusterStats
        p = AdmissionPolicy(AutoscaleConfig())
        t = 0.0
        for i in range(200):
            p.observe_arrival(32, t + i * 1e-3)      # enough rate to hold
        base = p.deadline_ms(32, 0.2, max_batch=16)
        stats = ClusterStats(replica=0, version=0, bucket=32, n_requests=2,
                             batch_rows=16, flush_reason="deadline",
                             queue_ms=1.0, wall_ms=1.0)
        for _ in range(20):                 # deadline flushes, 12% occupancy
            p.observe_batch(stats, 0.2)
        shrunk = p.deadline_ms(32, 0.2, max_batch=16)
        assert shrunk < base                # we waited, nobody came: stop
        full = dc.replace(stats, n_requests=16, flush_reason="full")
        for _ in range(30):
            p.observe_batch(full, 0.2)
        assert p.deadline_ms(32, 0.2, max_batch=16) > shrunk

    def test_replica_autoscaler_scales_up_down_with_bounds(self):
        import dataclasses as dc
        from repro.serving.scheduler import ClusterStats
        a = ReplicaAutoscaler(AutoscaleConfig(
            min_replicas=1, max_replicas=3, cooldown_s=0.0,
            target_utilization=0.5))
        batch = ClusterStats(replica=0, version=0, bucket=64, n_requests=16,
                             batch_rows=16, flush_reason="full",
                             queue_ms=0.5, wall_ms=16.0)   # mu = 1000 req/s
        t = 0.0
        for i in range(4000):
            a.observe_arrival(t + i * 5e-4)       # 2 kHz arrivals
        a.observe_batch(batch, t + 2.0)
        now = t + 2.0
        # demand: 2000/(1000*0.5) = 4 -> clamped to max 3, one step at a
        # time with hysteresis
        assert a.recommend(now, 1, outstanding=0, max_batch=16) == 2
        assert a.recommend(now, 2, outstanding=0, max_batch=16) == 3
        assert a.recommend(now, 3, outstanding=0, max_batch=16) == 3
        # idle an hour later: scale down one step, floor at min_replicas
        later = now + 3600.0
        assert a.recommend(later, 3, outstanding=0, max_batch=16) == 2
        assert a.recommend(later, 1, outstanding=0, max_batch=16) == 1

    def test_replica_autoscaler_cooldown_and_backlog(self):
        from repro.serving.scheduler import ClusterStats
        a = ReplicaAutoscaler(AutoscaleConfig(
            min_replicas=1, max_replicas=4, cooldown_s=10.0))
        batch = ClusterStats(replica=0, version=0, bucket=64, n_requests=16,
                             batch_rows=16, flush_reason="full",
                             queue_ms=0.5, wall_ms=16.0)
        a.observe_batch(batch, 0.0)
        # backlog forces a step up even with a modest rate estimate
        assert a.recommend(1.0, 1, outstanding=100, max_batch=16) == 2
        # cooldown: the next change is suppressed for 10 s
        assert a.recommend(2.0, 2, outstanding=200, max_batch=16) == 2
        assert a.recommend(12.0, 2, outstanding=200, max_batch=16) == 3

    def test_router_scale_to_drains_removed_replicas(self, reads):
        eng = _build("bitsliced", reads)
        with ReplicaRouter(eng, ServiceConfig(max_batch=2),
                           RouterConfig(n_replicas=1)) as router:
            assert router.scale_to(3) == 3
            res = router.search([np.asarray(reads[i % 3])
                                 for i in range(12)])
            assert len(res) == 12
            assert router.scale_to(1) == 1     # drains, zero dropped
            res = router.search([np.asarray(reads[0])])
            assert len(res) == 1
            with pytest.raises(ValueError, match="below 1"):
                router.scale_to(0)

    def test_router_autoscale_step_applies_recommendation(self, reads):
        eng = _build("bitsliced", reads)
        with ReplicaRouter(
                eng, ServiceConfig(max_batch=2),
                RouterConfig(n_replicas=1,
                             autoscale=AutoscaleConfig(
                                 min_replicas=1, max_replicas=2,
                                 cooldown_s=0.0, target_utilization=0.9)),
        ) as router:
            assert router.autoscale_step() == 1   # no load: stays at min
            router.search([np.asarray(reads[i % 3]) for i in range(8)])
            # force the demand signal: pretend arrivals far outrun service
            for i in range(5000):
                router.autoscaler.observe_arrival(time.monotonic())
            n = router.autoscale_step()
            assert n == 2                        # one hysteresis step up
            res = router.search([np.asarray(reads[0])] * 4)
            assert len(res) == 4

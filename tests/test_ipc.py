"""Wire framing under hostile kernels: short reads, EINTR, EOF.

A stream socket owes ``recv`` nothing but >= 1 byte — the 4-byte length
prefix itself can arrive one byte at a time, and a signal can interrupt
any read with EINTR. ``Wire.recv`` must reassemble frames byte-exactly
through both (EINTR is an ``OSError`` subclass, so a naive
``except OSError`` turns a live peer into a false ``WireClosed`` — the
gateway would declare a healthy worker dead). The fakes below drive
those schedules deterministically; a real-socketpair test keeps the
fakes honest.
"""

import pickle
import socket
import threading

import pytest

from repro.serving import ipc


class _ScriptedSocket:
    """Duck-typed socket whose recv follows a byte-exact script.

    The script is a list of items: ``bytes`` (returned AT MOST one item
    per recv call, truncated to the requested size with the remainder
    pushed back — the short-read schedule is the test's to choose) or an
    exception instance to raise (EINTR injection).
    """

    def __init__(self, script):
        self._script = list(script)
        self.recv_calls = 0

    def recv(self, size):
        self.recv_calls += 1
        if not self._script:
            return b""                     # EOF
        item = self._script.pop(0)
        if isinstance(item, BaseException):
            raise item
        if len(item) > size:
            self._script.insert(0, item[size:])
            item = item[:size]
        return item

    def sendall(self, data):
        raise AssertionError("recv-only fake")

    def shutdown(self, how):
        pass

    def close(self):
        pass


def _frame(obj) -> bytes:
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    return ipc._LEN.pack(len(data)) + data


class TestShortReads:
    def test_byte_at_a_time(self):
        """The worst legal kernel: every recv returns ONE byte — the
        length prefix itself fragments across four reads."""
        msg = ipc.Request(7, "query", (3, b"ACGT"))
        raw = _frame(msg)
        sock = _ScriptedSocket([raw[i:i + 1] for i in range(len(raw))])
        assert ipc.Wire(sock).recv() == msg
        assert sock.recv_calls == len(raw)

    def test_split_inside_length_prefix(self):
        """2+2 bytes of prefix, then the body in two jagged pieces."""
        msg = ipc.Reply(42, payload={"hits": 17})
        raw = _frame(msg)
        sock = _ScriptedSocket([raw[:2], raw[2:4], raw[4:9], raw[9:]])
        assert ipc.Wire(sock).recv() == msg

    def test_two_frames_back_to_back(self):
        """One recv's overshoot must not eat into the next frame."""
        a, b = ipc.Request(1, "stats"), ipc.Request(2, "shutdown")
        sock = _ScriptedSocket([_frame(a) + _frame(b)])
        wire = ipc.Wire(sock)
        assert wire.recv() == a
        assert wire.recv() == b

    def test_eof_mid_prefix_raises_wire_closed(self):
        sock = _ScriptedSocket([b"\x10\x00"])       # 2 of 4 prefix bytes
        with pytest.raises(ipc.WireClosed):
            ipc.Wire(sock).recv()

    def test_eof_mid_body_raises_wire_closed(self):
        raw = _frame(ipc.Request(1, "stats"))
        sock = _ScriptedSocket([raw[:-3]])           # body truncated
        with pytest.raises(ipc.WireClosed):
            ipc.Wire(sock).recv()


class TestEintr:
    def test_eintr_mid_prefix_is_retried(self):
        """A signal between prefix bytes must NOT look like peer death."""
        msg = ipc.Request(9, "insert", None)
        raw = _frame(msg)
        sock = _ScriptedSocket([
            raw[:1], InterruptedError(4, "Interrupted system call"),
            raw[1:4], InterruptedError(4, "Interrupted system call"),
            raw[4:]])
        assert ipc.Wire(sock).recv() == msg

    def test_eintr_storm_is_survived(self):
        msg = ipc.Reply(3, payload="ready")
        raw = _frame(msg)
        script = []
        for i in range(len(raw)):
            script += [InterruptedError(4, "Interrupted system call"),
                       raw[i:i + 1]]
        assert ipc.Wire(_ScriptedSocket(script)).recv() == msg

    def test_real_errors_still_raise_wire_closed(self):
        """EINTR is the ONLY retried errno — a reset is still death."""
        sock = _ScriptedSocket([
            ConnectionResetError(104, "Connection reset by peer")])
        with pytest.raises(ipc.WireClosed):
            ipc.Wire(sock).recv()


class TestRealSocketpair:
    """The fakes above encode assumptions; one real kernel pass keeps
    them honest (dribbled writes force genuine short reads)."""

    def test_dribbled_frame_reassembles(self):
        a, b = socket.socketpair()
        try:
            msg = ipc.Request(11, "query", (0, b"x" * 4096))
            raw = _frame(msg)

            def _dribble():
                for i in range(0, len(raw), 7):
                    a.sendall(raw[i:i + 7])

            t = threading.Thread(target=_dribble)
            t.start()
            got = ipc.Wire(b).recv()
            t.join()
            assert got == msg
        finally:
            a.close()
            b.close()

    def test_peer_close_mid_frame(self):
        a, b = socket.socketpair()
        try:
            raw = _frame(ipc.Reply(1, payload="partial"))
            a.sendall(raw[:len(raw) // 2])
            a.close()
            with pytest.raises(ipc.WireClosed):
                ipc.Wire(b).recv()
        finally:
            b.close()

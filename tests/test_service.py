"""Serving v2 acceptance: service answers bit-identical to direct engine
``msmt`` across 4 engines × schemes × {jnp, idl_probe} backends including
padded-bucket (mixed-length) requests; each (bucket, backend) compiles
exactly once; admission queue + stats; snapshot-backed startup."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idl
from repro.index import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
    packed,
    store,
)
from repro.serving import (
    GeneSearchService,
    SearchRequest,
    SearchResult,
    ServiceConfig,
)

ENGINES = ["bloom", "cobs", "rambo", "bitsliced"]


def _cfg(m: int = 1 << 16) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)


@pytest.fixture(scope="module")
def reads(rng):
    return jnp.asarray(rng.integers(0, 4, size=(3, 120), dtype=np.uint8))


@pytest.fixture(scope="module")
def queries(reads):
    """Mixed-length request stream: prefixes of indexed reads (guaranteed
    hits at theta=1) + short tails — spans three kmer buckets."""
    lens = [120, 100, 77, 120, 61, 99, 44]
    return [np.asarray(reads[i % 3][:n]) for i, n in enumerate(lens)]


def _build(name: str, reads, scheme: str = "idl"):
    fids = np.arange(reads.shape[0])
    if name == "bloom":
        return PackedBloomIndex.build(_cfg(), scheme).insert_batch(reads[:2])
    if name == "cobs":
        return CobsIndex.build(
            [100, 200, 150], _cfg(), scheme=scheme, n_groups=2
        ).insert_batch(reads, fids)
    if name == "rambo":
        return RamboIndex.build(
            5, _cfg(1 << 14), scheme=scheme, B=2, R=2
        ).insert_batch(reads, fids)
    if name == "bitsliced":
        return BitSlicedIndex.build(
            _cfg(), scheme, n_files=40
        ).insert_batch(reads, np.asarray([0, 9, 39]))
    raise KeyError(name)


class TestServiceParity:
    """The acceptance matrix: padded-bucket service == direct engine msmt."""

    @pytest.mark.parametrize("backend", ["jnp", "idl_probe"])
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    @pytest.mark.parametrize("engine", ENGINES)
    def test_bit_identical_to_engine_msmt(self, reads, queries, engine,
                                          scheme, backend):
        eng = _build(engine, reads, scheme)
        svc = GeneSearchService(
            eng, ServiceConfig(backend=backend, max_batch=4))
        results = svc.search(queries)
        for q, res in zip(queries, results):
            want = np.asarray(eng.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)
            if engine != "bloom":
                assert res.file_ids == tuple(np.nonzero(want)[0])

    @pytest.mark.parametrize("theta", [1.0, 0.6, 0.25])
    @pytest.mark.parametrize("engine", ENGINES)
    def test_theta_thresholds_use_true_lengths(self, reads, queries, engine,
                                               theta):
        """Each padded row keeps the integer threshold of its TRUE kmer
        count — the padding proof for theta < 1."""
        eng = _build(engine, reads)
        svc = GeneSearchService(eng, ServiceConfig(theta=theta, max_batch=8))
        for q, res in zip(queries, svc.search(queries)):
            want = np.asarray(eng.msmt(jnp.asarray(q)[None], theta=theta))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)

    def test_sharded_backend_single_device(self, reads, queries):
        eng = _build("bitsliced", reads)
        svc = GeneSearchService(eng, ServiceConfig(backend="sharded"))
        for q, res in zip(queries, svc.search(queries)):
            want = np.asarray(eng.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)


class TestBatchingAndCompiles:
    def test_each_bucket_compiles_exactly_once(self, reads):
        eng = _build("bitsliced", reads)
        svc = GeneSearchService(eng, ServiceConfig(max_batch=4))
        # 12 distinct read lengths over 3 buckets: naive per-shape serving
        # would compile 12 times
        lens = [31, 40, 50, 62, 63, 70, 80, 94, 95, 100, 110, 120]
        svc.search([np.asarray(reads[i % 3][:n]) for i, n in enumerate(lens)])
        counts = svc.compile_counts()
        assert set(counts) == {32, 64, 128}      # pow2 kmer buckets
        assert all(c == 1 for c in counts.values())
        # new lengths landing in known buckets: still no recompile
        svc.search([np.asarray(reads[0][:45]), np.asarray(reads[1][:99])])
        assert all(c == 1 for c in svc.compile_counts().values())

    def test_bucket_assignment_and_floor(self, reads):
        svc = GeneSearchService(
            _build("bloom", reads),
            ServiceConfig(min_bucket_kmers=16))
        assert svc.bucket_for(1) == 16
        assert svc.bucket_for(17) == 32
        assert svc.bucket_for(64) == 64
        assert svc.bucket_for(65) == 128

    def test_auto_flush_at_max_batch(self, reads):
        svc = GeneSearchService(_build("bloom", reads),
                                ServiceConfig(max_batch=2))
        a = svc.submit(np.asarray(reads[0]))
        assert not svc.batch_stats                 # queued, not served
        b = svc.submit(np.asarray(reads[1]))
        assert len(svc.batch_stats) == 1           # full batch auto-flushed
        assert {a, b} == {r.request_id for r in
                          [svc.result(a), svc.result(b)]}

    def test_stats_account_for_padding(self, reads, queries):
        svc = GeneSearchService(_build("bitsliced", reads),
                                ServiceConfig(max_batch=4))
        results = svc.search(queries)
        assert svc.requests_served() == len(queries)
        assert 0.0 < svc.occupancy() <= 1.0
        assert len(svc.request_latencies_ms()) == len(queries)
        for s in svc.batch_stats:
            assert s.batch_rows == 4
            assert s.pad_rows == s.batch_rows - s.n_requests
            assert s.pad_kmers >= s.pad_rows * 0
            assert s.wall_ms > 0
        assert all(isinstance(r, SearchResult) for r in results)

    def test_rejects_read_shorter_than_k(self, reads):
        svc = GeneSearchService(_build("bloom", reads))
        with pytest.raises(ValueError, match="no 31-mers"):
            svc.submit(np.zeros(5, dtype=np.uint8))

    def test_rejects_2d_read_batch(self, reads):
        """A (B, L) batch must not silently fuse into one long read."""
        svc = GeneSearchService(_build("bloom", reads))
        with pytest.raises(ValueError, match="one 1-D read"):
            svc.submit(np.asarray(reads))

    def test_rejects_duplicate_inflight_request_id(self, reads):
        svc = GeneSearchService(_build("bloom", reads),
                                ServiceConfig(auto_flush=False))
        svc.submit(SearchRequest(read=np.asarray(reads[0]), request_id=7))
        with pytest.raises(ValueError, match="in flight"):
            svc.submit(SearchRequest(read=np.asarray(reads[1]), request_id=7))
        svc.flush()
        with pytest.raises(ValueError, match="in flight"):   # unclaimed
            svc.submit(SearchRequest(read=np.asarray(reads[1]), request_id=7))
        svc.result(7)
        assert svc.submit(
            SearchRequest(read=np.asarray(reads[1]), request_id=7)) == 7

    def test_stats_window_is_bounded(self, reads):
        svc = GeneSearchService(_build("bloom", reads),
                                ServiceConfig(max_batch=1, stats_window=3))
        for _ in range(5):
            svc.search([np.asarray(reads[0])])
        assert len(svc.batch_stats) == 3
        assert len(svc.request_latencies_ms()) == 3

    def test_explicit_request_ids_and_queue(self, reads):
        svc = GeneSearchService(_build("bloom", reads),
                                ServiceConfig(auto_flush=False, max_batch=2))
        rid = svc.submit(SearchRequest(read=np.asarray(reads[0]),
                                       request_id=777))
        assert rid == 777
        for r in reads:                            # > one batch queued
            svc.submit(np.asarray(r))
        svc.flush()
        res = svc.result(777)
        assert res.n_kmers == reads.shape[1] - 31 + 1
        with pytest.raises(KeyError):
            svc.result(777)                        # results pop once


class TestSnapshotStartup:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_from_snapshot_serves_identically(self, tmp_path, reads, queries,
                                              engine):
        eng = _build(engine, reads)
        snap = store.save(eng, str(tmp_path / "snap"))
        svc = GeneSearchService.from_snapshot(snap,
                                              ServiceConfig(max_batch=4))
        for q, res in zip(queries, svc.search(queries)):
            want = np.asarray(eng.msmt(jnp.asarray(q)[None]))[0]
            np.testing.assert_array_equal(np.asarray(res.matches), want)

    def test_bad_config_rejected(self, reads):
        with pytest.raises(ValueError, match="unknown serving backend"):
            ServiceConfig(backend="cuda")
        with pytest.raises(ValueError, match="max_batch"):
            ServiceConfig(max_batch=0)


class TestV1CompatLayer:
    """serving.genesearch's deprecated v1 bodies are gone: every removed
    entry point raises ImportError carrying its migration target at CALL
    time (the module itself must stay importable for the import smoke),
    while the surviving config/plan helpers still drive the v2 path."""

    def test_removed_entry_points_raise_with_migration_hint(self, reads):
        from repro.serving import genesearch as gs

        cfg = gs.GeneSearchConfig(n_files=32, m=1 << 16, L=1 << 10, eta=2,
                                  read_len=120)
        fids = jnp.asarray([0, 7, 31], dtype=jnp.int32)
        with pytest.raises(ImportError, match="BitSlicedIndex"):
            gs.empty_index(cfg)
        index = jnp.zeros((cfg.m, cfg.file_words), dtype=jnp.uint32)
        with pytest.raises(ImportError, match="insert_plan"):
            gs.insert_read_batch(index, cfg, reads, fids)
        with pytest.raises(ImportError, match="insert_batch"):
            gs.insert_read(index, cfg, 0, reads[0])
        with pytest.raises(ImportError, match="build_archive"):
            gs.build_archive(cfg, [])
        with pytest.raises(ImportError, match="GeneSearchService"):
            gs.serve_step(index, reads, cfg)
        with pytest.raises(ImportError, match="unpack_file_bits"):
            gs.match_file_ids(np.zeros(1, dtype=np.uint32))

    def test_surviving_plan_helpers_drive_v2(self, reads):
        from repro.index import query
        from repro.serving import genesearch as gs

        cfg = gs.GeneSearchConfig(n_files=32, m=1 << 16, L=1 << 10, eta=2,
                                  read_len=120)
        fids = jnp.asarray([0, 7, 31], dtype=jnp.int32)
        index = jnp.zeros((cfg.m, cfg.file_words), dtype=jnp.uint32)
        index = gs.insert_plan(cfg, reads.shape[0], index.shape).execute(
            index, reads, fids)
        eng = BitSlicedIndex.build(cfg.idl_config(), cfg.scheme,
                                   n_files=cfg.n_files)
        eng = eng.insert_batch(reads, np.asarray(fids))
        np.testing.assert_array_equal(np.asarray(index), np.asarray(eng.words))
        per_kmer = gs.query_plan(cfg, reads.shape[0], index.shape).execute(
            jnp.asarray(index), reads)
        got = query.file_match_mask(per_kmer, cfg.theta)
        svc = GeneSearchService(eng, ServiceConfig(max_batch=4))
        for i, res in enumerate(svc.search(list(np.asarray(reads)))):
            np.testing.assert_array_equal(
                np.asarray(res.matches),
                packed.unpack_file_bits(jnp.asarray(got[i]), cfg.n_files))

    def test_v2_service_does_not_warn(self, reads):
        import warnings

        eng = _build("bitsliced", reads)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            svc = GeneSearchService(eng, ServiceConfig(max_batch=2))
            svc.search([np.asarray(reads[0])])

"""Claims-ledger gate (ROADMAP item 5): docs/CLAIMS.md is machine-checked.

Every row's harness must exist in the repo, and every row that pins a
``FILE.json:dotted.key.path`` record must match the checked-in value —
re-running a benchmark without updating its ledger row fails here, so a
claim and its evidence cannot drift apart silently.
"""

import json
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
LEDGER = ROOT / "docs" / "CLAIMS.md"

STATUSES = {"validated", "validated-on-CPU", "model-number",
            "unreplicated"}


def _rows():
    rows = []
    for line in LEDGER.read_text().splitlines():
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cells) != 5 or cells[0] in ("Claim", ""):
            continue
        if set(cells[0]) <= {"-"}:           # the |---|---| separator
            continue
        rows.append(dict(zip(
            ("claim", "harness", "record", "latest", "status"), cells)))
    return rows


@pytest.fixture(scope="module")
def rows():
    parsed = _rows()
    assert parsed, "no ledger rows parsed from docs/CLAIMS.md"
    return parsed


def test_ledger_covers_the_headline_claims(rows):
    text = " ".join(r["claim"] for r in rows)
    for needle in ("9.4x", "159x", "8.4x", "8.1x", "3.1x", "eq. (5)",
                   "Theorem 2", "Minimizer", "union index",
                   "Insert-to-searchable"):
        assert needle in text, f"ledger lost the {needle!r} claim row"


def test_statuses_are_from_the_vocabulary(rows):
    for r in rows:
        assert r["status"] in STATUSES, r


def test_every_harness_exists(rows):
    for r in rows:
        for path in re.findall(r"`([^`]+)`", r["harness"]):
            assert (ROOT / path).is_file(), (
                f"ledger row {r['claim']!r} references missing harness "
                f"{path}")


def test_every_record_matches_its_bench_json(rows):
    checked = 0
    for r in rows:
        m = re.match(r"`([\w.]+\.json):([\w.]+)`", r["record"])
        if not m:
            assert r["record"] == "—", f"unparseable record: {r['record']}"
            continue
        fname, dotted = m.groups()
        fpath = ROOT / fname
        assert fpath.is_file(), f"missing bench record {fname}"
        node = json.loads(fpath.read_text())
        for key in dotted.split("."):
            assert key in node, f"{fname}: no key {dotted!r}"
            node = node[key]
        want = float(r["latest"].rstrip("x"))
        assert float(node) == pytest.approx(want, rel=1e-9), (
            f"ledger says {want} but {fname}:{dotted} holds {node} — "
            "re-ran a benchmark without updating docs/CLAIMS.md?")
        checked += 1
    assert checked >= 5, "the ledger lost its numeric record rows"


def test_validated_rows_cite_a_checkable_harness(rows):
    """A 'validated' status must point at a test or a --smoke-capable
    benchmark actually present in the tree (spot check: tests/ rows run
    under tier-1, benchmarks/ rows are importable modules)."""
    for r in rows:
        if not r["status"].startswith("validated"):
            continue
        paths = re.findall(r"`([^`]+)`", r["harness"])
        assert paths, f"validated row without a harness: {r['claim']!r}"
        assert any(p.startswith(("tests/", "benchmarks/")) for p in paths), r

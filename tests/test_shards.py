"""Sharded-archive acceptance: partition/join round-trips bit-exactly,
the parallel sharded build equals the serial archive builder, the
scatter-gather router's answers are bit-identical to one service over
the unsharded index — across every engine x scheme x theta, including
through real shard worker processes — and shard death keeps the exact
semantics: row-probe answers name their ``missing_files``, bit-probe
death fails loud (``ShardDeadError``), and zero futures are ever
dropped.

Proc-mode routers spawn real interpreters (each re-imports jax), so
those tests keep fleets small (2 shards) and only cover one engine per
partition axis — the in-process matrix already proves the merge math
for all four engines.
"""

import json
import os
import shutil
import time

import numpy as np
import pytest

from repro.core import idl
from repro.index import engines, ingest, shards, state as state_mod, store
from repro.serving import service as service_mod
from repro.serving.scatter import (
    ScatterConfig,
    ScatterGatherRouter,
    ShardDeadError,
    ShardSearchService,
)

ENGINES = ("bitsliced", "cobs", "bloom", "rambo")
SCHEMES = ("idl", "rh")
THETAS = (1.0, 0.6)
N_FILES = 70     # >= 3 bit-sliced word columns, so 2-3 file shards exist


def _cfg() -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=1 << 14)


@pytest.fixture(scope="module")
def files(rng):
    return [rng.integers(0, 4, size=(6, 120), dtype=np.uint8)
            for _ in range(N_FILES)]


@pytest.fixture(scope="module")
def reads(files):
    return np.stack([files[i][0] for i in range(6)])


@pytest.fixture(scope="module")
def queries(rng, files):
    qs = [rng.integers(0, 4, size=(int(n),), dtype=np.uint8)
          for n in rng.integers(40, 100, size=6)]
    qs[0] = files[3][0][:80].copy()     # true positives across the
    qs[1] = files[60][2][:60].copy()    # file-shard boundary
    return qs


def _fresh_index(engine: str, scheme: str, files):
    if engine == "bitsliced":
        return engines.BitSlicedIndex.build(_cfg(), scheme=scheme,
                                            n_files=N_FILES)
    if engine == "cobs":
        return engines.CobsIndex.build([f.size for f in files], _cfg(),
                                       scheme=scheme, n_groups=3)
    if engine == "rambo":
        return engines.RamboIndex.build(N_FILES, _cfg(), scheme=scheme)
    return engines.PackedBloomIndex.build(_cfg(), scheme=scheme)


def _items(engine: str, files):
    # the flat BF indexes ONE set: give it a single concatenated file
    if engine == "bloom":
        return [(0, np.concatenate([f.ravel() for f in files[:4]]))]
    return list(enumerate(files))


@pytest.fixture(scope="module")
def built(files, tmp_path_factory):
    """Memoized (engine, scheme) -> (spec, states, set_dir, full_state):
    one parallel sharded build + saved shard set per combo, shared by
    every test in the module."""
    cache = {}

    def get(engine: str, scheme: str, n_shards: int = 2):
        key = (engine, scheme, n_shards)
        if key not in cache:
            out = str(tmp_path_factory.mktemp(f"{engine}-{scheme}")
                      / "set")
            spec, states = ingest.build_sharded_archive(
                _fresh_index(engine, scheme, files),
                _items(engine, files), n_shards=n_shards, out_dir=out,
                read_len=120, chunk_reads=8)
            cache[key] = (spec, states, out,
                          shards.join_states(spec, states))
        return cache[key]

    return get


# ---------------------------------------------------------------------------
# The shard math: partition/join round-trip + exact merged queries.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("engine", ENGINES)
class TestShardMath:

    def test_partition_join_roundtrip(self, built, engine, scheme):
        spec, states, _, full = built(engine, scheme)
        spec2, parts = shards.partition_state(full, spec.n_shards)
        assert spec2 == spec
        for got, want in zip(parts, states):
            for gw, ww in zip(got.words, want.words):
                np.testing.assert_array_equal(np.asarray(gw),
                                              np.asarray(ww))
        joined = shards.join_states(spec, parts)
        for gw, ww in zip(joined.words, full.words):
            np.testing.assert_array_equal(np.asarray(gw), np.asarray(ww))

    def test_sharded_msmt_equals_oracle(self, built, reads, engine,
                                        scheme):
        spec, states, _, full = built(engine, scheme)
        oracle = state_mod.to_engine(full)
        for theta in THETAS:
            want = np.asarray(oracle.msmt(reads, theta=theta))
            got = np.asarray(shards.sharded_msmt(spec, states, reads,
                                                 theta=theta))
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"theta={theta}")

    def test_sharded_build_equals_serial_build(self, built, files,
                                               engine, scheme):
        spec, states, _, _ = built(engine, scheme)
        serial = ingest.build_archive(
            _fresh_index(engine, scheme, files), _items(engine, files),
            read_len=120, chunk_reads=8)
        _, serial_parts = shards.partition_state(serial, spec.n_shards)
        for got, want in zip(states, serial_parts):
            for gw, ww in zip(got.words, want.words):
                np.testing.assert_array_equal(np.asarray(gw),
                                              np.asarray(ww))


# ---------------------------------------------------------------------------
# The scatter-gather tier (in-process members): bit-identical answers.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("engine", ENGINES)
class TestScatterGatherParity:

    def test_router_equals_unsharded_service(self, built, queries,
                                             engine, scheme):
        _, _, set_dir, full = built(engine, scheme)
        for theta in THETAS:
            svc_cfg = service_mod.ServiceConfig(theta=theta, max_batch=4)
            oracle = service_mod.GeneSearchService(full, svc_cfg)
            want = oracle.search(queries)
            with ScatterGatherRouter(
                    set_dir, ScatterConfig(service=svc_cfg)) as router:
                got = router.search(queries)
            for w, g in zip(want, got):
                np.testing.assert_array_equal(
                    np.asarray(g.matches), np.asarray(w.matches),
                    err_msg=f"theta={theta}")
                assert g.file_ids == w.file_ids
                assert g.n_kmers == w.n_kmers and g.bucket == w.bucket
                assert g.missing_files == ()
                assert g.version == router.set_version


class TestScatterSurface:

    def test_stats_and_geometry_views(self, built):
        spec, _, set_dir, _ = built("bitsliced", "idl")
        with ScatterGatherRouter(set_dir) as router:
            assert router.n_shards == spec.n_shards
            assert router.spec == spec
            assert router.live_shards() == list(range(spec.n_shards))
            stats = router.stats()
            assert set(stats) == set(range(spec.n_shards))

    def test_router_rejects_malformed_reads(self, built):
        _, _, set_dir, _ = built("bitsliced", "idl")
        with ScatterGatherRouter(set_dir) as router:
            with pytest.raises(ValueError, match="one 1-D read"):
                router.submit(np.zeros((2, 120), dtype=np.uint8))
            with pytest.raises(ValueError, match="has no 31-mers"):
                router.submit(np.zeros((7,), dtype=np.uint8))

    def test_bit_probe_shard_service_refuses_kmer_cache(self, built):
        from repro.serving.kmer_cache import KmerCacheConfig
        spec, states, _, _ = built("rambo", "idl")
        cfg = service_mod.ServiceConfig(
            kmer_cache=KmerCacheConfig(capacity=1 << 10))
        with pytest.raises(ValueError, match="partial miss counts"):
            ShardSearchService(spec, 0, states[0], cfg)

    def test_inprocess_kill_row_probe_names_missing_files(self, built,
                                                          queries):
        spec, _, set_dir, _ = built("bitsliced", "idl")
        with ScatterGatherRouter(set_dir) as router:
            router.search(queries[:1])
            router.kill_shard(1)
            lost = shards.shard_files(spec, 1)
            res = router.search(queries)
            for r in res:
                assert r.missing_files == lost
                assert not np.asarray(r.matches)[list(lost)].any()
            assert router.live_shards() == [0]


# ---------------------------------------------------------------------------
# Persistence: the CRC-checked shard-set manifest fails by name.
# ---------------------------------------------------------------------------

class TestShardSetPersistence:

    def test_load_round_trip(self, built, tmp_path):
        spec, states, _, _ = built("rambo", "rh")
        out = str(tmp_path / "set")
        shards.save_shard_set(spec, states, out, version=7)
        sm, loaded = shards.load_shard_set(out)
        assert sm.spec == spec and sm.set_version == 7
        for got, want in zip(loaded, states):
            for gw, ww in zip(got.words, want.words):
                np.testing.assert_array_equal(np.asarray(gw),
                                              np.asarray(ww))

    @pytest.fixture()
    def set_copy(self, built, tmp_path):
        _, _, set_dir, _ = built("bitsliced", "idl")
        dst = str(tmp_path / "set")
        shutil.copytree(set_dir, dst)
        return dst

    def test_missing_shard_dir_fails_by_name(self, set_copy):
        shutil.rmtree(os.path.join(set_copy, "shard_01"))
        with pytest.raises(shards.ShardSetError,
                           match="'shard_01' is missing"):
            shards.load_shard_set(set_copy)

    def test_rewritten_shard_manifest_fails_by_name(self, set_copy):
        manifest = os.path.join(set_copy, "shard_00", "manifest.json")
        with open(manifest) as f:
            doc = json.load(f)
        with open(manifest, "w") as f:
            json.dump(doc, f, indent=3)     # same content, foreign bytes
        with pytest.raises(shards.ShardSetError,
                           match="foreign or rewritten"):
            shards.load_shard(set_copy, 0)

    def test_corrupt_set_manifest_fails_closed(self, set_copy):
        path = os.path.join(set_copy, shards.SET_MANIFEST)
        with open(path) as f:
            doc = json.load(f)
        doc["body"]["n_shards"] = 3         # body edit without new CRC
        with open(path, "w") as f:
            json.dump(doc, f)
        with pytest.raises(shards.ShardSetError,
                           match="truncated or rewritten"):
            shards.read_set_meta(set_copy)

    def test_store_load_points_at_shard_set_loader(self, set_copy):
        with pytest.raises(store.SnapshotError,
                           match="SHARD-SET snapshot"):
            store.load(set_copy)

    def test_store_read_meta_answers_with_full_meta(self, built):
        spec, _, set_dir, _ = built("bitsliced", "idl")
        assert store.read_meta(set_dir) == spec.meta

    def test_plan_rejects_infeasible_shard_counts(self, built):
        spec, _, _, _ = built("bitsliced", "idl")
        with pytest.raises(shards.ShardSetError, match="want 1 <="):
            shards.plan_shards(spec.meta, 1000)
        with pytest.raises(shards.ShardSetError, match="want 1 <="):
            shards.plan_shards(spec.meta, 0)


# ---------------------------------------------------------------------------
# Proc-mode: real shard worker processes, one test per partition axis.
# ---------------------------------------------------------------------------

def _proc_router(set_dir, theta=1.0):
    return ScatterGatherRouter(set_dir, ScatterConfig(
        procs=True,
        service=service_mod.ServiceConfig(theta=theta, max_batch=4)))


class TestProcShards:

    def test_row_probe_procs_parity_then_kill(self, built, queries):
        """2 bit-sliced shard processes: answers == unsharded oracle;
        kill -9 one shard mid-stream and every future still resolves,
        late answers naming the dead shard's files as missing."""
        spec, _, set_dir, full = built("bitsliced", "idl")
        oracle = service_mod.GeneSearchService(
            full, service_mod.ServiceConfig(max_batch=4))
        want = oracle.search(queries)
        with _proc_router(set_dir) as router:
            got = router.search(queries)
            for w, g in zip(want, got):
                np.testing.assert_array_equal(np.asarray(g.matches),
                                              np.asarray(w.matches))
                assert g.file_ids == w.file_ids
                assert g.missing_files == ()
            stream = [queries[i % len(queries)] for i in range(18)]
            futures = [router.submit(q) for q in stream]
            router.kill_shard(1)
            results = [f.result(timeout=120) for f in futures]
            lost = shards.shard_files(spec, 1)
            kept = sorted(set(range(N_FILES)) - set(lost))
            for w, r in zip((want[i % len(want)] for i in range(18)),
                            results):
                wm, gm = np.asarray(w.matches), np.asarray(r.matches)
                if r.missing_files:     # answered after the kill landed
                    assert r.missing_files == lost
                    assert not gm[list(lost)].any()
                np.testing.assert_array_equal(gm[kept], wm[kept])
            # the surviving shard keeps serving honest partial answers
            deadline = time.monotonic() + 30
            while len(router.live_shards()) > 1 \
                    and time.monotonic() < deadline:
                time.sleep(0.05)
            assert router.live_shards() == [0]
            late = router.search(queries)
            for w, r in zip(want, late):
                assert r.missing_files == lost
                np.testing.assert_array_equal(
                    np.asarray(r.matches)[kept],
                    np.asarray(w.matches)[kept])

    def test_bit_probe_procs_parity_then_kill_fails_loud(self, built,
                                                         queries):
        """2 rambo shard processes: answers == unsharded oracle at
        theta=0.6; kill -9 one shard and affected futures raise
        ShardDeadError — never a silently-inflated answer, never a
        dropped future."""
        _, _, set_dir, full = built("rambo", "idl")
        oracle = service_mod.GeneSearchService(
            full, service_mod.ServiceConfig(theta=0.6, max_batch=4))
        want = oracle.search(queries)
        with _proc_router(set_dir, theta=0.6) as router:
            got = router.search(queries)
            for w, g in zip(want, got):
                np.testing.assert_array_equal(np.asarray(g.matches),
                                              np.asarray(w.matches))
                assert g.file_ids == w.file_ids
            stream = [queries[i % len(queries)] for i in range(18)]
            futures = [router.submit(q) for q in stream]
            router.kill_shard(0)
            outcomes = {"ok": 0, "dead": 0}
            for i, f in enumerate(futures):
                try:
                    r = f.result(timeout=120)
                    np.testing.assert_array_equal(
                        np.asarray(r.matches),
                        np.asarray(want[i % len(want)].matches))
                    outcomes["ok"] += 1
                except ShardDeadError:
                    outcomes["dead"] += 1
            assert sum(outcomes.values()) == len(futures)   # zero dropped
            with pytest.raises(ShardDeadError, match="failing loud"):
                router.submit(queries[0]).result(timeout=120)

"""IDL × Blocked-BF composition (paper §3.3): both localities at once."""

import jax.numpy as jnp
import numpy as np

from repro.core import bloom, idl
from repro.data import genome

CFG = idl.IDLConfig(k=31, t=16, L=1 << 14, eta=4, m=1 << 24)


def test_all_probes_in_one_block():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 4, size=2000, dtype=np.uint8))
    locs = np.asarray(idl.idl_bbf_locations_rolling(CFG, codes, block_bits=512))
    blocks = locs // 512
    # BBF property: the η probes of each kmer share one 512-bit block
    assert (blocks == blocks[0:1]).all()


def test_window_locality_preserved():
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(0, 4, size=3000, dtype=np.uint8))
    locs = np.asarray(idl.idl_bbf_locations_rolling(CFG, codes))[0]
    windows = locs // CFG.L
    # IDL property: consecutive kmers share the window w.p. ~J
    assert float(np.mean(windows[1:] == windows[:-1])) > 0.7


def test_no_false_negatives_and_fpr_tradeoff():
    g = genome.synthesize_genome(20_000, seed=2, repeat_fraction=0.0)
    gj = jnp.asarray(g)
    rng = np.random.default_rng(3)
    neg = jnp.asarray(rng.integers(0, 4, size=60_000, dtype=np.uint8))

    bits = bloom.insert_locations(
        bloom.empty_filter(CFG.m), idl.idl_bbf_locations_rolling(CFG, gj))
    hits = bloom.query_locations(bits, idl.idl_bbf_locations_rolling(CFG, gj))
    assert bool(jnp.all(hits))  # no false negatives

    fpr_bbf = float(jnp.mean(bloom.query_locations(
        bits, idl.idl_bbf_locations_rolling(CFG, neg))))
    bits_idl = bloom.insert_locations(
        bloom.empty_filter(CFG.m), idl.idl_locations_rolling(CFG, gj))
    fpr_idl = float(jnp.mean(bloom.query_locations(
        bits_idl, idl.idl_locations_rolling(CFG, neg))))
    # BBF trades FPR for locality (paper §3.3) — bounded degradation
    assert fpr_bbf <= max(20 * fpr_idl, 5e-3)


def test_line_level_misses_beat_plain_idl():
    """The composition's raison d'être: ONE 64-B line per kmer (BBF) inside
    a shared window (IDL) ⇒ line-miss rate far below plain IDL's."""
    from repro.core import cache_model
    rng = np.random.default_rng(4)
    codes = jnp.asarray(rng.integers(0, 4, size=10_000, dtype=np.uint8))
    tr_bbf = cache_model.probe_trace_from_locations(
        np.asarray(idl.idl_bbf_locations_rolling(CFG, codes)))
    tr_idl = cache_model.probe_trace_from_locations(
        np.asarray(idl.idl_locations_rolling(CFG, codes)))
    m_bbf, _ = cache_model.two_level_miss_rates(tr_bbf, l1_bytes=2 << 20,
                                                line_bytes=64)
    m_idl, _ = cache_model.two_level_miss_rates(tr_idl, l1_bytes=2 << 20,
                                                line_bytes=64)
    assert m_bbf < 0.5 * m_idl

"""Substrate tests: optimizers, train loop, checkpointing, fault tolerance,
gradient compression, data pipelines, roofline HLO cost analysis."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.data import genome, graph_pipeline, lm_pipeline, recsys_pipeline
from repro.distributed import collectives, fault_tolerance as ft
from repro.models import transformer as tf
from repro.roofline import analysis, hlo_cost
from repro.train import checkpoint as ckpt_mod, loop, optimizer as opt_mod, \
    train_state as ts


class TestOptimizers:
    def _numpy_adamw(self, g, p, mu, nu, step, lr=1e-3, b1=0.9, b2=0.95,
                     eps=1e-8, wd=0.1):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mh = mu / (1 - b1 ** step)
        nh = nu / (1 - b2 ** step)
        return p + (-lr * (mh / (np.sqrt(nh) + eps) + wd * p)), mu, nu

    def test_adamw_matches_numpy(self, rng):
        p0 = rng.normal(size=(4, 3)).astype(np.float32)
        params = {"w": jnp.asarray(p0)}
        opt = opt_mod.adamw(lr=1e-3)
        state = opt.init(params)
        p_np, mu, nu = p0.copy(), np.zeros_like(p0), np.zeros_like(p0)
        for step in range(1, 4):
            g = rng.normal(size=p0.shape).astype(np.float32)
            upd, state = opt.update({"w": jnp.asarray(g)}, state, params)
            params = opt_mod.apply_updates(params, upd)
            p_np, mu, nu = self._numpy_adamw(g, p_np, mu, nu, step)
            np.testing.assert_allclose(np.asarray(params["w"]), p_np,
                                       rtol=1e-5, atol=1e-6)

    def test_adafactor_descends(self, rng):
        w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
        target = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
        params = {"w": w}
        opt = opt_mod.adafactor(lr=0.05)
        state = opt.init(params)
        loss = lambda p: jnp.mean((p["w"] - target) ** 2)
        l0 = float(loss(params))
        for _ in range(30):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params)
            params = opt_mod.apply_updates(params, upd)
        assert float(loss(params)) < 0.3 * l0

    def test_adafactor_state_is_factored(self):
        params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
        st_ = opt_mod.adafactor().init(params)
        assert st_["per_param"]["w"]["vr"].shape == (64,)
        assert st_["per_param"]["w"]["vc"].shape == (32,)
        assert st_["per_param"]["b"]["v"].shape == (64,)

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.ones((10,)) * 3.0}
        clipped, norm = opt_mod.clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(3.0 * np.sqrt(10), rel=1e-5)
        assert float(opt_mod.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path, rng):
        tree = {"a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
                "nested": {"b": jnp.arange(5)}}
        mgr = ckpt_mod.CheckpointManager(str(tmp_path))
        mgr.save(7, tree, extra={"pipeline": {"cursor": 3}}, blocking=True)
        like = jax.tree.map(jnp.zeros_like, tree)
        restored, manifest = mgr.restore(like)
        assert manifest["step"] == 7
        assert manifest["extra"]["pipeline"]["cursor"] == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                      np.asarray(tree["nested"]["b"]))

    def test_async_save_and_gc(self, tmp_path):
        tree = {"w": jnp.ones((4,))}
        mgr = ckpt_mod.CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        mgr.wait()
        mgr._gc()
        assert mgr.all_steps() == [3, 4]

    def test_restore_rejects_shape_mismatch(self, tmp_path):
        mgr = ckpt_mod.CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.ones((4,))}, blocking=True)
        with pytest.raises(ValueError):
            mgr.restore({"w": jnp.ones((5,))})

    def test_mesh_agnostic_restore(self, tmp_path):
        """Leaves are saved global — restore works with any sharding_fn
        (elastic scaling contract)."""
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        mgr = ckpt_mod.CheckpointManager(str(tmp_path))
        mgr.save(1, tree, blocking=True)
        dev = jax.devices()[0]
        restored, _ = mgr.restore(
            jax.tree.map(jnp.zeros_like, tree),
            sharding_fn=lambda path: jax.sharding.SingleDeviceSharding(dev))
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


class TestTrainLoop:
    def _mk(self, tmp_path, total, pipe):
        cfg = tf.LMConfig(name="t", n_layers=1, d_model=16, n_heads=2,
                          n_kv_heads=1, d_ff=32, vocab=64, remat=False)
        params = tf.lm_init(jax.random.PRNGKey(0), cfg)
        lcfg = loop.LoopConfig(total_steps=total, ckpt_every=2,
                               ckpt_dir=str(tmp_path), log_every=1)
        return loop.run(
            lambda p, b: tf.lm_loss(p, b, cfg, loss_chunks=4),
            params, opt_mod.adamw(1e-3), pipe.next_batch, lcfg,
            pipeline_state=pipe.state_dict,
            restore_pipeline=pipe.load_state_dict)

    def test_loss_decreases_and_resumes(self, tmp_path):
        pcfg = lm_pipeline.LMPipelineConfig(vocab=64, seq_len=16,
                                            global_batch=4, doc_len=64,
                                            dedup=False)
        pipe = lm_pipeline.LMPipeline(pcfg)
        res = self._mk(tmp_path, 4, pipe)
        assert int(res.state.step) == 4
        res2 = self._mk(tmp_path, 8, pipe)
        assert res2.resumed_from == 4
        assert int(res2.state.step) == 8
        assert np.isfinite(res2.history[-1]["loss"])


class TestFaultTolerance:
    def test_straggler_detection(self):
        hb = ft.Heartbeat(straggler_factor=2.0, window=16)
        import time
        for i in range(10):
            hb.start_step(i)
            hb.end_step()
        hb.start_step(99)
        time.sleep(0.05)
        ev = hb.end_step()
        assert ev is not None and ev.step == 99

    def test_elastic_plan(self):
        plan = ft.plan_elastic_mesh(512, 16)
        assert (plan.data, plan.model, plan.dropped) == (32, 16, 0)
        plan = ft.plan_elastic_mesh(500, 16)
        assert (plan.data, plan.dropped) == (31, 4)
        with pytest.raises(RuntimeError):
            ft.plan_elastic_mesh(8, 16)

    @given(st.integers(1, 64), st.integers(0, 31), st.integers(2, 32))
    @settings(max_examples=30, deadline=None)
    def test_reassign_covers_all_shards(self, n_shards, failed_id, n_workers):
        failed = {failed_id} if failed_id < n_workers else set()
        if len(failed) >= n_workers:
            return
        out = ft.reassign_shards(n_shards, failed, n_workers)
        got = sorted(s for shards in out.values() for s in shards)
        assert got == list(range(n_shards))
        assert not (set(out) & failed)

    def test_preemption_guard_flag(self):
        g = ft.PreemptionGuard(install=False)
        assert not g.requested
        g._handler(None, None)
        assert g.requested


class TestCollectives:
    def test_int8_roundtrip_error_bounded(self, rng):
        x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
        q, s = collectives.quantize_int8(x)
        err = jnp.abs(collectives.dequantize_int8(q, s) - x)
        assert float(err.max()) <= float(s) * 0.51

    def test_error_feedback_accumulates(self, rng):
        g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
        ef = collectives.init_error_feedback(g)
        comp, ef = collectives.compress_with_feedback(g, ef)
        # residual = g - Q(g); next step's compression sees g + residual
        resid = np.asarray(ef.residual["w"])
        np.testing.assert_allclose(
            np.asarray(comp["w"]) + resid, np.asarray(g["w"]), rtol=1e-5,
            atol=1e-6)


class TestPipelines:
    def test_lm_dedup_drops_planted_duplicates(self):
        cfg = lm_pipeline.LMPipelineConfig(
            vocab=512, seq_len=32, global_batch=2, doc_len=128, dedup=True)
        pipe = lm_pipeline.LMPipeline(cfg)
        for _ in range(12):
            pipe.next_batch()
        assert pipe.dropped > 0  # every 7th doc is a planted duplicate

    def test_lm_dedup_idl_locality_beats_rh(self):
        """Technique integration point: the dedup BF's probe trace must be
        more page-local under IDL than RH."""
        from repro.core import cache_model
        traces = {}
        for scheme in ("idl", "rh"):
            cfg = lm_pipeline.LMPipelineConfig(
                vocab=512, seq_len=32, global_batch=2, doc_len=256,
                dedup=True, dedup_scheme=scheme)
            pipe = lm_pipeline.LMPipeline(cfg)
            for _ in range(6):
                pipe.next_batch()
            trace = np.concatenate(pipe.bf.byte_trace) * 8
            traces[scheme] = cache_model.two_level_miss_rates(
                trace, l1_bytes=64 * 1024, line_bytes=4096)[0]
        assert traces["rh"] > 2 * traces["idl"]

    def test_fanout_sampler_respects_fanout(self):
        g = graph_pipeline.synth_graph(500, 4000, seed=11)
        loader = graph_pipeline.FanoutLoader(g, 8, [5, 3], 256, 512)
        b = loader.next_batch()
        assert b["src"].shape == (512,)
        n_real = int(b["edge_mask"].sum())
        assert 0 < n_real <= 8 * 5 + 8 * 5 * 3

    def test_sessions_have_locality(self):
        gen = recsys_pipeline.SessionGenerator(
            recsys_pipeline.RecsysSynthConfig(n_items=1 << 16, locality=0.9))
        s = gen.sessions(64).astype(np.int64)
        jumps = np.abs(np.diff(s, axis=1))
        jumps = np.minimum(jumps, (1 << 16) - jumps)
        assert float(np.mean(jumps <= 256)) > 0.7

    def test_genome_poisoning_changes_one_base(self, rng):
        reads = genome.extract_reads(genome.synthesize_genome(2000, 1), 100, 8)
        poisoned = genome.poison_queries(reads, seed=3)
        assert ((poisoned != reads).sum(axis=1) == 1).all()

    def test_fasta_roundtrip(self, tmp_path):
        g = genome.synthesize_genome(500, seed=2)
        path = os.path.join(tmp_path, "x.fa")
        genome.write_fasta(path, {"chr1": g})
        back = genome.read_fasta(path)
        np.testing.assert_array_equal(back["chr1"], g)


class TestHloCost:
    def test_matmul_flops_exact(self):
        a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
        c = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
        cost = hlo_cost.analyze(c.as_text())
        assert cost.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)

    def test_scan_trip_count_multiplied(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def f(x):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=7)[0]
        c = jax.jit(f).lower(a).compile()
        cost = hlo_cost.analyze(c.as_text())
        assert cost.flops == pytest.approx(7 * 2 * 64 ** 3, rel=0.05)

    def test_collective_parse(self):
        txt = """
ENTRY %main (a: f32[128,64]) -> f32[128,64] {
  %a = f32[128,64]{1,0} parameter(0)
  ROOT %ag = f32[128,64]{1,0} all-gather(%a), replica_groups={}
}
"""
        cost = hlo_cost.analyze(txt)
        assert cost.coll_bytes["all-gather"] == 128 * 64 * 4

    def test_roofline_terms(self):
        r = analysis.Roofline(
            arch="x", shape="y", mesh="single", chips=256,
            flops_per_chip=197e12, bytes_per_chip=819e9,
            coll_bytes_per_chip=50e9, coll_breakdown={})
        assert r.t_compute == pytest.approx(1.0)
        assert r.t_memory == pytest.approx(1.0)
        assert r.t_collective == pytest.approx(1.0)
        assert r.t_bound == pytest.approx(1.0)

"""Functional IndexState (protocol v2): pytree behavior, engine<->state
round trips, pure insert/query/msmt parity, and the donation-footgun
regression — a consumed (donated-away) engine or state raises a clear
``StaleIndexError`` instead of a backend-dependent deleted-buffer crash,
and ``donate=False`` keeps the input alive and bit-identical."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idl
from repro.index import (
    BitSlicedIndex,
    CobsIndex,
    GeneIndex,
    IndexState,
    PackedBloomIndex,
    RamboIndex,
    StaleIndexError,
)
from repro.index import state as state_mod

ENGINES = ["bloom", "cobs", "rambo", "bitsliced"]


def _cfg(m: int = 1 << 16) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)


@pytest.fixture(scope="module")
def reads(rng):
    return jnp.asarray(rng.integers(0, 4, size=(3, 120), dtype=np.uint8))


def _build(name: str, reads, scheme: str = "idl"):
    fids = np.arange(reads.shape[0])
    if name == "bloom":
        return PackedBloomIndex.build(_cfg(), scheme).insert_batch(reads[:2])
    if name == "cobs":
        return CobsIndex.build(
            [100, 200, 150], _cfg(), scheme=scheme, n_groups=2
        ).insert_batch(reads, fids)
    if name == "rambo":
        return RamboIndex.build(
            5, _cfg(1 << 14), scheme=scheme, B=2, R=2
        ).insert_batch(reads, fids)
    if name == "bitsliced":
        return BitSlicedIndex.build(
            _cfg(), scheme, n_files=40
        ).insert_batch(reads, np.asarray([0, 9, 39]))
    raise KeyError(name)


def _file_ids(name: str, batch: int):
    return None if name == "bloom" else np.arange(batch)


class TestPytree:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_state_is_a_pytree_of_word_leaves(self, reads, engine):
        st = _build(engine, reads).state
        leaves, treedef = jax.tree_util.tree_flatten(st)
        assert all(leaf.dtype == jnp.uint32 for leaf in leaves)
        assert len(leaves) == len(st.meta.cfgs)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        assert rebuilt.meta == st.meta
        for a, b in zip(rebuilt.words, st.words):
            assert a is b

    @pytest.mark.parametrize("engine", ENGINES)
    def test_state_passes_through_jit(self, reads, engine):
        st = _build(engine, reads).state
        out = jax.jit(lambda s: s)(st)
        assert isinstance(out, IndexState)
        assert out.meta == st.meta
        for a, b in zip(out.words, st.words):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_tree_map_touches_only_words(self, reads):
        st = _build("cobs", reads).state
        doubled = jax.tree_util.tree_map(lambda w: w | jnp.uint32(1), st)
        assert doubled.meta == st.meta
        assert all(
            bool((w & 1).all()) for w in doubled.words)


class TestEngineStateRoundTrip:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_to_engine_is_loss_free(self, reads, engine):
        eng = _build(engine, reads)
        view = state_mod.to_engine(eng.state)
        assert type(view) is type(eng)
        np.testing.assert_array_equal(
            np.asarray(eng.msmt(reads)), np.asarray(view.msmt(reads)))

    @pytest.mark.parametrize("engine", ENGINES)
    def test_with_state_rebuilds_view(self, reads, engine):
        eng = _build(engine, reads)
        st = eng.state
        view = eng.with_state(st)
        np.testing.assert_array_equal(
            np.asarray(eng.query_batch(reads)),
            np.asarray(view.query_batch(reads)))

    def test_with_state_rejects_kind_mismatch(self, reads):
        bloom = _build("bloom", reads)
        bs = _build("bitsliced", reads)
        with pytest.raises(ValueError, match="with_state"):
            bloom.with_state(bs.state)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_protocol_v2(self, reads, engine):
        eng = _build(engine, reads)
        assert isinstance(eng, GeneIndex)
        assert isinstance(eng.state, IndexState)
        assert callable(eng.with_state)


class TestFunctionalAPI:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_insert_query_msmt_match_engine_methods(self, reads, engine):
        eng = _build(engine, reads)                    # method path
        # functional path: same inserts through state.insert
        if engine == "bloom":
            base = PackedBloomIndex.build(_cfg(), "idl")
            st = state_mod.insert(base.state, reads[:2])
        elif engine == "cobs":
            base = CobsIndex.build([100, 200, 150], _cfg(), n_groups=2)
            st = state_mod.insert(base.state, reads, np.arange(3))
        elif engine == "rambo":
            base = RamboIndex.build(5, _cfg(1 << 14), B=2, R=2)
            st = state_mod.insert(base.state, reads, np.arange(3))
        else:
            base = BitSlicedIndex.build(_cfg(), "idl", n_files=40)
            st = state_mod.insert(base.state, reads, np.asarray([0, 9, 39]))
        np.testing.assert_array_equal(
            np.asarray(state_mod.query(st, reads)),
            np.asarray(eng.query_batch(reads)))
        np.testing.assert_array_equal(
            np.asarray(state_mod.msmt(st, reads, theta=0.6)),
            np.asarray(eng.msmt(reads, theta=0.6)))

    def test_insert_backend_passthrough(self, reads):
        base = PackedBloomIndex.build(_cfg(), "idl")
        st_jnp = state_mod.insert(
            PackedBloomIndex.build(_cfg(), "idl").state, reads)
        st_planned = state_mod.insert(base.state, reads,
                                      backend="idl_insert")
        np.testing.assert_array_equal(
            np.asarray(st_jnp.words[0]), np.asarray(st_planned.words[0]))


class TestDonationFootgun:
    """PR-3's 'never reuse a pre-insert engine' rule, now enforced."""

    @pytest.mark.parametrize("engine", ENGINES)
    def test_reusing_consumed_engine_raises_clear_error(self, reads, engine):
        eng = _build(engine, reads)        # fresh, live
        _ = eng.insert_batch(reads[:1], _file_ids(engine, 1))
        with pytest.raises(StaleIndexError, match="donated"):
            eng.query_batch(reads)
        with pytest.raises(StaleIndexError, match="returned"):
            eng.insert_batch(reads[:1], _file_ids(engine, 1))
        with pytest.raises(StaleIndexError):
            eng.msmt(reads)
        with pytest.raises(StaleIndexError):
            _ = eng.state                  # can't snapshot a consumed view

    def test_consumed_state_raises_on_every_entry_point(self, reads):
        st = PackedBloomIndex.build(_cfg(), "idl").state
        st2 = state_mod.insert(st, reads)
        with pytest.raises(StaleIndexError):
            state_mod.query(st, reads)
        with pytest.raises(StaleIndexError):
            state_mod.insert(st, reads)
        # the returned state is live
        assert state_mod.query(st2, reads).shape[0] == reads.shape[0]

    @pytest.mark.parametrize("engine", ENGINES)
    def test_donate_false_keeps_input_alive_and_bit_identical(
            self, reads, engine):
        a = _build(engine, reads)
        b = _build(engine, reads)
        out_donated = a.insert_batch(reads[:1], _file_ids(engine, 1))
        out_kept = b.insert_batch(reads[:1], _file_ids(engine, 1),
                                  donate=False)
        # b is still usable, and both results are bit-identical
        np.testing.assert_array_equal(
            np.asarray(b.msmt(reads)),
            np.asarray(_build(engine, reads).msmt(reads)))
        np.testing.assert_array_equal(
            np.asarray(out_kept.msmt(reads)),
            np.asarray(out_donated.msmt(reads)))

    def test_functional_insert_donate_false(self, reads):
        st = PackedBloomIndex.build(_cfg(), "idl").state
        st2 = state_mod.insert(st, reads, donate=False)
        st3 = state_mod.insert(st, reads)      # st still live the 1st time
        np.testing.assert_array_equal(
            np.asarray(st2.words[0]), np.asarray(st3.words[0]))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)


def random_codes(rng, n: int):
    import jax.numpy as jnp
    return jnp.asarray(rng.integers(0, 4, size=n, dtype=np.uint8))

"""COBS / RAMBO / gene-search service end-to-end behaviour (MT + MSMT)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cobs, idl, rambo
from repro.data import genome
from repro.serving import genesearch as gs

CFG = idl.IDLConfig(k=31, t=16, L=1 << 10, eta=3, m=1 << 20)


@pytest.fixture(scope="module")
def archive():
    return genome.synth_archive(n_files=12, genome_len=3000, seed=7)


class TestCobs:
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    def test_msmt_exact_recall(self, archive, scheme):
        sizes = [f.n_kmers for f in archive]
        c = cobs.Cobs.build(sizes, CFG, scheme=scheme, n_groups=3)
        for f in archive:
            c = c.insert_sequence(f.file_id, jnp.asarray(f.genome))
        for fid in (0, 5, 11):
            read = archive[fid].reads(230, 1)[0]
            got = np.asarray(c.msmt(jnp.asarray(read)))
            assert got[fid], f"file {fid} must match its own read ({scheme})"
            assert got.sum() <= 2  # near-exact retrieval

    def test_poisoned_queries_rejected(self, archive):
        sizes = [f.n_kmers for f in archive]
        c = cobs.Cobs.build(sizes, CFG, scheme="idl", n_groups=2)
        for f in archive:
            c = c.insert_sequence(f.file_id, jnp.asarray(f.genome))
        reads = archive[3].reads(230, 8)
        poisoned = genome.poison_queries(reads, seed=9)
        fp = sum(int(np.asarray(c.msmt(jnp.asarray(q))).sum()) for q in poisoned)
        assert fp <= 2

    def test_theta_relaxes_match(self, archive):
        sizes = [f.n_kmers for f in archive]
        c = cobs.Cobs.build(sizes, CFG, scheme="idl", n_groups=2)
        for f in archive:
            c = c.insert_sequence(f.file_id, jnp.asarray(f.genome))
        read = archive[2].reads(230, 1)[0]
        poisoned = genome.poison_queries(read[None], seed=11)[0]
        strict = np.asarray(c.msmt(jnp.asarray(poisoned), theta=1.0))
        relaxed = np.asarray(c.msmt(jnp.asarray(poisoned), theta=0.5))
        assert not strict[2]
        assert relaxed[2]  # 1 flip kills <= k kmers of ~200


class TestRambo:
    @pytest.mark.parametrize("scheme", ["idl", "rh"])
    def test_candidate_set_contains_truth(self, archive, scheme):
        r = rambo.Rambo.build(len(archive), CFG, scheme=scheme)
        for f in archive:
            r = r.insert_sequence(f.file_id, jnp.asarray(f.genome))
        for fid in (1, 6, 10):
            read = archive[fid].reads(230, 1)[0]
            got = np.asarray(r.msmt(jnp.asarray(read)))
            assert got[fid]

    def test_bucket_layout(self, archive):
        r = rambo.Rambo.build(100, CFG)
        assert r.B >= int(np.sqrt(100))
        assert r.R >= 2
        assert r.filters.shape == (r.R * r.B, CFG.m)


class TestGeneSearchService:
    """Serve-geometry behaviour through the v2 engine (the removed v1
    serve_step path's semantics, now via BitSlicedIndex.msmt)."""

    @staticmethod
    def _engine(cfg):
        from repro.index import BitSlicedIndex

        return BitSlicedIndex.build(cfg.idl_config(), cfg.scheme,
                                    n_files=cfg.n_files)

    def test_serve_recall_and_fp(self):
        cfg = gs.GeneSearchConfig(n_files=64, m=1 << 18, L=1 << 10,
                                  read_len=100, eta=2)
        rng = np.random.default_rng(1)
        reads = np.stack([rng.integers(0, 4, 100, dtype=np.uint8)
                          for _ in range(6)])
        fids = np.arange(6, dtype=np.int32) * 9
        eng = self._engine(cfg).insert_batch(jnp.asarray(reads), fids)
        out = np.asarray(eng.msmt(jnp.asarray(reads), theta=cfg.theta))
        for i in range(len(reads)):
            ids = np.nonzero(out[i])[0]
            assert i * 9 in ids
            assert len(ids) <= 2

    def test_rh_variant_matches_semantics(self):
        cfg = gs.GeneSearchConfig(n_files=32, m=1 << 18, L=1 << 10,
                                  read_len=100, eta=2, scheme="rh")
        rng = np.random.default_rng(2)
        read = jnp.asarray(rng.integers(0, 4, 100, dtype=np.uint8))
        eng = self._engine(cfg).insert_batch(
            read[None], np.asarray([17], dtype=np.int32))
        out = np.asarray(eng.msmt(read[None], theta=cfg.theta))
        assert out[0, 17]

    def test_theta_below_one_popcount_path(self):
        cfg = gs.GeneSearchConfig(n_files=32, m=1 << 18, L=1 << 10,
                                  read_len=100, eta=2, theta=0.5)
        rng = np.random.default_rng(3)
        read = rng.integers(0, 4, 100, dtype=np.uint8)
        eng = self._engine(cfg).insert_batch(
            jnp.asarray(read)[None], np.asarray([5], dtype=np.int32))
        poisoned = genome.poison_queries(read[None], seed=4)[0]
        out = np.asarray(eng.msmt(jnp.asarray(poisoned)[None],
                                  theta=cfg.theta))
        assert out[0, 5]

"""IDL hash family + Bloom filter semantics (paper Algorithms 1-2, Thm 1-2)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import bloom, cache_model, idl, kmers, theory
from repro.data import genome


CFG = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=4, m=1 << 22)


class TestIDLLocations:
    def test_rolling_equals_batch(self, rng):
        codes = jnp.asarray(rng.integers(0, 4, size=1000, dtype=np.uint8))
        roll = idl.idl_locations_rolling(CFG, codes)
        batch = idl.idl_locations_kmer_batch(CFG, kmers.pack_kmers(codes, CFG.k))
        np.testing.assert_array_equal(np.asarray(roll), np.asarray(batch))

    def test_locations_in_partition(self, rng):
        codes = jnp.asarray(rng.integers(0, 4, size=500, dtype=np.uint8))
        locs = np.asarray(idl.idl_locations_rolling(CFG, codes))
        for j in range(CFG.eta):
            assert locs[j].min() >= j * CFG.m_part
            assert locs[j].max() < (j + 1) * CFG.m_part

    def test_locality_invariant(self, rng):
        """Adjacent kmers share the anchor block with P >= (L-1)/L * J
        (Theorem 1 lower bound); distant kmers do not."""
        codes = jnp.asarray(rng.integers(0, 4, size=3000, dtype=np.uint8))
        locs = np.asarray(idl.idl_locations_rolling(CFG, codes))[0]
        blocks = locs // CFG.L
        adjacent_same = float(np.mean(blocks[1:] == blocks[:-1]))
        w = CFG.w
        expected_j = (w - 1) / (w + 1)  # adjacent-kmer Jaccard
        assert adjacent_same > expected_j * (CFG.L - 1) / CFG.L - 0.1
        far_same = float(np.mean(blocks[64:] == blocks[:-64]))
        assert far_same < 0.02

    def test_identity_preserved(self, rng):
        """IDL must NOT collide similar keys (unlike LSH): distinct adjacent
        kmers map to distinct locations with high probability."""
        codes = jnp.asarray(rng.integers(0, 4, size=3000, dtype=np.uint8))
        locs = np.asarray(idl.idl_locations_rolling(CFG, codes))[0]
        collide = float(np.mean(locs[1:] == locs[:-1]))
        assert collide < 2.0 / CFG.L * 10 + 0.01

    def test_t_equals_k_degenerates_to_rh_stats(self, rng):
        """Paper §5.1: t=k ignores kmer similarity -> no locality."""
        cfg = idl.IDLConfig(k=31, t=31, L=1 << 12, eta=1, m=1 << 22,
                            minhash_mode="exact")
        codes = jnp.asarray(rng.integers(0, 4, size=2000, dtype=np.uint8))
        locs = np.asarray(idl.idl_locations_rolling(cfg, codes))[0]
        blocks = locs // cfg.L
        assert float(np.mean(blocks[1:] == blocks[:-1])) < 0.02

    def test_32bit_path_has_locality(self, rng):
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=2, m=1 << 22)
        codes = jnp.asarray(rng.integers(0, 4, size=2000, dtype=np.uint8))
        locs = np.asarray(idl.idl_locations_rolling32(cfg, codes))[0]
        blocks = locs // cfg.L
        assert float(np.mean(blocks[1:] == blocks[:-1])) > 0.6
        rh = np.asarray(idl.rh_locations_rolling32(cfg, codes))[0]
        assert float(np.mean((rh // cfg.L)[1:] == (rh // cfg.L)[:-1])) < 0.02


class TestBloomFilter:
    def test_no_false_negatives(self, rng):
        codes = jnp.asarray(rng.integers(0, 4, size=2000, dtype=np.uint8))
        for scheme in ("idl", "rh"):
            bf = bloom.BloomFilter(cfg=CFG, scheme=scheme).insert_sequence(codes)
            assert bool(bf.membership(codes)), scheme

    @given(st.integers(0, 2**31))
    @settings(max_examples=20, deadline=None)
    def test_no_false_negatives_property(self, seed):
        r = np.random.default_rng(seed)
        codes = jnp.asarray(r.integers(0, 4, size=200, dtype=np.uint8))
        cfg = idl.IDLConfig(k=31, t=12, L=1 << 10, eta=2, m=1 << 18)
        bf = bloom.BloomFilter(cfg=cfg, scheme="idl").insert_sequence(codes)
        assert bool(jnp.all(bf.query_sequence(codes)))

    def test_fpr_below_theorem2_bound(self, rng):
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=4, m=1 << 22)
        g = genome.synthesize_genome(20000, seed=3, repeat_fraction=0.0)
        bf = bloom.BloomFilter(cfg=cfg, scheme="idl").insert_sequence(
            jnp.asarray(g))
        n = len(g) - cfg.k + 1
        neg = jnp.asarray(rng.integers(0, 4, size=50000, dtype=np.uint8))
        fpr = float(jnp.mean(bf.query_sequence(neg)))
        bound = theory.idl_bf_fpr_bound(cfg.m, n, cfg.eta, cfg.L, cfg.k, cfg.t)
        assert fpr <= bound + 0.01

    def test_poisoned_query_rejected(self):
        g = genome.synthesize_genome(5000, seed=4, repeat_fraction=0.0)
        reads = genome.extract_reads(g, 230, 32, seed=5)
        poisoned = genome.poison_queries(reads, seed=6)
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 12, eta=4, m=1 << 22)
        bf = bloom.BloomFilter(cfg=cfg, scheme="idl").insert_sequence(
            jnp.asarray(g))
        hits = [bool(bf.membership(jnp.asarray(q))) for q in poisoned]
        clean = [bool(bf.membership(jnp.asarray(q))) for q in reads]
        assert all(clean)
        assert sum(hits) <= 2  # 1-poisoning must (whp) break membership

    def test_pack_unpack_roundtrip(self, rng):
        bits = jnp.asarray(rng.integers(0, 2, size=1 << 12, dtype=np.uint8))
        words = bloom.pack_bits(bits)
        np.testing.assert_array_equal(
            np.asarray(bloom.unpack_bits(words)), np.asarray(bits))

    def test_query_packed_matches_unpacked(self, rng):
        codes = jnp.asarray(rng.integers(0, 4, size=1000, dtype=np.uint8))
        bf = bloom.BloomFilter(cfg=CFG, scheme="idl").insert_sequence(codes)
        locs = idl.idl_locations_rolling(CFG, codes)
        words = bloom.pack_bits(bf.bits)
        got = bloom.query_packed(words, locs.astype(jnp.uint32))
        want = bloom.query_locations(bf.bits, locs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_blocked_bloom_locations(self, rng):
        karr = jnp.asarray(rng.integers(0, 2**62, size=500, dtype=np.uint64))
        locs = np.asarray(bloom.blocked_locations(karr, 1 << 20, 4, 512))
        blk = locs // 512
        assert (blk == blk[0:1]).all(axis=0).all()  # all η probes in one block


class TestCacheModel:
    def test_idl_reduces_misses_vs_rh(self, rng):
        """The paper's headline: ~5x fewer misses for IDL vs RH.

        The locality unit is the IDL window L (paper: one page, 2^15 bits),
        so the reduction shows at page/window granularity — ρ₂ scatters
        within the window by design (identity preservation), so 64-B-line
        reuse is not the mechanism; fetched-page reuse is. Measured with
        the fetch unit = one 4-KiB page, matching the paper's 'alt. page'
        reading and its L ≈ page-size recommendation."""
        codes = jnp.asarray(rng.integers(0, 4, size=20000, dtype=np.uint8))
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=1 << 26)
        tr_idl = cache_model.probe_trace_from_locations(
            np.asarray(idl.idl_locations_rolling(cfg, codes)))
        tr_rh = cache_model.probe_trace_from_locations(
            np.asarray(idl.rh_locations_rolling(cfg, codes)))
        l1_idl, _ = cache_model.two_level_miss_rates(
            tr_idl, l1_bytes=2 << 20, line_bytes=4096)
        l1_rh, _ = cache_model.two_level_miss_rates(
            tr_rh, l1_bytes=2 << 20, line_bytes=4096)
        assert l1_rh > 4 * l1_idl

    def test_idl_block_dmas_vs_rh(self, rng):
        """TPU formulation of the same claim: block-DMA count (the unit the
        Pallas probe kernel schedules) drops by ~1/(1-J) for IDL."""
        codes = jnp.asarray(rng.integers(0, 4, size=20000, dtype=np.uint8))
        cfg = idl.IDLConfig(k=31, t=16, L=1 << 15, eta=4, m=1 << 26)
        d_idl = cache_model.count_block_dmas_partitioned(
            np.asarray(idl.idl_locations_rolling(cfg, codes)), cfg.L)
        d_rh = cache_model.count_block_dmas_partitioned(
            np.asarray(idl.rh_locations_rolling(cfg, codes)), cfg.L)
        assert d_rh["switches"] > 4 * d_idl["switches"]

    def test_block_dma_counts(self):
        trace = np.array([0, 1, 2, 4096, 4097, 0])
        d = cache_model.count_block_dmas(trace, 4096)
        assert d["switches"] == 3 and d["unique"] == 2

    def test_lru_semantics(self):
        c = cache_model.LRUCache(capacity_bytes=128, line_bytes=64)  # 2 lines
        assert c.access(0) is True       # miss
        assert c.access(1) is False      # same line
        assert c.access(64 * 8) is True  # second line
        assert c.access(0) is False      # still resident
        assert c.access(128 * 8) is True # evicts LRU (line of bit 64*8? no: 0 touched later)
        assert c.access(64 * 8 ) is True # was evicted

"""Snapshot store: save→load→query bit-identical across 4 engines ×
{idl, rh, lsh} schemes, mmap/verify modes, and loud rejection of foreign,
corrupt, truncated or future-versioned snapshots."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idl
from repro.index import (
    BitSlicedIndex,
    CobsIndex,
    PackedBloomIndex,
    RamboIndex,
    SnapshotError,
    store,
)
from repro.index import state as state_mod

ENGINES = ["bloom", "cobs", "rambo", "bitsliced"]
SCHEMES = ["idl", "rh", "lsh"]


def _cfg(m: int = 1 << 16) -> idl.IDLConfig:
    return idl.IDLConfig(k=31, t=16, L=1 << 10, eta=2, m=m)


@pytest.fixture(scope="module")
def reads(rng):
    return jnp.asarray(rng.integers(0, 4, size=(3, 120), dtype=np.uint8))


def _build(name: str, scheme: str, reads):
    if name == "bitsliced" and scheme == "lsh":
        pytest.skip("lsh has no 32-bit lane path (bit-sliced engines "
                    "run lane32)")
    fids = np.arange(reads.shape[0])
    if name == "bloom":
        return PackedBloomIndex.build(_cfg(), scheme).insert_batch(reads[:2])
    if name == "cobs":
        return CobsIndex.build(
            [100, 200, 150], _cfg(), scheme=scheme, n_groups=2
        ).insert_batch(reads, fids)
    if name == "rambo":
        return RamboIndex.build(
            5, _cfg(1 << 14), scheme=scheme, B=2, R=2
        ).insert_batch(reads, fids)
    if name == "bitsliced":
        return BitSlicedIndex.build(
            _cfg(), scheme, n_files=40
        ).insert_batch(reads, np.asarray([0, 9, 39]))
    raise KeyError(name)


class TestRoundTrip:
    @pytest.mark.parametrize("scheme", SCHEMES)
    @pytest.mark.parametrize("engine", ENGINES)
    def test_save_load_query_bit_identical(self, tmp_path, reads, engine,
                                           scheme):
        eng = _build(engine, scheme, reads)
        store.save(eng, str(tmp_path / "snap"))
        loaded = store.load(str(tmp_path / "snap"))
        assert loaded.meta == eng.state.meta
        view = state_mod.to_engine(loaded)
        np.testing.assert_array_equal(
            np.asarray(eng.query_batch(reads)),
            np.asarray(view.query_batch(reads)))
        for theta in (1.0, 0.6):
            np.testing.assert_array_equal(
                np.asarray(eng.msmt(reads, theta=theta)),
                np.asarray(view.msmt(reads, theta=theta)))

    def test_load_engine_and_no_mmap_and_no_verify(self, tmp_path, reads):
        eng = _build("bitsliced", "idl", reads)
        d = store.save(eng, str(tmp_path / "snap"))
        for kw in ({"mmap": False}, {"verify": False},
                   {"mmap": False, "verify": False}):
            view = store.load_engine(d, **kw)
            np.testing.assert_array_equal(
                np.asarray(view.words), np.asarray(eng.words))

    def test_save_accepts_state_and_is_rewritable(self, tmp_path, reads):
        st = _build("rambo", "idl", reads).state
        d = store.save(st, str(tmp_path / "snap"))
        store.save(st, d)                       # overwrite in place is fine
        loaded = store.load(d)
        np.testing.assert_array_equal(
            np.asarray(loaded.words[0]), np.asarray(st.words[0]))

    def test_save_rejects_consumed_state(self, tmp_path, reads):
        eng = _build("bloom", "idl", reads)
        _ = eng.insert_batch(reads[:1])
        from repro.index import StaleIndexError

        with pytest.raises(StaleIndexError):
            store.save(eng, str(tmp_path / "snap"))


class TestRejection:
    @pytest.fixture
    def snap(self, tmp_path, reads):
        eng = _build("bitsliced", "idl", reads)
        return store.save(eng, str(tmp_path / "snap"))

    def _manifest(self, snap):
        with open(os.path.join(snap, store.MANIFEST)) as f:
            return json.load(f)

    def _rewrite(self, snap, manifest):
        with open(os.path.join(snap, store.MANIFEST), "w") as f:
            json.dump(manifest, f)

    def test_missing_manifest(self, tmp_path):
        with pytest.raises(SnapshotError, match="not a snapshot"):
            store.load(str(tmp_path / "nowhere"))

    def test_shard_set_dir_points_at_the_right_loader(self, tmp_path,
                                                      reads):
        """A shard-set snapshot is NOT a single-index snapshot: loading
        one here names the real loader instead of claiming 'not a
        snapshot', and ``read_meta`` still answers — with the FULL
        unsharded meta, the geometry the set serves."""
        from repro.index import shards

        eng = _build("rambo", "idl", reads)
        spec, parts = shards.partition_state(eng, 2)
        d = str(tmp_path / "set")
        shards.save_shard_set(spec, parts, d)
        with pytest.raises(SnapshotError, match="SHARD-SET snapshot"):
            store.load(d)
        with pytest.raises(SnapshotError, match="load_shard_set"):
            store.load(d)
        assert store.read_meta(d) == eng.state.meta
        # an empty dir is still just "not a snapshot"
        with pytest.raises(SnapshotError, match="not a snapshot"):
            store.load(str(tmp_path / "nowhere"))

    def test_foreign_format_tag(self, snap):
        m = self._manifest(snap)
        m["format"] = "some-other-store"
        self._rewrite(snap, m)
        with pytest.raises(SnapshotError, match="not an index snapshot"):
            store.load(snap)

    def test_future_version_rejected(self, snap):
        m = self._manifest(snap)
        m["version"] = store.VERSION + 1
        self._rewrite(snap, m)
        with pytest.raises(SnapshotError, match="version"):
            store.load(snap)

    def test_corrupt_manifest_json(self, snap):
        with open(os.path.join(snap, store.MANIFEST), "w") as f:
            f.write("{not json")
        with pytest.raises(SnapshotError, match="corrupt"):
            store.load(snap)

    def test_missing_array_file(self, snap):
        os.remove(os.path.join(snap, "words_0.npy"))
        with pytest.raises(SnapshotError, match="missing"):
            store.load(snap)

    def test_bitrot_fails_checksum(self, snap):
        path = os.path.join(snap, "words_0.npy")
        raw = bytearray(open(path, "rb").read())
        raw[-1] ^= 0xFF                          # flip bits in the payload
        open(path, "wb").write(bytes(raw))
        with pytest.raises(SnapshotError, match="checksum"):
            store.load(snap)
        store.load(snap, verify=False)           # opt-out skips the scan

    def test_shape_mismatch_always_checked(self, snap):
        m = self._manifest(snap)
        m["arrays"][0]["shape"][0] += 1
        self._rewrite(snap, m)
        with pytest.raises(SnapshotError, match="manifest says"):
            store.load(snap, verify=False)

    def test_array_count_mismatch(self, snap):
        m = self._manifest(snap)
        m["arrays"] = []
        self._rewrite(snap, m)
        with pytest.raises(SnapshotError, match="inconsistent"):
            store.load(snap)

    def test_malformed_cfg_rejected(self, snap):
        m = self._manifest(snap)
        m["meta"]["cfgs"][0]["no_such_field"] = 1
        self._rewrite(snap, m)
        with pytest.raises(SnapshotError, match="IDLConfig"):
            store.load(snap)

    def test_wrong_typed_meta_rejected(self, snap):
        """TypeError-shaped corruption must still surface as SnapshotError."""
        m = self._manifest(snap)
        m["meta"]["cfgs"] = None
        self._rewrite(snap, m)
        with pytest.raises(SnapshotError, match="malformed"):
            store.load(snap)

    def test_array_path_escape_rejected(self, snap, tmp_path):
        """A crafted manifest must not read files outside the snapshot."""
        outside = tmp_path / "outside.npy"
        np.save(outside, np.zeros((4, 2), dtype=np.uint32))
        m = self._manifest(snap)
        for bad in (str(outside), "../outside.npy", "sub/words_0.npy"):
            m["arrays"][0]["file"] = bad
            self._rewrite(snap, m)
            with pytest.raises(SnapshotError, match="plain file name"):
                store.load(snap)


class TestVerifyModes:
    """The eager/lazy/off verification contract the fabric boot path uses."""

    @pytest.fixture
    def snap(self, tmp_path, reads):
        eng = _build("bitsliced", "idl", reads)
        return store.save(eng, str(tmp_path / "snap"))

    def _rot(self, snap):
        path = os.path.join(snap, "words_0.npy")
        raw = bytearray(open(path, "rb").read())
        raw[-1] ^= 0xFF
        open(path, "wb").write(bytes(raw))

    def test_bool_verify_maps_to_modes(self, snap):
        self._rot(snap)
        with pytest.raises(SnapshotError, match="checksum"):
            store.load(snap, verify=True)        # legacy True == "eager"
        store.load(snap, verify=False)           # legacy False == "off"
        store.load(snap, verify="off")

    def test_unknown_mode_rejected(self, snap):
        with pytest.raises(ValueError, match="verify must be one of"):
            store.load(snap, verify="sometimes")

    def test_lazy_load_of_clean_snapshot_verifies_in_background(self, snap):
        state = store.load(snap, verify="lazy")
        assert state.words[0].dtype == jnp.uint32
        assert store.check_verified(snap, wait=True) is True

    def test_lazy_load_of_corrupt_snapshot_fails_loudly(self, snap):
        """Lazy boot returns immediately, but the background pass still
        catches the rot: check_verified raises instead of letting a
        worker serve bit-rotted words forever."""
        self._rot(snap)
        store.load(snap, verify="lazy")          # boot succeeds (by design)
        with pytest.raises(SnapshotError, match="background checksum"):
            store.check_verified(snap, wait=True)
        # and the registry keeps raising on every later check
        with pytest.raises(SnapshotError, match="background checksum"):
            store.check_verified(snap, wait=False)

    def test_check_verified_without_lazy_load_is_trivially_true(self, snap):
        assert store.check_verified(snap) is True

    def test_truncated_array_fails_at_open_in_every_mode(self, snap):
        """Shape/dtype come from the .npy header vs the manifest; a short
        file can't even mmap to its declared shape — loud at open time
        with verification off entirely."""
        path = os.path.join(snap, "words_0.npy")
        raw = open(path, "rb").read()
        open(path, "wb").write(raw[: len(raw) // 2])
        for mode in ("eager", "lazy", "off"):
            with pytest.raises(SnapshotError):
                store.load(snap, verify=mode)

    def test_device_false_keeps_memmap_leaves(self, snap, reads):
        """O(manifest) open: leaves stay memory-mapped numpy arrays; the
        first computation converts and answers bit-identically."""
        lazy = store.load(snap, verify="off", device=False)
        assert isinstance(lazy.words[0], np.ndarray)
        assert not isinstance(lazy.words[0], jnp.ndarray)
        eager = store.load(snap)
        np.testing.assert_array_equal(
            np.asarray(state_mod.to_engine(lazy).msmt(reads)),
            np.asarray(state_mod.to_engine(eager).msmt(reads)))

    def test_read_meta_is_data_free(self, snap):
        meta = store.read_meta(snap)
        assert meta.engine == "bitsliced"
        assert state_mod.kmer_size(meta) == 31
        os.remove(os.path.join(snap, "words_0.npy"))   # no array bytes read
        assert store.read_meta(snap).engine == "bitsliced"

#!/usr/bin/env bash
# Obs discipline lint: the metrics registry (repro.obs.metrics) is the
# ONLY mutable stats store in the serving stack. Serving modules read
# registry snapshots and bind handles; they do not grow parallel
# hand-rolled stat dicts or attribute counters again. Grep-based and
# deliberately blunt — it gates the *pattern*, reviewers gate the
# semantics.
#
# Usage: scripts/check_obs_discipline.sh   (run from the repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

SCOPE=(src/repro/serving src/repro/index/query.py)
fail=0

check() {
    local label="$1" pattern="$2"
    shift 2
    if out=$(grep -rnE "$@" "$pattern" "${SCOPE[@]}" 2>/dev/null); then
        echo "FAIL: $label"
        echo "$out" | sed 's/^/    /'
        fail=1
    fi
}

# 1. No mutable stats-dict entries: counters live in the registry, not
#    in dicts patched per event (stats dicts returned to callers are
#    built in one shot from registry snapshots).
check "stats dict mutated in place (use a registry counter)" \
    "stats\[[\"'][a-z_]+[\"']\][[:space:]]*(\+=|-=|=[^=])"

# 2. No ad-hoc attribute counters shadowing registry series.
check "hand-rolled attribute counter (bind a registry handle)" \
    "self\._[a-z_]*(hits|misses|requests_served|n_batches|evictions|invalidations)[a-z_]*[[:space:]]*\+="

# 3. One canonical cache-stats fold: ``merge_cache_stats`` is defined in
#    kmer_cache.py and nowhere else; fleet-wide rollups go through
#    repro.obs.export.cache_stats_view over merged snapshots.
check "second stats-merge implementation (use the canonical one)" \
    "def[[:space:]]+merge_[a-z_]*stats" --exclude=kmer_cache.py

# 4. Serving code must not reach into registry internals — snapshots and
#    handles are the whole API surface.
check "registry internals poked from serving code" \
    "DEFAULT\._(counters|gauges|hists)\b"

if [ "$fail" -ne 0 ]; then
    echo
    echo "Serving tiers must route stats through repro.obs (see"
    echo "docs/API.md, 'Observability plane')."
    exit 1
fi
echo "obs discipline: clean (${SCOPE[*]})"

"""The training loop: microbatching, checkpointing, straggler + preemption.

One loop serves every arch family — the per-arch pieces (loss_fn, pipeline)
come from the config registry. Fault-tolerance behaviors (DESIGN.md §6):

* periodic async checkpoint (params + opt state + pipeline cursor),
* preemption-signal checkpoint at the next step boundary,
* straggler detection via rolling-median heartbeat → data-shard reassignment
  (host-side; logged into metrics),
* deterministic resume: pipeline cursor is restored and the data order
  replays exactly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.distributed import fault_tolerance as ft
from repro.train import checkpoint as ckpt_mod, optimizer as opt_mod, train_state as ts


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 100
    ckpt_dir: str | None = None
    log_every: int = 10
    microbatch: int = 0
    grad_clip: float = 1.0
    straggler_factor: float = 3.0
    install_signal_handlers: bool = False


@dataclasses.dataclass
class LoopResult:
    state: ts.TrainState
    history: list[dict]
    straggler_events: list[ft.StragglerEvent]
    preempted: bool
    resumed_from: int | None


def run(
    loss_fn: Callable,
    init_params,
    optimizer: opt_mod.Optimizer,
    next_batch: Callable[[], dict],
    cfg: LoopConfig,
    *,
    pipeline_state: Callable[[], dict] | None = None,
    restore_pipeline: Callable[[dict], None] | None = None,
    step_fn_transform: Callable | None = None,
) -> LoopResult:
    """Generic fault-tolerant training driver."""
    step_fn = ts.make_train_step(
        loss_fn, optimizer, grad_clip=cfg.grad_clip, microbatch=cfg.microbatch
    )
    if step_fn_transform is not None:
        step_fn = step_fn_transform(step_fn)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    # one-time defensive copy: step_fn donates its input state, which would
    # otherwise invalidate the caller's init_params buffers on the first step
    init_params = jax.tree.map(lambda x: x + 0 if hasattr(x, "dtype") else x,
                               init_params)
    state = ts.TrainState.create(init_params, optimizer)
    manager = ckpt_mod.CheckpointManager(cfg.ckpt_dir) if cfg.ckpt_dir else None
    resumed_from = None
    if manager is not None and manager.latest_step() is not None:
        state, manifest = manager.restore(state)
        resumed_from = manifest["step"]
        if restore_pipeline is not None and "pipeline" in manifest["extra"]:
            restore_pipeline(manifest["extra"]["pipeline"])

    guard = ft.PreemptionGuard(install=cfg.install_signal_handlers)
    heartbeat = ft.Heartbeat(straggler_factor=cfg.straggler_factor)
    history: list[dict] = []
    start = int(state.step)

    def _save(step: int, blocking: bool = False) -> None:
        if manager is None:
            return
        extra = {}
        if pipeline_state is not None:
            extra["pipeline"] = pipeline_state()
        manager.save(step, state, extra=extra, blocking=blocking)

    preempted = False
    for step in range(start, cfg.total_steps):
        heartbeat.start_step(step)
        batch = next_batch()
        state, metrics = step_fn(state, batch)
        # materialize (forces async dispatch; heartbeat sees real step time)
        metrics = {k: float(v) for k, v in metrics.items()}
        ev = heartbeat.end_step()
        if ev is not None:
            metrics["straggler"] = 1.0
        if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
            history.append({"step": step, **metrics})
        if cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
            _save(step + 1)
        if guard.requested:
            _save(step + 1, blocking=True)
            preempted = True
            break

    if manager is not None:
        _save(int(state.step), blocking=True)
        manager.wait()
    guard.restore()
    return LoopResult(
        state=state, history=history,
        straggler_events=heartbeat.events,
        preempted=preempted, resumed_from=resumed_from,
    )

"""Hand-rolled pytree optimizers: AdamW and Adafactor (no optax offline).

Adafactor (Shazeer & Stern 2018) is the default for the >=100B archs: the
second moment is factored into row/col statistics, so optimizer state is
~2 bytes/param (bf16 momentum) instead of Adam's 8 — the difference between
fitting and not fitting Arctic-480B on a 256-chip pod (DESIGN.md §6).

API mirrors optax: ``opt.init(params) -> state``; ``opt.update(grads, state,
params) -> (updates, state)``; apply with ``apply_updates``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Any
    update: Any


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw(
    lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
    eps: float = 1e-8, weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            u = -lr * ((mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
                       + weight_decay * p.astype(jnp.float32))
            return u, mu, nu

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init=init, update=update)


# --------------------------------------------------------------------------
# Adafactor
# --------------------------------------------------------------------------

def adafactor(
    lr: float = 1e-2, decay: float = 0.8, eps1: float = 1e-30,
    eps2: float = 1e-3, clip_threshold: float = 1.0,
    momentum: float = 0.9, momentum_dtype=jnp.bfloat16,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Factored second moment for >=2D params; full for 1D."""

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def state_of(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    "m": jnp.zeros(p.shape, momentum_dtype) if momentum else None,
                }
            return {
                "v": jnp.zeros(p.shape, jnp.float32),
                "m": jnp.zeros(p.shape, momentum_dtype) if momentum else None,
            }

        return {
            "per_param": jax.tree.map(state_of, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if _factored(p):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.clip(jnp.mean(vr, axis=-1, keepdims=True), eps1)
                vhat = (
                    vr[..., :, None] * vc[..., None, :]
                    / denom[..., None]
                )
                u = g * jax.lax.rsqrt(vhat + eps1)
                news = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v + eps1)
                news = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            scale = jnp.maximum(
                eps2, jnp.sqrt(jnp.mean(p.astype(jnp.float32) ** 2))
            )
            u = -lr * scale * u
            if momentum:
                m = momentum * s["m"].astype(jnp.float32) + (1 - momentum) * u
                news["m"] = m.astype(momentum_dtype)
                u = m
            else:
                news["m"] = None
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u, news

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["per_param"])
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        per_param = treedef.unflatten([o[1] for o in outs])
        return updates, {"per_param": per_param, "step": step}

    return Optimizer(init=init, update=update)


def make_optimizer(name: str, lr: float, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr, **kw)
    if name == "adafactor":
        return adafactor(lr=lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")

"""Mesh-agnostic sharded checkpoints with async save.

Format: one ``.npz`` per checkpoint step holding every leaf under its
flattened pytree path, plus a JSON manifest (global shape, dtype, step,
pipeline cursor). Leaves are saved as *global* arrays (gathered via
``jax.device_get``), so restore works onto ANY mesh — the loader simply
``jax.device_put``s each global array with the target sharding. That is the
elastic-scaling contract (DESIGN.md §6): a 512-chip checkpoint restores on a
448-chip mesh unchanged.

Async save: device→host transfer happens on the caller thread (cheap,
overlaps with the next step's compute since XLA is async), the file write
runs in a background thread; ``wait()`` joins before the next save or exit.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Any, Callable

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None,
             blocking: bool = False) -> str:
        """Snapshot ``tree`` (+ json-serializable ``extra``) at ``step``."""
        self.wait()
        flat = _flatten_with_paths(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        manifest = {
            "step": int(step),
            "extra": extra or {},
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host.items()
            },
        }
        path = os.path.join(self.directory, f"ckpt_{step:08d}")

        def write():
            # np.savez appends ".npz" unless the name already ends with it
            np.savez(path + ".tmp.npz", **host)
            os.replace(path + ".tmp.npz", path + ".npz")
            with open(path + ".json.tmp", "w") as f:
                json.dump(manifest, f)
            os.replace(path + ".json.tmp", path + ".json")
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return path

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.directory, f"ckpt_{s:08d}{ext}"))
                except FileNotFoundError:
                    pass

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.directory):
            if f.startswith("ckpt_") and f.endswith(".json"):
                out.append(int(f[5:13]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None,
                sharding_fn: Callable[[str], Any] | None = None):
        """Restore into the structure of ``tree_like``.

        ``sharding_fn(path) -> jax.sharding.Sharding | None`` places each
        leaf on the target mesh (None = default device placement) — this is
        where a different mesh than the saver's is applied.
        Returns (tree, manifest).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"ckpt_{step:08d}")
        with open(path + ".json") as f:
            manifest = json.load(f)
        data = np.load(path + ".npz")
        flat_like = _flatten_with_paths(tree_like)
        restored = {}
        for key, like in flat_like.items():
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(np.shape(like)):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != model {np.shape(like)}"
                )
            sh = sharding_fn(key) if sharding_fn else None
            restored[key] = (
                jax.device_put(arr, sh) if sh is not None else
                jax.device_put(arr.astype(np.asarray(like).dtype))
            )
        # unflatten by path-order of tree_like
        leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
        keys = list(flat_like.keys())
        new_leaves = [restored[k] for k in keys]
        return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest

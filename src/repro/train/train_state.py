"""Train state + the generic train_step used by the loop and the dry-run."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_mod


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    @classmethod
    def create(cls, params, optimizer: opt_mod.Optimizer) -> "TrainState":
        return cls(
            params=params,
            opt_state=optimizer.init(params),
            step=jnp.zeros((), jnp.int32),
        )


def make_train_step(
    loss_fn: Callable, optimizer: opt_mod.Optimizer,
    *, grad_clip: float = 1.0, microbatch: int = 0,
    grad_compression: Callable | None = None,
):
    """Build train_step(state, batch) -> (state, metrics).

    ``microbatch`` > 0 splits the batch into that many accumulation steps via
    lax.scan (XLA's latency-hiding scheduler overlaps the reduce-scatter of
    one microbatch's grads with the next microbatch's backward).
    ``grad_compression`` optionally transforms grads before the optimizer
    (e.g. int8 + error feedback — see distributed.collectives).
    """

    def _grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        if microbatch and microbatch > 1:
            def split(x):
                return x.reshape((microbatch, x.shape[0] // microbatch) + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def body(acc, mbatch):
                loss, metrics, grads = _grads(state.params, mbatch)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            grads, (losses, metricses) = jax.lax.scan(body, zeros, mb)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metricses)
        else:
            loss, metrics, grads = _grads(state.params, batch)

        if grad_compression is not None:
            grads = grad_compression(grads)
        grads, gnorm = opt_mod.clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, state.opt_state, state.params)
        params = opt_mod.apply_updates(state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state, step=state.step + 1)
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm})
        return new_state, metrics

    return train_step

"""Distributed-optimization tricks: gradient compression with error feedback.

int8 quantized gradient exchange (per-tensor scale) cuts all-reduce bytes 4×
vs f32 / 2× vs bf16 — on the (2,16,16) production mesh the data-parallel
gradient reduce-scatter is the dominant collective for the dense-LM cells
(see EXPERIMENTS.md §Roofline), so this directly attacks the collective
roofline term. Error feedback (Seide et al. 2014 / Karimireddy et al. 2019)
keeps SGD unbiased-in-the-limit: the quantization residual is added back
into the next step's gradient.

Under pjit/GSPMD we express this as quantize → (sharded) values that the
partitioner reduces in int8 → dequantize; the compression function slots
into train_state.make_train_step(grad_compression=...).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass
class ErrorFeedbackState:
    residual: Any  # pytree matching grads


def init_error_feedback(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def compress_with_feedback(
    grads, ef: ErrorFeedbackState
) -> tuple[Any, ErrorFeedbackState]:
    """g' = Q(g + residual); residual' = (g + residual) - g'."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        return deq, gf - deq

    out = jax.tree.map(one, grads, ef.residual)
    is_pair = lambda x: isinstance(x, tuple)
    comp = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    resid = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return comp, ErrorFeedbackState(residual=resid)


def make_compression(kind: str | None):
    """Stateless compression hook for make_train_step (residual folded in by
    the caller when stateful EF is wanted; the stateless path quantizes and
    dequantizes in one step, which already bounds the reduce payload because
    XLA reduces the int8 intermediates under GSPMD)."""
    if kind in (None, "none"):
        return None
    if kind == "int8":
        def compress(grads):
            def one(g):
                q, s = quantize_int8(g)
                return dequantize_int8(q, s)
            return jax.tree.map(one, grads)
        return compress
    raise ValueError(f"unknown compression {kind!r}")

"""Fault tolerance: heartbeats, straggler detection, preemption, elasticity.

Designed for 1000+ nodes (DESIGN.md §6), implemented host-side so it runs
identically under the single-process CPU harness and a real multi-host pod:

* ``Heartbeat`` — per-step wall-clock monitor. A step slower than
  ``straggler_factor`` × the rolling median flags a straggler; the training
  loop responds by re-issuing the step's data shard to the healthy pool
  (data-shard reassignment is a host-side permutation — device code is
  untouched, XLA sees identical shapes every step).
* ``PreemptionGuard`` — SIGTERM/SIGINT → "checkpoint at the next step
  boundary" flag (the standard TPU-pod eviction contract).
* ``ElasticPlan`` — given the surviving device set, re-derive the largest
  (data, model) mesh that keeps the model-parallel groups intact; restart
  consumes the mesh-agnostic checkpoint (train/checkpoint.py) so a 512-chip
  job resumes on 448 chips without resharding tools.
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class Heartbeat:
    def __init__(self, straggler_factor: float = 3.0, window: int = 32):
        self.straggler_factor = straggler_factor
        self.window = window
        self.durations: list[float] = []
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.monotonic()

    def end_step(self) -> StragglerEvent | None:
        if self._t0 is None:
            return None
        dt = time.monotonic() - self._t0
        self._t0 = None
        hist = self.durations[-self.window:]
        self.durations.append(dt)
        if len(hist) >= 8:
            med = statistics.median(hist)
            if dt > self.straggler_factor * med:
                ev = StragglerEvent(step=self._step, duration=dt, median=med)
                self.events.append(ev)
                return ev
        return None


class PreemptionGuard:
    """Convert SIGTERM/SIGINT into a graceful checkpoint-and-exit request."""

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev: dict[int, object] = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:
                    pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


@dataclasses.dataclass
class ElasticPlan:
    data: int
    model: int
    dropped: int

    @property
    def n_devices(self) -> int:
        return self.data * self.model


def plan_elastic_mesh(
    n_alive: int, model_parallel: int, *, min_data: int = 1
) -> ElasticPlan:
    """Largest (data, model) grid from survivors, keeping TP groups whole.

    Model-parallel groups cannot be split across failures (params are
    sharded inside a group), so we keep `model_parallel` fixed and shrink the
    data axis to the largest multiple that fits. Raises if even min_data
    groups can't be formed.
    """
    if model_parallel <= 0:
        raise ValueError("model_parallel must be positive")
    data = n_alive // model_parallel
    if data < min_data:
        raise RuntimeError(
            f"cannot form a mesh: {n_alive} devices < {min_data}×{model_parallel}"
        )
    used = data * model_parallel
    return ElasticPlan(data=data, model=model_parallel, dropped=n_alive - used)


def reassign_shards(
    n_shards: int, failed: set[int], n_workers: int
) -> dict[int, list[int]]:
    """Round-robin data shards over surviving workers (failed ones excluded).

    Deterministic given (n_shards, failed set) → every survivor computes the
    same assignment without coordination.
    """
    alive = [w for w in range(n_workers) if w not in failed]
    if not alive:
        raise RuntimeError("no surviving workers")
    out: dict[int, list[int]] = {w: [] for w in alive}
    for s in range(n_shards):
        out[alive[s % len(alive)]].append(s)
    return out

"""Logical-axis sharding: model code names axes, policies map them to the mesh.

Model code calls ``shard(x, ("batch", None, "embed"))`` with *logical* axis
names. A :class:`ShardingRules` maps logical names to mesh axes (or None =
replicated). When no rules are active (CPU unit tests), ``shard`` is a no-op,
so the same model code runs everywhere.

Default production mapping (DESIGN.md §6):
  batch    -> ("pod", "data")   activations' batch dim (DP)
  fsdp     -> ("pod", "data")   params' largest dim (FSDP / ZeRO-3)
  embed    -> None              d_model of activations stays replicated on TP
  heads    -> "model"           attention heads (TP)
  kv_heads -> "model" if divisible else None (MQA/GQA replication)
  mlp      -> "model"           d_ff (TP)
  experts  -> "model"           MoE expert dim (EP)
  vocab    -> "model"           output logits dim
  seq      -> None ("model" under sequence-parallel prefill)
  nodes/edges -> ("pod", "data")  GNN graph partition
  table_rows  -> "model"          recsys embedding-table rows
  files       -> "model"          gene-search index file axis
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    mapping: dict[str, Any]

    def spec(self, logical: Sequence[str | None] | str | None) -> P:
        if logical is None:
            return P()
        if isinstance(logical, str):
            logical = (logical,)
        axes = []
        used: set[str] = set()
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            mesh_axes = self.mapping.get(name)
            if mesh_axes is None:
                axes.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            free = tuple(a for a in mesh_axes if a not in used)
            used.update(free)
            axes.append(free if len(free) != 1 else free[0])
        return P(*axes)

    def named(self, logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


def default_mapping(mesh: Mesh, *, seq_parallel: bool = False) -> dict[str, Any]:
    axes = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in axes)
    tp = "model" if "model" in axes else None
    m = {
        "batch": dp, "fsdp": dp,
        "embed": None,
        "heads": tp, "kv_heads": tp, "mlp": tp, "experts": tp, "vocab": tp,
        # Megatron-style sequence parallelism: the residual stream ("seq") is
        # seq-sharded over the TP axis; inside attention/MLP the seq dim is
        # unsharded ("act_seq") and the TP axis moves to heads/mlp — GSPMD
        # derives the all-gather / reduce-scatter pair at the boundary.
        "seq": tp if seq_parallel else None,
        "act_seq": None,
        # flattened (B·S) token dim (MoE dispatch/combine): batch part of
        # the merged dim keeps the DP sharding
        "tokens": dp,
        "nodes": dp, "edges": dp,
        "table_rows": tp, "files": tp,
        "expert_cap": dp,
    }
    return m


def make_rules(mesh: Mesh, **overrides) -> ShardingRules:
    mapping = default_mapping(mesh)
    mapping.update(overrides)
    return ShardingRules(mesh=mesh, mapping=mapping)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def active_rules() -> ShardingRules | None:
    return getattr(_STATE, "rules", None)


def shard(x: jax.Array, logical) -> jax.Array:
    """Constrain x's sharding by logical axis names; no-op without rules."""
    rules = active_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.named(logical))


def shard_if_divisible(x: jax.Array, logical, dim: int, axis_name: str = "model"):
    """Shard unless the dim doesn't divide the mesh axis (KV-head replication)."""
    rules = active_rules()
    if rules is None:
        return x
    size = rules.mesh.shape.get(axis_name, 1)
    if x.shape[dim] % max(size, 1):
        logical = tuple(
            None if i == dim else l for i, l in enumerate(logical)
        )
    return shard(x, logical)

"""Partitioned Bloom filters over registered hash-scheme location streams.

The canonical index storage now lives in :mod:`repro.index`: packed uint32
words mutated by batched, donated scatters. This module keeps the simple
``uint8`` bit-per-byte primitives (``insert_locations`` / ``query_locations``)
as the reference oracle the parity tests check engines against, plus
``pack_bits`` / ``unpack_bits`` to convert between the two layouts.
:class:`BloomFilter` is a deprecated adapter over
``repro.index.PackedBloomIndex``.

The Blocked Bloom filter (Putze et al.) is provided as the orthogonal
baseline the paper discusses in §3.3: all η probes of one key confined to a
single cache-line-sized block.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, idl as idl_mod


def empty_filter(m: int) -> jax.Array:
    return jnp.zeros((m,), dtype=jnp.uint8)


def insert_locations(bf: jax.Array, locs: jax.Array) -> jax.Array:
    """Set bits at (η, n) or flat locations. Donates nothing; pure."""
    return bf.at[locs.reshape(-1)].set(np.uint8(1))


def query_locations(bf: jax.Array, locs: jax.Array) -> jax.Array:
    """AND over the η axis → (n,) bool membership."""
    bits = bf[locs]  # (η, n) gather
    return jnp.all(bits == np.uint8(1), axis=0)


@dataclasses.dataclass
class BloomFilter:
    """Deprecated thin adapter over :class:`repro.index.PackedBloomIndex`.

    Kept for source compatibility with the seed API (uint8 ``bits`` field,
    single-sequence methods). New code should build a
    ``repro.index.PackedBloomIndex`` directly: it stores packed uint32
    words, inserts whole batches in one donated scatter, and exposes the
    Pallas kernel backend. Hash-scheme dispatch lives in
    :mod:`repro.index.registry` — any registered scheme name works here.
    """

    cfg: idl_mod.IDLConfig
    scheme: str = "idl"
    bits: jax.Array | None = None

    def __post_init__(self):
        if self.bits is None:
            # fresh user construction (internal dataclasses.replace calls
            # always pass bits — don't re-warn per method call); stacklevel
            # skips the generated __init__ to point at the caller
            warnings.warn(
                "core.bloom.BloomFilter is a deprecated adapter; build a "
                "repro.index.PackedBloomIndex instead (batched donated "
                "inserts, planned/sharded query backends).",
                DeprecationWarning, stacklevel=3,
            )
            self.bits = empty_filter(self.cfg.m)

    def _query_index(self):
        """Engine view for non-donating (query) use; packed words cached.

        Keyed on the bits array's identity — pack_bits over m=2^26 per
        query_sequence call would dominate. Never hand the cached words to
        ``insert_batch``: it donates its buffer.
        """
        from repro.index import engines

        cached = getattr(self, "_packed_cache", None)
        if cached is None or cached[0] is not self.bits:
            cached = (self.bits, pack_bits(self.bits))
            object.__setattr__(self, "_packed_cache", cached)
        return engines.PackedBloomIndex(
            cfg=self.cfg, scheme=self.scheme, words=cached[1]
        )

    # --- sequence (read / genome chunk) API: the paper's Alg. 1 / Alg. 2 ---
    def insert_sequence(self, codes: jax.Array) -> "BloomFilter":
        from repro.index import engines

        # pack a fresh temp for the donated insert; the cached view (and
        # this instance's bits) stay valid
        fresh = engines.PackedBloomIndex(
            cfg=self.cfg, scheme=self.scheme, words=pack_bits(self.bits)
        ).insert_batch(codes)
        out = dataclasses.replace(self, bits=unpack_bits(fresh.words))
        object.__setattr__(out, "_packed_cache", (out.bits, fresh.words))
        return out

    def query_sequence(self, codes: jax.Array) -> jax.Array:
        """Per-kmer membership bits for all stride-1 kmers of the read."""
        return self._query_index().query_batch(codes)[0]

    def membership(self, codes: jax.Array) -> jax.Array:
        """MT(Q, G): True iff every kmer of Q passes (Definition 2)."""
        return jnp.all(self.query_sequence(codes))

    # --- arbitrary kmer-batch API ---
    def insert_kmers(self, kmer_arr: jax.Array) -> "BloomFilter":
        locs = self._kmer_locs(kmer_arr)
        return dataclasses.replace(self, bits=insert_locations(self.bits, locs))

    def query_kmers(self, kmer_arr: jax.Array) -> jax.Array:
        return query_locations(self.bits, self._kmer_locs(kmer_arr))

    def _kmer_locs(self, kmer_arr: jax.Array) -> jax.Array:
        from repro.index import registry

        return registry.kmer_locations(self.cfg, kmer_arr, self.scheme)

    @property
    def fill_fraction(self) -> jax.Array:
        return jnp.mean(self.bits.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Blocked Bloom filter (Putze et al. 2007) — §3.3 orthogonal baseline.
# ---------------------------------------------------------------------------

def blocked_locations(
    kmer_arr: jax.Array, m: int, eta: int, block_bits: int
) -> jax.Array:
    """All η probes inside one block of ``block_bits`` chosen by key hash."""
    n_blocks = m // block_bits
    blk = hashing.hash_to_range(kmer_arr, 0xB10C, n_blocks).astype(jnp.uint32)
    base = blk * np.uint32(block_bits)
    locs = [
        base + hashing.hash_to_range(kmer_arr, 0xB10C + 31 * (j + 1), block_bits)
        for j in range(eta)
    ]
    return jnp.stack(locs, axis=0)


# ---------------------------------------------------------------------------
# Packed-word layout (used by kernels + serving; 32 bits/word).
# ---------------------------------------------------------------------------

def pack_bits(bf_u8: jax.Array) -> jax.Array:
    """(m,) uint8 {0,1} -> (m/32,) uint32 little-bit-endian words."""
    m = bf_u8.shape[0]
    if m % 32:
        raise ValueError(f"m={m} must be a multiple of 32")
    w = bf_u8.reshape(-1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(w << shifts[None, :], axis=1).astype(jnp.uint32)


def unpack_bits(words: jax.Array) -> jax.Array:
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts[None, :]) & np.uint32(1)
    return bits.reshape(-1).astype(jnp.uint8)


def query_packed(words: jax.Array, locs: jax.Array) -> jax.Array:
    """Membership test against the packed layout (pure-jnp oracle for kernels)."""
    word_idx = (locs >> np.uint32(5)).astype(jnp.int32)
    bit = locs & np.uint32(31)
    got = (words[word_idx] >> bit) & np.uint32(1)
    return jnp.all(got == np.uint32(1), axis=0)


@functools.partial(jax.jit, static_argnames=())
def _jit_query_packed(words, locs):
    return query_packed(words, locs)

"""Partitioned Bloom filters over IDL / RH / LSH location streams.

Canonical in-JAX representation: ``uint8`` array of m entries in {0,1}
("bit-per-byte") — scatter-set and gather are native XLA ops and dedup-safe.
``pack_bits`` / ``unpack_bits`` convert to the 32-bit-word packed layout used
by the Pallas kernels (`repro.kernels.idl_probe` / `idl_insert`) and by the
serving engine, where memory-realism matters.

The Blocked Bloom filter (Putze et al.) is provided as the orthogonal
baseline the paper discusses in §3.3: all η probes of one key confined to a
single cache-line-sized block.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, idl as idl_mod


def empty_filter(m: int) -> jax.Array:
    return jnp.zeros((m,), dtype=jnp.uint8)


def insert_locations(bf: jax.Array, locs: jax.Array) -> jax.Array:
    """Set bits at (η, n) or flat locations. Donates nothing; pure."""
    return bf.at[locs.reshape(-1)].set(np.uint8(1))


def query_locations(bf: jax.Array, locs: jax.Array) -> jax.Array:
    """AND over the η axis → (n,) bool membership."""
    bits = bf[locs]  # (η, n) gather
    return jnp.all(bits == np.uint8(1), axis=0)


@dataclasses.dataclass
class BloomFilter:
    """A partitioned BF bound to a hashing scheme ("idl" | "rh" | "lsh")."""

    cfg: idl_mod.IDLConfig
    scheme: str = "idl"
    bits: jax.Array | None = None

    def __post_init__(self):
        if self.bits is None:
            self.bits = empty_filter(self.cfg.m)

    # --- sequence (read / genome chunk) API: the paper's Alg. 1 / Alg. 2 ---
    def insert_sequence(self, codes: jax.Array) -> "BloomFilter":
        locs = idl_mod.locations(self.cfg, codes, self.scheme)
        return dataclasses.replace(self, bits=insert_locations(self.bits, locs))

    def query_sequence(self, codes: jax.Array) -> jax.Array:
        """Per-kmer membership bits for all stride-1 kmers of the read."""
        locs = idl_mod.locations(self.cfg, codes, self.scheme)
        return query_locations(self.bits, locs)

    def membership(self, codes: jax.Array) -> jax.Array:
        """MT(Q, G): True iff every kmer of Q passes (Definition 2)."""
        return jnp.all(self.query_sequence(codes))

    # --- arbitrary kmer-batch API ---
    def insert_kmers(self, kmer_arr: jax.Array) -> "BloomFilter":
        locs = self._kmer_locs(kmer_arr)
        return dataclasses.replace(self, bits=insert_locations(self.bits, locs))

    def query_kmers(self, kmer_arr: jax.Array) -> jax.Array:
        return query_locations(self.bits, self._kmer_locs(kmer_arr))

    def _kmer_locs(self, kmer_arr: jax.Array) -> jax.Array:
        if self.scheme == "idl":
            return idl_mod.idl_locations_kmer_batch(self.cfg, kmer_arr)
        if self.scheme == "rh":
            return idl_mod.rh_locations(self.cfg, kmer_arr)
        raise ValueError(f"kmer-batch API not defined for scheme {self.scheme!r}")

    @property
    def fill_fraction(self) -> jax.Array:
        return jnp.mean(self.bits.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Blocked Bloom filter (Putze et al. 2007) — §3.3 orthogonal baseline.
# ---------------------------------------------------------------------------

def blocked_locations(
    kmer_arr: jax.Array, m: int, eta: int, block_bits: int
) -> jax.Array:
    """All η probes inside one block of ``block_bits`` chosen by key hash."""
    n_blocks = m // block_bits
    blk = hashing.hash_to_range(kmer_arr, 0xB10C, n_blocks).astype(jnp.uint32)
    base = blk * np.uint32(block_bits)
    locs = [
        base + hashing.hash_to_range(kmer_arr, 0xB10C + 31 * (j + 1), block_bits)
        for j in range(eta)
    ]
    return jnp.stack(locs, axis=0)


# ---------------------------------------------------------------------------
# Packed-word layout (used by kernels + serving; 32 bits/word).
# ---------------------------------------------------------------------------

def pack_bits(bf_u8: jax.Array) -> jax.Array:
    """(m,) uint8 {0,1} -> (m/32,) uint32 little-bit-endian words."""
    m = bf_u8.shape[0]
    if m % 32:
        raise ValueError(f"m={m} must be a multiple of 32")
    w = bf_u8.reshape(-1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(w << shifts[None, :], axis=1).astype(jnp.uint32)


def unpack_bits(words: jax.Array) -> jax.Array:
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts[None, :]) & np.uint32(1)
    return bits.reshape(-1).astype(jnp.uint8)


def query_packed(words: jax.Array, locs: jax.Array) -> jax.Array:
    """Membership test against the packed layout (pure-jnp oracle for kernels)."""
    word_idx = (locs >> np.uint32(5)).astype(jnp.int32)
    bit = locs & np.uint32(31)
    got = (words[word_idx] >> bit) & np.uint32(1)
    return jnp.all(got == np.uint32(1), axis=0)


@functools.partial(jax.jit, static_argnames=())
def _jit_query_packed(words, locs):
    return query_packed(words, locs)

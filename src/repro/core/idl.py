"""IDentity-with-Locality (IDL) hash family — the paper's core contribution.

General construction (Theorem 1):  ψ(x) = ρ₁(φ(x)) + ρ₂(x)
  φ  : LSH on the input metric space (here: MinHash over t-sub-kmer sets,
       collision probability = Jaccard similarity, eq. 13-14)
  ρ₁ : RH of the LSH value into the anchor range [m' - L]
  ρ₂ : RH of the key itself into the locality window [L]

For a partitioned Bloom filter with η repetitions over total range m, each
repetition j gets its own sub-range of size m' = m // η (exactly the setup of
the paper's §6 analysis), its own MinHash (via densified one-permutation
hashing), and its own ρ₁/ρ₂ seeds. Consecutive kmers of a read share a
MinHash value with probability ≈ Jaccard ≈ (w-1)/(w+1) (w = k-t+1), hence
share the ρ₁ anchor and land within the same L-window — one cache line /
page / VMEM block serves a run of probes.

Setting t = k degenerates ρ₁ to a plain RH of the kmer → IDL == RH.
Setting L = 1 collapses the window → IDL == rehashed LSH. (Both tested.)
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, kmers, minhash

# seed salts (keep ρ₁, ρ₂ and MinHash streams independent)
_SALT_ANCHOR = 0xA17C
_SALT_LOCAL = 0x10CA
_SALT_MH = 0x0D0F
_SALT_RH = 0x5EED


@dataclasses.dataclass(frozen=True)
class IDLConfig:
    """Parameters of a gene-search IDL family (paper §5.1)."""

    k: int = 31          # kmer size (paper standard)
    t: int = 16          # sub-kmer size (paper recommends 16 for k=31)
    L: int = 1 << 15     # locality window in bits (≈ page on CPU, DMA block on TPU)
    eta: int = 4         # hash repetitions in the BF
    m: int = 1 << 26     # total BF bits
    minhash_mode: str = "doph"  # "doph" (paper §5.3.3) or "exact"
    # TPU adaptation (beyond-paper, see DESIGN.md §2 + EXPERIMENTS.md §Perf):
    # quantize the ρ₁ anchor to multiples of L so the locality window is
    # exactly ONE DMA block instead of straddling two. Identical FPR theory
    # (ψ stays uniform over the partition); ~3x fewer block switches under a
    # single-resident-tile kernel. align=False is the paper-faithful layout.
    align: bool = True

    def __post_init__(self):
        if not 1 <= self.t <= self.k <= 31:
            raise ValueError(f"need 1 <= t <= k <= 31, got t={self.t} k={self.k}")
        if self.m // self.eta <= self.L:
            raise ValueError(
                f"partition size m/η = {self.m // self.eta} must exceed L={self.L}"
            )
    @property
    def w(self) -> int:  # sub-kmers per kmer
        return self.k - self.t + 1

    @property
    def m_part(self) -> int:
        """Per-repetition sub-range; block-aligned mode rounds down to L."""
        part = self.m // self.eta
        if self.align:
            part = (part // self.L) * self.L
        return part

    @property
    def anchor_range(self) -> int:
        return self.m_part - self.L

    def exact_seeds(self) -> list[int]:
        return [_SALT_MH + 7919 * j for j in range(self.eta)]


def _minhash_rolling(cfg: IDLConfig, subk: jax.Array) -> jax.Array:
    if cfg.minhash_mode == "exact":
        return minhash.minhash_exact(subk, cfg.w, cfg.exact_seeds())
    return minhash.doph_minhash(subk, cfg.w, cfg.eta, seed=_SALT_MH)


def _combine(cfg: IDLConfig, mh: jax.Array, kmer_arr: jax.Array) -> jax.Array:
    """ψ_j(x) = j·m' + ρ₁_j(mh_j(x)) + ρ₂_j(x); output (η, n) uint32.

    align=True: ρ₁ picks a *block index* in [m'/L] and is scaled by L, so the
    locality window coincides with one DMA block. align=False: paper layout,
    ρ₁ uniform over [m' − L].
    """
    locs = []
    for j in range(cfg.eta):
        if cfg.align:
            blk = hashing.hash_to_range(mh[j], _SALT_ANCHOR + 31 * j, cfg.m_part // cfg.L)
            anchor = blk * np.uint32(cfg.L)
        else:
            anchor = hashing.hash_to_range(mh[j], _SALT_ANCHOR + 31 * j, cfg.anchor_range)
        local = hashing.hash_to_range(kmer_arr, _SALT_LOCAL + 31 * j, cfg.L)
        locs.append(anchor + local + np.uint32(j * cfg.m_part))
    return jnp.stack(locs, axis=0)


def idl_locations_rolling(cfg: IDLConfig, codes: jax.Array) -> jax.Array:
    """IDL bit locations for every stride-1 kmer of a code sequence.

    The fast path for reads: rolling MinHash via sliding-window minimum.

    Args:
      codes: (n,) uint8 base codes of the read/genome chunk.
    Returns:
      (η, n - k + 1) uint32 global bit locations in [0, m).
    """
    subk = kmers.pack_kmers(codes, cfg.t)
    mh = _minhash_rolling(cfg, subk)
    kmer_arr = kmers.pack_kmers(codes, cfg.k)
    return _combine(cfg, mh, kmer_arr)


def idl_locations_kmer_batch(cfg: IDLConfig, kmer_arr: jax.Array) -> jax.Array:
    """IDL bit locations for an arbitrary batch of packed kmers.

    Agrees exactly with :func:`idl_locations_rolling` on sequential kmers.
    """
    mh = minhash.minhash_kmer_batch(
        kmer_arr, cfg.k, cfg.t, cfg.eta,
        mode=cfg.minhash_mode, seed=_SALT_MH,
        seeds=cfg.exact_seeds() if cfg.minhash_mode == "exact" else None,
    )
    return _combine(cfg, mh, kmer_arr)


# ---------------------------------------------------------------------------
# 32-bit lane path (TPU target — no int64; see DESIGN.md §2). Semantically
# the same pipeline with 32-bit hashes; used by the sharded serving step and
# anything that must lower for the production mesh.
# ---------------------------------------------------------------------------

def idl_locations_rolling32(cfg: IDLConfig, codes: jax.Array) -> jax.Array:
    """(η, n_kmers) uint32 locations using only uint32 lanes."""
    if cfg.t > 16:
        raise ValueError("32-bit path needs t <= 16")
    subk = kmers.pack_kmers_u32(codes, cfg.t)
    h = hashing.mix32(subk * jnp.uint32(0x9E3779B9) + jnp.uint32(_SALT_MH))
    if cfg.minhash_mode == "doph":
        bins = ((h >> jnp.uint32(16)) * jnp.uint32(cfg.eta)) >> jnp.uint32(16)
        per_bin = []
        for j in range(cfg.eta):
            masked = jnp.where(bins == jnp.uint32(j), h, jnp.uint32(0xFFFFFFFF))
            per_bin.append(minhash.sliding_window_min(masked, cfg.w))
        mh = jnp.stack(per_bin, axis=0)
        for off in range(1, cfg.eta):
            donor = jnp.roll(mh, -off, axis=0)
            mh = jnp.where(
                (mh == jnp.uint32(0xFFFFFFFF)) & (donor != jnp.uint32(0xFFFFFFFF)),
                donor + jnp.uint32((0x9E3779B9 * off) & 0xFFFFFFFF),
                mh,
            )
    else:
        mh = jnp.stack(
            [
                minhash.sliding_window_min(
                    hashing.mix32(subk * jnp.uint32(2 * s + 1) + jnp.uint32(s)), cfg.w
                )
                for s in cfg.exact_seeds()
            ],
            axis=0,
        )
    hi, lo = kmers.pack_kmers_pair32(codes, cfg.k)
    locs = []
    for j in range(cfg.eta):
        if cfg.align:
            blk = hashing.hash32_to_range(
                hashing.mix32(mh[j] * jnp.uint32(2 * j + 3)), cfg.m_part // cfg.L
            )
            anchor = blk * jnp.uint32(cfg.L)
        else:
            anchor = hashing.hash32_to_range(
                hashing.mix32(mh[j] * jnp.uint32(2 * j + 3)), cfg.anchor_range
            )
        local = hashing.hash_pair32_to_range(hi, lo, _SALT_LOCAL + 31 * j, cfg.L)
        locs.append(anchor + local + jnp.uint32(j * cfg.m_part))
    return jnp.stack(locs, axis=0)


def rh_locations_rolling32(cfg: IDLConfig, codes: jax.Array) -> jax.Array:
    """Baseline RH locations on the 32-bit lane path."""
    hi, lo = kmers.pack_kmers_pair32(codes, cfg.k)
    locs = []
    for j in range(cfg.eta):
        locs.append(
            hashing.hash_pair32_to_range(hi, lo, _SALT_RH + 31 * j, cfg.m_part)
            + jnp.uint32(j * cfg.m_part)
        )
    return jnp.stack(locs, axis=0)


def idl_bbf_locations_rolling(
    cfg: IDLConfig, codes: jax.Array, block_bits: int = 512
) -> jax.Array:
    """IDL × Blocked-Bloom-filter composition (paper §3.3: "orthogonal
    approaches that can easily be integrated").

    Two levels of locality: the MinHash anchor picks the L-window (IDL —
    consecutive kmers share it), a per-KEY hash picks ONE cache-line-sized
    block inside the window, and all η probes land inside that block (BBF —
    one line fetch per kmer instead of η). Costs the BBF's slightly higher
    FPR (block-level collisions), exactly the trade the paper describes.

    Returns (η, n_kmers) uint32 locations; all η rows of a column share a
    block of ``block_bits``.
    """
    subk = kmers.pack_kmers(codes, cfg.t)
    mh = _minhash_rolling(cfg, subk)
    kmer_arr = kmers.pack_kmers(codes, cfg.k)
    n_blocks_in_window = max(cfg.L // block_bits, 1)
    # single anchor (repetition 0's MinHash) — all probes share the window
    window = hashing.hash_to_range(
        mh[0], _SALT_ANCHOR, cfg.m // cfg.L
    ).astype(jnp.uint32) * np.uint32(cfg.L)
    blk = hashing.hash_to_range(
        kmer_arr, _SALT_LOCAL, n_blocks_in_window
    ).astype(jnp.uint32) * np.uint32(block_bits)
    locs = []
    for j in range(cfg.eta):
        bit = hashing.hash_to_range(kmer_arr, _SALT_RH + 97 * j, block_bits)
        locs.append(window + blk + bit.astype(jnp.uint32))
    return jnp.stack(locs, axis=0)


def rh_locations(cfg: IDLConfig, kmer_arr: jax.Array) -> jax.Array:
    """Baseline partitioned-RH locations (MurmurHash-style), same BF layout.

    Returns: (η, n) uint32 global bit locations.
    """
    locs = []
    for j in range(cfg.eta):
        locs.append(
            hashing.hash_to_range(kmer_arr, _SALT_RH + 31 * j, cfg.m_part)
            + np.uint32(j * cfg.m_part)
        )
    return jnp.stack(locs, axis=0)


def rh_locations_rolling(cfg: IDLConfig, codes: jax.Array) -> jax.Array:
    return rh_locations(cfg, kmers.pack_kmers(codes, cfg.k))


def lsh_locations_rolling(cfg: IDLConfig, codes: jax.Array) -> jax.Array:
    """Rehashed MinHash only (Table 4's ablation: locality but identity
    loss → FPR blowup)."""
    subk = kmers.pack_kmers(codes, cfg.t)
    mh = _minhash_rolling(cfg, subk)
    locs = [
        hashing.hash_to_range(mh[j], _SALT_ANCHOR + 31 * j, cfg.m_part)
        + np.uint32(j * cfg.m_part)
        for j in range(cfg.eta)
    ]
    return jnp.stack(locs, axis=0)


def locations(cfg: IDLConfig, codes: jax.Array, scheme: str) -> jax.Array:
    """Rolling locations for a named scheme.

    Dispatch lives in :mod:`repro.index.registry` (the single place hash
    families are looked up by name); this wrapper is kept for callers that
    predate the registry.
    """
    from repro.index import registry  # local import: registry imports us

    return registry.locations(cfg, codes, scheme)

"""Vectorized random-hash (RH) families.

The paper uses MurmurHash3 as its 2-universal RH family. On TPU we need a
hash that is a handful of integer VPU ops per lane, applied elementwise to
2-bit-packed kmers held in ``uint32``/``uint64`` registers. We use the
murmur3/xxhash *finalizer* (an avalanche permutation) combined with a
per-seed odd multiplier — the standard "strongly universal enough" integer
hash used by hash-table and sketching literature (Mitzenmacher–Vadhan: simple
hashes work on entropy-rich data; genomic kmers are entropy-rich).

All functions are pure jnp, shape-polymorphic, and jit/vmap/shard_map safe.
Seeds are plain python ints or int32 scalars; a family is indexed by seed.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Golden-ratio based odd constants (splitmix64 / murmur3 lineage).
_M1_64 = np.uint64(0xFF51AFD7ED558CCD)
_M2_64 = np.uint64(0xC4CEB9FE1A85EC53)
_M1_32 = np.uint32(0x85EBCA6B)
_M2_32 = np.uint32(0xC2B2AE35)
_GOLDEN_64 = np.uint64(0x9E3779B97F4A7C15)
_GOLDEN_32 = np.uint32(0x9E3779B9)


def _to_u64(x: jax.Array) -> jax.Array:
    return x.astype(jnp.uint64)


def seed_const64(seed) -> jax.Array:
    """Derive a well-mixed 64-bit constant from a small integer seed."""
    s = jnp.asarray(seed, dtype=jnp.uint64)
    s = (s + _GOLDEN_64) * _M1_64
    s = s ^ (s >> np.uint64(29))
    s = s * _M2_64
    s = s ^ (s >> np.uint64(32))
    return s | jnp.uint64(1)  # odd multiplier


def mix64(x: jax.Array) -> jax.Array:
    """murmur3 64-bit finalizer (bijective avalanche on uint64)."""
    x = _to_u64(x)
    x = x ^ (x >> np.uint64(33))
    x = x * _M1_64
    x = x ^ (x >> np.uint64(33))
    x = x * _M2_64
    x = x ^ (x >> np.uint64(33))
    return x


def mix32(x: jax.Array) -> jax.Array:
    """murmur3 32-bit finalizer (bijective avalanche on uint32)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * _M1_32
    x = x ^ (x >> np.uint32(13))
    x = x * _M2_32
    x = x ^ (x >> np.uint32(16))
    return x


def hash64(x: jax.Array, seed) -> jax.Array:
    """Seeded 64-bit hash: full-range uint64 values."""
    c = seed_const64(seed)
    return mix64(_to_u64(x) * c + (c >> np.uint64(17)))


def hash_to_range(x: jax.Array, seed, m: int) -> jax.Array:
    """Seeded hash of integer keys into ``[0, m)``.

    Uses the multiply-shift (Lemire) reduction on the top 32 bits of a 64-bit
    hash: unbiased-enough for any m (not just powers of two) and avoids the
    modulo pipeline stall on real hardware.

    Returns uint32 (m must fit in uint32).
    """
    if m <= 0:
        raise ValueError(f"range m must be positive, got {m}")
    if m > (1 << 32):
        raise ValueError(f"range m={m} exceeds uint32")
    h = hash64(x, seed)
    hi = (h >> np.uint64(32)).astype(jnp.uint64)
    return ((hi * jnp.uint64(m)) >> np.uint64(32)).astype(jnp.uint32)


def hash_family_to_range(x: jax.Array, seeds: Sequence[int], m: int) -> jax.Array:
    """Stack of ``len(seeds)`` independent hashes of x into [0, m).

    Output shape ``(len(seeds),) + x.shape`` (uint32).
    """
    return jnp.stack([hash_to_range(x, s, m) for s in seeds], axis=0)


@functools.partial(jax.jit, static_argnames=("m",))
def _jit_hash_to_range(x, seed, m):
    return hash_to_range(x, seed, m)


# ---------------------------------------------------------------------------
# 32-bit-lane path (TPU target: no native int64 — kmers travel as uint32
# pairs; used by Pallas kernels and by everything that must lower for TPU).
# ---------------------------------------------------------------------------

def hash_pair32(hi: jax.Array, lo: jax.Array, seed) -> jax.Array:
    """Seeded 32-bit hash of a 64-bit key given as (hi, lo) uint32 lanes.

    Two murmur3 finalizer rounds with seed-derived odd multipliers; pure
    uint32 ALU ops (TPU VPU friendly).
    """
    s = jnp.asarray(seed, dtype=jnp.uint32)
    c1 = (s * _GOLDEN_32) | jnp.uint32(1)
    c2 = ((s ^ jnp.uint32(0xDEADBEEF)) * _M1_32) | jnp.uint32(1)
    h = mix32(lo.astype(jnp.uint32) * c1 + c2)
    h = mix32(h ^ (hi.astype(jnp.uint32) * c2 + c1))
    return h


def hash32_to_range(h32: jax.Array, m: int) -> jax.Array:
    """Lemire reduction of a uint32 hash into [0, m) without 64-bit mult.

    Splits the 32x32->64 product into two 16-bit halves (TPU-safe)."""
    if m <= 0 or m > (1 << 31):
        raise ValueError(f"bad range {m}")
    h = h32.astype(jnp.uint32)
    mm = jnp.uint32(m)
    hi16 = h >> jnp.uint32(16)
    lo16 = h & jnp.uint32(0xFFFF)
    # (h * m) >> 32 == (hi16*m + ((lo16*m) >> 16)) >> 16   (all fits uint32
    # when m < 2^31 and we pre-shift) — compute in two uint32 chunks.
    top = hi16 * mm                      # < 2^47 -> overflows uint32 if m big
    # guard: for m < 2^15 the fast path is exact in uint32
    if m < (1 << 15):
        return (top + ((lo16 * mm) >> jnp.uint32(16))) >> jnp.uint32(16)
    # general path: fall back to modulo-free masked reduction for 2^p ranges,
    # else modulo (still one op per lane).
    if m & (m - 1) == 0:
        p = int(m).bit_length() - 1
        return h >> jnp.uint32(32 - p) if p < 32 else h
    return h % mm


def hash_pair32_to_range(hi: jax.Array, lo: jax.Array, seed, m: int) -> jax.Array:
    return hash32_to_range(hash_pair32(hi, lo, seed), m)


def np_hash64(x: np.ndarray, seed: int) -> np.ndarray:
    """Pure-numpy mirror of :func:`hash64` (for host-side data pipelines)."""
    with np.errstate(over="ignore"):
        s = np.uint64(seed)
        s = (s + _GOLDEN_64) * _M1_64
        s ^= s >> np.uint64(29)
        s *= _M2_64
        s ^= s >> np.uint64(32)
        c = s | np.uint64(1)
        x = x.astype(np.uint64) * c + (c >> np.uint64(17))
        x ^= x >> np.uint64(33)
        x *= _M1_64
        x ^= x >> np.uint64(33)
        x *= _M2_64
        x ^= x >> np.uint64(33)
    return x


def np_hash_to_range(x: np.ndarray, seed: int, m: int) -> np.ndarray:
    h = np_hash64(x, seed)
    hi = h >> np.uint64(32)
    with np.errstate(over="ignore"):
        return ((hi * np.uint64(m)) >> np.uint64(32)).astype(np.uint32)

"""COBS — Compact Bit-sliced Signature index (Bingmann et al.), with IDL.

Layout (BIGSI/COBS): a bit-matrix of shape (m, N) — rows are hash locations,
columns are files. Querying a kmer gathers η rows and ANDs them → an N-bit
membership slice for *all* files at once. The "Compact" part: files are
grouped by size and each group gets its own row count m_g (smaller files →
smaller filters → less space), exactly as in the paper's reference [4].

IDL-COBS = identical structure, IDL locations instead of RH (paper §5.2):
consecutive kmers gather *adjacent rows*, so one fetched row-block of the
matrix serves a run of queries — the matrix row-block is the TPU DMA unit.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import idl as idl_mod


@dataclasses.dataclass
class CobsGroup:
    """One size-group: files sharing a filter size m_g."""

    cfg: idl_mod.IDLConfig             # cfg.m is this group's m_g
    scheme: str
    file_ids: list[int]
    matrix: jax.Array | None = None    # (m_g, n_files) uint8

    def __post_init__(self):
        if self.matrix is None:
            self.matrix = jnp.zeros(
                (self.cfg.m, len(self.file_ids)), dtype=jnp.uint8
            )

    def insert_sequence(self, col: int, codes: jax.Array) -> "CobsGroup":
        locs = idl_mod.locations(self.cfg, codes, self.scheme)  # (η, n)
        mat = self.matrix.at[locs.reshape(-1), col].set(np.uint8(1))
        return dataclasses.replace(self, matrix=mat)

    def query_sequence(self, codes: jax.Array) -> jax.Array:
        """(n_kmers, n_files) bool — per-kmer membership slice."""
        locs = idl_mod.locations(self.cfg, codes, self.scheme)
        rows = self.matrix[locs]              # (η, n_kmers, n_files)
        return jnp.all(rows == np.uint8(1), axis=0)


@dataclasses.dataclass
class Cobs:
    """Size-grouped array of bit-sliced filters over N files."""

    groups: list[CobsGroup]
    n_files: int

    @classmethod
    def build(
        cls,
        file_sizes: Sequence[int],
        base_cfg: idl_mod.IDLConfig,
        scheme: str = "idl",
        bits_per_kmer: float = 10.0,
        n_groups: int = 2,
    ) -> "Cobs":
        """Group files by kmer count; m_g sized from the group's largest file."""
        order = np.argsort(file_sizes)
        chunks = np.array_split(order, n_groups)
        groups = []
        for chunk in chunks:
            if len(chunk) == 0:
                continue
            biggest = max(int(file_sizes[i]) for i in chunk)
            m_g = _round_up(int(bits_per_kmer * biggest), 1 << 12)
            m_g = max(m_g, base_cfg.eta * (base_cfg.L * 2))
            cfg = dataclasses.replace(base_cfg, m=m_g)
            groups.append(CobsGroup(cfg=cfg, scheme=scheme, file_ids=[int(i) for i in chunk]))
        return cls(groups=groups, n_files=len(file_sizes))

    def insert_sequence(self, file_id: int, codes: jax.Array) -> "Cobs":
        groups = list(self.groups)
        for gi, g in enumerate(groups):
            if file_id in g.file_ids:
                groups[gi] = g.insert_sequence(g.file_ids.index(file_id), codes)
                break
        else:
            raise KeyError(f"file {file_id} not in any group")
        return dataclasses.replace(self, groups=groups)

    def query_sequence(self, codes: jax.Array) -> jax.Array:
        """MSMT kmer slice: (n_kmers, N) bool across all files (Definition 3)."""
        n_kmers = codes.shape[0] - self.groups[0].cfg.k + 1
        out = jnp.zeros((n_kmers, self.n_files), dtype=bool)
        for g in self.groups:
            sl = g.query_sequence(codes)  # (n_kmers, len(g.file_ids))
            out = out.at[:, jnp.asarray(g.file_ids)].set(sl)
        return out

    def msmt(self, codes: jax.Array, theta: float = 1.0) -> jax.Array:
        """Per-file match: fraction of query kmers present >= theta.

        theta=1.0 reproduces Definition 2 (all kmers present); theta<1 is the
        standard COBS approximate-match mode.
        """
        slices = self.query_sequence(codes)  # (n_kmers, N)
        n_kmers = slices.shape[0]
        hits = jnp.sum(slices.astype(jnp.int32), axis=0)
        # integer threshold: exact for theta=1.0 (float mean of n ones != 1.0
        # in f32 for many n, which silently breaks Definition 2)
        need = int(np.ceil(theta * n_kmers - 1e-9))
        return hits >= need

    @property
    def total_bits(self) -> int:
        return sum(int(g.matrix.shape[0]) * len(g.file_ids) for g in self.groups)


def _round_up(x: int, align: int) -> int:
    return -(-x // align) * align

"""COBS — Compact Bit-sliced Signature index (Bingmann et al.), with IDL.

Layout (BIGSI/COBS): a bit-matrix of shape (m, N) — rows are hash locations,
columns are files. Querying a kmer gathers η rows and ANDs them → an N-bit
membership slice for *all* files at once. The "Compact" part: files are
grouped by size and each group gets its own row count m_g (smaller files →
smaller filters → less space), exactly as in the paper's reference [4].

IDL-COBS = identical structure, IDL locations instead of RH (paper §5.2):
consecutive kmers gather *adjacent rows*, so one fetched row-block of the
matrix serves a run of queries — the matrix row-block is the TPU DMA unit.

:class:`Cobs` is now a deprecated thin adapter over
:class:`repro.index.CobsIndex` (packed uint32 storage, batched donated
inserts, registry-dispatched hash schemes). New code should use the engine
directly; this class keeps the seed's single-sequence call signatures.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax
import numpy as np

from repro.core import idl as idl_mod
from repro.index import engines


@dataclasses.dataclass
class Cobs:
    """Deprecated adapter: size-grouped bit-sliced filters over N files."""

    index: engines.CobsIndex

    @classmethod
    def build(
        cls,
        file_sizes: Sequence[int],
        base_cfg: idl_mod.IDLConfig,
        scheme: str = "idl",
        bits_per_kmer: float = 10.0,
        n_groups: int = 2,
    ) -> "Cobs":
        """Group files by kmer count; m_g sized from the group's largest file.

        Validates up front that the index is non-empty and that every group
        shares one kmer size ``k`` (stored top-level on the engine — query
        paths never reach into ``groups[0]``).
        """
        warnings.warn(
            "core.cobs.Cobs is a deprecated adapter; build a "
            "repro.index.CobsIndex instead (packed storage, batched donated "
            "inserts, planned/sharded query backends).",
            DeprecationWarning, stacklevel=2,
        )
        return cls(index=engines.CobsIndex.build(
            file_sizes, base_cfg, scheme=scheme,
            bits_per_kmer=bits_per_kmer, n_groups=n_groups,
        ))

    @property
    def groups(self):
        return self.index.groups

    @property
    def n_files(self) -> int:
        return self.index.n_files

    @property
    def k(self) -> int:
        return self.index.k

    def insert_sequence(self, file_id: int, codes: jax.Array) -> "Cobs":
        # insert_batch donates the target group's buffer; copy it first so
        # this (pre-insert) instance keeps the seed's functional semantics
        gi, _ = self.index._slot(int(file_id))
        groups = list(self.index.groups)
        groups[gi] = dataclasses.replace(
            groups[gi], words=groups[gi].words.copy())
        safe = dataclasses.replace(self.index, groups=tuple(groups))
        return dataclasses.replace(
            self, index=safe.insert_batch(codes, np.asarray([file_id]))
        )

    def query_sequence(self, codes: jax.Array) -> jax.Array:
        """MSMT kmer slice: (n_kmers, N) bool across all files (Definition 3)."""
        return self.index.query_batch(codes)[0]

    def msmt(self, codes: jax.Array, theta: float = 1.0) -> jax.Array:
        """Per-file match: fraction of query kmers present >= theta.

        theta=1.0 reproduces Definition 2 (all kmers present); theta<1 is the
        standard COBS approximate-match mode.
        """
        return self.index.msmt(codes, theta=theta)[0]

    @property
    def total_bits(self) -> int:
        return self.index.total_bits

"""FPR theory: classic BF (eq. 5), IDL-BF bound (Theorem 2), parameter solvers."""

from __future__ import annotations

import math


def bf_fpr(m: int, n: int, eta: int) -> float:
    """Classic BF false-positive rate, eq. (5)."""
    return (1.0 - math.exp(-eta * n / m)) ** eta


def bf_optimal_eta(m: int, n: int) -> int:
    """η* = ln(2)·m/n (rounded, >=1)."""
    return max(1, round(math.log(2) * m / n))


def bf_size_for_fpr(n: int, eps: float) -> int:
    """m = -n ln(ε) / ln²2 under optimal η."""
    return int(math.ceil(-n * math.log(eps) / (math.log(2) ** 2)))


def idl_bf_fpr_bound(
    m: int, n: int, eta: int, L: int, k: int = 31, t: int = 16,
    w1: int | None = None, w2: int | None = None,
) -> float:
    """Theorem 2 upper bound on IDL-BF FPR.

    ε ≤ ( w₂(1/L + η/m) + 2(1 − e^{−ηn/2m}) )^η
    with gene-search instantiation w₁ = k, w₂ = (k−t+1)² (Lemma 1).
    """
    if w1 is None:
        w1 = k
    if w2 is None:
        w2 = (k - t + 1) ** 2
    inner = w2 * (1.0 / L + eta / m) + 2.0 * (1.0 - math.exp(-eta * n / (2.0 * m)))
    return min(1.0, inner) ** eta


def idl_bf_fpr_bound_exact(
    m: int, n: int, eta: int, L: int, k: int = 31, t: int = 16,
) -> float:
    """Theorem 2 without the exponential approximation."""
    w1 = k
    w2 = (k - t + 1) ** 2
    base = 1.0 - (w1 * eta / m)
    if base <= 0.0:
        return 1.0
    inner = w2 * (1.0 / L + eta / m) + 2.0 * (1.0 - base ** (n / (2.0 * w1)))
    return min(1.0, inner) ** eta


def idl_limit_bound(L: int, eta: int, k: int = 31, t: int = 16) -> float:
    """m→∞ limit of the Thm 2 bound: (w₂/L)^η."""
    w2 = (k - t + 1) ** 2
    return min(1.0, w2 / L) ** eta


def grid_best_eta(m: int, n: int, L: int, k: int = 31, t: int = 16,
                  eta_max: int = 16) -> int:
    """Paper §6: grid-search η minimizing the Thm 2 bound."""
    best, best_eps = 1, float("inf")
    for eta in range(1, eta_max + 1):
        eps = idl_bf_fpr_bound(m, n, eta, L, k, t)
        if eps < best_eps:
            best, best_eps = eta, eps
    return best


def expected_adjacent_jaccard(k: int, t: int) -> float:
    """Jaccard of adjacent kmers' sub-kmer sets when all sub-kmers distinct.

    Adjacent windows of w = k−t+1 sub-kmers share w−1 elements:
    J = (w−1)/(w+1).
    """
    w = k - t + 1
    return (w - 1) / (w + 1)

"""RAMBO — Repeated And Merged Bloom filters (Gupta et al.), with IDL.

N files are hashed into B buckets, independently R times. Bucket (r, b)
holds ONE Bloom filter containing the union of kmers of all files mapped to
it. A kmer query probes the R*B filters → a (R, B) hit grid; file i is a
candidate iff its bucket hit in *every* repetition (intersection of unions).
B = O(sqrt(N)), R = O(log N) gives sub-linear query time with linear memory.

IDL-RAMBO (paper §5.2, Table 3): each bucket BF swaps RH → IDL locations;
parameters (B, R, m, η) are unchanged — IDL is a drop-in.

Implementation: the R*B filters are ONE stacked uint8 array (R*B, m_b) so a
batched query is a single gather — this is also the layout the serving layer
shards across the mesh (filter axis → 'model').
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, idl as idl_mod


@dataclasses.dataclass
class Rambo:
    cfg: idl_mod.IDLConfig            # cfg.m = bits per bucket BF (m_b)
    scheme: str
    n_files: int
    B: int                            # buckets per repetition
    R: int                            # repetitions
    filters: jax.Array | None = None  # (R*B, m_b) uint8
    assignment: np.ndarray | None = None  # (R, N) int32: file -> bucket

    def __post_init__(self):
        if self.filters is None:
            self.filters = jnp.zeros((self.R * self.B, self.cfg.m), dtype=jnp.uint8)
        if self.assignment is None:
            files = np.arange(self.n_files, dtype=np.uint64)
            self.assignment = np.stack(
                [
                    hashing.np_hash_to_range(files, 0xA3B0 + r, self.B).astype(np.int32)
                    for r in range(self.R)
                ],
                axis=0,
            )

    @classmethod
    def build(
        cls, n_files: int, cfg: idl_mod.IDLConfig, scheme: str = "idl",
        B: int | None = None, R: int | None = None,
    ) -> "Rambo":
        if B is None:
            B = max(2, int(np.ceil(np.sqrt(n_files))))
        if R is None:
            R = max(2, int(np.ceil(np.log2(max(n_files, 2)))))
        return cls(cfg=cfg, scheme=scheme, n_files=n_files, B=B, R=R)

    # ------------------------------------------------------------------
    def _locs(self, codes: jax.Array) -> jax.Array:
        return idl_mod.locations(self.cfg, codes, self.scheme)  # (η, n_kmers)

    def insert_sequence(self, file_id: int, codes: jax.Array) -> "Rambo":
        locs = self._locs(codes).reshape(-1)
        filt = self.filters
        for r in range(self.R):
            row = r * self.B + int(self.assignment[r, file_id])
            filt = filt.at[row, locs].set(np.uint8(1))
        return dataclasses.replace(self, filters=filt)

    def query_kmer_grid(self, codes: jax.Array) -> jax.Array:
        """(n_kmers, R, B) bool: bucket hits per kmer."""
        locs = self._locs(codes)                    # (η, n_kmers)
        bits = self.filters[:, locs]                # (R*B, η, n_kmers)
        hit = jnp.all(bits == np.uint8(1), axis=1)  # (R*B, n_kmers)
        return hit.T.reshape(-1, self.R, self.B)

    def msmt(self, codes: jax.Array, theta: float = 1.0) -> jax.Array:
        """Candidate files whose kmer-coverage >= theta (N-bool)."""
        grid = self.query_kmer_grid(codes)          # (n_kmers, R, B)
        assign = jnp.asarray(self.assignment)       # (R, N)
        # file i present for a kmer iff all R of its buckets hit
        per_rep = jnp.take_along_axis(
            grid, assign.T[None, :, :].transpose(0, 2, 1), axis=2
        )  # (n_kmers, R, N)
        present = jnp.all(per_rep, axis=1)          # (n_kmers, N)
        n_kmers = present.shape[0]
        hits = jnp.sum(present.astype(jnp.int32), axis=0)
        need = int(np.ceil(theta * n_kmers - 1e-9))  # exact at theta=1.0
        return hits >= need

    @property
    def total_bits(self) -> int:
        return int(self.filters.shape[0]) * int(self.filters.shape[1])

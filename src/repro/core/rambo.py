"""RAMBO — Repeated And Merged Bloom filters (Gupta et al.), with IDL.

N files are hashed into B buckets, independently R times. Bucket (r, b)
holds ONE Bloom filter containing the union of kmers of all files mapped to
it. A kmer query probes the R*B filters → a (R, B) hit grid; file i is a
candidate iff its bucket hit in *every* repetition (intersection of unions).
B = O(sqrt(N)), R = O(log N) gives sub-linear query time with linear memory.

IDL-RAMBO (paper §5.2, Table 3): each bucket BF swaps RH → IDL locations;
parameters (B, R, m, η) are unchanged — IDL is a drop-in.

:class:`Rambo` is now a deprecated thin adapter over
:class:`repro.index.RamboIndex`, which stores the R*B filters as ONE packed
(R*B, m/32) uint32 array mutated by a single batched donated scatter per
insert. This shim keeps the seed's uint8 ``filters`` field and
single-sequence call signatures.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import idl as idl_mod
from repro.index import engines, packed


@dataclasses.dataclass
class Rambo:
    """Deprecated adapter over ``repro.index.RamboIndex``."""

    cfg: idl_mod.IDLConfig            # cfg.m = bits per bucket BF (m_b)
    scheme: str
    n_files: int
    B: int                            # buckets per repetition
    R: int                            # repetitions
    filters: jax.Array | None = None  # (R*B, m_b) uint8 (compat view)
    assignment: np.ndarray | None = None  # (R, N) int32: file -> bucket

    def __post_init__(self):
        if self.assignment is None:
            self.assignment = engines.rambo_assignment(
                self.n_files, self.B, self.R)
        if self.filters is None:
            self.filters = jnp.zeros(
                (self.R * self.B, self.cfg.m), dtype=jnp.uint8)

    @classmethod
    def build(
        cls, n_files: int, cfg: idl_mod.IDLConfig, scheme: str = "idl",
        B: int | None = None, R: int | None = None,
    ) -> "Rambo":
        warnings.warn(
            "core.rambo.Rambo is a deprecated adapter; build a "
            "repro.index.RamboIndex instead (packed storage, batched "
            "donated inserts, planned/sharded query backends).",
            DeprecationWarning, stacklevel=2,
        )
        B, R = engines.rambo_dimensions(n_files, B, R)
        return cls(cfg=cfg, scheme=scheme, n_files=n_files, B=B, R=R)

    # ------------------------------------------------------------------
    def _as_index(self) -> engines.RamboIndex:
        return engines.RamboIndex(
            cfg=self.cfg, scheme=self.scheme, n_files=self.n_files,
            n_buckets=self.B, n_rep=self.R,
            words=packed.pack_rows(self.filters), assignment=self.assignment,
        )

    def insert_sequence(self, file_id: int, codes: jax.Array) -> "Rambo":
        eng = self._as_index().insert_batch(codes, np.asarray([file_id]))
        return dataclasses.replace(
            self, filters=packed.unpack_rows(eng.words, self.cfg.m))

    def query_kmer_grid(self, codes: jax.Array) -> jax.Array:
        """(n_kmers, R, B) bool: bucket hits per kmer."""
        return self._as_index().query_grid(codes)[0]

    def msmt(self, codes: jax.Array, theta: float = 1.0) -> jax.Array:
        """Candidate files whose kmer-coverage >= theta (N-bool)."""
        return self._as_index().msmt(codes, theta=theta)[0]

    @property
    def total_bits(self) -> int:
        return int(self.filters.shape[0]) * int(self.filters.shape[1])

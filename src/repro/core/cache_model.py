"""Cache / DMA traffic models for locality accounting.

Two complementary metrics (see DESIGN.md §2):

1. ``LRUCache`` — a software fully-associative LRU cache simulator, mirroring
   the paper's Valgrind two-level experiment (L1 = 2 MB, L3 = 256 MB, 64 B
   lines). Feed it the bit-address trace of BF probes; read miss rates.

2. ``count_block_dmas`` — the TPU-native metric: number of HBM→VMEM block
   DMAs an ideal block-caching kernel (``kernels/idl_probe``) issues for a
   probe trace, i.e. the number of *changes* in the block-id stream (1-deep
   cache = the currently-resident VMEM tile), plus the unique-block count
   (infinite cache lower bound).

Host-side (numpy + dict) — these are measurement tools, not model code.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np


@dataclasses.dataclass
class CacheStats:
    accesses: int = 0
    misses: int = 0

    @property
    def miss_rate(self) -> float:
        return self.misses / self.accesses if self.accesses else 0.0


class LRUCache:
    """Fully-associative LRU over fixed-size lines (addresses in *bits*)."""

    def __init__(self, capacity_bytes: int, line_bytes: int = 64):
        self.capacity_lines = max(1, capacity_bytes // line_bytes)
        self.line_bits = line_bytes * 8
        self._lines: collections.OrderedDict[int, None] = collections.OrderedDict()
        self.stats = CacheStats()

    def access(self, bit_addr: int) -> bool:
        """Returns True on miss."""
        line = bit_addr // self.line_bits
        self.stats.accesses += 1
        if line in self._lines:
            self._lines.move_to_end(line)
            return False
        self.stats.misses += 1
        self._lines[line] = None
        if len(self._lines) > self.capacity_lines:
            self._lines.popitem(last=False)
        return True

    def access_trace(self, bit_addrs: np.ndarray) -> CacheStats:
        # line-id vectorization then python LRU walk (line ids are small ints)
        lines = np.asarray(bit_addrs, dtype=np.int64) // self.line_bits
        ln = self._lines
        cap = self.capacity_lines
        misses = 0
        for line in lines.tolist():
            if line in ln:
                ln.move_to_end(line)
            else:
                misses += 1
                ln[line] = None
                if len(ln) > cap:
                    ln.popitem(last=False)
        self.stats.accesses += len(lines)
        self.stats.misses += misses
        return self.stats


def two_level_miss_rates(
    bit_addrs: np.ndarray,
    l1_bytes: int = 2 * 1024 * 1024,
    l3_bytes: int = 256 * 1024 * 1024,
    line_bytes: int = 64,
) -> tuple[float, float]:
    """Paper's Valgrind setup: (L1 miss rate, L3 miss rate of L1 misses)."""
    l1 = LRUCache(l1_bytes, line_bytes)
    l3 = LRUCache(l3_bytes, line_bytes)
    lines = np.asarray(bit_addrs, dtype=np.int64) // (line_bytes * 8)
    l1_m = 0
    l3_m = 0
    for line in lines.tolist():
        if l1.access(line * l1.line_bits):
            l1_m += 1
            if l3.access(line * l3.line_bits):
                l3_m += 1
    n = len(lines)
    return (l1_m / n if n else 0.0, l3_m / n if n else 0.0)


def count_block_dmas(bit_addrs: np.ndarray, block_bits: int) -> dict[str, int]:
    """TPU model: DMAs issued by a 1-tile-resident VMEM cache + unique blocks.

    ``switches``  — DMA count with a single resident tile (what the
                    scalar-prefetch Pallas kernel actually issues);
    ``unique``    — lower bound (infinite VMEM);
    ``accesses``  — trace length.
    """
    blocks = np.asarray(bit_addrs, dtype=np.int64) // block_bits
    if blocks.size == 0:
        return {"switches": 0, "unique": 0, "accesses": 0}
    switches = int(1 + np.count_nonzero(blocks[1:] != blocks[:-1]))
    return {
        "switches": switches,
        "unique": int(len(np.unique(blocks))),
        "accesses": int(blocks.size),
    }


def count_block_dmas_partitioned(locs: np.ndarray, block_bits: int) -> dict[str, int]:
    """TPU model for the partitioned-BF probe kernel.

    The kernel keeps one resident VMEM tile *per hash repetition* (η tiles),
    so block switches are counted per row of the (η, n_kmers) location grid
    and summed. ``unique`` likewise sums per-row unique blocks (each
    repetition owns a disjoint sub-range anyway).
    """
    locs = np.asarray(locs)
    if locs.ndim == 1:
        locs = locs[None, :]
    tot = {"switches": 0, "unique": 0, "accesses": 0}
    for row in locs:
        d = count_block_dmas(row, block_bits)
        for k in tot:
            tot[k] += d[k]
    return tot


def probe_trace_from_locations(locs: np.ndarray) -> np.ndarray:
    """Flatten (η, n_kmers) location grid into the temporal access order.

    The BF probe loop (Alg. 2) iterates kmers outer, η inner — so the trace
    interleaves the η probes of each kmer: order = locs.T.reshape(-1).
    """
    locs = np.asarray(locs)
    if locs.ndim == 1:
        return locs
    return locs.T.reshape(-1)

"""Core library: the paper's IDL hash family + BF/COBS/RAMBO indices."""

from repro.core.idl import IDLConfig  # noqa: F401
from repro.core.bloom import BloomFilter  # noqa: F401
from repro.core.cobs import Cobs  # noqa: F401
from repro.core.rambo import Rambo  # noqa: F401

"""DNA tokenization: strings -> 2-bit codes -> packed kmers / sub-kmers.

The paper's S(G, k) (eq. 6) produces |G|-k+1 kmers with a stride-1 sliding
window. We pack each kmer into an integer: 2 bits per base (A=0 C=1 G=2 T=3),
so k<=31 fits uint64 and t<=16 fits uint32. Packing is done with the rolling
recurrence kmer[i+1] = ((kmer[i] << 2) | code[i+k]) & mask, vectorized as a
shift-accumulate over k static slices (O(k) fused VPU ops, no scan
serialization, no k x n memory blowup beyond one accumulator).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Base encoding used throughout ('N' and other chars are mapped to A by the
# sanitizer in repro.data.genome — standard practice for BF indices).
BASES = "ACGT"
_LUT = np.zeros(256, dtype=np.uint8)
for _i, _b in enumerate(BASES):
    _LUT[ord(_b)] = _i
    _LUT[ord(_b.lower())] = _i


def encode_bases(s: str | bytes) -> np.ndarray:
    """ASCII DNA string -> uint8 codes in {0,1,2,3} (host-side)."""
    if isinstance(s, str):
        s = s.encode("ascii", errors="replace")
    arr = np.frombuffer(s, dtype=np.uint8)
    return _LUT[arr]


def decode_bases(codes: np.ndarray) -> str:
    return "".join(BASES[int(c)] for c in codes)


def pack_kmers(codes: jax.Array, k: int) -> jax.Array:
    """All stride-1 kmers of a code sequence, packed.

    Args:
      codes: int array shape (n,), values in {0..3}.
      k: kmer length, 1 <= k <= 31 (needs 2k bits < 64).

    Returns:
      uint64 array shape (n - k + 1,): kmer[i] = sum_j codes[i+j] << 2(k-1-j).
    """
    if not 1 <= k <= 31:
        raise ValueError(f"k must be in [1, 31], got {k}")
    n = codes.shape[0]
    if n < k:
        raise ValueError(f"sequence length {n} < k={k}")
    out_len = n - k + 1
    c64 = codes.astype(jnp.uint64)
    acc = jnp.zeros((out_len,), dtype=jnp.uint64)
    for j in range(k):  # static unroll: k fused shift-or ops
        acc = (acc << np.uint64(2)) | jax.lax.dynamic_slice(c64, (j,), (out_len,))
    return acc


def pack_kmers_np(codes: np.ndarray, k: int) -> np.ndarray:
    """numpy mirror of :func:`pack_kmers` (host-side pipelines)."""
    n = codes.shape[0]
    out_len = n - k + 1
    acc = np.zeros((out_len,), dtype=np.uint64)
    c64 = codes.astype(np.uint64)
    for j in range(k):
        acc = (acc << np.uint64(2)) | c64[j : j + out_len]
    return acc


def subkmers_of_kmers(codes: jax.Array, k: int, t: int) -> jax.Array:
    """Sub-kmer sets S(x_i, t) for every kmer x_i of the sequence.

    Because kmers come from a stride-1 window over one sequence, the sub-kmer
    set of kmer i is exactly subk[i : i + (k - t + 1)] where subk are the
    packed t-mers of the *whole* sequence. We exploit that and return the flat
    t-mer array; callers index windows into it (this identity is what makes
    rolling MinHash possible).

    Returns:
      uint64 array shape (n - t + 1,) of packed t-mers.
    """
    if not 1 <= t <= k:
        raise ValueError(f"need 1 <= t <= k, got t={t}, k={k}")
    return pack_kmers(codes, t)


def pack_kmers_u32(codes: jax.Array, t: int) -> jax.Array:
    """Packed t-mers in uint32 (t <= 16) — the TPU 32-bit lane path."""
    if not 1 <= t <= 16:
        raise ValueError(f"t must be in [1, 16] for uint32 packing, got {t}")
    n = codes.shape[0]
    out_len = n - t + 1
    c32 = codes.astype(jnp.uint32)
    acc = jnp.zeros((out_len,), dtype=jnp.uint32)
    for j in range(t):
        acc = (acc << np.uint32(2)) | jax.lax.dynamic_slice(c32, (j,), (out_len,))
    return acc


def pack_kmers_pair32(codes: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Packed kmers as (hi, lo) uint32 pairs (TPU path, k <= 31).

    lo = last min(k,16) bases; hi = the remaining leading bases (0 if k<=16).
    """
    if not 1 <= k <= 31:
        raise ValueError(f"k must be in [1, 31], got {k}")
    n = codes.shape[0]
    out_len = n - k + 1
    c32 = codes.astype(jnp.uint32)
    n_lo = min(k, 16)
    n_hi = k - n_lo
    hi = jnp.zeros((out_len,), dtype=jnp.uint32)
    for j in range(n_hi):
        hi = (hi << np.uint32(2)) | jax.lax.dynamic_slice(c32, (j,), (out_len,))
    lo = jnp.zeros((out_len,), dtype=jnp.uint32)
    for j in range(n_hi, k):
        lo = (lo << np.uint32(2)) | jax.lax.dynamic_slice(c32, (j,), (out_len,))
    return hi, lo


def unpack_kmer(kmer: int, k: int) -> str:
    out = []
    for j in range(k - 1, -1, -1):
        out.append(BASES[(int(kmer) >> (2 * j)) & 3])
    return "".join(out)


def kmer_subkmer_window(k: int, t: int) -> int:
    """Number of t-sub-kmers per kmer: |S(x, t)| = k - t + 1."""
    return k - t + 1

"""MinHash, rolling (sliding-window) MinHash, densified one-permutation hashing.

Paper fidelity + TPU adaptation:

* The paper computes the MinHash of each kmer's sub-kmer set with a *segment
  tree* (Alg. 3): one new hash + log(k-t) comparisons per kmer. A segment
  tree is pointer-chasing and inherently serial — a degenerate port on a
  vector machine. Because stride-1 kmers have *contiguous* sub-kmer windows
  (see ``kmers.subkmers_of_kmers``), rolling MinHash is exactly a
  **sliding-window minimum**, which the Gil–Werman / van Herk algorithm
  computes in two branch-free prefix-min passes: O(1) amortized comparisons
  per element (same asymptotics as the segment tree) and fully vectorizable
  on the TPU VPU. Outputs are bit-identical to the naive per-window min.

* Densified one-permutation hashing (Shrivastava & Li, 2014; paper §5.3.3):
  η MinHash repetitions from ONE hash evaluation per sub-kmer. Each element's
  hash selects a bin in [η]; the per-window minimum is taken per bin; empty
  bins borrow by rotation. We implement the rolling variant: η masked
  sliding-window minima over the single hashed stream.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

UINT64_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)
# Offset constant used by rotation densification so borrowed values do not
# collide with native values of the donor bin.
_DENSIFY_C = np.uint64(0x9E3779B97F4A7C15)


def sliding_window_min(a: jax.Array, w: int) -> jax.Array:
    """Minimum over every stride-1 window of length ``w`` (Gil–Werman).

    Args:
      a: (n,) array (any dtype with a total order; uint64 used here).
      w: window length, 1 <= w <= n.

    Returns:
      (n - w + 1,) array: out[i] = min(a[i : i + w]).
    """
    n = a.shape[0]
    if w < 1:
        raise ValueError(f"window must be >= 1, got {w}")
    if n < w:
        raise ValueError(f"length {n} < window {w}")
    if w == 1:
        return a
    fill = _maxval(a.dtype)
    nb = -(-n // w)  # ceil blocks
    pad = nb * w - n
    ap = jnp.concatenate([a, jnp.full((pad,), fill, dtype=a.dtype)]) if pad else a
    blocks = ap.reshape(nb, w)
    # L[i] = min(a[block_start : i]); R[i] = min(a[i : block_end])
    prefix = jax.lax.cummin(blocks, axis=1)
    suffix = jax.lax.cummin(blocks[:, ::-1], axis=1)[:, ::-1]
    lflat = prefix.reshape(-1)
    rflat = suffix.reshape(-1)
    out_len = n - w + 1
    # window [i, i+w-1] spans at most two blocks; suffix of the first plus
    # prefix of the second covers it exactly.
    return jnp.minimum(
        jax.lax.dynamic_slice(rflat, (0,), (out_len,)),
        jax.lax.dynamic_slice(lflat, (w - 1,), (out_len,)),
    )


def _maxval(dtype) -> np.generic:
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return np.array(jnp.iinfo(dtype).max, dtype=dtype)
    return np.array(jnp.inf, dtype=dtype)


def minhash_exact(subk: jax.Array, w: int, seeds: Sequence[int]) -> jax.Array:
    """η independent rolling MinHashes (one hash pass per seed).

    Args:
      subk: (n_sub,) packed t-mers of the sequence.
      w: sub-kmers per kmer = k - t + 1.
      seeds: η hash seeds.

    Returns:
      (η, n_sub - w + 1) uint64 MinHash values per kmer.
    """
    return jnp.stack(
        [sliding_window_min(hashing.hash64(subk, s), w) for s in seeds], axis=0
    )


def doph_minhash(subk: jax.Array, w: int, eta: int, seed: int = 0x0D0F) -> jax.Array:
    """Densified one-permutation rolling MinHash.

    One hash evaluation per sub-kmer yields η MinHash repetitions per kmer.

    Returns:
      (η, n_sub - w + 1) uint64.
    """
    h = hashing.hash64(subk, seed)
    # bin via Lemire reduction on the top 32 bits; value = full hash.
    bins = ((h >> np.uint64(32)) * np.uint64(eta)) >> np.uint64(32)
    per_bin = []
    for j in range(eta):
        masked = jnp.where(bins == np.uint64(j), h, UINT64_MAX)
        per_bin.append(sliding_window_min(masked, w))
    mh = jnp.stack(per_bin, axis=0)  # (eta, n_kmer); UINT64_MAX marks empty bins
    return densify_rotation(mh)


def densify_rotation(mh: jax.Array) -> jax.Array:
    """Rotation densification: empty bins borrow from the next non-empty bin.

    Borrowed values are offset by C * distance so donor/borrower do not alias.
    """
    eta = mh.shape[0]
    out = mh
    for off in range(1, eta):
        donor = jnp.roll(mh, -off, axis=0)
        offset = np.uint64((int(_DENSIFY_C) * off) & 0xFFFFFFFFFFFFFFFF)
        candidate = donor + offset
        # only fill still-empty bins from a non-empty donor
        out = jnp.where(
            (out == UINT64_MAX) & (donor != UINT64_MAX), candidate, out
        )
    return out


def minhash_kmer_batch(
    kmers: jax.Array, k: int, t: int, eta: int, *,
    mode: str = "doph", seed: int = 0x0D0F, seeds: Sequence[int] | None = None,
) -> jax.Array:
    """MinHash of arbitrary (not necessarily sequential) packed kmers.

    Extracts the w = k-t+1 sub-kmers of each kmer by shifting the packed
    representation, then reduces. Agrees exactly with the rolling variants on
    stride-1 sequences (tested).

    Returns: (eta, n) uint64.
    """
    w = k - t + 1
    tmask = (np.uint64(1) << np.uint64(2 * t)) - np.uint64(1)
    # sub-kmer i of kmer (leftmost first) = (kmer >> 2*(k - t - i)) & mask
    subs = jnp.stack(
        [(kmers >> np.uint64(2 * (k - t - i))) & tmask for i in range(w)], axis=0
    )  # (w, n)
    if mode == "exact":
        if seeds is None:
            raise ValueError("exact mode needs seeds")
        return jnp.stack(
            [jnp.min(hashing.hash64(subs, s), axis=0) for s in seeds], axis=0
        )
    h = hashing.hash64(subs, seed)  # (w, n)
    bins = ((h >> np.uint64(32)) * np.uint64(eta)) >> np.uint64(32)
    per_bin = []
    for j in range(eta):
        masked = jnp.where(bins == np.uint64(j), h, UINT64_MAX)
        per_bin.append(jnp.min(masked, axis=0))
    return densify_rotation(jnp.stack(per_bin, axis=0))


def jaccard_subkmers(x: int, y: int, k: int, t: int) -> float:
    """Exact Jaccard similarity of two kmers' sub-kmer sets (host-side)."""
    w = k - t + 1
    mask = (1 << (2 * t)) - 1
    sx = {(int(x) >> (2 * (k - t - i))) & mask for i in range(w)}
    sy = {(int(y) >> (2 * (k - t - i))) & mask for i in range(w)}
    return len(sx & sy) / len(sx | sy)

"""HLO-text cost analysis with while-trip-count multiplication.

XLA's ``compiled.cost_analysis()`` visits each instruction ONCE — a
``jax.lax.scan`` over 96 layers reports the flops of a single layer
(verified empirically; see EXPERIMENTS.md §Dry-run methodology). For a
trustworthy roofline we re-derive costs from ``compiled.as_text()``:

* the computation graph is walked from ENTRY; ``while`` bodies are
  multiplied by their trip count (parsed from the loop condition's
  ``compare(%iv, %constant)`` bound — scans always lower to this form);
* ``fusion``/``call``/``reduce`` include their called computation's flops;
* dot flops = 2 × |output| × (contracted extent), from
  ``lhs_contracting_dims`` and the operand's shape;
* bytes are counted at FUSION boundaries (operands + outputs of each
  top-level instruction — fusion internals live in registers, which is
  exactly the HBM-traffic model the memory roofline term wants);
* collective bytes are also trip-multiplied, per collective kind.

The SPMD module is the per-device program, so every number is per-chip.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|condition|body|true_computation|false_computation)="
    r"%?([\w.\-]+)"
)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "sign", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "expm1", "log1p", "cosine", "sine", "atan2"}


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x] if m.group(2) else []


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _num_elements(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the opening paren of operands

    @property
    def operands(self) -> list[str]:
        # operand names appear before the closing paren of the operand list;
        # attrs after "), " may also contain %refs (computations) — harmless
        # for bytes since unknown names resolve to 0.
        depth, i = 1, 0
        s = self.rest
        while i < len(s) and depth:
            if s[i] == "(":
                depth += 1
            elif s[i] == ")":
                depth -= 1
            i += 1
        return _OPERAND_RE.findall(s[: i])

    @property
    def attrs(self) -> str:
        depth, i = 1, 0
        s = self.rest
        while i < len(s) and depth:
            if s[i] == "(":
                depth += 1
            elif s[i] == ")":
                depth -= 1
            i += 1
        return s[i:]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    param_types: dict[str, str]

    def shapes(self) -> dict[str, str]:
        out = dict(self.param_types)
        for ins in self.instrs:
            out[ins.name] = ins.type_str
        return out


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped == "}":
            if cur is not None:
                comps[cur.name] = cur
                cur = None
            continue
        if stripped.endswith("{") and ("->" in stripped):
            m = _COMP_HDR_RE.match(stripped)
            if m:
                params: dict[str, str] = {}
                for pdecl in m.group(2).split(","):
                    if ":" in pdecl:
                        pname, ptype = pdecl.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(name=m.group(1), instrs=[], param_types=params)
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(stripped)
        if m:
            cur.instrs.append(Instr(
                name=m.group(1), type_str=m.group(2),
                opcode=m.group(3), rest=m.group(4),
            ))
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition (the scan bound —
    jax scans lower to `while i < N`, so N is the only sizable constant)."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.match(r"(\d+)\)", ins.rest.strip())
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    matmul_flops_f32: float = 0.0   # f32 dots run at ~half MXU rate on v5e
    matmul_flops_lp: float = 0.0    # bf16/f16 dots at full rate
    transcendentals: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_count: float = 0.0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.matmul_flops_f32 += o.matmul_flops_f32
        self.matmul_flops_lp += o.matmul_flops_lp
        self.transcendentals += o.transcendentals
        self.bytes += o.bytes
        for k in COLLECTIVES:
            self.coll_bytes[k] += o.coll_bytes[k]
        self.coll_count += o.coll_count
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(
            flops=self.flops * k,
            matmul_flops_f32=self.matmul_flops_f32 * k,
            matmul_flops_lp=self.matmul_flops_lp * k,
            transcendentals=self.transcendentals * k,
            bytes=self.bytes * k,
            coll_bytes={c: v * k for c, v in self.coll_bytes.items()},
            coll_count=self.coll_count * k,
        )

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())


class Analyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.entry = self._find_entry(text)
        self._memo: dict[tuple[str, bool], Cost] = {}

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, flags=re.M)
        if m:
            return m.group(1)
        # fall back: biggest computation
        return max(self.comps, key=lambda c: len(self.comps[c].instrs))

    # ------------------------------------------------------------------
    def cost(self) -> Cost:
        return self.comp_cost(self.entry, top_level=True)

    def comp_cost(self, name: str, top_level: bool) -> Cost:
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            self._memo[key] = total
            return total
        shapes = comp.shapes()
        cur = {i.name: i for i in comp.instrs}
        for ins in comp.instrs:
            self._cur_instrs = cur   # restore after recursive comp_cost calls
            total += self.instr_cost(ins, shapes, top_level)
        self._memo[key] = total
        return total

    def _produced_by_widening_convert(self, name: str) -> bool:
        prod = getattr(self, "_cur_instrs", {}).get(name)
        if prod is None:
            return False
        if prod.opcode == "convert":
            # operand dtype from the same computation
            ops = prod.operands
            src = self._cur_instrs.get(ops[0]) if ops else None
            return bool(src and src.type_str.lstrip().startswith("bf16"))
        if prod.opcode in ("fusion", "call") and "convert" in prod.name:
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", prod.attrs)
            called = self.comps.get(m.group(1)) if m else None
            if called is None:
                return False
            cshapes = called.shapes()
            for cins in called.instrs:
                if cins.opcode == "convert" and cins.operands:
                    src_t = cshapes.get(cins.operands[0], "")
                    if (src_t.lstrip().startswith("bf16")
                            and cins.type_str.lstrip().startswith("f32")):
                        return True
        return False

    def instr_cost(self, ins: Instr, shapes: dict[str, str],
                   top_level: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        attrs = ins.attrs

        if op == "while":
            called = _CALL_ATTR_RE.findall(attrs)
            body = cond = None
            mb = re.search(r"body=%?([\w.\-]+)", attrs)
            mc = re.search(r"condition=%?([\w.\-]+)", attrs)
            if mb:
                body = mb.group(1)
            if mc:
                cond = mc.group(1)
            trips = _trip_count(self.comps[cond]) if cond in self.comps else 1
            inner = Cost()
            if body in self.comps:
                inner += self.comp_cost(body, top_level=True)
            if cond in self.comps:
                inner += self.comp_cost(cond, top_level=True)
            return inner.scaled(max(trips, 1))

        if op in ("fusion", "call"):
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", attrs)
            # a `call` is not a materialization boundary — XLA:CPU wraps
            # parallel fusions in call computations whose parameters are
            # forwarded untouched. Recurse with the caller's top_level so
            # the callee's own fusions charge (discounted) boundary bytes;
            # charging the call's operands here would re-bill a gathered
            # table at full size.
            if op == "call" and m and m.group(1) in self.comps:
                return self.comp_cost(m.group(1), top_level=top_level)
            called = self.comps.get(m.group(1)) if m else None
            if m:
                c += self.comp_cost(m.group(1), top_level=False)
            # bytes at the fusion boundary, aliasing-aware: a fused
            # dynamic-update-slice writes ONE SLICE of an accumulator that
            # XLA aliases in place — charge 2× the update slice instead of
            # the full accumulator on both sides of the boundary.
            io = self._io_bytes(ins, shapes)
            if called is not None:
                cshapes = called.shapes()
                ops = ins.operands
                # map fused-computation parameters to fusion operands
                param_idx: dict[str, int] = {}
                for cins in called.instrs:
                    if cins.opcode == "parameter":
                        mm = re.match(r"(\d+)\)", cins.rest.strip())
                        if mm:
                            param_idx[cins.name] = int(mm.group(1))
                charged: set[int] = set()
                for cins in called.instrs:
                    if cins.opcode == "dynamic-update-slice":
                        acc_bytes = _shape_bytes(cins.type_str)
                        upd = (cshapes.get(cins.operands[1], "")
                               if len(cins.operands) > 1 else "")
                        io -= 2.0 * acc_bytes      # operand + output side
                        io += 2.0 * _shape_bytes(upd)
                    elif cins.opcode in ("gather", "dynamic-slice"):
                        # a fused sparse read touches ~the result, not the
                        # whole table operand
                        table = cins.operands[0] if cins.operands else None
                        pi = param_idx.get(table, -1)
                        if 0 <= pi < len(ops) and pi not in charged:
                            io -= _shape_bytes(shapes.get(ops[pi], ""))
                            charged.add(pi)
                        io += 2.0 * _shape_bytes(cins.type_str)
                    # in-place: subtract the table on BOTH sides
                    # (operand + fusion output) like DUS
                    elif cins.opcode == "scatter":
                        table = cins.operands[0] if cins.operands else None
                        pi = param_idx.get(table, -1)
                        if 0 <= pi < len(ops) and pi not in charged:
                            io -= _shape_bytes(shapes.get(ops[pi], ""))
                            charged.add(pi)
                        io -= _shape_bytes(cins.type_str)
                        upd = (cshapes.get(cins.operands[2], "")
                               if len(cins.operands) > 2 else "")
                        io += 3.0 * _shape_bytes(upd)
            c.bytes += max(io, 0.0)
            return c

        if op == "conditional":
            branches = re.findall(
                r"(?:true_computation|false_computation|branch_computations=\{([^}]*)\})",
                attrs)
            names = _CALL_ATTR_RE.findall(attrs)
            best = Cost()
            for n in names:
                if n in self.comps:
                    bc = self.comp_cost(n, top_level=True)
                    if bc.flops >= best.flops:
                        best = bc
            c += best
            c.bytes += self._io_bytes(ins, shapes)
            return c

        for coll in COLLECTIVES:
            if op.startswith(coll) and not op.endswith("-done"):
                nbytes = _shape_bytes(ins.type_str)
                # XLA:CPU widens bf16 to f32 BEFORE collectives (a backend
                # artifact — the TPU target moves bf16). When the collective
                # directly consumes a widening convert, charge the narrow
                # dtype's bytes.
                if ins.type_str.lstrip().startswith("f32") and ins.operands:
                    src = ins.operands[0]
                    if self._produced_by_widening_convert(src):
                        nbytes //= 2
                c.coll_bytes[coll] += nbytes
                c.coll_count += 1
                c.bytes += self._io_bytes(ins, shapes)
                return c

        if op == "dot":
            out_elems = _num_elements(ins.type_str)
            kdim = 1
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
            ops = ins.operands
            if m and ops:
                lhs_shape = _first_shape_dims(shapes.get(ops[0], ""))
                for idx in m.group(1).split(","):
                    if idx and int(idx) < len(lhs_shape):
                        kdim *= lhs_shape[int(idx)]
            fl = 2.0 * out_elems * kdim
            c.flops += fl
            # dtype of the LHS operand decides the MXU rate
            lhs_type = shapes.get(ops[0], ins.type_str) if ops else ins.type_str
            if lhs_type.startswith(("bf16", "f16")):
                c.matmul_flops_lp += fl
            else:
                c.matmul_flops_f32 += fl
            c.bytes += self._io_bytes(ins, shapes)
            return c

        if op == "convolution":
            out_elems = _num_elements(ins.type_str)
            ops = ins.operands
            k = 1
            if len(ops) >= 2:
                k = max(1, _num_elements(shapes.get(ops[1], "")) // max(
                    1, _first_shape_dims(shapes.get(ops[1], ""))[-1]
                    if _first_shape_dims(shapes.get(ops[1], "")) else 1))
            c.flops += 2.0 * out_elems * k
            c.bytes += self._io_bytes(ins, shapes)
            return c

        if op in ("reduce", "reduce-window"):
            ops = ins.operands
            in_elems = sum(_num_elements(shapes.get(o, "")) for o in ops[:1])
            c.flops += float(in_elems)
            c.bytes += self._io_bytes(ins, shapes)
            return c

        if op in _TRANSCENDENTAL:
            n = _num_elements(ins.type_str)
            c.transcendentals += float(n)
            c.flops += float(n)
            if top_level:
                c.bytes += self._io_bytes(ins, shapes)
            return c

        if op in _ELEMENTWISE:
            c.flops += float(_num_elements(ins.type_str))
            if top_level:
                c.bytes += self._io_bytes(ins, shapes)
            return c

        # in-place / sparse-access ops: count touched bytes, not whole
        # operands (XLA aliases the buffers; a scan's dynamic-update-slice
        # accumulator writes one slice per step, not the whole stack)
        if op == "dynamic-update-slice":
            upd = (_shape_bytes(shapes.get(ins.operands[1], ""))
                   if len(ins.operands) > 1 else 0)
            c.bytes += 2.0 * upd
            return c
        if op == "dynamic-slice":
            c.bytes += 2.0 * _shape_bytes(ins.type_str)
            return c
        if op == "gather":
            idx = (_shape_bytes(shapes.get(ins.operands[1], ""))
                   if len(ins.operands) > 1 else 0)
            c.bytes += 2.0 * _shape_bytes(ins.type_str) + idx
            return c
        if op == "scatter":
            upd = (_shape_bytes(shapes.get(ins.operands[2], ""))
                   if len(ins.operands) > 2 else 0)
            idx = (_shape_bytes(shapes.get(ins.operands[1], ""))
                   if len(ins.operands) > 1 else 0)
            c.bytes += 3.0 * upd + idx
            c.flops += float(_num_elements(ins.type_str)) * 0  # combiner ~upd
            return c

        # data movement / structural ops: bytes only, at top level
        if top_level and op not in ("parameter", "constant", "tuple",
                                    "get-tuple-element", "bitcast"):
            c.bytes += self._io_bytes(ins, shapes)
        return c

    def _io_bytes(self, ins: Instr, shapes: dict[str, str]) -> float:
        total = float(_shape_bytes(ins.type_str))
        for o in ins.operands:
            total += float(_shape_bytes(shapes.get(o, "")))
        return total


def analyze(text: str) -> Cost:
    return Analyzer(text).cost()

"""Three-term roofline from a compiled dry-run artifact.

    compute   = HLO_FLOPs_per_chip / peak_FLOP/s
    memory    = HLO_bytes_per_chip / HBM_bw
    collective= collective_bytes_per_chip / ICI_link_bw

Source: ``repro.roofline.hlo_cost`` — our own HLO-text cost analysis.
XLA's ``compiled.cost_analysis()`` counts each instruction ONCE, so a
scan-over-96-layers reports one layer's flops (verified empirically, see
EXPERIMENTS.md); hlo_cost walks the computation tree and multiplies
``while`` bodies by their parsed trip counts. Collective bytes are likewise
trip-aware, per collective kind. The SPMD module is the per-device program,
so every number is per-chip. f32 dots are charged at half the bf16 MXU rate
(v5e).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (the assignment's numbers).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# one HLO result type, e.g. f32[128,7168]{1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction line: "%name = <type-or-tuple> <op>(" — op may be suffixed
# ("all-gather-start") which we still count once (ignore matching -done).
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind result bytes of every collective in the module."""
    out: dict[str, int] = {k: 0 for k in _COLL_OPS}
    out["count"] = 0
    for m in _INSTR_RE.finditer(hlo_text):
        type_str, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # paired with -start; count once
        out[op] += _shape_bytes(type_str)
        out["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict[str, int]
    model_flops: float | None = None
    memory_stats: dict | None = None
    matmul_flops_f32: float = 0.0
    matmul_flops_lp: float = 0.0

    @property
    def t_compute(self) -> float:
        """All flops at bf16 peak. Note: XLA:CPU upcasts bf16 dots to f32
        before the dot op, so dtype-splitting the CPU-compiled HLO would
        mis-charge the TPU target (where these dots run in bf16); the
        f32/lp split fields are informational only."""
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time = max of the three overlappable terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float | None:
        """MODEL_FLOPS / HLO_FLOPs (total over chips) — remat/dispatch waste."""
        if not self.model_flops:
            return None
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else None

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant term pins us to the compute roofline:
        T_compute / T_bound (1.0 = perfectly compute-bound)."""
        tb = self.t_bound
        return self.t_compute / tb if tb else 0.0

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "matmul_flops_f32": self.matmul_flops_f32,
            "matmul_flops_lp": self.matmul_flops_lp,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "memory_stats": self.memory_stats,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(
    arch: str, shape: str, mesh_name: str, chips: int,
    compiled, model_flops: float | None = None,
) -> Roofline:
    from repro.roofline import hlo_cost

    text = compiled.as_text()
    cost = hlo_cost.analyze(text)
    coll = {k: int(v) for k, v in cost.coll_bytes.items()}
    coll["count"] = int(cost.coll_count)
    mem = memory_stats(compiled)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=cost.flops, bytes_per_chip=cost.bytes,
        coll_bytes_per_chip=float(cost.coll_total),
        coll_breakdown=coll, model_flops=model_flops,
        memory_stats=mem,
        matmul_flops_f32=cost.matmul_flops_f32,
        matmul_flops_lp=cost.matmul_flops_lp,
    )


def memory_stats(compiled) -> dict | None:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out = {"repr": str(ma)[:500]}
    return out


def load_records(path: str) -> list[Roofline]:
    with open(path) as f:
        raw = json.load(f)
    out = []
    for r in raw:
        out.append(Roofline(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            chips=r["chips"], flops_per_chip=r["flops_per_chip"],
            bytes_per_chip=r["bytes_per_chip"],
            coll_bytes_per_chip=r["coll_bytes_per_chip"],
            coll_breakdown=r.get("coll_breakdown", {}),
            model_flops=r.get("model_flops"),
            memory_stats=r.get("memory_stats"),
            matmul_flops_f32=r.get("matmul_flops_f32", 0.0),
            matmul_flops_lp=r.get("matmul_flops_lp", 0.0),
        ))
    return out


def format_table(rows: list[Roofline]) -> str:
    hdr = (f"{'arch':22} {'shape':14} {'mesh':6} "
           f"{'T_comp(s)':>10} {'T_mem(s)':>10} {'T_coll(s)':>10} "
           f"{'bound':>10} {'useful':>7} {'roofl%':>7}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        uf = r.useful_flops_fraction
        lines.append(
            f"{r.arch:22} {r.shape:14} {r.mesh:6} "
            f"{r.t_compute:10.3e} {r.t_memory:10.3e} {r.t_collective:10.3e} "
            f"{r.bottleneck:>10} "
            f"{uf if uf is None else f'{uf:.2f}':>7} "
            f"{100*r.roofline_fraction:6.1f}%"
        )
    return "\n".join(lines)

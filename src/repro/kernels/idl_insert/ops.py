"""Host-side planner + wrapper for block-resident BF insertion.

plan_insert_rounds groups the (η, n) location grid by BF block and emits
ROUNDS: within one round every block id is unique, so the kernel can process
the whole round with zero write conflicts. IDL needs few blocks (locality!)
→ few, densely-packed rounds; RH touches ~every block once → many sparse
singleton tiles. The round structure is itself a locality measurement.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.idl_insert import kernel, ref


@dataclasses.dataclass
class InsertPlan:
    rounds: list[tuple[np.ndarray, np.ndarray]]  # [(block_ids (R,), offsets (R, C))]
    block_bits: int
    inserts_per_round: int
    n_locs: int

    @property
    def n_tiles(self) -> int:
        return sum(int(b.shape[0]) for b, _ in self.rounds)

    @property
    def dma_bytes(self) -> int:
        # read + write one tile per scheduled block
        return 2 * self.n_tiles * (self.block_bits // 8)


def plan_insert_rounds(
    locs: np.ndarray, block_bits: int, inserts_per_round: int = 128
) -> InsertPlan:
    flat = np.asarray(locs, dtype=np.int64).reshape(-1)
    c = inserts_per_round
    blocks = flat // block_bits
    offsets = (flat % block_bits).astype(np.int32)
    order = np.argsort(blocks, kind="stable")
    blocks_s = blocks[order]
    offsets_s = offsets[order]
    # segment boundaries per block
    uniq, starts = np.unique(blocks_s, return_index=True)
    ends = np.append(starts[1:], len(blocks_s))
    counts = ends - starts
    max_rounds = int(np.ceil(counts.max() / c)) if len(counts) else 0
    rounds = []
    for r in range(max_rounds):
        sel = counts > r * c
        bids = uniq[sel].astype(np.int32)
        offs = np.full((len(bids), c), -1, dtype=np.int32)
        for i, (s, e) in enumerate(zip(starts[sel], ends[sel])):
            lo = s + r * c
            hi = min(e, lo + c)
            offs[i, : hi - lo] = offsets_s[lo:hi]
        rounds.append((bids, offs))
    return InsertPlan(
        rounds=rounds, block_bits=block_bits,
        inserts_per_round=c, n_locs=len(flat),
    )


def insert_with_plan(
    bf_words: jax.Array, plan: InsertPlan, *, interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    block_words = plan.block_bits // 32
    for bids_np, offs_np in plan.rounds:
        bids = jnp.asarray(bids_np)
        offs = jnp.asarray(offs_np)
        if use_ref:
            tiles = ref.insert_round_ref(
                bf_words, bids, offs,
                block_words=block_words,
                inserts_per_round=plan.inserts_per_round,
            )
        else:
            tiles = kernel.insert_round(
                bf_words, bids, offs,
                block_words=block_words,
                inserts_per_round=plan.inserts_per_round,
                interpret=interpret,
            )
        bf_words = ref.apply_insert_to_words(bf_words, bids, tiles, block_words)
    return bf_words

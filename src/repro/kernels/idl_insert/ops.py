"""Host-side planners + wrappers for block-resident scatter-OR insertion.

Two generations of planner (mirroring kernel.py):

* :func:`plan_insert_rounds` (legacy) groups the (η, n) location grid by BF
  block and emits ROUNDS: within one round every block id is unique, so the
  kernel can process the whole round with zero write conflicts — but each
  round is its own launch.
* :func:`plan_insert_runs` — the vectorized planner behind
  ``repro.index.ingest``: the whole batch's (already flattened) bit
  positions are **sorted and deduplicated once** (np.unique), run-length
  encoded by matrix row-block in a handful of cumsum passes (the same
  technique as idl_probe.plan_probe_runs), and emitted as ONE kernel
  launch. Sorting makes runs of a block consecutive, so the kernel
  accumulates into a resident output tile (revisiting) and each touched
  block costs exactly one tile read + one tile write, however many runs
  land in it. IDL needs few blocks (locality!) → few tiles; RH touches
  ~every block once → many singleton tiles. The run/tile structure is
  itself a locality measurement.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.idl_insert import kernel, ref


@dataclasses.dataclass
class InsertPlan:
    rounds: list[tuple[np.ndarray, np.ndarray]]  # [(block_ids (R,), offsets (R, C))]
    block_bits: int
    inserts_per_round: int
    n_locs: int

    @property
    def n_tiles(self) -> int:
        return sum(int(b.shape[0]) for b, _ in self.rounds)

    @property
    def dma_bytes(self) -> int:
        # read + write one tile per scheduled block
        return 2 * self.n_tiles * (self.block_bits // 8)


def plan_insert_rounds(
    locs: np.ndarray, block_bits: int, inserts_per_round: int = 128
) -> InsertPlan:
    flat = np.asarray(locs, dtype=np.int64).reshape(-1)
    c = inserts_per_round
    blocks = flat // block_bits
    offsets = (flat % block_bits).astype(np.int32)
    order = np.argsort(blocks, kind="stable")
    blocks_s = blocks[order]
    offsets_s = offsets[order]
    # segment boundaries per block
    uniq, starts = np.unique(blocks_s, return_index=True)
    ends = np.append(starts[1:], len(blocks_s))
    counts = ends - starts
    max_rounds = int(np.ceil(counts.max() / c)) if len(counts) else 0
    rounds = []
    for r in range(max_rounds):
        sel = counts > r * c
        bids = uniq[sel].astype(np.int32)
        offs = np.full((len(bids), c), -1, dtype=np.int32)
        for i, (s, e) in enumerate(zip(starts[sel], ends[sel])):
            lo = s + r * c
            hi = min(e, lo + c)
            offs[i, : hi - lo] = offsets_s[lo:hi]
        rounds.append((bids, offs))
    return InsertPlan(
        rounds=rounds, block_bits=block_bits,
        inserts_per_round=c, n_locs=len(flat),
    )


_PAD_BLOCK = np.int32(np.iinfo(np.int32).max)  # never a real block id


@dataclasses.dataclass
class InsertRunPlan:
    """One-launch, sorted-run plan over a flattened (rows*W*32)-bit space."""

    block_ids: np.ndarray    # (R_pad,) int32 row-block per run, nondecreasing
    slot_ids: np.ndarray     # (R_pad,) int32 output tile slot, nondecreasing
    offsets: np.ndarray      # (R_pad, C) int32 tile bit offsets, -1 padded
    run_lengths: np.ndarray  # (n_runs,) int32 inserts per true run
                             # (precomputed at plan time so telemetry never
                             # re-reduces the (R_pad, C) offset matrix)
    uniq_blocks: np.ndarray  # (S_pad,) int32 touched blocks, sorted unique,
                             # padded with _PAD_BLOCK (dropped at write-back)
    n_locs: int              # deduplicated insert count
    n_runs: int              # true run count (before pow2 padding)
    n_tiles: int             # true touched-block count (before pow2 padding)
    block_bits: int          # bits per tile (rows_per_block * W * 32)
    inserts_per_run: int

    @property
    def n_slots(self) -> int:
        """Pow2-padded output tile count (the executor's static shape)."""
        return int(self.uniq_blocks.shape[0])

    @property
    def dma_bytes(self) -> int:
        # one tile read + one tile write per touched block, for the batch
        return 2 * self.n_tiles * (self.block_bits // 8)


def plan_insert_runs(
    flat_bits: np.ndarray, block_bits: int, inserts_per_run: int = 128
) -> InsertRunPlan | None:
    """Sort + dedup flat bit positions, run-length encode by block.

    ``flat_bits``: any-shape int array of global bit positions within the
    flattened matrix (``(row * W + word) * 32 + bit``); int64 on the host,
    so arbitrarily large matrices are fine. Negative positions are dropped
    (masked inserts). Returns None when nothing survives.

    Both data-dependent sizes are padded to powers of two so the
    executor's compile cache stays small: the run count (pad runs are
    all-pad lanes of the last block/slot — bit-exact no-ops) and the
    output tile count (pad slots carry the ``_PAD_BLOCK`` sentinel and
    are dropped by the write-back scatter).
    """
    flat = np.asarray(flat_bits, dtype=np.int64).reshape(-1)
    flat = np.unique(flat[flat >= 0])        # sorted + deduplicated
    n = int(flat.shape[0])
    if n == 0:
        return None
    c = inserts_per_run
    blocks = flat // block_bits
    idx = np.arange(n, dtype=np.int64)
    start = np.empty(n, dtype=bool)
    start[0] = True
    np.not_equal(blocks[1:], blocks[:-1], out=start[1:])
    pos_in_block = idx - np.maximum.accumulate(np.where(start, idx, 0))
    # new run at a block start or every C inserts (split long runs); block
    # keys are nondecreasing so a cumsum numbers runs and slots directly
    run = np.cumsum(start | (pos_in_block % c == 0)) - 1
    slot = np.cumsum(start) - 1
    n_runs = int(run[-1]) + 1
    r_pad = 1 << max(n_runs - 1, 1).bit_length()
    pos = pos_in_block % c

    offs = np.full((r_pad, c), -1, dtype=np.int32)
    offs[run, pos] = (flat % block_bits).astype(np.int32)
    uniq = blocks[start].astype(np.int32)
    bids = np.full(r_pad, uniq[-1], dtype=np.int32)
    bids[run] = blocks.astype(np.int32)
    sids = np.full(r_pad, len(uniq) - 1, dtype=np.int32)
    sids[run] = slot.astype(np.int32)
    n_tiles = len(uniq)
    s_pad = 1 << max(n_tiles - 1, 1).bit_length()
    uniq = np.concatenate(
        [uniq, np.full(s_pad - n_tiles, _PAD_BLOCK, dtype=np.int32)])

    return InsertRunPlan(
        block_ids=bids, slot_ids=sids, offsets=offs, uniq_blocks=uniq,
        run_lengths=np.bincount(run, minlength=n_runs).astype(np.int32),
        n_locs=n, n_runs=n_runs, n_tiles=n_tiles,
        block_bits=block_bits, inserts_per_run=c,
    )


def insert_planned(
    matrix: jax.Array, plan: InsertRunPlan | None, *,
    interpret: bool = True, use_ref: bool = False,
) -> jax.Array:
    """Execute a run plan against a packed (n_rows, W) matrix — ONE launch.

    The matrix buffer is donated: on accelerators the tile write-back is
    in-place. ``use_ref`` swaps the Pallas kernel for its fused jnp oracle
    (same plan, bit-identical — the executor on hosts without Mosaic).
    """
    if plan is None:
        return matrix
    w = int(matrix.shape[-1]) if matrix.ndim > 1 else 1
    if plan.block_bits % (w * 32):
        raise ValueError(
            f"block_bits={plan.block_bits} not a row multiple of W={w}")
    return _planned_insert(
        matrix,
        jnp.asarray(plan.block_ids), jnp.asarray(plan.slot_ids),
        jnp.asarray(plan.offsets), jnp.asarray(plan.uniq_blocks),
        rows_per_block=plan.block_bits // (w * 32),
        inserts_per_run=plan.inserts_per_run,
        n_tiles=plan.n_slots,
        row_words=w,
        interpret=interpret,
        use_ref=use_ref,
    )


@functools.partial(
    jax.jit,
    donate_argnums=(0,),
    static_argnames=("rows_per_block", "inserts_per_run", "n_tiles",
                     "row_words", "interpret", "use_ref"),
)
def _planned_insert(matrix, bids, sids, offs, uniq, *, rows_per_block,
                    inserts_per_run, n_tiles, row_words, interpret, use_ref):
    """One fused call: run the kernel (or ref) over all runs, then scatter
    the updated tiles back (slots are unique blocks — conflict-free)."""
    shape = matrix.shape
    matrix = jnp.reshape(matrix, (-1, row_words))
    if use_ref:
        tiles = ref.insert_runs_ref(
            matrix, bids, sids, offs,
            rows_per_block=rows_per_block, n_tiles=n_tiles,
        )
    else:
        tiles = kernel.insert_runs(
            matrix, bids, sids, offs,
            rows_per_block=rows_per_block,
            inserts_per_run=inserts_per_run,
            n_tiles=n_tiles,
            interpret=interpret,
        )
    return ref.apply_tiles_to_matrix(matrix, uniq, tiles).reshape(shape)


def insert_with_plan(
    bf_words: jax.Array, plan: InsertPlan, *, interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    block_words = plan.block_bits // 32
    for bids_np, offs_np in plan.rounds:
        bids = jnp.asarray(bids_np)
        offs = jnp.asarray(offs_np)
        if use_ref:
            tiles = ref.insert_round_ref(
                bf_words, bids, offs,
                block_words=block_words,
                inserts_per_round=plan.inserts_per_round,
            )
        else:
            tiles = kernel.insert_round(
                bf_words, bids, offs,
                block_words=block_words,
                inserts_per_round=plan.inserts_per_round,
                interpret=interpret,
            )
        bf_words = ref.apply_insert_to_words(bf_words, bids, tiles, block_words)
    return bf_words

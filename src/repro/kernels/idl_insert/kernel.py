"""Pallas TPU kernel: block-resident scatter-OR insert.

Indexing-side twin of idl_probe, in two generations:

* :func:`insert_round` — the original flat-BF kernel. The host planner
  groups insert locations by BF block such that **each block appears at
  most once per call** (rounds, see ops.plan_insert_rounds) — no
  read-after-write hazards, but one launch per round.
* :func:`insert_runs` — the generalized single-launch kernel behind
  ``repro.index.ingest``: inserts into an arbitrary packed ``(rows, W)``
  bit-matrix, planned as **sorted, deduplicated runs** (ops.plan_insert_runs).
  Runs of the same tile are consecutive, so the output tile is *revisited*:
  the first run of a tile initializes it from the resident input tile, the
  following runs OR into it while it stays in VMEM, and Pallas flushes it
  exactly once when the next tile begins. One tile read + one tile write
  per *touched block* for the whole batch, however many runs land in it.

Either way each grid step ORs in the bit-image of up to C insertions,
built MXU-natively from two one-hot matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _insert_kernel(
    block_ids_ref,   # scalar-prefetch (R,) int32
    offsets_ref,     # (1, C) int32, -1 padded
    bf_ref,          # (block_words,) uint32 resident tile
    out_ref,         # (1, block_words) uint32 updated tile
):
    del block_ids_ref
    words = bf_ref[:]
    out_ref[0, :] = words | _bit_image(offsets_ref[0, :], words.shape[0])


@functools.partial(
    jax.jit, static_argnames=("block_words", "inserts_per_round", "interpret")
)
def insert_round(
    bf_words: jax.Array,     # (n_words,) uint32
    block_ids: jax.Array,    # (R,) int32 — unique per call (planner guarantee)
    offsets: jax.Array,      # (R, C) int32, -1 padded
    *,
    block_words: int,
    inserts_per_round: int,
    interpret: bool = True,
) -> jax.Array:
    """Returns (R, block_words) updated tiles for the given blocks."""
    r = block_ids.shape[0]
    c = inserts_per_round
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i, bid: (i, 0)),
            pl.BlockSpec((block_words,), lambda i, bid: (bid[i],)),
        ],
        out_specs=pl.BlockSpec((1, block_words), lambda i, bid: (i, 0)),
    )
    return pl.pallas_call(
        _insert_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, block_words), jnp.uint32),
        interpret=interpret,
    )(block_ids, offsets, bf_words)


def _bit_image(offsets: jax.Array, n_words: int) -> jax.Array:
    """(C,) -1-padded bit offsets -> (n_words,) uint32 OR-image (MXU path)."""
    valid = offsets >= 0
    off = jnp.where(valid, offsets, 0)
    word_idx = (off >> 5).astype(jnp.int32)
    bit_idx = (off & 31).astype(jnp.int32)
    c = offsets.shape[0]
    row_onehot = (
        (word_idx[:, None]
         == jax.lax.broadcasted_iota(jnp.int32, (c, n_words), 1))
        & valid[:, None]
    ).astype(jnp.float32)                            # (C, NW)
    col_onehot = (
        bit_idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (c, 32), 1)
    ).astype(jnp.float32)                            # (C, 32)
    counts = jnp.dot(
        row_onehot.T, col_onehot, preferred_element_type=jnp.float32
    )                                                # (NW, 32)
    add_bits = (counts > 0.5).astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (n_words, 32), 1)
    return jnp.sum(add_bits << shifts, axis=1).astype(jnp.uint32)


def _insert_runs_kernel(
    block_ids_ref,   # scalar-prefetch (R,) int32 — matrix row-block per run
    slot_ids_ref,    # scalar-prefetch (R,) int32 — output tile slot per run
    offsets_ref,     # (1, C) int32 bit offsets within the tile, -1 padded
    mat_ref,         # (rows_per_block, W) uint32 resident input tile
    out_ref,         # (1, rows_per_block, W) uint32 accumulated output tile
):
    del block_ids_ref  # consumed by the index_map only
    i = pl.program_id(0)
    tile = mat_ref[...]                              # (RPB, W)
    rpb, w = tile.shape
    img = _bit_image(offsets_ref[0, :], rpb * w).reshape(rpb, w)

    # Runs are sorted by tile, so revisits are consecutive: initialize the
    # output tile on its first run, OR into the resident copy afterwards.
    first = (i == 0) | (slot_ids_ref[i] != slot_ids_ref[jnp.maximum(i - 1, 0)])

    @pl.when(first)
    def _():
        out_ref[0, :, :] = tile | img

    @pl.when(jnp.logical_not(first))
    def _():
        out_ref[0, :, :] = out_ref[0, :, :] | img


@functools.partial(
    jax.jit,
    static_argnames=("rows_per_block", "inserts_per_run", "n_tiles",
                     "interpret"),
)
def insert_runs(
    matrix: jax.Array,       # (n_rows, W) uint32 packed bit-matrix
    block_ids: jax.Array,    # (R,) int32 row-block id per run (nondecreasing)
    slot_ids: jax.Array,     # (R,) int32 output slot per run (nondecreasing)
    offsets: jax.Array,      # (R, C) int32 tile bit offsets, -1 padded
    *,
    rows_per_block: int,
    inserts_per_run: int,
    n_tiles: int,
    interpret: bool = True,
) -> jax.Array:
    """Run-coalesced scatter-OR into an arbitrary packed bit-matrix.

    One grid step per run; one ``(rows_per_block, W)`` input tile DMA per
    *touched block* (consecutive runs of a block reuse the resident output
    tile). Returns ``(n_tiles, rows_per_block, W)`` uint32 — the updated
    tile per touched block, for the caller to scatter back (slots are
    unique blocks, so the write-back is conflict-free).
    """
    r = block_ids.shape[0]
    c = inserts_per_run
    if offsets.shape != (r, c):
        raise ValueError(f"offsets shape {offsets.shape} != {(r, c)}")
    n_rows, w = matrix.shape
    if n_rows % rows_per_block:
        raise ValueError(
            f"n_rows={n_rows} must be a multiple of rows_per_block="
            f"{rows_per_block}"
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i, bid, sid: (i, 0)),
            pl.BlockSpec((rows_per_block, w), lambda i, bid, sid: (bid[i], 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, rows_per_block, w), lambda i, bid, sid: (sid[i], 0, 0)
        ),
    )
    return pl.pallas_call(
        _insert_runs_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_tiles, rows_per_block, w), jnp.uint32),
        interpret=interpret,
    )(block_ids, slot_ids, offsets, matrix)

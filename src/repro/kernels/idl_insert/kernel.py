"""Pallas TPU kernel: block-resident Bloom-filter insert (scatter-OR).

Indexing-side twin of idl_probe. The host planner groups insert locations by
BF block such that **each block appears at most once per call** (rounds, see
ops.plan_insert_rounds) — no read-after-write hazards. Each grid step DMAs
one resident tile, ORs in the bit-image of up to C insertions (built
MXU-natively from two one-hot matmuls), and emits the updated tile; the
wrapper block-scatters updated tiles back (conflict-free by construction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _insert_kernel(
    block_ids_ref,   # scalar-prefetch (R,) int32
    offsets_ref,     # (1, C) int32, -1 padded
    bf_ref,          # (block_words,) uint32 resident tile
    out_ref,         # (1, block_words) uint32 updated tile
):
    del block_ids_ref
    offsets = offsets_ref[0, :]
    valid = offsets >= 0
    off = jnp.where(valid, offsets, 0)
    word_idx = (off >> 5).astype(jnp.int32)
    bit_idx = (off & 31).astype(jnp.int32)

    words = bf_ref[:]
    w = words.shape[0]
    c = offsets.shape[0]
    # bit image of the insertions: counts (W, 32) = rows^T @ cols, then clip
    row_onehot = (
        (word_idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (c, w), 1))
        & valid[:, None]
    ).astype(jnp.float32)                            # (C, W)
    col_onehot = (
        bit_idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (c, 32), 1)
    ).astype(jnp.float32)                            # (C, 32)
    counts = jnp.dot(
        row_onehot.T, col_onehot, preferred_element_type=jnp.float32
    )                                                # (W, 32)
    add_bits = (counts > 0.5).astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (w, 32), 1)
    add_words = jnp.sum(add_bits << shifts, axis=1).astype(jnp.uint32)
    out_ref[0, :] = words | add_words


@functools.partial(
    jax.jit, static_argnames=("block_words", "inserts_per_round", "interpret")
)
def insert_round(
    bf_words: jax.Array,     # (n_words,) uint32
    block_ids: jax.Array,    # (R,) int32 — unique per call (planner guarantee)
    offsets: jax.Array,      # (R, C) int32, -1 padded
    *,
    block_words: int,
    inserts_per_round: int,
    interpret: bool = True,
) -> jax.Array:
    """Returns (R, block_words) updated tiles for the given blocks."""
    r = block_ids.shape[0]
    c = inserts_per_round
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i, bid: (i, 0)),
            pl.BlockSpec((block_words,), lambda i, bid: (bid[i],)),
        ],
        out_specs=pl.BlockSpec((1, block_words), lambda i, bid: (i, 0)),
    )
    return pl.pallas_call(
        _insert_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, block_words), jnp.uint32),
        interpret=interpret,
    )(block_ids, offsets, bf_words)

"""Pure-jnp oracle for the insert kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def insert_round_ref(
    bf_words: jax.Array,
    block_ids: jax.Array,
    offsets: jax.Array,
    *,
    block_words: int,
    inserts_per_round: int,
) -> jax.Array:
    """(R, block_words) updated tiles: tile OR bit-image of valid offsets."""
    del inserts_per_round
    r, c = offsets.shape
    valid = offsets >= 0
    off = jnp.where(valid, offsets, 0)
    word_idx = off >> 5                           # (R, C)
    bit_idx = (off & 31).astype(jnp.uint32)
    tiles = bf_words.reshape(-1, block_words)[block_ids]  # (R, W)
    # build OR image per run with a one-hot reduce (jnp, exact)
    onehot_words = (
        word_idx[:, :, None]
        == jnp.arange(block_words, dtype=jnp.int32)[None, None, :]
    ) & valid[:, :, None]                          # (R, C, W)
    contrib = jnp.where(
        onehot_words,
        (np.uint32(1) << bit_idx)[:, :, None].astype(jnp.uint32),
        np.uint32(0),
    )
    img = jax.lax.reduce(
        contrib, np.uint32(0), jax.lax.bitwise_or, dimensions=(1,)
    )                                              # (R, W)
    return tiles | img


def apply_insert_to_words(
    bf_words: jax.Array, block_ids: jax.Array, tiles: jax.Array,
    block_words: int,
) -> jax.Array:
    """Scatter updated tiles back (block ids unique per call)."""
    blocks = bf_words.reshape(-1, block_words)
    return blocks.at[block_ids].set(tiles).reshape(-1)


def insert_locations_packed_ref(bf_words: jax.Array, locs: jax.Array) -> jax.Array:
    """Direct packed insert oracle via the unpacked representation."""
    from repro.core import bloom

    bits = bloom.unpack_bits(bf_words)
    bits = bits.at[locs.reshape(-1)].set(np.uint8(1))
    return bloom.pack_bits(bits)

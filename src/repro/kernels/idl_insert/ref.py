"""Pure-jnp oracle for the insert kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def insert_round_ref(
    bf_words: jax.Array,
    block_ids: jax.Array,
    offsets: jax.Array,
    *,
    block_words: int,
    inserts_per_round: int,
) -> jax.Array:
    """(R, block_words) updated tiles: tile OR bit-image of valid offsets."""
    del inserts_per_round
    r, c = offsets.shape
    valid = offsets >= 0
    off = jnp.where(valid, offsets, 0)
    word_idx = off >> 5                           # (R, C)
    bit_idx = (off & 31).astype(jnp.uint32)
    tiles = bf_words.reshape(-1, block_words)[block_ids]  # (R, W)
    # build OR image per run with a one-hot reduce (jnp, exact)
    onehot_words = (
        word_idx[:, :, None]
        == jnp.arange(block_words, dtype=jnp.int32)[None, None, :]
    ) & valid[:, :, None]                          # (R, C, W)
    contrib = jnp.where(
        onehot_words,
        (np.uint32(1) << bit_idx)[:, :, None].astype(jnp.uint32),
        np.uint32(0),
    )
    img = jax.lax.reduce(
        contrib, np.uint32(0), jax.lax.bitwise_or, dimensions=(1,)
    )                                              # (R, W)
    return tiles | img


def apply_insert_to_words(
    bf_words: jax.Array, block_ids: jax.Array, tiles: jax.Array,
    block_words: int,
) -> jax.Array:
    """Scatter updated tiles back (block ids unique per call)."""
    blocks = bf_words.reshape(-1, block_words)
    return blocks.at[block_ids].set(tiles).reshape(-1)


def insert_runs_ref(
    matrix: jax.Array,
    block_ids: jax.Array,
    slot_ids: jax.Array,
    offsets: jax.Array,
    *,
    rows_per_block: int,
    n_tiles: int,
) -> jax.Array:
    """(n_tiles, rows_per_block, W) updated tiles — oracle for insert_runs.

    Relies on the planner's invariant that deduplicated runs never repeat a
    (tile, offset) pair, so accumulating each lane's single-bit word into
    its slot-local word with a scatter-add equals the OR the kernel
    computes (add == OR on disjoint bits). Memory is exactly the touched
    tiles, so this doubles as the CPU executor for production-size plans.
    """
    w = matrix.shape[1]
    nw = rows_per_block * w
    valid = offsets >= 0
    off = jnp.where(valid, offsets, 0)
    slot_word = slot_ids[:, None] * nw + (off >> 5)    # (R, C) flat word
    bit = jnp.where(
        valid, jnp.uint32(1) << (off & 31).astype(jnp.uint32), np.uint32(0))
    acc = jnp.zeros((n_tiles * nw,), dtype=jnp.uint32)
    acc = acc.at[slot_word.reshape(-1)].add(bit.reshape(-1), mode="drop")
    # base tile per slot: every run of a slot names the same block, so a
    # scatter-max of run block ids recovers the slot -> block map in-graph
    slot_block = jnp.zeros((n_tiles,), dtype=jnp.int32).at[slot_ids].max(
        block_ids, mode="drop")
    base = matrix.reshape(-1, nw)[slot_block]          # (S, NW)
    return (base | acc.reshape(n_tiles, nw)).reshape(
        n_tiles, rows_per_block, w)


def apply_tiles_to_matrix(
    matrix: jax.Array, uniq_blocks: jax.Array, tiles: jax.Array
) -> jax.Array:
    """Scatter updated (S_pad, RPB, W) tiles back.

    Real block ids are unique per plan (conflict-free); pad slots carry an
    out-of-range sentinel block and their (never-written) tiles are
    dropped by the scatter.
    """
    n_rows, w = matrix.shape
    rpb = tiles.shape[1]
    blocks = matrix.reshape(-1, rpb, w)
    return blocks.at[uniq_blocks].set(tiles, mode="drop").reshape(n_rows, w)


def insert_locations_packed_ref(bf_words: jax.Array, locs: jax.Array) -> jax.Array:
    """Direct packed insert oracle via the unpacked representation."""
    from repro.core import bloom

    bits = bloom.unpack_bits(bf_words)
    bits = bits.at[locs.reshape(-1)].set(np.uint8(1))
    return bloom.pack_bits(bits)

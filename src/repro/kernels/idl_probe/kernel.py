"""Pallas TPU kernel: block-resident Bloom-filter probe.

The TPU translation of the paper's cache-locality argument (DESIGN.md §2):

* The BF lives packed (uint32 words) in HBM — far too big for VMEM.
* The host scheduler (ops.plan_probe_runs) run-length-encodes the probe
  stream by BF *block* (block = the IDL locality window L). IDL makes runs
  long (mean ≈ 1/(1−J) kmers); RH makes every probe its own run.
* Grid = one step per run. A scalar-prefetch array holds each run's block
  id; the BlockSpec index_map consumes it, so Pallas DMAs exactly ONE
  L-sized BF tile from HBM per run and double-buffers the next tile while
  the current one is probed. HBM traffic = n_runs × block_bytes — the
  quantity IDL minimizes.
* Within a resident tile the probe gather is done MXU-natively with two
  one-hot matmuls (word-row pick, then bit-column pick) — no scalar loads,
  no unsupported vector gathers.

All lanes are uint32/int32/float32 (TPU has no 64-bit integer lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _probe_kernel(
    block_ids_ref,   # scalar-prefetch: (R,) int32 — BF block id per run
    offsets_ref,     # (1, C) int32 — bit offsets within the block (-1 = pad)
    bf_ref,          # (block_words,) uint32 — the resident BF tile (VMEM)
    out_ref,         # (1, C) int32 — probed bit per lane (pad lanes = 1)
):
    del block_ids_ref  # consumed by the index_map only
    offsets = offsets_ref[0, :]                      # (C,)
    valid = offsets >= 0
    off = jnp.where(valid, offsets, 0)
    word_idx = (off >> 5).astype(jnp.int32)          # word within block
    bit_idx = (off & 31).astype(jnp.int32)

    words = bf_ref[:]                                # (W,) uint32
    w = words.shape[0]
    # unpack words -> (W, 32) bit image {0,1} (vector shifts, no gather)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (w, 32), 1)
    bits2d = ((words[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)

    c = offsets.shape[0]
    # gather via two one-hot matmuls (MXU-native)
    row_onehot = (
        word_idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (c, w), 1)
    ).astype(jnp.float32)                            # (C, W)
    picked_rows = jnp.dot(
        row_onehot, bits2d, preferred_element_type=jnp.float32
    )                                                # (C, 32)
    col_onehot = (
        bit_idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (c, 32), 1)
    ).astype(jnp.float32)
    bit = jnp.sum(picked_rows * col_onehot, axis=1)  # (C,)
    out_ref[0, :] = jnp.where(valid, bit.astype(jnp.int32), 1)


def _probe_rows_kernel(
    block_ids_ref,   # scalar-prefetch: (R,) int32 — matrix row-block per run
    offsets_ref,     # (1, C) int32 — row offsets within the block (-1 = pad)
    mat_ref,         # (rows_per_block, W) uint32 — the resident tile (VMEM)
    out_ref,         # (1, C, W) uint32 — gathered row per lane
):
    del block_ids_ref  # consumed by the index_map only
    offsets = offsets_ref[0, :]                      # (C,)
    off = jnp.where(offsets >= 0, offsets, 0)        # pad lanes read row 0

    tile = mat_ref[...]                              # (RPB, W) uint32
    rpb, w = tile.shape
    # unpack the tile -> (RPB, W*32) bit image {0,1} (vector shifts only)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (rpb, w, 32), 2)
    bits = ((tile[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    bits2d = bits.reshape(rpb, w * 32)

    c = offsets.shape[0]
    # row gather via one one-hot matmul (MXU-native; {0,1} values are exact
    # in f32), then an integer repack — f32 cannot hold full uint32 words
    row_onehot = (
        off[:, None] == jax.lax.broadcasted_iota(jnp.int32, (c, rpb), 1)
    ).astype(jnp.float32)                            # (C, RPB)
    picked = jnp.dot(
        row_onehot, bits2d, preferred_element_type=jnp.float32
    )                                                # (C, W*32) {0,1}
    picked = picked.reshape(c, w, 32).astype(jnp.uint32)
    sh = jax.lax.broadcasted_iota(jnp.uint32, (c, w, 32), 2)
    out_ref[0, :, :] = jnp.sum(picked << sh, axis=2, dtype=jnp.uint32)


@functools.partial(
    jax.jit, static_argnames=("rows_per_block", "probes_per_run", "interpret")
)
def probe_rows(
    matrix: jax.Array,       # (n_rows, W) uint32 packed bit-matrix
    block_ids: jax.Array,    # (R,) int32 row-block id per run
    offsets: jax.Array,      # (R, C) int32 row offset in block, -1 padded
    *,
    rows_per_block: int,
    probes_per_run: int,
    interpret: bool = True,
) -> jax.Array:
    """Run-coalesced row gather over an arbitrary packed bit-matrix.

    The generalization of :func:`probe_runs` that serves every engine: one
    grid step per run, one ``(rows_per_block, W)`` tile DMA'd per step (the
    next tile double-buffers while the current one is probed), C row
    gathers resolved MXU-natively inside the resident tile. Returns
    ``(R, C, W)`` uint32 — the W-word row each probe hit (pad lanes
    replicate row 0 of their block and must be masked by the caller's
    ``probe_index`` scatter).
    """
    r = block_ids.shape[0]
    c = probes_per_run
    if offsets.shape != (r, c):
        raise ValueError(f"offsets shape {offsets.shape} != {(r, c)}")
    n_rows, w = matrix.shape
    if n_rows % rows_per_block:
        raise ValueError(
            f"n_rows={n_rows} must be a multiple of rows_per_block="
            f"{rows_per_block}"
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i, bid: (i, 0)),
            pl.BlockSpec((rows_per_block, w), lambda i, bid: (bid[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, c, w), lambda i, bid: (i, 0, 0)),
    )
    return pl.pallas_call(
        _probe_rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, c, w), jnp.uint32),
        interpret=interpret,
    )(block_ids, offsets, matrix)


@functools.partial(
    jax.jit, static_argnames=("block_words", "probes_per_run", "interpret")
)
def probe_runs(
    bf_words: jax.Array,     # (n_words,) uint32 packed BF
    block_ids: jax.Array,    # (R,) int32
    offsets: jax.Array,      # (R, C) int32, -1 padded
    *,
    block_words: int,
    probes_per_run: int,
    interpret: bool = True,
) -> jax.Array:
    """Returns (R, C) int32 probed bits (pad lanes read as 1)."""
    r = block_ids.shape[0]
    c = probes_per_run
    if offsets.shape != (r, c):
        raise ValueError(f"offsets shape {offsets.shape} != {(r, c)}")
    if bf_words.shape[0] % block_words:
        raise ValueError("bf length must be a multiple of block_words")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i, bid: (i, 0)),
            pl.BlockSpec((block_words,), lambda i, bid: (bid[i],)),
        ],
        out_specs=pl.BlockSpec((1, c), lambda i, bid: (i, 0)),
    )
    return pl.pallas_call(
        _probe_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int32),
        interpret=interpret,
    )(block_ids, offsets, bf_words)

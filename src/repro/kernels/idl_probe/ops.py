"""jit wrapper + host-side probe planner for the IDL probe kernel.

The planner is the "scheduler" half of the design (vLLM-style): it
run-length-encodes the probe stream by BF block and emits fixed-shape,
kernel-ready arrays. It runs on the host (numpy) exactly like a serving
scheduler builds block tables — data-dependent sizing lives here, the device
kernel sees static shapes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.idl_probe import kernel, ref


@dataclasses.dataclass
class ProbePlan:
    block_ids: np.ndarray    # (R,) int32
    offsets: np.ndarray      # (R, C) int32, -1 padded
    probe_index: np.ndarray  # (R, C) int32 position in flattened (η·n) stream
    n_probes: int
    eta: int
    n_keys: int
    block_bits: int
    probes_per_run: int

    @property
    def n_runs(self) -> int:
        return int(self.block_ids.shape[0])

    @property
    def dma_bytes(self) -> int:
        return self.n_runs * (self.block_bits // 8)


def plan_probe_runs(
    locs: np.ndarray, block_bits: int, probes_per_run: int = 128
) -> ProbePlan:
    """Run-length-encode (η, n) bit locations into block-resident runs.

    Rows (hash repetitions) are planned independently and concatenated, so a
    run never crosses repetitions. Runs longer than C are split.
    """
    locs = np.asarray(locs, dtype=np.int64)
    if locs.ndim == 1:
        locs = locs[None, :]
    eta, n = locs.shape
    c = probes_per_run

    all_bids, all_offs, all_pidx = [], [], []
    for j in range(eta):
        row = locs[j]
        blocks = row // block_bits
        # run starts: first element or block change
        start = np.empty(n, dtype=bool)
        start[0] = True
        np.not_equal(blocks[1:], blocks[:-1], out=start[1:])
        run_id = np.cumsum(start) - 1
        # split runs longer than C
        pos_in_run = np.arange(n) - np.maximum.accumulate(
            np.where(start, np.arange(n), 0)
        )
        sub = pos_in_run // c
        key = run_id * (n // c + 2) + sub
        _, seg = np.unique(key, return_inverse=True)
        n_runs = seg.max() + 1 if n else 0
        pos = pos_in_run % c
        offs = np.full((n_runs, c), -1, dtype=np.int32)
        pidx = np.full((n_runs, c), -1, dtype=np.int32)
        offs[seg, pos] = (row % block_bits).astype(np.int32)
        pidx[seg, pos] = (j * n + np.arange(n)).astype(np.int32)
        bids = np.zeros(n_runs, dtype=np.int32)
        bids[seg] = blocks.astype(np.int32)
        all_bids.append(bids)
        all_offs.append(offs)
        all_pidx.append(pidx)

    return ProbePlan(
        block_ids=np.concatenate(all_bids),
        offsets=np.concatenate(all_offs),
        probe_index=np.concatenate(all_pidx),
        n_probes=eta * n,
        eta=eta,
        n_keys=n,
        block_bits=block_bits,
        probes_per_run=c,
    )


def probe_membership(
    bf_words: jax.Array, plan: ProbePlan, *, interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    """Execute the plan; return (n_keys,) bool membership (AND over η)."""
    block_words = plan.block_bits // 32
    args = (
        bf_words,
        jnp.asarray(plan.block_ids),
        jnp.asarray(plan.offsets),
    )
    if use_ref:
        bits = ref.probe_runs_ref(
            *args, block_words=block_words, probes_per_run=plan.probes_per_run
        )
    else:
        bits = kernel.probe_runs(
            *args,
            block_words=block_words,
            probes_per_run=plan.probes_per_run,
            interpret=interpret,
        )
    return scatter_and_reduce(bits, plan)


def scatter_and_reduce(bits: jax.Array, plan: ProbePlan) -> jax.Array:
    """(R, C) run bits -> (n_keys,) membership via the plan's probe_index."""
    flat = jnp.ones((plan.n_probes,), dtype=jnp.int32)
    idx = jnp.asarray(plan.probe_index.reshape(-1))
    vals = bits.reshape(-1)
    # pad lanes are routed out of range and dropped by the scatter
    idx = jnp.where(idx >= 0, idx, plan.n_probes)
    flat = flat.at[idx].set(vals, mode="drop")
    per_rep = flat.reshape(plan.eta, plan.n_keys)
    return jnp.all(per_rep == 1, axis=0)

"""jit wrapper + host-side probe planner for the IDL probe kernel.

The planner is the "scheduler" half of the design (vLLM-style): it
run-length-encodes the probe stream by BF block and emits fixed-shape,
kernel-ready arrays. It runs on the host (numpy) exactly like a serving
scheduler builds block tables — data-dependent sizing lives here, the device
kernel sees static shapes.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.idl_probe import kernel, ref


@dataclasses.dataclass
class ProbePlan:
    block_ids: np.ndarray    # (R,) int32
    offsets: np.ndarray      # (R, C) int32, -1 padded
    run_lengths: np.ndarray  # (R,) int32 probes per run (== row-wise count
                             # of valid offsets, precomputed at plan time
                             # so telemetry never re-reduces the (R, C)
                             # offset matrix)
    probe_index: np.ndarray  # (R, C) int32 position in flattened (η·n) stream
    gather_index: np.ndarray # (n_probes,) int32 flat (run, lane) per probe —
                             # the inverse of probe_index, so executors can
                             # realign with a cheap gather instead of a
                             # scatter over padded lanes
    n_probes: int
    eta: int
    n_keys: int
    block_bits: int
    probes_per_run: int

    @property
    def n_runs(self) -> int:
        return int(self.block_ids.shape[0])
    # NOTE: per-run DMA bytes depend on the probed matrix's row width,
    # which the plan does not know — see QueryPlan.run_dma_bytes.


def plan_probe_runs(
    locs: np.ndarray, block_bits: int, probes_per_run: int = 128
) -> ProbePlan:
    """Run-length-encode (P, n) probe streams into block-resident runs.

    ``locs`` may be bit locations (``block_bits`` = bits per block, the
    original flat-BF use) or matrix row indices (``block_bits`` = rows per
    block — the generalized ``probe_rows`` path); the arithmetic is
    identical. Leading rows (hash repetitions, or batch × η streams) are
    planned independently and concatenated, so a run never crosses streams.
    Runs longer than C are split.
    """
    locs = np.asarray(locs, dtype=np.int64)
    if locs.ndim == 1:
        locs = locs[None, :]
    p, n = locs.shape
    c = probes_per_run

    # Vectorized over ALL streams at once (no per-stream Python loop): the
    # whole (P, n) probe stream is planned in a handful of cumsum passes,
    # which is what lets a (B·η, n_kmers) batch plan in ~ms on the host.
    flat = locs.reshape(-1)
    blocks = flat // block_bits
    idx = np.arange(p * n, dtype=np.int64)
    start = np.empty(p * n, dtype=bool)
    start[0] = True
    np.not_equal(blocks[1:], blocks[:-1], out=start[1:])
    start[:: n] = True                       # a run never crosses streams
    pos_in_run = idx - np.maximum.accumulate(np.where(start, idx, 0))
    # new segment at a run start or every C probes (split long runs); run
    # keys are nondecreasing along the stream so a cumsum IS the inverse
    # np.unique used to compute
    seg = np.cumsum(start | (pos_in_run % c == 0)) - 1
    n_runs = int(seg[-1]) + 1
    pos = pos_in_run % c

    offs = np.full((n_runs, c), -1, dtype=np.int32)
    pidx = np.full((n_runs, c), -1, dtype=np.int32)
    offs[seg, pos] = (flat % block_bits).astype(np.int32)
    pidx[seg, pos] = idx.astype(np.int32)
    bids = np.zeros(n_runs, dtype=np.int32)
    bids[seg] = blocks.astype(np.int32)

    return ProbePlan(
        block_ids=bids,
        offsets=offs,
        run_lengths=np.bincount(seg, minlength=n_runs).astype(np.int32),
        probe_index=pidx,
        gather_index=(seg * c + pos).astype(np.int32),
        n_probes=p * n,
        eta=p,
        n_keys=n,
        block_bits=block_bits,
        probes_per_run=c,
    )


def probe_membership(
    bf_words: jax.Array, plan: ProbePlan, *, interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    """Execute the plan; return (n_keys,) bool membership (AND over η)."""
    block_words = plan.block_bits // 32
    args = (
        bf_words,
        jnp.asarray(plan.block_ids),
        jnp.asarray(plan.offsets),
    )
    if use_ref:
        bits = ref.probe_runs_ref(
            *args, block_words=block_words, probes_per_run=plan.probes_per_run
        )
    else:
        bits = kernel.probe_runs(
            *args,
            block_words=block_words,
            probes_per_run=plan.probes_per_run,
            interpret=interpret,
        )
    return scatter_and_reduce(bits, plan)


def gather_planned_rows(
    matrix: jax.Array, plan: ProbePlan, *, interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    """Execute a row plan; return (n_probes, W) uint32 rows in probe order.

    ``plan.block_bits`` is interpreted as rows-per-block. ``use_ref`` swaps
    the Pallas kernel for the fused pure-jnp oracle — same plan, same
    result; the default executor on hosts without a Mosaic target.

    The run count is padded to a power of two (pad runs are all-pad lanes
    of block 0) so the executor's compile cache stays small even though
    the true run count is data-dependent.
    """
    r = plan.n_runs
    r_pad = 1 << max(r - 1, 1).bit_length()
    bids = np.zeros((r_pad,), dtype=np.int32)
    bids[:r] = plan.block_ids
    offs = np.full((r_pad, plan.probes_per_run), -1, dtype=np.int32)
    offs[:r] = plan.offsets
    return _planned_gather(
        matrix, jnp.asarray(bids), jnp.asarray(offs),
        jnp.asarray(plan.gather_index),
        rows_per_block=plan.block_bits,
        probes_per_run=plan.probes_per_run,
        row_words=int(matrix.shape[-1]) if matrix.ndim > 1 else 1,
        interpret=interpret,
        use_ref=use_ref,
    )


@functools.partial(jax.jit, static_argnames=(
    "rows_per_block", "probes_per_run", "row_words", "interpret", "use_ref",
))
def _planned_gather(matrix, bids, offs, gidx, *, rows_per_block,
                    probes_per_run, row_words, interpret, use_ref):
    """One fused call: run the kernel (or ref), then realign to probe order
    with the plan's precomputed inverse permutation (a cheap gather — pad
    lanes are never referenced by ``gather_index``)."""
    matrix = jnp.reshape(matrix, (-1, row_words))
    if use_ref:
        runs = ref.probe_rows_ref(
            matrix, bids, offs, rows_per_block=rows_per_block)
    else:
        runs = kernel.probe_rows(
            matrix, bids, offs, rows_per_block=rows_per_block,
            probes_per_run=probes_per_run, interpret=interpret,
        )
    return runs.reshape(-1, row_words)[gidx]


def scatter_and_reduce(bits: jax.Array, plan: ProbePlan) -> jax.Array:
    """(R, C) run bits -> (n_keys,) membership via the plan's probe_index."""
    flat = jnp.ones((plan.n_probes,), dtype=jnp.int32)
    idx = jnp.asarray(plan.probe_index.reshape(-1))
    vals = bits.reshape(-1)
    # pad lanes are routed out of range and dropped by the scatter
    idx = jnp.where(idx >= 0, idx, plan.n_probes)
    flat = flat.at[idx].set(vals, mode="drop")
    per_rep = flat.reshape(plan.eta, plan.n_keys)
    return jnp.all(per_rep == 1, axis=0)

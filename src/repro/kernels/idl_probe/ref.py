"""Pure-jnp oracle for the probe kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def probe_runs_ref(
    bf_words: jax.Array,
    block_ids: jax.Array,
    offsets: jax.Array,
    *,
    block_words: int,
    probes_per_run: int,
) -> jax.Array:
    """(R, C) int32 bits; pad lanes (offset < 0) read as 1."""
    del probes_per_run
    valid = offsets >= 0
    off = jnp.where(valid, offsets, 0)
    global_word = block_ids[:, None] * block_words + (off >> 5)
    bit_idx = (off & 31).astype(jnp.uint32)
    words = bf_words[global_word]
    bit = ((words >> bit_idx) & np.uint32(1)).astype(jnp.int32)
    return jnp.where(valid, bit, 1)


def probe_rows_ref(
    matrix: jax.Array,
    block_ids: jax.Array,
    offsets: jax.Array,
    *,
    rows_per_block: int,
) -> jax.Array:
    """(R, C, W) uint32 gathered rows; pad lanes (offset < 0) read row 0."""
    off = jnp.where(offsets >= 0, offsets, 0)
    rows = block_ids[:, None] * rows_per_block + off
    return matrix[rows]


def query_membership_ref(bf_words: jax.Array, locs: jax.Array) -> jax.Array:
    """Direct packed query on (η, n) locations (matches core.bloom.query_packed)."""
    word_idx = (locs >> np.uint32(5)).astype(jnp.int32)
    bit = locs & np.uint32(31)
    got = (bf_words[word_idx] >> bit) & np.uint32(1)
    return jnp.all(got == np.uint32(1), axis=0)

"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel package: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper + host-side planning), ref.py (pure-jnp oracle).
Validated in interpret=True mode on CPU; written for TPU as the target
(32-bit lanes only, MXU-friendly gathers, scalar-prefetch DMA scheduling).
"""

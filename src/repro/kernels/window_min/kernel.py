"""Pallas TPU kernel: sliding-window minimum (rolling MinHash core).

TPU-native replacement for the paper's segment tree (DESIGN.md §2): for the
small windows used by gene search (w = k − t + 1 ≤ 16) the cheapest
branch-free form is w shifted vector-mins per tile — pure VPU work, fully
pipelined with the tile DMAs. Tiles need a (w−1)-element halo; Pallas blocks
don't overlap, so the input is passed twice with index_maps i and i+1 and
the kernel stitches the halo from the start of the next tile.

Lanes are uint32 (TPU target — see hashing.hash_pair32); the pure-jnp
reference path (core.minhash.sliding_window_min) keeps exact uint64 paper
semantics on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _window_min_kernel(a_ref, nxt_ref, out_ref, *, w: int):
    tile = a_ref[:]                 # (T,)
    halo = nxt_ref[: w - 1] if w > 1 else nxt_ref[:0]
    ext = jnp.concatenate([tile, halo])   # (T + w - 1,)
    t = tile.shape[0]
    acc = jax.lax.dynamic_slice(ext, (0,), (t,))
    for s in range(1, w):           # static unroll, w <= 16: w-1 vector mins
        acc = jnp.minimum(acc, jax.lax.dynamic_slice(ext, (s,), (t,)))
    out_ref[:] = acc


@functools.partial(jax.jit, static_argnames=("w", "tile", "interpret"))
def window_min(
    a: jax.Array, *, w: int, tile: int = 1024, interpret: bool = True
) -> jax.Array:
    """out[i] = min(a[i : i + w]) for all n − w + 1 windows.

    a: (n,) uint32 (or any orderable 32-bit dtype).
    """
    n = a.shape[0]
    if n < w:
        raise ValueError(f"length {n} < window {w}")
    if w > tile:
        raise ValueError(f"window {w} must fit in a tile ({tile})")
    out_len = n - w + 1
    fill = jnp.iinfo(a.dtype).max if jnp.issubdtype(a.dtype, jnp.integer) else jnp.inf
    # pad to a whole number of tiles PLUS one extra tile so the "next tile"
    # operand of the last step is in-bounds.
    n_tiles = -(-n // tile)
    padded = (n_tiles + 1) * tile
    ap = jnp.concatenate([a, jnp.full((padded - n,), fill, dtype=a.dtype)])

    out = pl.pallas_call(
        functools.partial(_window_min_kernel, w=w),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i + 1,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * tile,), a.dtype),
        interpret=interpret,
    )(ap, ap)
    return out[:out_len]

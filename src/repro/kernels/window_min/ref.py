"""Pure-jnp oracle for window_min: the Gil-Werman core + a naive check."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.minhash import sliding_window_min


def window_min_ref(a: jax.Array, *, w: int) -> jax.Array:
    return sliding_window_min(a, w)


def window_min_naive(a: jax.Array, *, w: int) -> jax.Array:
    n = a.shape[0]
    return jnp.stack([a[i : i + w].min() for i in range(n - w + 1)])

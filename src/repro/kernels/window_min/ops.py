"""jit wrapper for the window_min kernel with CPU fallback."""

from __future__ import annotations

import jax

from repro.kernels.window_min import kernel, ref


def window_min(a: jax.Array, w: int, *, use_kernel: bool = True,
               interpret: bool = True, tile: int = 1024) -> jax.Array:
    if not use_kernel or a.shape[0] < w + 1:
        return ref.window_min_ref(a, w=w)
    return kernel.window_min(a, w=w, tile=tile, interpret=interpret)

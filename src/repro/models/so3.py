"""Real spherical-harmonic rotation matrices (Wigner D for real SH).

Ivanic & Ruedenberg recursion (J. Phys. Chem. 1996 + 1998 erratum): builds
R^l (the (2l+1)x(2l+1) rotation acting on real SH coefficients of degree l)
from R^{l-1} and the l=1 matrix. All loops are static Python over (l, m, n);
every emitted op is vectorized over the edge batch — this is the
irrep-rotation half of the eSCN trick (rotate each edge to the z-axis so the
tensor-product convolution becomes a cheap SO(2) m-channel mix).

Index convention: R^l[..., m + l, n + l], m,n in [-l, l]. The l=1 real-SH
basis order is (y, z, x), i.e. m = (-1, 0, 1).
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np


def rotation_to_z(edge_vec: jax.Array, eps: float = 1e-9) -> jax.Array:
    """Per-edge 3x3 rotation M with M @ d_hat = z_hat.

    edge_vec: (E, 3). Returns (E, 3, 3) with rows = new (x', y', z'=d_hat)
    axes — branchless reference-vector selection avoids the polar singularity.
    """
    d = edge_vec / (jnp.linalg.norm(edge_vec, axis=-1, keepdims=True) + eps)
    near_z = jnp.abs(d[..., 2:3]) > 0.9
    ref = jnp.where(
        near_z,
        jnp.asarray([1.0, 0.0, 0.0], edge_vec.dtype),
        jnp.asarray([0.0, 0.0, 1.0], edge_vec.dtype),
    )
    x_ax = jnp.cross(ref, d)
    x_ax = x_ax / (jnp.linalg.norm(x_ax, axis=-1, keepdims=True) + eps)
    y_ax = jnp.cross(d, x_ax)
    return jnp.stack([x_ax, y_ax, d], axis=-2)  # rows


def _r1_from_matrix(m3: jax.Array) -> jax.Array:
    """3x3 rotation (xyz basis) -> R^1 in real-SH order (y, z, x)."""
    perm = np.array([1, 2, 0])
    return m3[..., perm[:, None], perm[None, :]]


@lru_cache(maxsize=None)
def _uvw(l: int, m: int, n: int) -> tuple[float, float, float]:
    denom = (l + n) * (l - n) if abs(n) < l else (2 * l) * (2 * l - 1)
    u = math.sqrt((l + m) * (l - m) / denom)
    dm0 = 1.0 if m == 0 else 0.0
    v = 0.5 * math.sqrt(
        (1.0 + dm0) * (l + abs(m) - 1) * (l + abs(m)) / denom
    ) * (1.0 - 2.0 * dm0)
    w = -0.5 * math.sqrt((l - abs(m) - 1) * (l - abs(m)) / denom) * (1.0 - dm0)
    return u, v, w


def _p(i: int, l: int, a: int, b: int, r1, rlm1):
    """Helper P_i^{a,b} of the recursion (vectorized over leading dims)."""
    if b == l:
        return (
            r1[..., i + 1, 2] * rlm1[..., a + l - 1, 2 * l - 2]
            - r1[..., i + 1, 0] * rlm1[..., a + l - 1, 0]
        )
    if b == -l:
        return (
            r1[..., i + 1, 2] * rlm1[..., a + l - 1, 0]
            + r1[..., i + 1, 0] * rlm1[..., a + l - 1, 2 * l - 2]
        )
    return r1[..., i + 1, 1] * rlm1[..., a + l - 1, b + l - 1]


def _u_fn(l, m, n, r1, rlm1):
    return _p(0, l, m, n, r1, rlm1)


def _v_fn(l, m, n, r1, rlm1):
    if m == 0:
        return _p(1, l, 1, n, r1, rlm1) + _p(-1, l, -1, n, r1, rlm1)
    if m > 0:
        s = math.sqrt(2.0) if m == 1 else 1.0
        out = _p(1, l, m - 1, n, r1, rlm1) * s
        if m != 1:
            out = out - _p(-1, l, -m + 1, n, r1, rlm1)
        return out
    s = math.sqrt(2.0) if m == -1 else 1.0
    out = _p(-1, l, -m - 1, n, r1, rlm1) * s
    if m != -1:
        out = out + _p(1, l, m + 1, n, r1, rlm1)
    return out


def _w_fn(l, m, n, r1, rlm1):
    if m == 0:
        raise AssertionError("w coefficient is zero for m == 0")
    if m > 0:
        return _p(1, l, m + 1, n, r1, rlm1) + _p(-1, l, -m - 1, n, r1, rlm1)
    return _p(1, l, m - 1, n, r1, rlm1) - _p(-1, l, -m + 1, n, r1, rlm1)


def wigner_matrices(m3: jax.Array, l_max: int) -> list[jax.Array]:
    """Real-SH rotation matrices [R^0, R^1, ..., R^l_max].

    m3: (..., 3, 3) xyz rotation matrices. R^l has shape (..., 2l+1, 2l+1).
    """
    batch = m3.shape[:-2]
    mats: list[jax.Array] = [jnp.ones(batch + (1, 1), m3.dtype)]
    if l_max == 0:
        return mats
    r1 = _r1_from_matrix(m3)
    mats.append(r1)
    for l in range(2, l_max + 1):
        rlm1 = mats[-1]
        rows = []
        for m in range(-l, l + 1):
            row = []
            for n in range(-l, l + 1):
                u, v, w = _uvw(l, m, n)
                term = jnp.zeros(batch, m3.dtype)
                if abs(u) > 1e-12:
                    term = term + u * _u_fn(l, m, n, r1, rlm1)
                if abs(v) > 1e-12:
                    term = term + v * _v_fn(l, m, n, r1, rlm1)
                if abs(w) > 1e-12:
                    term = term + w * _w_fn(l, m, n, r1, rlm1)
                row.append(term)
            rows.append(jnp.stack(row, axis=-1))
        mats.append(jnp.stack(rows, axis=-2))
    return mats


def block_diag_wigner(m3: jax.Array, l_max: int) -> jax.Array:
    """Stacked block-diagonal rotation over all degrees: (..., K, K),
    K = (l_max+1)^2 — convenient for a single einsum over flat coeffs."""
    mats = wigner_matrices(m3, l_max)
    k = (l_max + 1) ** 2
    batch = m3.shape[:-2]
    out = jnp.zeros(batch + (k, k), m3.dtype)
    off = 0
    for l, r in enumerate(mats):
        n = 2 * l + 1
        out = out.at[..., off : off + n, off : off + n].set(r)
        off += n
    return out


# --- real spherical harmonics evaluation (for tests) ----------------------

def sh_l1(d: jax.Array) -> jax.Array:
    """l=1 real SH (unnormalized, basis order y,z,x) of unit vectors."""
    return jnp.stack([d[..., 1], d[..., 2], d[..., 0]], axis=-1)

"""Decoder-only transformer LM: dense or MoE, GQA, RoPE, scan-over-layers.

Covers all five assigned LM archs (arctic-480b, granite-moe-1b, granite-20b,
nemotron-4-340b, internlm2-20b) via config. Layers are stacked into one
pytree and iterated with ``jax.lax.scan`` + remat — constant-size HLO
regardless of depth (essential for 96-layer dry-run compiles) and the
standard activation-memory policy at scale.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers, moe as moe_mod
from repro.models.layers import Params


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    act: str = "silu"
    gated_mlp: bool = True
    moe: moe_mod.MoeConfig | None = None
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    remat: bool = True
    # sliding-window attention (beyond-paper option for long context); 0=full
    attn_window: int = 0
    # query-chunked (flash-style) attention; 0 = full scores. Enabled for the
    # 32k prefill shapes where full scores exceed device memory.
    attn_chunk: int = 0

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def attn_cfg(self, window: int | None = None) -> layers.AttnConfig:
        return layers.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, d_head=self.head_dim,
            rope_theta=self.rope_theta,
            window=self.attn_window if window is None else window,
        )

    def mlp_cfg(self) -> layers.MlpConfig:
        return layers.MlpConfig(self.d_model, self.d_ff, self.act, self.gated_mlp)

    def param_count(self) -> int:
        """Total parameters (N for MODEL_FLOPS = 6·N·D)."""
        d, dh = self.d_model, self.head_dim
        attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe is not None:
            f = self.moe.d_ff
            per_e = d * f * (3 if self.moe.gated else 2)
            ffn = self.moe.n_experts * per_e + d * self.moe.n_experts
            if self.moe.residual_d_ff:
                ffn += d * self.moe.residual_d_ff * (3 if self.gated_mlp else 2)
        else:
            ffn = d * self.d_ff * (3 if self.gated_mlp else 2)
        per_layer = attn + ffn + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Activated parameters per token (N_active for MoE)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        f = self.moe.d_ff
        per_e = d * f * (3 if self.moe.gated else 2)
        dense_like = dataclasses.replace(self, moe=None, d_ff=0, gated_mlp=False)
        base = dense_like.param_count()
        act_ffn = self.moe.top_k * per_e + d * self.moe.n_experts
        if self.moe.residual_d_ff:
            act_ffn += d * self.moe.residual_d_ff * (3 if self.gated_mlp else 2)
        return base + self.n_layers * act_ffn


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _layer_init(key, cfg: LMConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": layers.attn_init(k1, cfg.attn_cfg(), dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_init(k2, cfg.moe, dtype)
    else:
        p["mlp"] = layers.mlp_init(k2, cfg.mlp_cfg(), dtype)
    return p


def lm_init(key, cfg: LMConfig, dtype=jnp.float32) -> Params:
    ke, kl, ko = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(lkeys)
    p: Params = {
        "embed": layers.embed_init(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": stacked,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = layers.dense_init(ko, cfg.d_model, cfg.vocab, dtype)
    return p


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

# logical specs for layer weights ONCE GATHERED over the FSDP axis: only the
# TP axis remains. Constraining the scan-carried slice to these specs inside
# the body makes GSPMD emit one per-layer weight all-gather (ZeRO-3 /
# FSDP-style) instead of contracting against dp-sharded dims and
# all-reducing full activations — found via the dry-run HLO byte profile,
# ~30x collective reduction on nemotron train_4k (EXPERIMENTS.md §Perf).
_GATHERED_SPECS = {
    "wq": (None, "heads"), "wk": (None, "heads"), "wv": (None, "heads"),
    "wo": ("heads", None),
    "wi": (None, "mlp"), "wg": (None, "mlp"),
}


def _gather_fsdp(lp: Params) -> Params:
    """Constrain layer weights to their dp-gathered (TP-only) sharding."""
    out = {}
    for k, v in lp.items():
        if isinstance(v, dict):
            if k == "moe":
                out[k] = _gather_moe(v)
            else:
                out[k] = {
                    kk: shard(vv, _GATHERED_SPECS[kk])
                    if kk in _GATHERED_SPECS and vv.ndim == 2 else vv
                    for kk, vv in v.items()
                }
        else:
            out[k] = v
    return out


def _gather_moe(mp: Params) -> Params:
    out = {}
    for k, v in mp.items():
        if k in ("wi", "wg", "wo") and not isinstance(v, dict):
            out[k] = shard(v, ("experts", None, None))  # EP stays; dp gathered
        elif k == "residual" and isinstance(v, dict):
            out[k] = {
                kk: shard(vv, _GATHERED_SPECS[kk])
                if kk in _GATHERED_SPECS and vv.ndim == 2 else vv
                for kk, vv in v.items()
            }
        else:
            out[k] = v
    return out


def _block(cfg: LMConfig, lp: Params, x: jax.Array,
           positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    lp = _gather_fsdp(lp)
    h = layers.rmsnorm(x, lp["ln1"])
    if cfg.attn_chunk:
        a = layers.attention_chunked(
            lp["attn"], h, cfg.attn_cfg(), positions, chunk=cfg.attn_chunk
        )
    else:
        a = layers.attention(lp["attn"], h, cfg.attn_cfg(), positions)
    # constrain the residual sum back to the seq-sharded stream HERE so the
    # wo-matmul partial sums lower as reduce-scatter, not all-reduce +
    # re-shard (§Perf iteration 4)
    x = shard(x + a, ("batch", "seq", "embed"))
    h = layers.rmsnorm(x, lp["ln2"])
    if cfg.moe is not None:
        y, aux = moe_mod.moe(lp["moe"], h, cfg.moe)
    else:
        y, aux = layers.mlp(lp["mlp"], h, cfg.mlp_cfg()), jnp.float32(0)
    x = shard(x + y, ("batch", "seq", "embed"))
    return x, aux


def lm_hidden(params: Params, tokens: jax.Array, cfg: LMConfig) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) int32 -> (final hidden (B, S, d), moe aux loss)."""
    dt = params["ln_f"].dtype
    # gather the embedding over dp once (vocab stays TP-sharded) — the token
    # gather is then local per TP shard instead of a dp-wide exchange
    embed = shard(params["embed"], ("vocab", None))
    x = embed[tokens].astype(dt)
    x = shard(x, ("batch", "seq", "embed"))
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    def body(carry, lp):
        x, aux = carry
        x, a = _block(cfg, lp, x, positions)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)), params["layers"])
    return layers.rmsnorm(x, params["ln_f"]), aux


def _unembed(params: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if "unembed" in params:
        return x @ params["unembed"].astype(dt)
    return x @ params["embed"].T.astype(dt)


def lm_forward(params: Params, tokens: jax.Array, cfg: LMConfig) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) int32 -> (logits (B, S, V) f32, aux loss)."""
    x, aux = lm_hidden(params, tokens, cfg)
    logits = shard(_unembed(params, x).astype(jnp.float32), ("batch", "seq", "vocab"))
    return logits, aux


def lm_loss(params: Params, batch: dict, cfg: LMConfig,
            loss_chunks: int = 8) -> tuple[jax.Array, dict]:
    """Next-token CE + z-loss + MoE aux, with CHUNKED cross-entropy.

    Full (B, S, V) logits at e.g. B·S=1M, V=256k are ~1 TB — never
    materialized. The unembed+CE runs over sequence chunks inside a
    checkpointed scan, so only one chunk of logits is ever live (forward and
    backward); the standard large-vocab loss treatment.
    """
    x, aux = lm_hidden(params, batch["tokens"], cfg)   # (B, S, d)
    labels = batch["labels"]
    b, s, d = x.shape
    n = loss_chunks if s % loss_chunks == 0 else 1
    xs = x.reshape(b, n, s // n, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, s // n).transpose(1, 0, 2)
    # NB: the unembed stays dp-sharded on d — gathering it outside the scan
    # makes its gradient accumulator dp-replicated, which costs a full-size
    # all-reduce per CE chunk (measured 2.4 TB/chip on nemotron; §Perf).
    # Contracting over the sharded d costs one (tokens, V/16) logits
    # all-reduce per chunk instead.

    def chunk(carry, xl):
        xc, lc = xl
        logits = _unembed(params, xc).astype(jnp.float32)
        logits = shard(logits, ("batch", "seq", "vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.clip(lc, 0)[..., None], axis=-1)[..., 0] - logz
        mask = (lc >= 0).astype(jnp.float32)
        ce_sum, z_sum, cnt = carry
        return (
            ce_sum - (ll * mask).sum(),
            z_sum + ((logz * mask) ** 2).sum(),
            cnt + mask.sum(),
        ), None

    init = (jnp.float32(0), jnp.float32(0), jnp.float32(0))
    (ce_sum, z_sum, cnt), _ = jax.lax.scan(jax.checkpoint(chunk), init, (xs, ls))
    denom = jnp.clip(cnt, 1.0)
    ce = ce_sum / denom
    zloss = 1e-4 * z_sum / denom
    loss = ce + zloss + aux
    return loss, {"ce": ce, "zloss": zloss, "moe_aux": aux}


# --------------------------------------------------------------------------
# prefill (serve): fill the KV cache for a prompt, return last-token logits
# --------------------------------------------------------------------------

def lm_prefill(params: Params, tokens: jax.Array, cfg: LMConfig):
    """tokens (B, S) -> (last-position logits (B, V) f32, kv cache pytree)."""
    dt = params["ln_f"].dtype
    x = params["embed"][tokens].astype(dt)
    x = shard(x, ("batch", "seq", "embed"))
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    def body(x, lp):
        h = layers.rmsnorm(x, lp["ln1"])
        q, k, v = layers._qkv(lp["attn"], h, cfg.attn_cfg())
        del q
        # the cache stores POST-RoPE keys (attention_decode rotates only the
        # incoming token and scores against the cache as-is)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
        x, _ = _block(cfg, lp, x, positions)
        return x, (k, v)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(body_fn, x, params["layers"])
    x = layers.rmsnorm(x, params["ln_f"])
    logits = _unembed(params, x[:, -1, :]).astype(jnp.float32)
    cache = {
        "k": ks.astype(jnp.bfloat16),   # caches are bf16 in production
        "v": vs.astype(jnp.bfloat16),
        "len": jnp.full((b,), s, jnp.int32),
    }
    return logits, cache


# --------------------------------------------------------------------------
# decode (serve_step): one token against a per-layer KV cache
# --------------------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.float32) -> Params:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def lm_decode_step(params: Params, cache: Params, tokens: jax.Array,
                   cfg: LMConfig) -> tuple[jax.Array, Params]:
    """tokens (B,) int32 -> (logits (B, V), updated cache)."""
    dt = params["ln_f"].dtype
    x = params["embed"][tokens][:, None, :].astype(dt)     # (B, 1, d)
    x = shard(x, ("batch", None, "embed"))

    def body(carry, inputs):
        x = carry
        lp, kc, vc = inputs
        lp = _gather_fsdp(lp)
        h = layers.rmsnorm(x, lp["ln1"])
        a, kc, vc = layers.attention_decode(
            lp["attn"], h, cfg.attn_cfg(), kc, vc, cache["len"]
        )
        x = x + a
        h = layers.rmsnorm(x, lp["ln2"])
        if cfg.moe is not None:
            y, _ = moe_mod.moe(lp["moe"], h, cfg.moe)
        else:
            y = layers.mlp(lp["mlp"], h, cfg.mlp_cfg())
        return x + y, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = layers.rmsnorm(x, params["ln_f"])
    if "unembed" in params:
        logits = x[:, 0, :] @ params["unembed"].astype(dt)
    else:
        logits = x[:, 0, :] @ params["embed"].T.astype(dt)
    new_cache = {"k": k_new, "v": v_new, "len": cache["len"] + 1}
    return logits.astype(jnp.float32), new_cache

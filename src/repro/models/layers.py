"""Common neural building blocks — pure functions over param pytrees.

Conventions: params are nested dicts of jnp arrays; every apply fn takes
(params, inputs, cfg-ish kwargs) and is jit/vmap/scan-safe; compute dtype is
pinned by the caller (bf16 for the TPU target, f32 for CPU tests); weights
are stored f32 (or bf16 under ``param_dtype``) and cast on use.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard, shard_if_divisible

Params = dict


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------

def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "relu2":  # squared ReLU (Primer / Nemotron-4)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name!r}")


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, d_head); positions: broadcastable to (..., seq)."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d_head, theta))          # (d_head/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# grouped-query attention (full, causal) + KV-cache decode
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    causal: bool = True
    # sliding-window attention (beyond-paper long-context option); 0 = full
    window: int = 0


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * cfg.d_head, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * cfg.d_head, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * cfg.d_head, dtype),
        "wo": dense_init(k4, cfg.n_heads * cfg.d_head, cfg.d_model, dtype),
    }


def _qkv(params: Params, x: jax.Array, cfg: AttnConfig):
    b, s, _ = x.shape
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (x @ params["wk"].astype(dt)).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = (x @ params["wv"].astype(dt)).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    q = shard(q, ("batch", "act_seq", "heads", None))
    k = shard_if_divisible(k, ("batch", "act_seq", "kv_heads", None), dim=2)
    v = shard_if_divisible(v, ("batch", "act_seq", "kv_heads", None), dim=2)
    return q, k, v


def attention(params: Params, x: jax.Array, cfg: AttnConfig,
              positions: jax.Array | None = None) -> jax.Array:
    """Full (optionally causal / sliding-window) GQA attention.

    x: (B, S, d_model) -> (B, S, d_model).
    """
    b, s, _ = x.shape
    dt = x.dtype
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, s, cfg.n_kv_heads, groups, cfg.d_head)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(cfg.d_head)
    ii = jnp.arange(s)
    mask = jnp.ones((s, s), dtype=bool)
    if cfg.causal:
        mask &= ii[:, None] >= ii[None, :]
    if cfg.window:
        mask &= ii[:, None] - ii[None, :] < cfg.window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v).reshape(b, s, -1)
    out = shard(out, ("batch", "act_seq", "heads"))
    return out @ params["wo"].astype(dt)


def attention_chunked(params: Params, x: jax.Array, cfg: AttnConfig,
                      positions: jax.Array | None = None,
                      chunk: int = 1024) -> jax.Array:
    """Query-chunked causal GQA (flash-attention outer loop).

    Never materializes the (S, S) score matrix — per chunk the live buffer is
    (B, H, chunk, S). This is the long-prefill path (32k+): full attention at
    S=32k would need ~12 GB/device of f32 scores on the production mesh;
    chunked needs S/chunk × less. Numerics identical to :func:`attention`
    (tested).
    """
    b, s, _ = x.shape
    dt = x.dtype
    if s % chunk:
        return attention(params, x, cfg, positions)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    groups = cfg.n_heads // cfg.n_kv_heads
    n_chunks = s // chunk
    qg = q.reshape(b, n_chunks, chunk, cfg.n_kv_heads, groups, cfg.d_head)
    qg = jnp.moveaxis(qg, 1, 0)                      # (n_chunks, B, c, h, g, d)
    kk = jnp.arange(s)

    def one(args):
        qi, i = args
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qi, k).astype(jnp.float32)
        scores *= 1.0 / math.sqrt(cfg.d_head)
        qq = i * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, s), dtype=bool)
        if cfg.causal:
            mask &= qq[:, None] >= kk[None, :]
        if cfg.window:
            mask &= qq[:, None] - kk[None, :] < cfg.window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)

    out = jax.lax.map(one, (qg, jnp.arange(n_chunks)))   # (n_chunks, B, c, ...)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, -1)
    out = shard(out, ("batch", "act_seq", "heads"))
    return out @ params["wo"].astype(dt)


def attention_decode(
    params: Params, x: jax.Array, cfg: AttnConfig,
    k_cache: jax.Array, v_cache: jax.Array, cache_len: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a KV cache.

    x: (B, 1, d_model); caches: (B, S_max, n_kv, d_head); cache_len: (B,)
    Returns (out (B,1,d_model), new_k, new_v).
    """
    b, _, _ = x.shape
    s_max = k_cache.shape[1]
    dt = x.dtype
    positions = cache_len[:, None]                      # (B, 1)
    q, k_new, v_new = _qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    # write the new token into the cache at cache_len (per-batch dynamic);
    # the cache keeps ITS dtype (bf16 in production even under f32 params)
    cdt = k_cache.dtype
    onehot = (jnp.arange(s_max)[None, :] == cache_len[:, None]).astype(cdt)
    k_cache = (k_cache * (1 - onehot)[..., None, None]
               + onehot[..., None, None] * k_new.astype(cdt))
    v_cache = (v_cache * (1 - onehot)[..., None, None]
               + onehot[..., None, None] * v_new.astype(cdt))
    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, cfg.n_kv_heads, groups, cfg.d_head)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(cfg.d_head)
    valid = jnp.arange(s_max)[None, :] <= cache_len[:, None]   # (B, S_max)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache).reshape(b, 1, -1)
    return out @ params["wo"].astype(dt), k_cache, v_cache


# --------------------------------------------------------------------------
# MLP (dense FFN)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MlpConfig:
    d_model: int
    d_ff: int
    act: str = "silu"     # "silu" => SwiGLU (gated); others => plain 2-layer
    gated: bool = True


def mlp_init(key, cfg: MlpConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": dense_init(k1, cfg.d_model, cfg.d_ff, dtype),
        "wo": dense_init(k2, cfg.d_ff, cfg.d_model, dtype),
    }
    if cfg.gated:
        p["wg"] = dense_init(k3, cfg.d_model, cfg.d_ff, dtype)
    return p


def mlp(params: Params, x: jax.Array, cfg: MlpConfig) -> jax.Array:
    dt = x.dtype
    h = x @ params["wi"].astype(dt)
    h = shard(h, ("batch", "act_seq", "mlp"))
    if cfg.gated:
        g = x @ params["wg"].astype(dt)
        h = activation(cfg.act, g) * h
    else:
        h = activation(cfg.act, h)
    return h @ params["wo"].astype(dt)

"""RecSys model zoo: SASRec, FM, two-tower retrieval, MIND.

The embedding LOOKUP is the hot path: JAX has no native EmbeddingBag, so it
is built here from ``jnp.take`` + ``jax.ops.segment_sum`` (part of the
system, per assignment). Tables are row-sharded over the 'model' mesh axis.

Paper-technique integration (DESIGN.md §4): tables support the hashing
trick, and the row-assignment hash is selectable between RH and **IDL** —
temporally-correlated ids (session neighbors) then co-locate in the table so
gathers touch fewer HBM pages; same locality argument as the BF probes.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.distributed.sharding import shard
from repro.models import layers
from repro.models.layers import Params


# --------------------------------------------------------------------------
# EmbeddingBag with optional hashing-trick (RH or IDL row assignment)
# --------------------------------------------------------------------------

def _rows_none(ids: jax.Array, n_rows: int, L: int) -> jax.Array:
    del L
    return (ids % n_rows).astype(jnp.int32)


def _rows_rh(ids: jax.Array, n_rows: int, L: int) -> jax.Array:
    del L
    return hashing.hash_to_range(ids.astype(jnp.uint64), 0x5EED, n_rows).astype(jnp.int32)


def _rows_idl(ids: jax.Array, n_rows: int, L: int) -> jax.Array:
    # ids are grouped L/16 per window of L rows (load factor 1/16) —
    # identity preservation needs the window sparse, exactly like the
    # paper's L >> expected probes-per-window
    group = max(1, L // 16)
    bucket = (ids // group).astype(jnp.uint64)  # locality proxy: id blocks
    anchor = hashing.hash_to_range(bucket, 0xA17C, max(n_rows // L, 1))
    local = hashing.hash_to_range(ids.astype(jnp.uint64), 0x10CA, L)
    return (anchor.astype(jnp.int32) * np.int32(L) + local.astype(jnp.int32)) % n_rows


_ROW_SCHEMES = {"none": _rows_none, "rh": _rows_rh, "idl": _rows_idl}


def hash_rows(ids: jax.Array, n_rows: int, scheme: str = "none",
              L: int = 4096) -> jax.Array:
    """Map raw ids -> table rows. "none": modulo; "rh": murmur-style;
    "idl": anchor from id-bucket (locality) + local hash — session-adjacent
    ids land in the same L-row window without colliding."""
    try:
        row_fn = _ROW_SCHEMES[scheme]
    except KeyError:
        raise ValueError(scheme) from None
    return row_fn(ids, n_rows, L)


def embedding_bag(
    table: jax.Array, ids: jax.Array, offsets: jax.Array | None = None,
    mode: str = "sum", hash_scheme: str = "none",
) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent.

    ids (n,) with offsets (bags+1,) => ragged bags; or ids (B, k) fixed bags.
    """
    n_rows = table.shape[0]
    rows = hash_rows(ids, n_rows, hash_scheme)
    vecs = jnp.take(table, rows, axis=0)
    if offsets is None:
        red = vecs.sum(axis=-2) if mode == "sum" else vecs.mean(axis=-2)
        return red
    n_bags = offsets.shape[0] - 1
    seg = jnp.searchsorted(offsets[1:], jnp.arange(ids.shape[0]), side="right")
    out = jax.ops.segment_sum(vecs, seg, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(seg, vecs.dtype), seg, n_bags)
        out = out / jnp.clip(cnt, 1.0)[:, None]
    return out


# --------------------------------------------------------------------------
# FM — factorization machine (Rendle ICDM'10): O(nk) sum-square trick
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab_per_field: int = 1 << 20
    hash_scheme: str = "none"


def fm_init(key, cfg: FMConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "tables": layers.embed_init(
            k1, cfg.n_sparse * cfg.vocab_per_field, cfg.embed_dim, dtype
        ),
        "linear": layers.embed_init(k2, cfg.n_sparse * cfg.vocab_per_field, 1, dtype),
        "bias": jnp.zeros((), dtype),
    }


def fm_forward(params: Params, feats: jax.Array, cfg: FMConfig) -> jax.Array:
    """feats: (B, n_sparse) int32 raw categorical ids -> (B,) logit."""
    b = feats.shape[0]
    field_offset = jnp.arange(cfg.n_sparse, dtype=feats.dtype) * cfg.vocab_per_field
    ids = feats + field_offset[None, :]
    rows = hash_rows(ids, params["tables"].shape[0], cfg.hash_scheme)
    v = jnp.take(params["tables"], rows, axis=0)        # (B, F, k)
    v = shard(v, ("batch", None, None))
    lin = jnp.take(params["linear"], rows, axis=0)[..., 0].sum(-1)
    s = v.sum(axis=1)                                    # Σ v_i x_i
    pair = 0.5 * ((s * s).sum(-1) - (v * v).sum(axis=(1, 2)))
    return params["bias"].astype(jnp.float32) + lin.astype(jnp.float32) + pair.astype(jnp.float32)


def fm_loss(params: Params, batch: dict, cfg: FMConfig):
    logit = fm_forward(params, batch["feats"], cfg)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )
    return loss, {"bce": loss}


# --------------------------------------------------------------------------
# two-tower retrieval (YouTube RecSys'19): in-batch sampled softmax
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_dims: tuple[int, ...] = (1024, 512, 256)
    n_users: int = 1 << 23
    n_items: int = 1 << 23
    n_user_feats: int = 8
    n_item_feats: int = 4
    hash_scheme: str = "none"
    temperature: float = 0.05


def _tower_init(key, d_in: int, dims: tuple[int, ...], dtype) -> Params:
    ks = jax.random.split(key, len(dims))
    return {
        f"w{i}": layers.dense_init(ks[i], d_in if i == 0 else dims[i - 1], d, dtype)
        for i, d in enumerate(dims)
    }


def _tower(params: Params, x: jax.Array, dims: tuple[int, ...]) -> jax.Array:
    for i in range(len(dims)):
        x = x @ params[f"w{i}"].astype(x.dtype)
        if i < len(dims) - 1:
            x = jax.nn.relu(x)
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-6)


def twotower_init(key, cfg: TwoTowerConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "user_table": layers.embed_init(k1, cfg.n_users, cfg.embed_dim, dtype),
        "item_table": layers.embed_init(k2, cfg.n_items, cfg.embed_dim, dtype),
        "user_tower": _tower_init(k3, cfg.n_user_feats * cfg.embed_dim, cfg.tower_dims, dtype),
        "item_tower": _tower_init(k4, cfg.n_item_feats * cfg.embed_dim, cfg.tower_dims, dtype),
    }


def twotower_embed(params: Params, batch: dict, cfg: TwoTowerConfig):
    ue = embedding_bag(params["user_table"], batch["user_feats"],
                       hash_scheme=cfg.hash_scheme, mode="sum")
    # (B, n_user_feats, d) -> flatten: keep per-feat vectors
    uraw = jnp.take(
        params["user_table"],
        hash_rows(batch["user_feats"], cfg.n_users, cfg.hash_scheme), axis=0,
    ).reshape(batch["user_feats"].shape[0], -1)
    iraw = jnp.take(
        params["item_table"],
        hash_rows(batch["item_feats"], cfg.n_items, cfg.hash_scheme), axis=0,
    ).reshape(batch["item_feats"].shape[0], -1)
    del ue
    u = _tower(params["user_tower"], shard(uraw, ("batch", None)), cfg.tower_dims)
    it = _tower(params["item_tower"], shard(iraw, ("batch", None)), cfg.tower_dims)
    return u, it


def twotower_loss(params: Params, batch: dict, cfg: TwoTowerConfig):
    u, it = twotower_embed(params, batch, cfg)
    logits = (u @ it.T) / cfg.temperature          # (B, B) in-batch negatives
    labels = jnp.arange(u.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0] - logz
    loss = -ll.mean()
    return loss, {"sampled_softmax": loss}


def twotower_score_candidates(params: Params, batch: dict, cfg: TwoTowerConfig):
    """retrieval_cand shape: one query vs n_candidates items (batched dot)."""
    u, _ = twotower_embed(
        params, {"user_feats": batch["user_feats"], "item_feats": batch["cand_feats"][:1]}, cfg
    )
    iraw = jnp.take(
        params["item_table"],
        hash_rows(batch["cand_feats"], cfg.n_items, cfg.hash_scheme), axis=0,
    ).reshape(batch["cand_feats"].shape[0], -1)
    it = _tower(params["item_tower"], shard(iraw, ("batch", None)), cfg.tower_dims)
    return (it @ u[0]).astype(jnp.float32)          # (n_candidates,)


# --------------------------------------------------------------------------
# SASRec (arXiv:1808.09781): causal self-attention over item sequences
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    n_items: int = 1 << 20
    hash_scheme: str = "none"

    def attn_cfg(self) -> layers.AttnConfig:
        return layers.AttnConfig(
            d_model=self.embed_dim, n_heads=self.n_heads,
            n_kv_heads=self.n_heads, d_head=self.embed_dim // self.n_heads,
        )


def sasrec_init(key, cfg: SASRecConfig, dtype=jnp.float32) -> Params:
    ke, kp, kl = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_blocks)

    def blk(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": jnp.ones((cfg.embed_dim,), dtype),
            "ln2": jnp.ones((cfg.embed_dim,), dtype),
            "attn": layers.attn_init(k1, cfg.attn_cfg(), dtype),
            "mlp": layers.mlp_init(
                k2, layers.MlpConfig(cfg.embed_dim, 4 * cfg.embed_dim, "relu", False), dtype
            ),
        }

    return {
        "item_table": layers.embed_init(ke, cfg.n_items, cfg.embed_dim, dtype),
        "pos": layers.embed_init(kp, cfg.seq_len, cfg.embed_dim, dtype),
        "blocks": jax.vmap(blk)(lkeys),
        "ln_f": jnp.ones((cfg.embed_dim,), dtype),
    }


def sasrec_forward(params: Params, seq: jax.Array, cfg: SASRecConfig) -> jax.Array:
    """seq (B, S) item ids -> (B, S, d) sequence representations."""
    rows = hash_rows(seq, cfg.n_items, cfg.hash_scheme)
    x = jnp.take(params["item_table"], rows, axis=0)
    x = x + params["pos"][None, : seq.shape[1], :].astype(x.dtype)
    x = shard(x, ("batch", "seq", None))

    def body(x, bp):
        h = layers.rmsnorm(x, bp["ln1"])
        x = x + layers.attention(bp["attn"], h, cfg.attn_cfg())
        h = layers.rmsnorm(x, bp["ln2"])
        return x + layers.mlp(bp["mlp"], h, layers.MlpConfig(cfg.embed_dim, 4 * cfg.embed_dim, "relu", False)), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return layers.rmsnorm(x, params["ln_f"])


def sasrec_loss(params: Params, batch: dict, cfg: SASRecConfig):
    """BCE on (positive next item, sampled negative) — the paper's objective."""
    h = sasrec_forward(params, batch["seq"], cfg)            # (B, S, d)
    pos_rows = hash_rows(batch["pos"], cfg.n_items, cfg.hash_scheme)
    neg_rows = hash_rows(batch["neg"], cfg.n_items, cfg.hash_scheme)
    pe = jnp.take(params["item_table"], pos_rows, axis=0)
    ne = jnp.take(params["item_table"], neg_rows, axis=0)
    pos_logit = (h * pe).sum(-1).astype(jnp.float32)
    neg_logit = (h * ne).sum(-1).astype(jnp.float32)
    mask = (batch["pos"] >= 0).astype(jnp.float32)
    bce = (
        jnp.log1p(jnp.exp(-pos_logit)) + jnp.log1p(jnp.exp(neg_logit))
    )
    loss = (bce * mask).sum() / jnp.clip(mask.sum(), 1.0)
    return loss, {"bce": loss}


# --------------------------------------------------------------------------
# MIND (arXiv:1904.08030): multi-interest dynamic-routing capsules
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    n_items: int = 1 << 20
    hash_scheme: str = "none"


def mind_init(key, cfg: MINDConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "item_table": layers.embed_init(k1, cfg.n_items, cfg.embed_dim, dtype),
        "S": layers.dense_init(k2, cfg.embed_dim, cfg.embed_dim, dtype),  # bilinear map
    }


def _squash(v: jax.Array) -> jax.Array:
    n2 = jnp.sum(v * v, axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * v / jnp.sqrt(n2 + 1e-9)


def mind_interests(params: Params, seq: jax.Array, mask: jax.Array,
                   cfg: MINDConfig) -> jax.Array:
    """Dynamic routing: (B, S) history -> (B, K, d) interest capsules."""
    rows = hash_rows(seq, cfg.n_items, cfg.hash_scheme)
    e = jnp.take(params["item_table"], rows, axis=0)         # (B, S, d)
    e = shard(e, ("batch", "seq", None))
    u = e @ params["S"].astype(e.dtype)                      # behavior caps
    b = jnp.zeros((seq.shape[0], cfg.n_interests, seq.shape[1]), jnp.float32)
    for _ in range(cfg.capsule_iters):                       # fixed 3 iters
        w = jax.nn.softmax(b, axis=1)                        # over interests
        w = w * mask[:, None, :].astype(w.dtype)
        v = _squash(jnp.einsum("bks,bsd->bkd", w.astype(u.dtype), u))
        b = b + jnp.einsum("bkd,bsd->bks", v, u).astype(jnp.float32)
    return v


def mind_loss(params: Params, batch: dict, cfg: MINDConfig):
    """Label-aware attention: train with sampled softmax on argmax interest."""
    v = mind_interests(params, batch["seq"], batch["mask"], cfg)   # (B,K,d)
    pos_rows = hash_rows(batch["pos"], cfg.n_items, cfg.hash_scheme)
    neg_rows = hash_rows(batch["negs"], cfg.n_items, cfg.hash_scheme)
    pe = jnp.take(params["item_table"], pos_rows, axis=0)          # (B, d)
    ne = jnp.take(params["item_table"], neg_rows, axis=0)          # (B, Nneg, d)
    # label-aware attention: pick interest with max dot to positive
    sim = jnp.einsum("bkd,bd->bk", v, pe)
    best = jnp.take_along_axis(v, jnp.argmax(sim, axis=1)[:, None, None], axis=1)[:, 0]
    pos_logit = (best * pe).sum(-1).astype(jnp.float32)
    neg_logit = jnp.einsum("bd,bnd->bn", best, ne).astype(jnp.float32)
    logits = jnp.concatenate([pos_logit[:, None], neg_logit], axis=1)
    loss = -(pos_logit - jax.nn.logsumexp(logits, axis=1)).mean()
    return loss, {"sampled_softmax": loss}

"""EquiformerV2-style SO(2)-eSCN equivariant graph attention network.

Faithful mechanism (arXiv:2306.12059 / eSCN arXiv:2302.03655):
  * node features are real-SH irrep coefficients up to l_max (flat K =
    (l_max+1)^2 coeffs x C channels),
  * per edge, features are rotated into the edge-aligned frame with EXACT
    Wigner matrices (`so3.wigner_matrices`, Ivanic-Ruedenberg recursion),
  * the tensor-product convolution becomes an SO(2) per-m linear mix,
    truncated to |m| <= m_max (the O(L^6) -> O(L^3) eSCN trick),
  * messages are weighted by scalar-channel graph attention
    (segment-softmax over incoming edges), rotated back, aggregated.

Documented simplifications vs the released model (DESIGN.md §5): the radial
function modulates each (m-block, channel) pair of the static mixing weights
(separable radial x channel), and the S2 pointwise activation is replaced by
scalar-gated magnitude gating per l — both preserve exact equivariance
(verified by the rotation-invariance property test).

Non-geometric graph shapes (Cora / ogbn-products) carry synthetic 3D
positions in input_specs — the backbone is unchanged.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard
from repro.models import gnn_common, layers, so3
from repro.models.layers import Params


@dataclasses.dataclass(frozen=True)
class EquiformerConfig:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_feat: int = 0            # input node feature dim (0 = atom-type embed)
    n_node_types: int = 120
    n_classes: int = 0         # >0 => node classification head
    n_rbf: int = 32
    cutoff: float = 6.0
    remat: bool = True

    @property
    def n_coeff(self) -> int:
        return (self.l_max + 1) ** 2

    def degree_slices(self) -> list[tuple[int, int]]:
        """[(offset, 2l+1)] per l into the flat coefficient axis."""
        out, off = [], 0
        for l in range(self.l_max + 1):
            out.append((off, 2 * l + 1))
            off += 2 * l + 1
        return out

    def m_blocks(self) -> list[tuple[int, list[int]]]:
        """SO(2) blocks: for m=0 the flat indices of (l, m=0) coeffs; for
        m>0 the indices of (l, +m) — (l, -m) pairs share the block."""
        blocks = []
        for m in range(0, self.m_max + 1):
            idx_pos, idx_neg = [], []
            off = 0
            for l in range(self.l_max + 1):
                width = 2 * l + 1
                if m <= l:
                    idx_pos.append(off + l + m)
                    idx_neg.append(off + l - m)
                off += width
            blocks.append((m, idx_pos if m else idx_pos))
            if m == 0:
                continue
            blocks[-1] = (m, idx_pos)
            blocks.append((-m, idx_neg))
        return blocks


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _so2_weights(key, cfg: EquiformerConfig, dtype) -> Params:
    """Static mixing weights per |m|: real & imaginary parts.

    For block m: maps (n_l_in(m) * C) -> (n_l_out(m) * C) where n_l(m) =
    number of degrees with l >= m."""
    p = {}
    keys = jax.random.split(key, cfg.m_max + 1)
    for m in range(cfg.m_max + 1):
        n_l = cfg.l_max + 1 - m
        d = n_l * cfg.d_hidden
        kr, ki = jax.random.split(keys[m])
        p[f"w{m}_r"] = layers.dense_init(kr, d, d, dtype)
        if m > 0:
            p[f"w{m}_i"] = layers.dense_init(ki, d, d, dtype)
    return p


def _layer_init(key, cfg: EquiformerConfig, dtype) -> Params:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    c = cfg.d_hidden
    return {
        "so2": _so2_weights(k1, cfg, dtype),
        "radial": {
            "w1": layers.dense_init(k2, cfg.n_rbf, c, dtype),
            "w2": layers.dense_init(
                k3, c, (cfg.m_max + 1) * c, dtype
            ),
        },
        "attn": {
            "w_alpha": layers.dense_init(k4, 3 * c, cfg.n_heads, dtype),
        },
        "ffn": {
            # per-degree channel mixing (equivariant: shared over m within l)
            "wl": (jax.random.normal(k5, (cfg.l_max + 1, c, c), jnp.float32)
                   / math.sqrt(c)).astype(dtype),
            "gate": layers.dense_init(k6, c, (cfg.l_max + 1) * c, dtype),
        },
        "ln_scale": jnp.ones((cfg.l_max + 1, c), dtype),
    }


def equiformer_init(key, cfg: EquiformerConfig, dtype=jnp.float32) -> Params:
    ke, kl, kh = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(lkeys)
    d_in = cfg.d_feat if cfg.d_feat else cfg.n_node_types
    p: Params = {
        "embed": layers.dense_init(ke, d_in, cfg.d_hidden, dtype),
        "layers": stacked,
        "head": layers.dense_init(
            kh, cfg.d_hidden, cfg.n_classes if cfg.n_classes else 1, dtype
        ),
    }
    return p


# --------------------------------------------------------------------------
# equivariant primitives
# --------------------------------------------------------------------------

def equiv_layernorm(x: jax.Array, scale: jax.Array, cfg: EquiformerConfig) -> jax.Array:
    """Norm over each degree's (2l+1, C) block magnitude; scale per (l, C)."""
    outs = []
    for l, (off, w) in enumerate(cfg.degree_slices()):
        blk = x[:, off : off + w, :]
        norm = jnp.sqrt(jnp.mean(blk.astype(jnp.float32) ** 2,
                                 axis=(1, 2), keepdims=True) + 1e-6)
        outs.append((blk / norm.astype(blk.dtype)) * scale[l][None, None, :])
    return jnp.concatenate(outs, axis=1)


def _rbf(dist: jax.Array, cfg: EquiformerConfig) -> jax.Array:
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf, dtype=jnp.float32)
    gamma = (cfg.n_rbf / cfg.cutoff) ** 2
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2).astype(dist.dtype)


def so2_conv(
    lp: Params, x_rot: jax.Array, radial: jax.Array, cfg: EquiformerConfig
) -> jax.Array:
    """SO(2) convolution in the edge frame, |m| <= m_max.

    x_rot: (E, K, C) rotated coefficients; radial: (E, m_max+1, C).
    Output: (E, K, C) with coefficients for |m| > m_max zeroed.
    """
    e = x_rot.shape[0]
    c = cfg.d_hidden
    dt = x_rot.dtype
    out = jnp.zeros_like(x_rot)

    # m = 0
    idx0 = _m_indices(cfg, 0)
    h0 = x_rot[:, idx0, :].reshape(e, -1)
    y0 = h0 @ lp["so2"]["w0_r"].astype(dt)
    y0 = y0.reshape(e, len(idx0), c) * radial[:, 0:1, :]
    out = out.at[:, idx0, :].set(y0)

    for m in range(1, cfg.m_max + 1):
        ip = _m_indices(cfg, m)
        im = _m_indices(cfg, -m)
        xp = x_rot[:, ip, :].reshape(e, -1)
        xm = x_rot[:, im, :].reshape(e, -1)
        wr = lp["so2"][f"w{m}_r"].astype(dt)
        wi = lp["so2"][f"w{m}_i"].astype(dt)
        yp = xp @ wr - xm @ wi
        ym = xp @ wi + xm @ wr
        rad = radial[:, m : m + 1, :]
        out = out.at[:, ip, :].set(yp.reshape(e, len(ip), c) * rad)
        out = out.at[:, im, :].set(ym.reshape(e, len(im), c) * rad)
    return out


def _m_indices(cfg: EquiformerConfig, m: int) -> list[int]:
    idx, off = [], 0
    for l in range(cfg.l_max + 1):
        w = 2 * l + 1
        if abs(m) <= l:
            idx.append(off + l + m)
        off += w
    return idx


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _edge_rotations(positions: jax.Array, src: jax.Array, dst: jax.Array,
                    cfg: EquiformerConfig):
    """Per-degree Wigner blocks [(E, 2l+1, 2l+1)] — NOT the dense (E, K, K)
    block-diagonal, which is 81% zeros at l_max=6; rotating per degree cuts
    both the rotation flops and the dmat memory traffic ~5.3×
    (EXPERIMENTS.md §Perf, equiformer iteration)."""
    vec = positions[dst] - positions[src]
    dist = jnp.linalg.norm(vec.astype(jnp.float32), axis=-1) + 1e-9
    m3 = so3.rotation_to_z(vec.astype(jnp.float32))
    mats = so3.wigner_matrices(m3, cfg.l_max)     # [(E, 2l+1, 2l+1)]
    return ([m.astype(positions.dtype) for m in mats],
            dist.astype(positions.dtype))


def _rotate(mats: list[jax.Array], x: jax.Array, cfg: EquiformerConfig,
            transpose: bool = False) -> jax.Array:
    """Apply the block-diagonal rotation degree-by-degree."""
    outs = []
    eq = "eji,ejc->eic" if transpose else "eij,ejc->eic"
    for l, (off, w) in enumerate(cfg.degree_slices()):
        outs.append(jnp.einsum(eq, mats[l], x[:, off : off + w, :]))
    return jnp.concatenate(outs, axis=1)


def _layer(lp: Params, x: jax.Array, dmat: jax.Array, dist: jax.Array,
           src: jax.Array, dst: jax.Array, edge_mask: jax.Array,
           n_nodes: int, cfg: EquiformerConfig) -> jax.Array:
    dt = x.dtype
    c = cfg.d_hidden
    # gather + rotate into edge frame (per-degree blocks)
    x_src = x[src]                                    # (E, K, C)
    x_rot = _rotate(dmat, x_src, cfg)
    x_rot = shard(x_rot, ("edges", None, None))
    # radial modulation
    rad = _rbf(dist, cfg)
    h = jax.nn.silu(rad @ lp["radial"]["w1"].astype(dt))
    radial = (h @ lp["radial"]["w2"].astype(dt)).reshape(-1, cfg.m_max + 1, c)
    msg_rot = so2_conv(lp, x_rot, radial, cfg)
    # rotate back (D^T = D^{-1}, per degree)
    msg = _rotate(dmat, msg_rot, cfg, transpose=True)
    msg = shard(msg, ("edges", None, None))
    # scalar-channel attention over incoming edges
    inv_t = x[dst][:, 0, :]
    inv_s = x_src[:, 0, :]
    inv_m = msg[:, 0, :]
    alpha_in = jnp.concatenate([inv_t, inv_s, inv_m], axis=-1)
    logits = (alpha_in @ lp["attn"]["w_alpha"].astype(dt)).astype(jnp.float32)
    logits = jnp.where(edge_mask[:, None] > 0, logits, -1e30)
    alpha = gnn_common.segment_softmax(logits, dst, n_nodes)      # (E, H)
    alpha = (alpha * edge_mask[:, None]).astype(dt)
    mh = msg.reshape(msg.shape[0], cfg.n_coeff, cfg.n_heads, c // cfg.n_heads)
    mh = mh * alpha[:, None, :, None]
    agg = jax.ops.segment_sum(
        mh.reshape(msg.shape[0], cfg.n_coeff, c), dst, num_segments=n_nodes
    )
    x = x + agg
    # equivariant FFN: scalar-gated per-degree channel mix
    x = equiv_layernorm(x, lp["ln_scale"], cfg)
    gates = jax.nn.sigmoid(
        (x[:, 0, :] @ lp["ffn"]["gate"].astype(dt))
    ).reshape(-1, cfg.l_max + 1, c)
    outs = []
    for l, (off, w) in enumerate(cfg.degree_slices()):
        blk = x[:, off : off + w, :] @ lp["ffn"]["wl"][l].astype(dt)
        outs.append(blk * gates[:, l : l + 1, :])
    return x + jnp.concatenate(outs, axis=1)


def equiformer_forward(params: Params, batch: dict, cfg: EquiformerConfig) -> jax.Array:
    """batch: positions (N,3), node_feat (N,d) or node_type (N,), src/dst (E,),
    edge_mask (E,), node_mask (N,). Returns per-node head output."""
    dt = params["embed"].dtype
    if cfg.d_feat:
        feats = batch["node_feat"].astype(dt)
    else:
        feats = jax.nn.one_hot(batch["node_type"], cfg.n_node_types, dtype=dt)
    n = feats.shape[0]
    x0 = feats @ params["embed"].astype(dt)           # (N, C)
    x = jnp.zeros((n, cfg.n_coeff, cfg.d_hidden), dt).at[:, 0, :].set(x0)
    x = shard(x, ("nodes", None, None))
    dmat, dist = _edge_rotations(
        batch["positions"].astype(dt), batch["src"], batch["dst"], cfg
    )

    def body(x, lp):
        return _layer(lp, x, dmat, dist, batch["src"], batch["dst"],
                      batch["edge_mask"], n, cfg), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    return x[:, 0, :] @ params["head"].astype(dt)     # (N, n_classes or 1)


def equiformer_loss(params: Params, batch: dict, cfg: EquiformerConfig):
    out = equiformer_forward(params, batch, cfg).astype(jnp.float32)
    mask = batch["node_mask"].astype(jnp.float32)
    if cfg.n_classes:
        labels = batch["labels"]
        lm = mask * (labels >= 0)
        logz = jax.nn.logsumexp(out, axis=-1)
        ll = jnp.take_along_axis(out, jnp.clip(labels, 0)[:, None], axis=-1)[:, 0]
        ce = -((ll - logz) * lm).sum() / jnp.clip(lm.sum(), 1.0)
        return ce, {"ce": ce}
    # graph energy regression: sum node scalars per graph
    graph_id = batch["graph_id"]
    n_graphs = batch["targets"].shape[0]
    energy = jax.ops.segment_sum(out[:, 0] * mask, graph_id, num_segments=n_graphs)
    mse = jnp.mean((energy - batch["targets"]) ** 2)
    return mse, {"mse": mse}

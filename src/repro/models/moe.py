"""Top-k routed Mixture-of-Experts with capacity-based dense dispatch.

Compile-friendly (static shapes) and EP-shardable: tokens are assigned
top-k experts; each expert takes up to C = ceil(T·k·cf / E) tokens (overflow
drops, standard GShard/Switch semantics); dispatch/combine are gather/
scatter by index — the expert dim shards over the 'model' mesh axis (EP) and
the capacity dim over 'data', so GSPMD emits the canonical all-to-all pair
around the expert GEMMs. Load-balance aux loss per Switch Transformer.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers
from repro.models.layers import Params


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act: str = "silu"
    gated: bool = True
    aux_loss_weight: float = 0.01
    # Arctic-style dense residual FFN running in parallel with the MoE path
    residual_d_ff: int = 0
    # group-local dispatch (beyond-paper §Perf optimization): tokens are
    # dispatched within G groups aligned to the data-parallel shards, so the
    # capacity gather/scatter never crosses devices; each device computes
    # its (group × expert-shard) slot block. 0 = global dispatch (baseline).
    dispatch_groups: int = 0


def moe_init(key, cfg: MoeConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    def ed(k, a, b):
        return (jax.random.normal(k, (e, a, b), dtype=jnp.float32) / jnp.sqrt(a)).astype(dtype)
    p = {
        "router": layers.dense_init(k1, d, e, jnp.float32),  # router stays f32
        "wi": ed(k2, d, f),
        "wo": ed(k3, f, d),
    }
    if cfg.gated:
        p["wg"] = ed(k4, d, f)
    if cfg.residual_d_ff:
        p["residual"] = layers.mlp_init(
            k5, layers.MlpConfig(d, cfg.residual_d_ff, cfg.act, cfg.gated), dtype
        )
    return p


def _capacity(n_tokens: int, cfg: MoeConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe(params: Params, x: jax.Array, cfg: MoeConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    if cfg.dispatch_groups > 1 and (x.shape[0] * x.shape[1]) % cfg.dispatch_groups == 0:
        return moe_grouped(params, x, cfg)
    b, s, d = x.shape
    dt = x.dtype
    t = b * s
    xt = shard(x.reshape(t, d), ("tokens", None))
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg)

    logits = (xt.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # (T, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)         # (T, k, E)
    flat_oh = onehot.reshape(t * k, e)
    pos_in_expert = jnp.cumsum(flat_oh, axis=0) - flat_oh          # (T·k, E)
    pos = jnp.sum(pos_in_expert * flat_oh, axis=-1)                # (T·k,)
    expert = gate_idx.reshape(t * k)
    keep = pos < cap

    # scatter token indices into the (E, C) dispatch table.
    # NB: all (T,)-sized intermediates stay exactly T long — a pad row
    # (T+1) makes the token dim odd and therefore UNSHARDABLE, which
    # costs a full-size all-reduce per layer at mesh scale (found via the
    # HLO byte profile; see EXPERIMENTS.md §Perf). OOB indices with
    # mode="drop"/"fill" give the pad semantics without the pad row.
    slot = expert * cap + pos                                      # (T·k,)
    slot = jnp.where(keep, slot, e * cap)                          # dropped -> OOB
    dispatch = jnp.full((e * cap,), t, dtype=jnp.int32)            # t = OOB id
    token_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    dispatch = dispatch.at[slot].set(token_ids, mode="drop").reshape(e, cap)

    # gather tokens (OOB dispatch ids read as 0), run expert FFNs over E
    xe = xt.at[dispatch].get(mode="fill", fill_value=0)            # (E, C, d)
    xe = shard(xe, ("experts", "expert_cap", None))
    h = jnp.einsum("ecd,edf->ecf", xe, params["wi"].astype(dt))
    if cfg.gated:
        g = jnp.einsum("ecd,edf->ecf", xe, params["wg"].astype(dt))
        h = layers.activation(cfg.act, g) * h
    else:
        h = layers.activation(cfg.act, h)
    ye = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dt))    # (E, C, d)
    ye = shard(ye, ("experts", "expert_cap", None))

    # combine: weighted scatter-add back to tokens (OOB ids dropped; the
    # output stays (T, d) so the token dim keeps its batch sharding)
    gate_flat = jnp.where(keep, gate_vals.reshape(t * k), 0.0)
    src_token = jnp.where(keep, token_ids, t)                      # t = OOB
    ye_flat = ye.reshape(e * cap, d)
    picked = ye_flat[jnp.where(keep, expert * cap + pos, 0)]       # (T·k, d)
    out = jnp.zeros((t, d), dt).at[src_token].add(
        picked * gate_flat[:, None].astype(dt), mode="drop"
    )
    out = shard(out, ("tokens", None))

    # Switch aux loss: E * sum(frac_tokens_e * mean_prob_e)
    frac = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = cfg.aux_loss_weight * e * jnp.sum(frac * mean_prob)
    return _finish(params, x, out, aux, cfg)


def _finish(params, x, out, aux, cfg):
    b, s, d = x.shape
    dt = x.dtype

    out = out.reshape(b, s, d)
    if cfg.residual_d_ff:
        out = out + layers.mlp(
            params["residual"], x,
            layers.MlpConfig(cfg.d_model, cfg.residual_d_ff, cfg.act, cfg.gated),
        )
    return out, aux


def moe_grouped(params: Params, x: jax.Array,
                cfg: MoeConfig) -> tuple[jax.Array, jax.Array]:
    """Group-local dispatch (§Perf): routing, capacity positions, gather and
    combine all happen WITHIN G token groups aligned to the DP shards, so no
    dispatch collective ever crosses devices. The expert einsum computes each
    (group, expert) slot block on the device owning (data=g, model=e) — the
    compute is identical to the global path up to per-group capacity
    (standard local-dispatch EP semantics; equals the dense reference when
    capacity is ample, tested)."""
    b, s, d = x.shape
    dt = x.dtype
    t = b * s
    G = cfg.dispatch_groups
    tg = t // G
    e, k = cfg.n_experts, cfg.top_k
    cap = max(8, -(-int(tg * k * cfg.capacity_factor / e) // 8) * 8)

    xg = shard(x.reshape(G, tg, d), ("tokens", None, None))
    logits = (xg.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (G, tg, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (G, tg, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)       # (G, tg, k, E)
    flat_oh = onehot.reshape(G, tg * k, e)
    pos_in_expert = jnp.cumsum(flat_oh, axis=1) - flat_oh       # per-group!
    pos = jnp.sum(pos_in_expert * flat_oh, axis=-1)             # (G, tg·k)
    expert = gate_idx.reshape(G, tg * k)
    keep = pos < cap

    slot = jnp.where(keep, expert * cap + pos, e * cap)         # OOB drop
    token_ids = jnp.tile(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (G, 1))
    gidx = jnp.arange(G, dtype=jnp.int32)[:, None]
    dispatch = jnp.full((G, e * cap), tg, dtype=jnp.int32)
    dispatch = dispatch.at[gidx, slot].set(token_ids, mode="drop")

    valid = dispatch < tg                                       # (G, E·C)
    safe = jnp.minimum(dispatch, tg - 1)
    xe = jnp.take_along_axis(xg, safe[..., None], axis=1)       # group-LOCAL
    xe = jnp.where(valid[..., None], xe, 0).reshape(G, e, cap, d)
    xe = shard(xe, ("tokens", "experts", None, None))           # (dp, model)

    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"].astype(dt))
    if cfg.gated:
        gg = jnp.einsum("gecd,edf->gecf", xe, params["wg"].astype(dt))
        h = layers.activation(cfg.act, gg) * h
    else:
        h = layers.activation(cfg.act, h)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(dt))
    ye = shard(ye, ("tokens", "experts", None, None))

    # token-major combine: gather each token's k expert outputs, then a
    # group-local scatter-add. NB a slot-major variant (scatter ye into the
    # token buffer and all-reduce only (G, tg, d)) was napkin-math better
    # (T×d ideal volume) but measured 2.2× WORSE under GSPMD (34 s → 76 s
    # T_coll on arctic train_4k) — the cross-shard scatter lowers as
    # all-gather + all-reduce; refutation logged in EXPERIMENTS.md §Perf.
    gate_flat = jnp.where(keep, gate_vals.reshape(G, tg * k), 0.0)
    src_token = jnp.where(keep, token_ids, tg)                  # OOB drop
    ye_flat = ye.reshape(G, e * cap, d)
    picked = jnp.take_along_axis(
        ye_flat, jnp.where(keep, expert * cap + pos, 0)[..., None], axis=1)
    out = jnp.zeros((G, tg, d), dt).at[gidx, src_token].add(
        picked * gate_flat[..., None].astype(dt), mode="drop")
    out = shard(out, ("tokens", None, None))

    frac = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_weight * e * jnp.sum(frac * mean_prob)
    return _finish(params, x, out.reshape(t, d), aux, cfg)

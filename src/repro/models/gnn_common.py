"""GNN substrate: segment message-passing ops + neighbor sampling.

JAX sparse is BCOO-only, so message passing is implemented directly over an
edge index with ``jax.ops.segment_sum`` / ``segment_max`` — this IS part of
the system (see assignment note). The fanout sampler is the real host-side
neighbor sampler used by the ``minibatch_lg`` shape.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def segment_softmax(
    logits: jax.Array, segment_ids: jax.Array, num_segments: int
) -> jax.Array:
    """Softmax over entries sharing a segment id (edge-softmax)."""
    maxes = jax.ops.segment_max(
        logits, segment_ids, num_segments=num_segments,
        indices_are_sorted=False,
    )
    maxes = jnp.where(jnp.isfinite(maxes), maxes, 0.0)
    shifted = logits - maxes[segment_ids]
    ex = jnp.exp(shifted)
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments=num_segments)
    return ex / (denom[segment_ids] + 1e-9)


def scatter_mean(
    values: jax.Array, segment_ids: jax.Array, num_segments: int
) -> jax.Array:
    s = jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
    c = jax.ops.segment_sum(
        jnp.ones(values.shape[:1], values.dtype), segment_ids,
        num_segments=num_segments,
    )
    return s / jnp.clip(c, 1.0)[(...,) + (None,) * (values.ndim - 1)]


# --------------------------------------------------------------------------
# host-side graph structures + fanout sampler
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    @classmethod
    def from_edge_index(cls, src: np.ndarray, dst: np.ndarray, n_nodes: int):
        order = np.argsort(dst, kind="stable")
        src_s, dst_s = src[order], dst[order]
        counts = np.bincount(dst_s, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return cls(indptr=indptr, indices=src_s.astype(np.int64))


def sample_fanout(
    graph: CSRGraph, seed_nodes: np.ndarray, fanouts: list[int],
    rng: np.random.Generator,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """GraphSAGE fanout sampling.

    Returns (nodes, src, dst): ``nodes`` is the union of sampled nodes with
    seeds first; (src, dst) are edges in *local* (renumbered) ids.
    """
    node_map: dict[int, int] = {int(n): i for i, n in enumerate(seed_nodes)}
    nodes = [int(n) for n in seed_nodes]
    src_l, dst_l = [], []
    frontier = list(seed_nodes)
    for fanout in fanouts:
        nxt = []
        for u in frontier:
            lo, hi = graph.indptr[u], graph.indptr[u + 1]
            neigh = graph.indices[lo:hi]
            if len(neigh) == 0:
                continue
            if len(neigh) > fanout:
                neigh = rng.choice(neigh, size=fanout, replace=False)
            for v in neigh:
                v = int(v)
                if v not in node_map:
                    node_map[v] = len(nodes)
                    nodes.append(v)
                src_l.append(node_map[v])
                dst_l.append(node_map[int(u)])
                nxt.append(v)
        frontier = nxt
    return (
        np.asarray(nodes, dtype=np.int64),
        np.asarray(src_l, dtype=np.int64),
        np.asarray(dst_l, dtype=np.int64),
    )


def pad_graph_batch(
    src: np.ndarray, dst: np.ndarray, n_nodes: int,
    max_nodes: int, max_edges: int,
) -> dict[str, np.ndarray]:
    """Pad a sampled subgraph to static shapes (pad edges point at a sink)."""
    e = len(src)
    if e > max_edges or n_nodes > max_nodes:
        raise ValueError(f"subgraph ({n_nodes} nodes, {e} edges) exceeds pad")
    src_p = np.full(max_edges, max_nodes - 1, dtype=np.int32)
    dst_p = np.full(max_edges, max_nodes - 1, dtype=np.int32)
    src_p[:e] = src
    dst_p[:e] = dst
    edge_mask = np.zeros(max_edges, dtype=np.float32)
    edge_mask[:e] = 1.0
    node_mask = np.zeros(max_nodes, dtype=np.float32)
    node_mask[:n_nodes] = 1.0
    return {
        "src": src_p, "dst": dst_p,
        "edge_mask": edge_mask, "node_mask": node_mask,
    }

"""Serving layer: the v2 dynamic-batching service + the v1 functional API.

:mod:`repro.serving.service` is the serving surface — typed
request/response, shape-bucketed dynamic batching over any
``IndexState``, snapshot-backed startup. :mod:`repro.serving.genesearch`
remains as the v1 compatibility layer (raw-matrix ``serve_step`` /
``insert_read_batch`` over the fixed-shape bit-sliced index).
"""

from repro.serving import genesearch, service
from repro.serving.service import (
    BatchStats,
    GeneSearchService,
    SearchRequest,
    SearchResult,
    ServiceConfig,
)

__all__ = [
    "BatchStats",
    "GeneSearchService",
    "SearchRequest",
    "SearchResult",
    "ServiceConfig",
    "genesearch",
    "service",
]

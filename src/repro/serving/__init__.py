"""Serving layer: dynamic-batching service + serving cluster + v1 compat.

:mod:`repro.serving.service` is the synchronous serving surface — typed
request/response, shape-bucketed dynamic batching over any ``IndexState``,
snapshot-backed startup. On top of it, the serving cluster:
:mod:`repro.serving.scheduler` (futures, deadline flusher, double-buffered
batch pipeline), :mod:`repro.serving.router` (K ``device_put`` replicas,
pluggable routing, hot snapshot swap under traffic) and
:mod:`repro.serving.autoscale` (admission policy + replica autoscaler
driven by the recorded batch telemetry). :mod:`repro.serving.live` adds
the write path: ``LiveGeneSearchService`` / ``LiveReplicaRouter`` serve a
:class:`repro.index.lsm.LiveIndex` (base + delta) with background
compaction. :mod:`repro.serving.genesearch` keeps the serve-geometry
config + plan helpers; its removed v1 bodies are call-time ImportError
stubs.
"""

from repro.serving import autoscale, fabric, genesearch, ipc, kmer_cache, \
    live, router, scatter, scheduler, service
from repro.serving.autoscale import (
    AdmissionPolicy,
    AutoscaleConfig,
    ReplicaAutoscaler,
)
from repro.serving.fabric import FabricConfig, FabricError, ProcessFabric, \
    WorkerLost
from repro.serving.kmer_cache import KmerCache, KmerCacheConfig, \
    merge_cache_stats, pack_codes
from repro.serving.live import Compactor, LiveGeneSearchService, \
    LiveReplicaRouter
from repro.serving.router import ReplicaRouter, RouterConfig, RoutingPolicy
from repro.serving.scatter import ScatterConfig, ScatterError, \
    ScatterGatherRouter, ShardDeadError, ShardSearchService
from repro.serving.scheduler import AsyncScheduler, ClusterStats, InsertAck, \
    SchedulerConfig
from repro.serving.service import (
    BatchStats,
    GeneSearchService,
    SearchRequest,
    SearchResult,
    ServiceConfig,
)

__all__ = [
    "AdmissionPolicy",
    "AsyncScheduler",
    "AutoscaleConfig",
    "BatchStats",
    "ClusterStats",
    "Compactor",
    "FabricConfig",
    "FabricError",
    "GeneSearchService",
    "InsertAck",
    "KmerCache",
    "KmerCacheConfig",
    "LiveGeneSearchService",
    "LiveReplicaRouter",
    "ProcessFabric",
    "ReplicaAutoscaler",
    "ReplicaRouter",
    "RouterConfig",
    "RoutingPolicy",
    "ScatterConfig",
    "ScatterError",
    "ScatterGatherRouter",
    "SchedulerConfig",
    "SearchRequest",
    "SearchResult",
    "ServiceConfig",
    "ShardDeadError",
    "ShardSearchService",
    "WorkerLost",
    "autoscale",
    "fabric",
    "genesearch",
    "ipc",
    "kmer_cache",
    "live",
    "merge_cache_stats",
    "pack_codes",
    "router",
    "scatter",
    "scheduler",
    "service",
]

"""Versioned cross-batch membership cache: the serving half of probe reuse.

Membership of a kmer is a pure function of ``(kmer, IndexState)`` — the
same fact that makes intra-batch dedup exact (``query.execute(...,
dedup=True)``) makes it exact to memoize per-kmer membership *across*
batches: overlapping shotgun reads from one genomic region re-probe the
same kmers thousands of times, and every repeat after the first is a
cache hit instead of a hash + matrix gather.

:class:`KmerCache` is that memo, built to be cheaper per kmer than the
compiled probe it shortcuts (a python dict of byte keys is NOT — an
early version lost to XLA by 2x):

* **Keys** are kmers packed 2 bits/base into one ``uint64``
  (:func:`pack_codes` — k <= 32 covers the paper's k=31). Packing a
  whole ``(batch, L)`` read matrix is five vectorized shift-or passes
  (doubling blocks of 1, 2, 4, 8, 16 bases), ~20x faster than per-window
  ``tobytes()``.
* **Store** is two tiers of parallel arrays, both key-sorted: a large
  immutable-between-compactions *main* tier and a small *nursery* that
  absorbs fresh inserts. A batch lookup is one ``np.searchsorted`` per
  tier plus one fancy-index row gather — no per-key python at all.
* **Values** are per-kmer membership rows (the engine-shaped
  ``query_batch`` output for one kmer — a bool for the flat BF, an
  ``(n_files,)`` bool vector for COBS/RAMBO, a packed ``(F/32,)``
  uint32 mask for the bit-sliced index), stacked in one matrix so a
  warm batch is served by a single C-level gather.
* **LRU** is by last-hit tick: every lookup stamps its hits with a
  monotone batch counter, and when an insert pushes the store past
  ``capacity`` the lowest-stamped entries are evicted (the classic
  approximation: exact LRU order *between* evictions is not tracked,
  victims are always the least-recently-hit).

**Invalidation contract.** The logical cache key is ``(packed kmer,
version, delta_seq)`` — the two staleness coordinates every
``SearchResult`` already carries. The static service pins ``version``
as its cache's :meth:`begin` generation: a base swap (``swap_state`` /
compaction ``publish``) drops every entry, because the matrix those
rows were gathered from is gone. The live service runs TWO instances:

* its **front cache** holds merged base|delta rows pinned to the full
  ``(version, delta_seq)`` — the warm batch is one lookup, and any
  write drops every merged row (cheap: see below);
* its **base-row cache** pins ``version`` only, so it survives writes.
  After a ``delta_seq`` bump, re-merging a dropped front row is a pure
  base-cache gather plus a probe of just the (small by construction)
  delta for just the missing kmers — the fine-grained half of the
  contract: a cached negative flips positive the instant a write
  lands, without the engine ever re-probing the base. The per-kmer OR
  is exactly ``lsm.merge_kmer_hits`` — the LSM split restated:
  immutable base (long-lived rows), mutable delta (re-probed rows).

Single-writer discipline: all mutation happens on the thread that
dispatches batches (the scheduler's flusher / the synchronous caller) —
the same discipline the live index's donated delta buffers already
require, so the cache adds no new locking. Counter reads from other
threads (stats scraping) see monotone ints.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics

__all__ = ["KmerCacheConfig", "KmerCache", "pack_codes",
           "merge_cache_stats"]

# distinguishes each cache instance's gauge series in the process
# registry (two live-service caches per replica must not overwrite each
# other's ``entries``); counters with the same labels would merge fine,
# but one vocabulary for both is simpler to read in a snapshot
_CACHE_IDS = itertools.count()

# nursery merges into the sorted main tier past this many fresh entries —
# bounds per-insert cost (the nursery's own merge sort stays tiny) while
# keeping main-tier re-sorts rare on a warm cache (zero on an all-hit one)
_NURSERY_MAX = 4096

# Fibonacci-hash multiplier (odd, golden-ratio) for the main tier's
# direct-mapped slot table: one wrap-around multiply spreads packed kmer
# codes across the high bits, and the table is sized >= 8x the entry
# count, so almost every warm lookup resolves with a single O(1) probe
# instead of a binary search (collision losers fall back to searchsorted)
_HASH_MULT = np.uint64(0x9E3779B97F4A7C15)


def pack_codes(reads: np.ndarray, k: int) -> np.ndarray:
    """Pack every kmer of ``(batch, L)`` reads into ``uint64`` keys.

    Returns ``(batch, L - k + 1)`` codes with base ``i + j`` of a window
    at bits ``[2j, 2j + 2)`` — the literal 2-bit packing, so codes are
    injective over kmers (requires ``k <= 32``; the paper's k=31 fits
    with 2 bits to spare). Built by block doubling: 5 shift-or passes
    combine 1, 2, 4, 8, 16-base blocks into 32-base codes which are then
    masked to ``2k`` bits, so the cost is ~5 vectorized ops over the
    read matrix instead of a per-window reduction.
    """
    if k > 32:
        raise ValueError(
            f"pack_codes packs kmers into uint64 keys, so k <= 32 "
            f"(got k={k})")
    arr = np.asarray(reads, dtype=np.uint8)
    if arr.ndim == 1:
        arr = arr[None]
    b, length = arr.shape
    n_k = length - k + 1
    if n_k < 1:
        raise ValueError(f"reads of length {length} have no {k}-mers")
    # zero-pad to 32-base windows; pad bases land at bits >= 2k and are
    # masked away, so every real window's code is exact
    acc = np.zeros((b, length + 32 - k), dtype=np.uint64)
    acc[:, :length] = arr
    for level in range(5):
        step = 1 << level
        acc = acc[:, :-step] | (acc[:, step:] << np.uint64(2 * step))
    return acc[:, :n_k] & np.uint64((1 << (2 * k)) - 1)


@dataclasses.dataclass(frozen=True)
class KmerCacheConfig:
    """Knobs of the serving membership cache (static, picklable — rides
    ``ServiceConfig`` across the fabric's process boundary)."""

    capacity: int = 1 << 16   # max cached kmers (least-recently-hit beyond)

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")


class KmerCache:
    """Membership-row memo for ONE served index state (see module doc)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        # obs: every counter bump below mirrors into the process registry
        # (pre-bound handles; one inc per batched op, not per kmer)
        labels = {"tier": "kmer_cache", "cache": next(_CACHE_IDS)}
        reg = obs_metrics.DEFAULT
        self._obs_hits = reg.counter("kmer_cache.hits", **labels)
        self._obs_misses = reg.counter("kmer_cache.misses", **labels)
        self._obs_evictions = reg.counter("kmer_cache.evictions", **labels)
        self._obs_invalidations = reg.counter(
            "kmer_cache.invalidations", **labels)
        self._obs_entries = reg.gauge("kmer_cache.entries", **labels)
        self._obs_capacity = reg.gauge("kmer_cache.capacity", **labels)
        self._obs_capacity.set(self.capacity)
        # main tier: key-sorted parallel arrays (keys / row matrix / last-
        # hit tick); nursery: same shape, absorbs inserts between merges
        self._keys: Optional[np.ndarray] = None
        self._vals: Optional[np.ndarray] = None
        self._stamp: Optional[np.ndarray] = None
        self._table: Optional[np.ndarray] = None   # slot -> main-tier index
        self._table_shift = np.uint64(64)
        self._nkeys: Optional[np.ndarray] = None
        self._nvals: Optional[np.ndarray] = None
        self._nstamp: Optional[np.ndarray] = None
        self._generation: Optional[object] = None
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        n = 0 if self._keys is None else len(self._keys)
        if self._nkeys is not None:
            n += len(self._nkeys)
        return n

    # -- the generation gate -------------------------------------------------
    def begin(self, generation) -> None:
        """Pin the state version this batch probes under.

        A changed generation means the state the cached rows were
        gathered from was replaced (hot swap / compaction publish for a
        version-keyed cache; any write for the live front cache): every
        entry drops. Same generation is the overwhelmingly common case
        and costs one comparison.
        """
        if generation != self._generation:
            if len(self):
                self.invalidations += 1
                self._obs_invalidations.inc()
                self._keys = self._vals = self._stamp = None
                self._table = None
                self._nkeys = self._nvals = self._nstamp = None
                self._obs_entries.set(0)
            self._generation = generation

    # -- lookup / fill -------------------------------------------------------
    def lookup(self, codes: np.ndarray
               ) -> Tuple[Optional[np.ndarray], np.ndarray]:
        """Batch probe: ``(rows, hit)`` for ``(n,)`` packed uint64 codes.

        ``rows`` is a fresh ``(n, ...)`` matrix with miss rows
        zero-filled — or None when the cache is empty (the caller learns
        the row shape from its own probe). ``hit`` is the ``(n,)`` bool
        mask. Hits refresh the LRU stamp.

        The warm all-hit case — the whole point of the cache — is one
        hash-probe of the main tier's direct-mapped slot table (a
        multiply, a shift and two gathers) plus one row gather and one
        stamp scatter. Codes the table can't resolve (hash-collision
        losers and real misses) fall back to a subset-sized searchsorted;
        only main-tier misses pay the (subset-sized) nursery probe.
        ``insert`` keeps the invariant that the nursery is only ever
        populated alongside a main tier.
        """
        self._tick += 1
        n = int(codes.size)
        if self._keys is None:
            self.misses += n
            self._obs_misses.inc(n)
            return None, np.zeros(n, dtype=bool)
        keys = self._keys
        cand = self._table[(codes * _HASH_MULT) >> self._table_shift]
        pos = np.maximum(cand, 0)
        hit = keys[pos] == codes           # empty slots hold index 0's key...
        hit &= cand >= 0                   # ...so mask them back out
        rows = self._vals[pos]             # direct gather (miss rows fixed up)
        if hit.all():
            self._stamp[pos] = self._tick
            self.hits += n
            self._obs_hits.inc(n)
            return rows, hit
        miss = np.flatnonzero(~hit)
        rows[miss] = 0
        self._stamp[pos[hit]] = self._tick
        # collision losers: present in the sorted tier, shadowed in the table
        sub = codes[miss]
        spos = np.minimum(np.searchsorted(keys, sub), len(keys) - 1)
        shit = keys[spos] == sub
        if shit.any():
            found = spos[shit]
            rows[miss[shit]] = self._vals[found]
            self._stamp[found] = self._tick
            hit[miss[shit]] = True
            miss = miss[~shit]
        if self._nkeys is not None and len(miss):
            sub = codes[miss]
            nkeys = self._nkeys
            npos = np.minimum(np.searchsorted(nkeys, sub), len(nkeys) - 1)
            nhit = nkeys[npos] == sub
            if nhit.any():
                found = npos[nhit]
                rows[miss[nhit]] = self._nvals[found]
                self._nstamp[found] = self._tick
                hit[miss[nhit]] = True
        n_hit = int(hit.sum())
        self.hits += n_hit
        self.misses += n - n_hit
        self._obs_hits.inc(n_hit)
        self._obs_misses.inc(n - n_hit)
        return rows, hit

    def insert(self, codes: np.ndarray, rows: np.ndarray) -> None:
        """Add freshly probed rows (``codes`` sorted-unique, all misses).

        Lands in the nursery (a small merge); the nursery folds into the
        sorted main tier — evicting least-recently-hit entries past
        ``capacity`` — when it outgrows ``_NURSERY_MAX``, the cache is
        over capacity, or there is no main tier yet, so a warm cache
        never re-sorts and lookups on a cold one stay single-tier.
        """
        stamp = np.full(codes.shape, self._tick, dtype=np.int64)
        if self._nkeys is None:
            self._nkeys = codes.copy()
            self._nvals = np.array(rows)
            self._nstamp = stamp
        else:
            keys = np.concatenate([self._nkeys, codes])
            order = np.argsort(keys, kind="stable")
            self._nkeys = keys[order]
            self._nvals = np.concatenate([self._nvals, rows])[order]
            self._nstamp = np.concatenate([self._nstamp, stamp])[order]
        if self._keys is None or len(self) > self.capacity \
                or len(self._nkeys) > _NURSERY_MAX:
            self._compact_store()
        self._obs_entries.set(len(self))

    def _compact_store(self) -> None:
        """Fold nursery into main; evict least-recently-hit past capacity."""
        tiers = [(self._keys, self._vals, self._stamp),
                 (self._nkeys, self._nvals, self._nstamp)]
        live = [t for t in tiers if t[0] is not None]
        if len(live) == 2:
            keys = np.concatenate([live[0][0], live[1][0]])
            vals = np.concatenate([live[0][1], live[1][1]])
            stamp = np.concatenate([live[0][2], live[1][2]])
        else:
            keys, vals, stamp = live[0]
        if len(keys) > self.capacity:
            n_evict = len(keys) - self.capacity
            keep = np.argpartition(stamp, n_evict)[n_evict:]
            self.evictions += n_evict
            self._obs_evictions.inc(n_evict)
            keys, vals, stamp = keys[keep], vals[keep], stamp[keep]
        order = np.argsort(keys, kind="stable")
        self._keys = keys[order]
        self._vals = vals[order]
        self._stamp = stamp[order]
        self._nkeys = self._nvals = self._nstamp = None
        # direct-mapped slot table over the sorted tier, >= 8x oversized;
        # later entries win collisions, losers resolve via searchsorted
        p = max(10, (len(self._keys) * 8 - 1).bit_length())
        self._table_shift = np.uint64(64 - p)
        self._table = np.full(1 << p, -1, dtype=np.int64)
        slots = (self._keys * _HASH_MULT) >> self._table_shift
        self._table[slots] = np.arange(len(self._keys), dtype=np.int64)

    # -- observability -------------------------------------------------------
    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    def stats(self) -> Dict[str, float]:
        """One picklable dict — the shape ClusterStats scrapers, the
        fabric's ``stats`` reply and the benches all share."""
        return {
            "hits": self.hits,
            "misses": self.misses,
            "lookups": self.lookups,
            "hit_rate": self.hit_rate,
            "entries": len(self),
            "capacity": self.capacity,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
        }


def merge_cache_stats(parts: Iterable[Optional[Dict[str, float]]]
                      ) -> Optional[Dict[str, float]]:
    """Aggregate per-replica/per-worker ``KmerCache.stats()`` dicts.

    None entries (cache-less members) are skipped; returns None when no
    member carries a cache — the routers' and the fabric gateway's
    fleet-wide hit-rate view.
    """
    merged: Optional[Dict[str, float]] = None
    for part in parts:
        if part is None:
            continue
        if merged is None:
            merged = dict(part)
            continue
        for key in ("hits", "misses", "lookups", "entries", "capacity",
                    "evictions", "invalidations"):
            merged[key] += part.get(key, 0)
    if merged is not None:
        merged["hit_rate"] = (merged["hits"] / merged["lookups"]
                              if merged["lookups"] else 0.0)
    return merged

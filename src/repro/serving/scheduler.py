"""Async event-loop serving: futures, flush deadlines, pipelined batches.

The PR-4 :class:`~repro.serving.service.GeneSearchService` is synchronous:
a bucket flushes when ``max_batch`` requests are waiting or when the
caller says so, and ``submit → flush → result`` all happen on one thread.
This module gives it a real event loop:

* **Futures** — :meth:`AsyncScheduler.submit` returns a
  ``concurrent.futures.Future[SearchResult]`` immediately; callers block
  (or chain callbacks) only when they need the answer.

* **Deadline flusher** — a background thread watches every bucket queue
  and launches a batch when it is *full* (``target_batch`` requests
  waiting — the admission knob an :class:`~repro.serving.autoscale
  .AdmissionPolicy` can move) or when its oldest request has waited
  ``max_delay_ms`` (so a lone request on an idle bucket is never held
  hostage to batching).

* **Double-buffered pipeline** — the flusher runs the *host* half of a
  batch (padding, thresholds, the ``idl_probe`` backend's per-batch probe
  planning) and dispatches the device step, then immediately starts on
  the next batch while a completer thread blocks on the previous batch's
  device output, decodes verdicts and resolves futures. The bounded
  hand-off queue (``pipeline_depth``) is the double buffer: host planning
  for batch N+1 overlaps device execution of batch N, and backpressure
  stops a fast submitter from piling up unbounded device work.

All three stages call the SAME ``_assemble`` / ``_execute`` / ``_finalize``
methods the synchronous ``flush()`` path uses, so scheduler answers are
bit-identical to direct :meth:`GeneSearchService.flush` results by
construction (asserted across engines × schemes × theta in
``tests/test_cluster.py``), and the compile-once-per-(bucket, backend)
guarantee is untouched — the scheduler never introduces a new batch shape
(``compile_counts()`` still proves it). All device dispatch happens on the
single flusher thread, so not even a cold-start race can double-compile a
bucket.

Telemetry rides the same bounded ring-buffer pattern as the service's
``BatchStats``: a ``stats_window``-long deque of :class:`ClusterStats`
records (flush reason, queue delay, occupancy, wall) that long soak runs
cannot grow without bound.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import queue as queue_mod
import threading
import time
from concurrent.futures import Future
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import service as service_mod
from repro.serving.autoscale import AdmissionPolicy

# unique per-scheduler label (replica ids repeat across independent
# schedulers in one process; the registry series must not)
_SCHED_IDS = itertools.count()

__all__ = [
    "SchedulerConfig",
    "ClusterStats",
    "AsyncScheduler",
    "InsertAck",
    "FLUSH_FULL",
    "FLUSH_DEADLINE",
    "FLUSH_DRAIN",
]

FLUSH_FULL = "full"          # target_batch requests were waiting
FLUSH_DEADLINE = "deadline"  # oldest request hit max_delay_ms
FLUSH_DRAIN = "drain"        # explicit drain()/close()

# writes are preferred over queries, but in bounded bursts: at most this
# many pending writes apply per burst, and an overdue query bucket gets a
# flush between bursts (a sustained insert stream cannot starve queries)
_WRITE_BURST = 64


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Event-loop knobs (static; the AdmissionPolicy moves within them)."""

    max_delay_ms: float = 2.0    # flush deadline for a bucket's oldest req
    pipeline_depth: int = 2      # dispatched-but-unmaterialized batches
    stats_window: int = 4096     # ClusterStats records kept (bounded)

    def __post_init__(self):
        if self.max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")
        if self.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")


@dataclasses.dataclass(frozen=True)
class ClusterStats:
    """Accounting for one batch executed through the event loop.

    Extends the service's ``BatchStats`` view with the cluster-level
    fields the autoscaler consumes: which replica ran it, which state
    version answered, why the batch flushed, and how long its oldest
    request queued before dispatch.
    """

    replica: int         # router replica id (0 for a lone scheduler)
    version: int         # IndexState version that served the batch
    bucket: int          # kmer bucket
    n_requests: int      # real requests in the batch
    batch_rows: int      # fixed physical batch shape (= max_batch)
    flush_reason: str    # FLUSH_FULL | FLUSH_DEADLINE | FLUSH_DRAIN
    queue_ms: float      # oldest request's wait before dispatch
    wall_ms: float       # dispatch -> results materialized
    cache_hits: int = 0      # kmer-cache hits THIS batch (0 = cache off)
    cache_lookups: int = 0   # kmer-cache lookups this batch

    @property
    def occupancy(self) -> float:
        return self.n_requests / max(self.batch_rows, 1)


@dataclasses.dataclass(frozen=True)
class InsertAck:
    """Acknowledgement of one admitted write batch: the state coordinates
    at which it became searchable (``SearchResult`` stamps the same pair,
    so read-your-writes is checkable: any result with ``delta_seq >=
    ack.delta_seq`` — or a later ``base_version`` — saw the write)."""

    base_version: int
    delta_seq: int
    n_reads: int


@dataclasses.dataclass
class _Pending:
    request: service_mod.SearchRequest
    n_kmers: int
    future: Future
    t_enq: float
    # (trace_id, parent_span_id) minted at admission — locally, or in the
    # gateway process when the request came over an IPC frame
    trace: Optional[Tuple[str, Optional[str]]] = None


@dataclasses.dataclass
class _PendingWrite:
    reads: np.ndarray
    file_ids: Optional[np.ndarray]
    future: Future
    t_enq: float
    seq: Optional[int] = None    # router-assigned fleet sequence number
    trace: Optional[Tuple[str, Optional[str]]] = None


class AsyncScheduler:
    """Futures + deadline flusher + pipelined execution over one service.

    Takes ownership of the wrapped :class:`GeneSearchService`: while the
    scheduler is live, do not call ``submit``/``flush`` on the service
    directly (the scheduler keeps its own queues and drives the service's
    flush pipeline stages from its worker threads).
    """

    def __init__(self, service: service_mod.GeneSearchService,
                 config: Optional[SchedulerConfig] = None, *,
                 admission: Optional[AdmissionPolicy] = None,
                 on_batch=None, replica_id: int = 0):
        self._svc = service
        self.config = config or SchedulerConfig()
        self.admission = admission
        self._on_batch = on_batch    # cluster hook: fn(ClusterStats, now)
        self.replica_id = replica_id
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)    # flusher wakeups
        self._idle = threading.Condition(self._lock)    # drain/pause waits
        self._queues: Dict[int, Deque[_Pending]] = {}
        self._writes: Deque[_PendingWrite] = collections.deque()
        self._inflight_ids: set = set()
        self._next_id = 0
        self._outstanding = 0        # submitted, future not yet resolved
        self._inflight = 0           # batches dispatched, not finalized
        self._paused = False
        self._draining = False
        self._closed = False
        self._wrote_last = False     # last flush was a write burst
                                     # (alternation vs overdue queries)
        self.stats: Deque[ClusterStats] = collections.deque(
            maxlen=self.config.stats_window)
        labels = {"tier": "scheduler", "replica": replica_id,
                  "sched": next(_SCHED_IDS)}
        reg = obs_metrics.DEFAULT
        self._obs_flushes = {
            reason: reg.counter("scheduler.flushes", reason=reason,
                                **labels)
            for reason in (FLUSH_FULL, FLUSH_DEADLINE, FLUSH_DRAIN)}
        self._obs_queue_ms = reg.histogram("scheduler.queue_ms", **labels)
        self._obs_wall_ms = reg.histogram("scheduler.wall_ms", **labels)
        self._obs_writes = reg.counter("scheduler.write_batches", **labels)
        self._obs_write_reads = reg.counter("scheduler.write_reads",
                                            **labels)
        # the double buffer: flusher blocks here once `pipeline_depth`
        # batches are dispatched but not yet materialized
        self._handoff: queue_mod.Queue = queue_mod.Queue(
            maxsize=self.config.pipeline_depth)
        self._flusher = threading.Thread(
            target=self._flusher_loop, daemon=True,
            name=f"idl-flusher-{replica_id}")
        self._completer = threading.Thread(
            target=self._completer_loop, daemon=True,
            name=f"idl-completer-{replica_id}")
        self._flusher.start()
        self._completer.start()

    # -- delegated views ----------------------------------------------------
    @property
    def service(self) -> service_mod.GeneSearchService:
        return self._svc

    @property
    def outstanding(self) -> int:
        """Requests whose futures have not resolved yet (queued or in a
        dispatched batch) — the router's least-outstanding signal."""
        with self._lock:
            return self._outstanding

    def compile_counts(self) -> Dict[int, int]:
        return self._svc.compile_counts()

    def cache_stats(self):
        """The wrapped service's ``KmerCache.stats()`` (None = cache off)."""
        return self._svc.cache_stats()

    # -- admission ----------------------------------------------------------
    def submit(self, request: Union[service_mod.SearchRequest, np.ndarray],
               *, trace: Optional[Tuple[str, Optional[str]]] = None
               ) -> Future:
        """Enqueue one read; returns a Future resolving to SearchResult.

        ``trace`` parents this request's spans under an admission span
        minted elsewhere (the fabric gateway / scatter router); None
        mints a fresh trace id here.
        """
        req, n_kmers = self._svc._normalize(request)
        return self._enqueue(req, n_kmers, trace=trace)

    def _enqueue(self, req: service_mod.SearchRequest, n_kmers: int, *,
                 trace: Optional[Tuple[str, Optional[str]]] = None
                 ) -> Future:
        """Admission for an already-normalized request (router fast path)."""
        bucket = self._svc.bucket_for(n_kmers)
        fut: Future = Future()
        now = time.monotonic()
        if trace is None and obs_trace.DEFAULT.enabled:
            trace = (obs_trace.DEFAULT.mint_trace(), None)
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            rid = req.request_id
            if rid is None:
                rid = self._next_id
            elif rid in self._inflight_ids:
                # same rule as the sync service (PR-4 hardening): two live
                # results with one id would make caller-side keying and the
                # hot-swap audit trail ambiguous
                raise ValueError(
                    f"request id {rid} is already in flight")
            self._next_id = max(self._next_id, rid) + 1
            self._inflight_ids.add(rid)
            pending = _Pending(
                request=service_mod.SearchRequest(read=req.read,
                                                  request_id=rid),
                n_kmers=n_kmers, future=fut, t_enq=now, trace=trace)
            self._queues.setdefault(bucket, collections.deque()
                                    ).append(pending)
            self._outstanding += 1
            if self.admission is not None:
                self.admission.observe_arrival(bucket, now)
            self._work.notify_all()
        return fut

    def submit_insert(self, reads, file_ids=None, *,
                      seq: Optional[int] = None,
                      trace: Optional[Tuple[str, Optional[str]]] = None
                      ) -> Future:
        """Admit one write batch; returns a Future[InsertAck].

        Requires a live-index service (one exposing ``apply_insert`` —
        :class:`~repro.serving.live.LiveGeneSearchService`); a static
        service raises immediately. ``seq`` threads a router-assigned
        fleet sequence number through to the live index so every
        replica's watermark is the fleet journal's (standalone callers
        leave it None and the index numbers locally). Writes are applied
        by the flusher thread *between* query batches, preferred over
        queued queries in bounded bursts (the insert-to-searchable
        latency knob; overdue queries still flush between bursts), and on
        the SAME thread as all query dispatch — which is exactly the
        single-dispatch-thread discipline the live index's donated delta
        buffers require. Writes count toward ``outstanding`` (``drain``
        waits for them) and are gated by ``pause`` (the hot-swap /
        compaction-publish window).
        """
        if not hasattr(self._svc, "apply_insert"):
            raise TypeError(
                f"{type(self._svc).__name__} is not writable — wrap a "
                f"LiveIndex in a LiveGeneSearchService to serve a write "
                f"path (repro.serving.live)")
        reads = np.asarray(reads, dtype=np.uint8)
        if reads.ndim == 1:
            reads = reads[None]
        fids = (None if file_ids is None
                else np.asarray(file_ids, dtype=np.int32).reshape(-1))
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if trace is None and obs_trace.DEFAULT.enabled:
                trace = (obs_trace.DEFAULT.mint_trace(), None)
            self._writes.append(_PendingWrite(
                reads=reads, file_ids=fids, future=fut,
                t_enq=time.monotonic(),
                seq=None if seq is None else int(seq), trace=trace))
            self._outstanding += 1
            self._work.notify_all()
        return fut

    def search(self, reads: Sequence[np.ndarray]
               ) -> List[service_mod.SearchResult]:
        """Synchronous convenience: submit all, drain, results in order."""
        futures = [self.submit(r) for r in reads]
        self.drain()
        return [f.result() for f in futures]

    # -- lifecycle ----------------------------------------------------------
    def drain(self) -> None:
        """Flush every queued request (deadlines ignored) and block until
        all futures are resolved. Zero futures are dropped: anything
        submitted before drain() returns has a result or an exception."""
        with self._lock:
            if self._paused:
                raise RuntimeError("cannot drain a paused scheduler")
            self._draining = True
            self._work.notify_all()
            while self._outstanding > 0:
                self._idle.wait()
            self._draining = False

    def pause(self) -> None:
        """Stop launching batches and wait for in-flight ones to finish.

        Queued requests stay queued (their futures stay pending) — this is
        the hot-swap window: with zero batches in flight, the service's
        state can be swapped and every already-dispatched result is
        guaranteed to carry the version that actually computed it.
        """
        with self._lock:
            self._paused = True
            while self._inflight > 0:
                self._idle.wait()

    def resume(self) -> None:
        with self._lock:
            self._paused = False
            self._work.notify_all()

    def close(self) -> None:
        """Drain, then stop both worker threads. Idempotent."""
        with self._lock:
            if self._closed:
                return
            if self._paused:
                self._paused = False
                self._work.notify_all()
        self.drain()
        with self._lock:
            self._closed = True
            self._work.notify_all()
        self._handoff.put(None)                 # completer sentinel
        self._flusher.join(timeout=10)
        self._completer.join(timeout=10)

    def __enter__(self) -> "AsyncScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- the event loop -----------------------------------------------------
    def _knobs(self, bucket: int, now: float) -> Tuple[int, float]:
        """(target_batch, deadline_s) — adaptive when admission is set."""
        max_batch = self._svc.config.max_batch
        if self.admission is None:
            return max_batch, self.config.max_delay_ms * 1e-3
        return (self.admission.target_batch(bucket, now, max_batch),
                self.admission.deadline_ms(bucket, now, max_batch) * 1e-3)

    def _pick(self, now: float):
        """Choose the next bucket to flush (caller holds the lock).

        Overdue buckets win over full ones: a sustained hot bucket must
        not starve a lone request on a quiet bucket past its deadline
        (the most-overdue bucket flushes first; full buckets flush
        whenever nothing is overdue, which is the common case).
        """
        if self._paused:
            return None
        best_overdue = None
        full = None
        for bucket, q in self._queues.items():
            if not q:
                continue
            if self._draining:
                return bucket, FLUSH_DRAIN
            target, deadline_s = self._knobs(bucket, now)
            overdue = (now - q[0].t_enq) - deadline_s
            if overdue >= 0 and (best_overdue is None
                                 or overdue > best_overdue[1]):
                best_overdue = (bucket, overdue)
            elif full is None and len(q) >= target:
                full = bucket
        if best_overdue is not None:
            return best_overdue[0], FLUSH_DEADLINE
        return (full, FLUSH_FULL) if full is not None else None

    def _next_timeout(self, now: float) -> Optional[float]:
        """Seconds until the earliest bucket deadline (None = no queue)."""
        timeout = None
        for bucket, q in self._queues.items():
            if not q:
                continue
            _, deadline_s = self._knobs(bucket, now)
            remain = max(q[0].t_enq + deadline_s - now, 0.0)
            timeout = remain if timeout is None else min(timeout, remain)
        return timeout

    def _apply_writes(self, writes: List[_PendingWrite]) -> None:
        """Apply a write burst (flusher thread, outside the lock)."""
        trc = obs_trace.DEFAULT
        for w in writes:
            t0 = time.monotonic()
            try:
                version, seq = self._svc.apply_insert(
                    w.reads, w.file_ids, seq=w.seq)
                w.future.set_result(InsertAck(
                    base_version=version, delta_seq=seq,
                    n_reads=int(w.reads.shape[0])))
                status = "ok"
            except Exception as e:  # noqa: BLE001 - forward to futures
                if not w.future.done():
                    w.future.set_exception(e)
                status = "error"
            if w.trace is not None and trc.enabled:
                trc.emit("replica_apply", w.trace[0], w.trace[1],
                         t0, time.monotonic(), status=status,
                         attrs={"replica": self.replica_id,
                                "n_reads": int(w.reads.shape[0]),
                                "queue_ms": (t0 - w.t_enq) * 1e3})
        self._obs_writes.inc(len(writes))
        self._obs_write_reads.inc(sum(int(w.reads.shape[0])
                                      for w in writes))
        with self._lock:
            self._inflight -= 1
            self._outstanding -= len(writes)
            self._idle.notify_all()

    def _flusher_loop(self) -> None:
        while True:
            with self._lock:
                writes: List[_PendingWrite] = []
                while True:
                    if self._closed:
                        # zero dropped futures, even on a racy late submit:
                        # anything still queued fails loudly instead of
                        # hanging its caller forever
                        err = RuntimeError("scheduler closed")
                        for q in self._queues.values():
                            while q:
                                q.popleft().future.set_exception(err)
                        while self._writes:
                            self._writes.popleft().future.set_exception(err)
                        return
                    now = time.monotonic()
                    pick = self._pick(now)
                    # writes beat queries: an admitted insert becomes
                    # searchable before the next query batch dispatches —
                    # THE insert-to-searchable latency lever (live_bench
                    # measures it). The preference is BOUNDED: bursts cap
                    # at _WRITE_BURST and a deadline-overdue (or draining)
                    # bucket flushes between consecutive bursts, so a
                    # sustained insert stream cannot starve queries past
                    # their deadlines. Gated by pause like query batches.
                    overdue = pick is not None and pick[1] != FLUSH_FULL
                    if self._writes and not self._paused and \
                            not (overdue and self._wrote_last):
                        while self._writes and len(writes) < _WRITE_BURST:
                            writes.append(self._writes.popleft())
                        self._inflight += 1      # pause() waits for a burst
                        self._wrote_last = True
                        break
                    if pick is not None:
                        self._wrote_last = False
                        break
                    self._work.wait(
                        timeout=None if self._paused
                        else self._next_timeout(now))
                if writes:
                    take = None
                else:
                    bucket, reason = pick
                    q = self._queues[bucket]
                    take = [q.popleft() for _ in
                            range(min(len(q), self._svc.config.max_batch))]
                    self._inflight += 1
            if writes:
                self._apply_writes(writes)
                continue
            # host + dispatch, outside the lock: assemble the padded batch,
            # run per-batch host planning (idl_probe) and launch the device
            # step; with async dispatch the completer owns the blocking wait
            try:
                pairs = [(p.request, p.n_kmers) for p in take]
                t0 = time.monotonic()
                # kmer-cache counters only move on this (dispatch) thread,
                # so a before/after snapshot is exactly THIS batch's traffic
                cache = self._svc.kmer_cache
                h0, l0 = ((cache.hits, cache.lookups)
                          if cache is not None else (0, 0))
                batch_args = self._svc._assemble(pairs, bucket)
                t_asm = time.monotonic()
                out = self._svc._execute(bucket, *batch_args)
                t_exec = time.monotonic()
                dh, dl = ((cache.hits - h0, cache.lookups - l0)
                          if cache is not None else (0, 0))
                self._handoff.put((bucket, take, out, reason, t0, t_asm,
                                   t_exec, dh, dl))
            except Exception as e:  # noqa: BLE001 - forward to futures
                self._fail_batch(take, e)

    def _completer_loop(self) -> None:
        while True:
            item = self._handoff.get()
            if item is None:
                return
            bucket, take, out, reason, t0, t_asm, t_exec, cache_hits, \
                cache_lookups = item
            pairs = [(p.request, p.n_kmers) for p in take]
            try:
                results = self._svc._finalize(pairs, bucket, out)
            except Exception as e:  # noqa: BLE001 - forward to futures
                self._fail_batch(take, e)
                continue
            now = time.monotonic()
            wall_ms = (now - t0) * 1e3
            rows = self._svc.config.max_batch
            stats = ClusterStats(
                replica=self.replica_id, version=self._svc.version,
                bucket=bucket, n_requests=len(take), batch_rows=rows,
                flush_reason=reason,
                queue_ms=(t0 - min(p.t_enq for p in take)) * 1e3,
                wall_ms=wall_ms,
                cache_hits=cache_hits, cache_lookups=cache_lookups)
            self.stats.append(stats)
            self._obs_flushes[reason].inc()
            self._obs_queue_ms.observe(stats.queue_ms)
            self._obs_wall_ms.observe(wall_ms)
            self._svc._record_batch(service_mod.BatchStats(
                bucket=bucket, n_requests=len(take), batch_rows=rows,
                pad_rows=rows - len(take),
                pad_kmers=rows * bucket - sum(p.n_kmers for p in take),
                wall_ms=wall_ms))
            service_mod.emit_request_spans(
                [(p.trace, p.t_enq, p.request.request_id) for p in take],
                bucket=bucket, t0=t0, t_asm=t_asm, t_exec=t_exec,
                t_done=now, replica=self.replica_id,
                version=self._svc.version)
            if self.admission is not None:
                self.admission.observe_batch(stats, now)
            if self._on_batch is not None:
                self._on_batch(stats, now)
            for p, res in zip(take, results):
                p.future.set_result(res)
            self._batch_done(take)

    def _fail_batch(self, take: List[_Pending], exc: Exception) -> None:
        for p in take:
            if not p.future.done():
                p.future.set_exception(exc)
        self._batch_done(take)

    def _batch_done(self, take: List[_Pending]) -> None:
        with self._lock:
            self._inflight -= 1
            self._outstanding -= len(take)
            for p in take:
                self._inflight_ids.discard(p.request.request_id)
            self._idle.notify_all()

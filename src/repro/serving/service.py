"""Gene-search serving v2: typed requests, shape-bucketed dynamic batching.

The v1 surface (:mod:`repro.serving.genesearch`) is a stateless function
over one raw matrix with one fixed read length. Real query streams are
ragged — reads of every length, arriving one at a time — and a compiled
serving path must not recompile per length. This layer closes that gap:

* **Typed boundary** — :class:`SearchRequest` in (one read of any length
  >= k), :class:`SearchResult` out (per-file verdicts + decoded ids +
  which bucket served it). The index itself is an immutable
  :class:`~repro.index.state.IndexState` pytree, so ANY engine (flat BF,
  COBS, RAMBO, bit-sliced) serves through the same front-end and hot
  snapshot swap is one attribute assignment.

* **Shape-bucketed dynamic batching** — a request with ``n`` kmers is
  padded to the next power-of-two kmer bucket (floor
  ``ServiceConfig.min_bucket_kmers``) and batched with its bucket peers
  into a fixed ``(max_batch, bucket + k - 1)`` shape, so each
  ``(bucket, backend)`` pair compiles **exactly once** no matter how many
  distinct read lengths arrive (asserted in ``tests/test_service.py``).
  Padding is proven not to change answers: pad kmers are masked out of
  the coverage reduction and each row keeps the integer threshold of its
  TRUE kmer count (``query.coverage_need`` — the single theta rule), so
  results are bit-identical to the engine's own unpadded ``msmt``.

* **Admission queue + stats** — ``submit`` enqueues; a bucket flushes
  when ``max_batch`` requests are waiting (or on ``flush()``). Every
  executed batch records occupancy, padding waste and wall time
  (:class:`BatchStats`) — the observability the autoscaling story needs.

* **Snapshot-backed startup** — :meth:`GeneSearchService.from_snapshot`
  boots straight from a :mod:`repro.index.store` directory.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import itertools
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import packed, query, store
from repro.index import state as state_mod
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import kmer_cache as kmer_cache_mod

BACKENDS = ("jnp", "idl_probe", "sharded")

# distinguishes each service instance's counter series in the process
# registry (a router's replicas must not share one series, or the
# router-level sum would double-count)
_SERVICE_IDS = itertools.count()


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


def bucket_for(n_kmers: int, min_bucket_kmers: int = 32) -> int:
    """The pow2 kmer bucket a request with ``n_kmers`` kmers lands in.

    Module-level so admission-owning layers WITHOUT an index — the
    process-fabric gateway routes on ``StateMeta`` alone — bucket with
    the exact geometry the worker services compile for.
    """
    return max(next_pow2(n_kmers), min_bucket_kmers)


# ---------------------------------------------------------------------------
# Typed request/response boundary.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """One query read (uint8 base codes, any length >= k)."""

    read: np.ndarray
    request_id: Optional[int] = None   # assigned by the service if None


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Engine verdicts for one request.

    ``matches``: the engine's ``msmt`` row — a scalar bool for single-set
    engines (flat BF), a (n_files,) bool vector otherwise. ``file_ids``
    decodes it: indices of matching files ((0,) for a flat-BF hit).
    """

    request_id: int
    matches: np.ndarray
    file_ids: Tuple[int, ...]
    n_kmers: int
    bucket: int
    version: int = 0     # state version that served it (hot-swap audit trail)
    delta_seq: int = 0   # live-index write watermark that served it (0 =
    #                      static index / empty delta) — with `version` this
    #                      makes staleness observable per result
    missing_files: Tuple[int, ...] = ()  # file ids whose row-probe shard was
    #                      down when a scatter-gather answer was assembled —
    #                      those entries of `matches` are vacuously False
    #                      (see repro.serving.scatter); always () elsewhere


def normalize_request(request: Union[SearchRequest, np.ndarray], k: int
                      ) -> Tuple[SearchRequest, int]:
    """Shared admission validation: ``(request, n_kmers)`` or raise.

    The ONE place a read becomes a typed request — used by the in-process
    service and the fabric gateway alike, so a malformed read is rejected
    with the same message at either boundary.
    """
    if not isinstance(request, SearchRequest):
        request = SearchRequest(read=np.asarray(request))
    read = np.asarray(request.read, dtype=np.uint8)
    if read.ndim != 1:
        # a flattened (B, L) batch would silently fuse reads across
        # their boundaries — one request is ONE read (batch via search)
        raise ValueError(
            f"submit takes one 1-D read, got shape {read.shape}; "
            f"submit each read separately (or use search())")
    n_kmers = read.shape[0] - k + 1
    if n_kmers < 1:
        raise ValueError(
            f"read of length {read.shape[0]} has no {k}-mers")
    return SearchRequest(read=read, request_id=request.request_id), n_kmers


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving knobs (static for the life of the service)."""

    theta: float = 1.0            # kmer-coverage threshold for a file match
    backend: str = "jnp"          # "jnp" | "idl_probe" | "sharded"
    max_batch: int = 8            # rows per bucket step (fixed batch shape)
    min_bucket_kmers: int = 32    # floor of the pow2 kmer buckets
    auto_flush: bool = True       # flush a bucket once max_batch are waiting
    stats_window: int = 4096      # batches of telemetry kept (bounded)
    # cross-batch membership cache (None = off): per-kmer probe results are
    # memoized under the served state's version — exact by construction
    # (see repro.serving.kmer_cache); the win for overlapping read streams
    kmer_cache: Optional[kmer_cache_mod.KmerCacheConfig] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown serving backend {self.backend!r} "
                f"(want one of {BACKENDS})")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")


@dataclasses.dataclass(frozen=True)
class BatchStats:
    """Accounting for one executed (bucket, batch) step."""

    bucket: int          # kmer bucket (padded kmer count)
    n_requests: int      # real requests served
    batch_rows: int      # fixed batch shape rows (= max_batch)
    pad_rows: int        # batch_rows - n_requests
    pad_kmers: int       # wasted kmer slots incl. pad rows
    wall_ms: float


def emit_request_spans(entries, *, bucket: int, t0: float, t_asm: float,
                       t_exec: float, t_done: float, replica: int = 0,
                       version: int = 0, status: str = "ok") -> None:
    """Emit the per-request span chain for one finalized batch.

    ``entries`` is ``[(trace_ctx, t_enq, request_id), ...]`` where
    ``trace_ctx`` is ``(trace_id, parent_span_id_or_None)`` minted at
    admission (possibly in another process — the fabric gateway's ctx
    rides the IPC frame). Each request gets a root ``request`` span with
    ``queue_wait → assemble → execute → finalize`` children; the batch
    stages share their (batch-level) boundaries, the queue wait is the
    request's own. The whole batch is ONE
    :meth:`~repro.obs.trace.Tracer.emit_request_chains` call (batch-
    invariant work hoisted out of the per-request loop), entirely off the
    submit hot path — the pipeline only stamps monotonic times it mostly
    takes anyway."""
    trc = obs_trace.DEFAULT
    if not trc.enabled:
        return
    stages = (("assemble", t0, t_asm), ("execute", t_asm, t_exec),
              ("finalize", t_exec, t_done))
    trc.emit_request_chains(
        [(ctx[0], ctx[1], t_enq, rid)
         for ctx, t_enq, rid in entries if ctx is not None],
        t0, stages, t_done, status=status,
        shared_attrs={"bucket": bucket, "replica": replica,
                      "version": version})


# ---------------------------------------------------------------------------
# The per-(engine-kind) MSMT postlude — ONE threshold path (query.py).
# ---------------------------------------------------------------------------

def _msmt_reduce(kind: str, n_files: Optional[int], theta: float,
                 per, valid, need):
    """Per-kmer engine output -> per-request verdicts, padding-aware.

    All threshold math routes through ``query.file_match_mask`` /
    ``query.member_coverage`` with per-row ``need`` thresholds — the same
    single ``coverage_need`` rule every engine and ``serve_step`` use.
    """
    if kind == "bitsliced":
        if theta >= 1.0:
            # at theta=1 a row matches iff ALL its valid kmers hit, which
            # is exactly the masked AND fast path — skip the 32x popcount
            # bit expansion (need[i] == n_valid[i] by construction, so the
            # answers are identical)
            mask = query.file_match_mask(per, theta, valid=valid)
        else:
            mask = query.file_match_mask(per, theta, valid=valid, need=need)
        return packed.unpack_file_bits(mask, n_files)
    return query.member_coverage(per, theta, valid=valid, need=need)


# ---------------------------------------------------------------------------
# The service.
# ---------------------------------------------------------------------------

class GeneSearchService:
    """Dynamic-batching front-end over any :class:`IndexState` / engine."""

    def __init__(self, index, config: Optional[ServiceConfig] = None,
                 *, version: int = 0):
        self.config = config or ServiceConfig()
        self._state = state_mod.from_engine(index)
        self._k = state_mod.kmer_size(self._state.meta)
        self._version = int(version)
        self._next_id = 0
        self._pending: Dict[int, List[Tuple[SearchRequest, int]]] = {}
        self._results: Dict[int, SearchResult] = {}
        self._inflight: set = set()
        self._runners: Dict[int, Tuple] = {}
        self.kmer_cache: Optional[kmer_cache_mod.KmerCache] = (
            kmer_cache_mod.KmerCache(self.config.kmer_cache.capacity)
            if self.config.kmer_cache is not None else None)
        if self.kmer_cache is not None and self._k > 32:
            raise ValueError(
                f"kmer_cache packs kmers into uint64 keys, so k <= 32 "
                f"(index has k={self._k})")
        # bounded: a long-running service must not leak telemetry. The
        # deque keeps the last-N per-batch records (request_latencies_ms
        # needs individual batches); the aggregate views below read the
        # registry counters fed by _record_batch — the single feed point
        # for the sync flush AND the async scheduler's completer.
        self.batch_stats: Deque[BatchStats] = collections.deque(
            maxlen=self.config.stats_window)
        meta = self._state.meta
        labels = {"tier": "service", "engine": meta.engine,
                  "scheme": meta.scheme, "backend": self.config.backend,
                  "service": next(_SERVICE_IDS)}
        reg = obs_metrics.DEFAULT
        self._obs_requests = reg.counter("serving.requests", **labels)
        self._obs_batches = reg.counter("serving.batches", **labels)
        self._obs_batch_rows = reg.counter("serving.batch_rows", **labels)
        self._obs_pad_rows = reg.counter("serving.pad_rows", **labels)
        self._obs_pad_kmers = reg.counter("serving.pad_kmers", **labels)
        self._obs_wall_ms = reg.histogram("serving.batch_wall_ms", **labels)
        # sync-path trace bookkeeping: request id -> (trace ctx, t_enq).
        # The async scheduler keeps its own (_Pending.trace) and never
        # routes through submit(), so the two never mix.
        self._admitted: Dict[int, Tuple[Tuple[str, Optional[str]], float]] \
            = {}

    # -- construction -------------------------------------------------------
    @classmethod
    def from_snapshot(cls, directory: str,
                      config: Optional[ServiceConfig] = None,
                      **load_kw) -> "GeneSearchService":
        """Boot a service straight from a ``repro.index.store`` snapshot."""
        return cls(store.load(directory, **load_kw), config)

    @property
    def state(self) -> state_mod.IndexState:
        return self._state

    @property
    def version(self) -> int:
        """Monotone id of the :class:`IndexState` currently served (stamped
        into every :class:`SearchResult` — the hot-swap audit trail)."""
        return self._version

    @property
    def n_files(self) -> int:
        return int(self._state.meta.n_files or 1)

    def swap_state(self, index, *, version: Optional[int] = None) -> int:
        """Hot snapshot swap: atomically replace the served state.

        Because every compiled step takes the state as a *pytree argument*
        (never a baked-in constant), swapping to a state with the same
        ``StateMeta`` reuses every compiled executable — zero recompiles
        under live traffic (asserted in ``tests/test_cluster.py``). A state
        with different meta (e.g. regrouped COBS) drops the runner cache so
        the next batch recompiles against the new geometry; a different
        kmer size is rejected outright (queued requests were bucketed under
        the old ``k``, their batches would be cut into the wrong kmers).

        NOT thread-safe on its own: callers running the async scheduler
        must pause it first (``AsyncScheduler.pause`` — what
        ``ReplicaRouter.swap_snapshot`` does).
        """
        new = state_mod.from_engine(index)
        if state_mod.kmer_size(new.meta) != self._k:
            raise ValueError(
                f"cannot hot-swap to a state with kmer size "
                f"{state_mod.kmer_size(new.meta)} (service buckets were "
                f"built for k={self._k}); boot a fresh service instead")
        if new.meta != self._state.meta:
            self._runners.clear()
        self._state = new
        self._version = self._version + 1 if version is None else int(version)
        return self._version

    # -- admission ----------------------------------------------------------
    def bucket_for(self, n_kmers: int) -> int:
        return bucket_for(n_kmers, self.config.min_bucket_kmers)

    def _normalize(self, request: Union[SearchRequest, np.ndarray]
                   ) -> Tuple[SearchRequest, int]:
        """Shared admission validation: ``(request, n_kmers)`` or raise."""
        return normalize_request(request, self._k)

    def submit(self, request: Union[SearchRequest, np.ndarray]) -> int:
        """Enqueue one read; returns its request id.

        The request joins its kmer bucket's queue; with ``auto_flush`` the
        bucket executes as soon as ``max_batch`` requests are waiting.
        """
        request, n_kmers = self._normalize(request)
        rid = request.request_id
        if rid is None:
            rid = self._next_id
        elif rid in self._inflight:
            raise ValueError(
                f"request id {rid} is already in flight (pending or "
                f"unclaimed result)")
        self._next_id = max(self._next_id, rid) + 1
        self._inflight.add(rid)
        if obs_trace.DEFAULT.enabled:
            # trace id minted at admission; the span chain is emitted in
            # one pass when the batch finalizes (_flush_bucket)
            self._admitted[rid] = ((obs_trace.DEFAULT.mint_trace(), None),
                                   time.monotonic())
        req = SearchRequest(read=request.read, request_id=rid)
        bucket = self.bucket_for(n_kmers)
        self._pending.setdefault(bucket, []).append((req, n_kmers))
        if self.config.auto_flush and \
                len(self._pending[bucket]) >= self.config.max_batch:
            self._flush_bucket(bucket)
        return rid

    def flush(self) -> None:
        """Execute every queued bucket (partial batches padded)."""
        for bucket in sorted(self._pending):
            while self._pending.get(bucket):
                self._flush_bucket(bucket)
        self._pending = {b: q for b, q in self._pending.items() if q}

    def result(self, request_id: int) -> SearchResult:
        """Pop a finished request's result (KeyError if not served yet)."""
        out = self._results.pop(request_id)
        self._inflight.discard(request_id)
        return out

    def search(self, reads: Sequence[np.ndarray]) -> List[SearchResult]:
        """Synchronous convenience: submit all, flush, return in order."""
        ids = [self.submit(r) for r in reads]
        self.flush()
        return [self.result(i) for i in ids]

    # -- execution ----------------------------------------------------------
    def _runner(self, bucket: int):
        """The compiled step for one (bucket, backend) pair.

        ``"jnp"`` jits the whole step end-to-end (the state is a pytree
        argument, so the index matrices are real inputs, not baked-in
        constants). The host-planned backends (``idl_probe`` / ``sharded``)
        run the probe eagerly and jit only the coverage postlude.
        """
        r = self._runners.get(bucket)
        if r is not None:
            return r
        meta = self._state.meta
        reduce = functools.partial(
            _msmt_reduce, meta.engine, meta.n_files, self.config.theta)
        backend = self.config.backend
        if self.kmer_cache is not None:
            # cached path (any backend): the per-kmer probe runs host-side
            # through the membership cache, and only the coverage postlude
            # is jitted — one compile per bucket, same as planned backends
            post = jax.jit(reduce)

            def step(state, reads, valid, need):
                per = self._cached_per_kmer(
                    state, reads, generation=self._version)
                return post(per, valid, need)

            self._runners[bucket] = (step, post)
        elif backend == "jnp":
            @jax.jit
            def step(state, reads, valid, need):
                per = state_mod.to_engine(state).query_batch(
                    reads, backend="jnp")
                return reduce(per, valid, need)

            self._runners[bucket] = (step, step)
        else:
            post = jax.jit(reduce)
            kw = self._probe_kw()

            def step(state, reads, valid, need):
                per = state_mod.to_engine(state).query_batch(
                    reads, backend=backend, **kw)
                return post(per, valid, need)

            self._runners[bucket] = (step, post)
        return self._runners[bucket]

    def _probe_kw(self) -> Dict[str, bool]:
        """Backend kwargs for ``query_batch``: no Mosaic target on CPU, so
        ``idl_probe`` executes the planned backend with the kernel's fused
        jnp oracle instead of the (python-stepped) Pallas interpreter —
        same plan, bit-identical results."""
        if self.config.backend == "idl_probe" and \
                jax.default_backend() == "cpu":
            return {"use_ref": True}
        return {}

    def _probe_unique(self, state, kmers: np.ndarray) -> np.ndarray:
        """Probe ``(M, k)`` distinct kmers -> ``(M, ...)`` engine rows.

        Each kmer is a standalone length-k read through the dedup'd probe
        path (``query.execute(..., dedup=True)``): already-unique input
        means the dedup layer contributes only its pow2 padding (bounded
        compile shapes) and locality sort (DMA-minimal gather order).
        Small miss-sets are padded up to one floor size first — a warm
        cache yields a trickle of tiny, varied miss counts, and without
        the floor each distinct pow2 size would trigger its own XLA
        compile (seconds) to probe a handful of kmers (microseconds).
        """
        m = kmers.shape[0]
        floor = 128
        if m < floor:
            kmers = np.concatenate(
                [kmers, np.repeat(kmers[:1], floor - m, axis=0)])
        out = state_mod.to_engine(state).query_batch(
            jnp.asarray(kmers), backend=self.config.backend,
            dedup=True, **self._probe_kw())
        return np.asarray(out)[:m, 0]

    def _rows_via_cache(self, cache, state, arr, flat, generation
                        ) -> np.ndarray:
        """Per-kmer rows for ``flat`` packed codes, memoized in ``cache``.

        Warm path is pure vectorized numpy (one searchsorted + one row
        gather per tier — see ``kmer_cache``); only MISS codes are
        deduplicated and probed through the dedup'd compiled path, then
        inserted for the next batch. Returns a fresh ``(n, ...)`` row
        matrix the caller may mutate.
        """
        cache.begin(generation)
        vals, hit = cache.lookup(flat)
        if vals is None or not hit.all():
            miss = np.flatnonzero(~hit)
            uniq, first, inverse = np.unique(
                flat[miss], return_index=True, return_inverse=True)
            wins = np.lib.stride_tricks.sliding_window_view(
                arr, self._k, axis=1).reshape(-1, self._k)
            probed = self._probe_unique(state, wins[miss[first]])
            if vals is None:
                vals = np.zeros((flat.size,) + probed.shape[1:],
                                probed.dtype)
            vals[miss] = probed[inverse]
            cache.insert(uniq, probed)
        return vals

    def _rows_for_unique(self, cache, state, codes, wins, generation
                         ) -> np.ndarray:
        """Like ``_rows_via_cache`` for SORTED-UNIQUE codes with their
        aligned ``(M, k)`` windows — the live service's base-backfill
        entry point, where the (deduplicated) merged-cache misses are
        already known. Returns a fresh row matrix."""
        cache.begin(generation)
        vals, hit = cache.lookup(codes)
        if vals is None or not hit.all():
            miss = np.flatnonzero(~hit)
            probed = self._probe_unique(state, wins[miss])
            if vals is None:
                vals = np.zeros((codes.size,) + probed.shape[1:],
                                probed.dtype)
            vals[miss] = probed
            cache.insert(codes[miss], probed)
        return vals

    def _cached_per_kmer(self, state, reads, *, generation: int):
        """The cache-mediated probe: reads -> per-kmer membership rows.

        Packs the batch's kmers into uint64 codes and serves per-kmer
        rows from :class:`~repro.serving.kmer_cache.KmerCache`, probing
        only misses. Exact: membership is a pure function of ``(kmer,
        state)``. The live service overrides the runner with its merged
        base|delta variant (see ``LiveGeneSearchService._runner``).
        """
        arr = np.asarray(reads)
        codes = kmer_cache_mod.pack_codes(arr, self._k)
        flat = codes.ravel()
        vals = self._rows_via_cache(self.kmer_cache, state, arr, flat,
                                    int(generation))
        # host array straight out: the jitted postlude converts on entry,
        # which is cheaper than an explicit jnp.asarray round-trip here
        return vals.reshape(codes.shape + vals.shape[1:])

    # The flush pipeline, split into its three stages so the async
    # scheduler (repro.serving.scheduler) can overlap them across batches:
    # _assemble (host: padding + thresholds) -> _execute (device dispatch)
    # -> _finalize (host: materialize + decode). The synchronous path below
    # runs them back to back — both paths are the SAME code, so scheduler
    # answers are bit-identical to flush() by construction.

    def _assemble(self, take, bucket: int):
        """Pad ``take`` = [(request, n_kmers), ...] into the bucket's fixed
        batch shape (host-side; no device work)."""
        rows, read_len = self.config.max_batch, bucket + self._k - 1
        batch = np.zeros((rows, read_len), dtype=np.uint8)
        valid = np.zeros((rows, bucket), dtype=bool)
        need = np.zeros((rows,), dtype=np.int32)
        for i, (req, n_k) in enumerate(take):
            batch[i, :req.read.shape[0]] = req.read
            valid[i, :n_k] = True
            need[i] = query.coverage_need(self.config.theta, n_k)
        for i in range(len(take), rows):       # pad rows replay row 0;
            batch[i], valid[i], need[i] = batch[0], valid[0], need[0]
        return batch, valid, need

    def _execute(self, bucket: int, batch, valid, need):
        """Dispatch the bucket's compiled step; returns the on-device out."""
        step, _ = self._runner(bucket)         # pad results are discarded
        if self.kmer_cache is not None:
            # host arrays straight through: the cached step packs and
            # looks up on the host anyway (a jnp round-trip of the batch
            # would be copied right back), and jit converts valid/need
            return step(self._state, batch, valid, need)
        return step(self._state, jnp.asarray(batch), jnp.asarray(valid),
                    jnp.asarray(need))

    def _finalize(self, take, bucket: int, out) -> List[SearchResult]:
        """Materialize the device output and decode per-request verdicts."""
        out = np.asarray(out)                  # blocks until device done
        single_set = self._state.meta.engine == "bloom"
        results = []
        for i, (req, n_k) in enumerate(take):
            row = out[i]
            if single_set:
                fids = (0,) if bool(row) else ()
            else:
                fids = tuple(int(f) for f in np.nonzero(row)[0])
            results.append(SearchResult(
                request_id=req.request_id, matches=row, file_ids=fids,
                n_kmers=n_k, bucket=bucket, version=self._version))
        return results

    def _flush_bucket(self, bucket: int) -> None:
        queue = self._pending.get(bucket, [])
        take, self._pending[bucket] = \
            queue[:self.config.max_batch], queue[self.config.max_batch:]
        if not take:
            return
        t0 = time.monotonic()
        batch, valid, need = self._assemble(take, bucket)
        t_asm = time.monotonic()
        out = self._execute(bucket, batch, valid, need)
        t_exec = time.monotonic()
        for res in self._finalize(take, bucket, out):
            self._results[res.request_id] = res
        t_done = time.monotonic()
        self._record_batch(BatchStats(
            bucket=bucket, n_requests=len(take),
            batch_rows=self.config.max_batch,
            pad_rows=self.config.max_batch - len(take),
            pad_kmers=self.config.max_batch * bucket
            - sum(n_k for _, n_k in take),
            wall_ms=(t_done - t0) * 1e3))
        entries = []
        for req, _ in take:
            ctx, t_enq = self._admitted.pop(req.request_id, (None, t0))
            entries.append((ctx, t_enq, req.request_id))
        emit_request_spans(entries, bucket=bucket, t0=t0, t_asm=t_asm,
                           t_exec=t_exec, t_done=t_done,
                           version=self._version)

    # -- observability ------------------------------------------------------
    def _record_batch(self, bs: BatchStats) -> None:
        """The single batch-telemetry feed: window the record (the deque
        keeps per-batch detail for ``request_latencies_ms``) and mirror
        the aggregates into the process registry — both the sync flush
        and the async scheduler's completer land here, so the aggregate
        views below hold for either path."""
        self.batch_stats.append(bs)
        self._obs_requests.inc(bs.n_requests)
        self._obs_batches.inc()
        self._obs_batch_rows.inc(bs.batch_rows)
        self._obs_pad_rows.inc(bs.pad_rows)
        self._obs_pad_kmers.inc(bs.pad_kmers)
        self._obs_wall_ms.observe(bs.wall_ms)

    def compile_counts(self) -> Dict[int, int]:
        """Compiled-executable count per bucket (the compile-once proof).

        For the ``jnp`` backend this counts the end-to-end jitted step; for
        planned backends, the jitted coverage postlude (the probe itself is
        host-planned per batch by design).
        """
        return {b: counter._cache_size()
                for b, (_, counter) in sorted(self._runners.items())}

    def cache_stats(self) -> Optional[Dict[str, float]]:
        """``KmerCache.stats()`` of this service (None when cache is off)."""
        return (self.kmer_cache.stats()
                if self.kmer_cache is not None else None)

    def requests_served(self) -> int:
        """Lifetime requests served — a view over the registry counter
        (the deque is a bounded window; the counter never forgets)."""
        return int(self._obs_requests.value)

    def occupancy(self) -> float:
        """Fraction of batch rows that carried real requests (lifetime,
        registry-backed)."""
        rows = self._obs_batch_rows.value
        return self._obs_requests.value / rows if rows else 0.0

    def request_latencies_ms(self) -> List[float]:
        """Per-request latency: each request is charged its batch's wall."""
        out: List[float] = []
        for s in self.batch_stats:
            out.extend([s.wall_ms] * s.n_requests)
        return out

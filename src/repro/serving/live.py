"""Ingest-while-serving: the live index behind the serving cluster.

Three pieces close the loop between :mod:`repro.index.lsm` and the
serving stack:

* :class:`LiveGeneSearchService` — a :class:`GeneSearchService` whose
  compiled step probes **base and delta** and ORs the per-kmer membership
  before the coverage threshold, so every answer is bit-identical to a
  single index holding the union of all inserts (the exactness argument
  lives in :mod:`repro.index.lsm`). Adds ``apply_insert`` (the write the
  scheduler's admission path calls) and ``publish`` (the compaction
  swap). Results carry ``(version, delta_seq)`` — the staleness
  coordinates.

* :class:`LiveReplicaRouter` — a :class:`ReplicaRouter` whose replicas
  each hold a device-local :class:`LiveIndex`. Writes fan out to every
  replica in one total order (so per-replica ``delta_seq`` watermarks
  stay aligned with the router's write-ahead journal), queries route to
  one replica as before, and :meth:`LiveReplicaRouter.compact` folds
  delta into base fleet-wide: the merge computes ONCE off the hot path,
  optionally lands in the versioned snapshot store, then publishes
  replica-by-replica through the same pause → swap → resume window the
  PR-5 hot-swap uses — zero dropped futures, zero recompiles (the merged
  state keeps the base ``StateMeta``).

* :class:`Compactor` — a background thread that watches a live target's
  ``delta_batches()`` and triggers ``compact()`` past a threshold, the
  LSM background-merge loop.

Mid-compaction exactness: the compaction plan freezes (base, delta,
watermark ``S``) under the write lock; queries keep merging the *live*
pair while the merge computes; at publish, writes with seq > ``S``
replay into the fresh delta. Every fanned write carries its FLEET
sequence number (``submit_insert(..., seq=)``), so a replica that had
not yet applied some write ≤ ``S`` when it published recognizes the
late delivery (its new base already contains seq ≤ ``S``) and no-ops it
— watermarks stay equal to the fleet journal's on every replica, the
``(version, delta_seq)`` coordinates in acks and results stay
comparable fleet-wide, and every instant still answers exactly the
union of acknowledged inserts.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time
from concurrent.futures import Future
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import lsm, store
from repro.index import state as state_mod
from repro.obs import trace as obs_trace
from repro.serving import kmer_cache as kmer_cache_mod
from repro.serving import router as router_mod
from repro.serving import service as service_mod

__all__ = ["LiveGeneSearchService", "LiveReplicaRouter", "Compactor"]


class LiveGeneSearchService(service_mod.GeneSearchService):
    """Dynamic-batching front-end over a :class:`~repro.index.lsm.LiveIndex`.

    Same admission, bucketing, padding and threshold rules as the static
    service — the compiled step just takes TWO state pytrees and merges
    their per-kmer membership. Compile-once-per-bucket still holds: base
    and delta are arguments of the jitted step, and both keep their
    ``StateMeta`` across writes *and* compaction publishes.
    """

    def __init__(self, live: lsm.LiveIndex,
                 config: Optional[service_mod.ServiceConfig] = None):
        self._live = live
        super().__init__(live.base, config, version=live.base_version)
        # Two-store cache split (see kmer_cache module doc): the FRONT
        # cache (the inherited ``self.kmer_cache`` — what the scheduler's
        # per-batch attribution reads) holds MERGED base|delta rows keyed
        # by generation (version, delta_seq), so a warm batch is ONE
        # lookup; the base-row cache keyed by version survives writes, so
        # a delta_seq bump (which drops every merged row) only re-probes
        # the small delta — cached base rows backfill without touching
        # the engine. Compaction publishes bump version and drop both.
        self._base_cache = (
            kmer_cache_mod.KmerCache(self.config.kmer_cache.capacity)
            if self.config.kmer_cache is not None else None)

    @classmethod
    def open(cls, snapshot_dir: str,
             config: Optional[service_mod.ServiceConfig] = None, *,
             journal_path: Optional[str] = None,
             delta_cfg=None, base_version: int = 0,
             **load_kw) -> "LiveGeneSearchService":
        """Boot from snapshot + journal (crash recovery in one call)."""
        return cls(lsm.LiveIndex.open(
            snapshot_dir, journal_path=journal_path, delta_cfg=delta_cfg,
            base_version=base_version, **load_kw), config)

    @property
    def live(self) -> lsm.LiveIndex:
        return self._live

    # -- the write path -----------------------------------------------------
    def apply_insert(self, reads, file_ids=None, *, seq=None, **kw):
        """Absorb one write batch (journal + delta); returns the
        ``(base_version, delta_seq)`` at which it became searchable.

        ``seq`` carries a router-assigned fleet sequence number through to
        the live index (see :meth:`LiveIndex.insert`) so replica
        watermarks never drift from the fleet journal; standalone services
        leave it None and number locally. Must run on the same thread as
        query dispatch (the scheduler's flusher provides that; the
        synchronous path is single-threaded by construction) — the delta
        mutates between batches, never under a dispatched one.
        """
        seq = self._live.insert(reads, file_ids, seq=seq, **kw)
        return self._live.base_version, seq

    # -- compaction ---------------------------------------------------------
    def publish(self, merged: state_mod.IndexState, upto_seq: int, *,
                durable: bool = False) -> int:
        """Install a compacted base (callers hold the no-dispatch window —
        ``AsyncScheduler.pause`` — exactly like ``swap_state``). Pass
        ``durable=True`` ONLY after ``merged`` reached stable storage: it
        licenses the journal truncation (see :meth:`LiveIndex.publish`)."""
        version = self._live.publish(merged, upto_seq, durable=durable)
        self._state = self._live.base
        self._version = version
        return version

    def compact(self, scheduler=None, *, save_dir: Optional[str] = None
                ) -> int:
        """Plan → merge (off the hot path) → publish. With a scheduler,
        the publish runs inside its pause window (zero dropped futures);
        without one, the caller is the only dispatcher anyway.

        ``save_dir`` writes the merged base through the snapshot store
        BEFORE the publish, which is what allows the journal to drop the
        folded writes; without it the journal keeps them — an acked write
        stays durable across a crash either way.
        """
        plan = self._live.plan_compaction()
        merged = lsm.LiveIndex.compact(plan).block_until_ready()
        if save_dir is not None:
            store.save(merged, save_dir)
        if scheduler is not None:
            scheduler.pause()
        try:
            return self.publish(merged, plan.upto_seq,
                                durable=save_dir is not None)
        finally:
            if scheduler is not None:
                scheduler.resume()

    def delta_batches(self) -> int:
        return self._live.delta_batches()

    def swap_state(self, index, *, version=None) -> int:
        raise NotImplementedError(
            "a live service's base only changes through compaction "
            "(plan_compaction -> compact -> publish); swapping an "
            "arbitrary state would orphan the delta and journal")

    # -- execution ----------------------------------------------------------
    def _runner(self, bucket: int):
        r = self._runners.get(bucket)
        if r is not None:
            return r
        meta = self._live.meta
        reduce = functools.partial(
            service_mod._msmt_reduce, meta.engine, meta.n_files,
            self.config.theta)
        backend = self.config.backend
        if self.kmer_cache is not None:
            # cached path: merged base|delta rows from the front cache
            # keyed (version, delta_seq); misses backfill from the
            # version-keyed base-row cache plus a delta probe of just the
            # missing kmers. The coordinates come from the SAME
            # ``states()`` snapshot that supplied the pytrees, so cache
            # entries can never cross a publish or a write.
            post = jax.jit(reduce)

            def step(base, delta, reads, valid, need, version, seq):
                per = self._merged_per_kmer(base, delta, reads,
                                            version, seq)
                return post(per, valid, need)

            self._runners[bucket] = (step, post)
        elif backend == "jnp":
            @jax.jit
            def step(base, delta, reads, valid, need):
                per = lsm.merge_kmer_hits(
                    state_mod.to_engine(base).query_batch(
                        reads, backend="jnp"),
                    state_mod.to_engine(delta).query_batch(
                        reads, backend="jnp"))
                return reduce(per, valid, need)

            self._runners[bucket] = (step, step)
        else:
            post = jax.jit(reduce)
            kw = ({"use_ref": True}
                  if backend == "idl_probe" and
                  jax.default_backend() == "cpu" else {})

            def step(base, delta, reads, valid, need):
                per = lsm.merge_kmer_hits(
                    state_mod.to_engine(base).query_batch(
                        reads, backend=backend, **kw),
                    state_mod.to_engine(delta).query_batch(
                        reads, backend=backend, **kw))
                return post(per, valid, need)

            self._runners[bucket] = (step, post)
        return self._runners[bucket]

    def _merged_per_kmer(self, base, delta, reads, version: int,
                         seq: int) -> np.ndarray:
        """Merged base|delta per-kmer rows through the two-store cache.

        Warm path: one front-cache lookup of the batch's packed codes —
        the merged rows are exact for the pinned ``(version, seq)``
        generation. Miss path: deduplicate the missing codes, pull their
        BASE rows through the version-keyed base cache (which survives
        writes, so after a delta_seq bump this is a pure gather), probe
        the delta for just those kmers, OR, and promote the merged rows
        into the front cache. Exact because membership is a pure function
        of ``(kmer, state)`` and OR over duplicates is idempotent.
        """
        arr = np.asarray(reads)
        codes = kmer_cache_mod.pack_codes(arr, self._k)
        flat = codes.ravel()
        front = self.kmer_cache
        front.begin((version, seq))
        vals, hit = front.lookup(flat)
        if vals is not None and hit.all():
            return vals.reshape(codes.shape + vals.shape[1:])
        miss = (np.arange(flat.size) if vals is None
                else np.flatnonzero(~hit))
        uniq, first, inverse = np.unique(
            flat[miss], return_index=True, return_inverse=True)
        wins = np.lib.stride_tricks.sliding_window_view(
            arr, self._k, axis=1).reshape(-1, self._k)
        uniq_wins = wins[miss[first]]
        merged_rows = np.bitwise_or(
            self._rows_for_unique(self._base_cache, base, uniq,
                                  uniq_wins, int(version)),
            self._probe_unique(delta, uniq_wins))
        front.insert(uniq, merged_rows)
        if vals is None:
            vals = np.zeros((flat.size,) + merged_rows.shape[1:],
                            merged_rows.dtype)
        vals[miss] = merged_rows[inverse]
        return vals.reshape(codes.shape + vals.shape[1:])

    def cache_stats(self):
        """Combined view over the two stores: front (merged rows — what
        answers warm batches; a write shows up as one invalidation) plus
        the base-row cache (whose hits are the write-survival reuse)."""
        if self.kmer_cache is None:
            return None
        return kmer_cache_mod.merge_cache_stats(
            [self.kmer_cache.stats(), self._base_cache.stats()])

    def _execute(self, bucket: int, batch, valid, need):
        """Dispatch the two-probe step; rides the state coordinates along
        with the device output so ``_finalize`` stamps the (version,
        delta_seq) that actually COMPUTED the batch — writes may advance
        the delta while this batch is still in the completer's hands."""
        step, _ = self._runner(bucket)
        base, delta, version, seq = self._live.states()
        if self.kmer_cache is not None:   # cache generations = this snapshot
            # host arrays straight through (see GeneSearchService._execute)
            out = step(base, delta, batch, valid, need, version, seq)
        else:
            out = step(base, delta, jnp.asarray(batch), jnp.asarray(valid),
                       jnp.asarray(need))
        return out, version, seq

    def _finalize(self, take, bucket: int, out
                  ) -> List[service_mod.SearchResult]:
        out, version, seq = out
        return [dataclasses.replace(r, version=version, delta_seq=seq)
                for r in super()._finalize(take, bucket, out)]


class LiveReplicaRouter(router_mod.ReplicaRouter):
    """A replica fleet over per-replica live indexes, plus a write path.

    One write-ahead journal lives at the ROUTER (``journal_path``):
    :meth:`insert` journals the batch under the router lock — assigning
    one fleet-wide sequence number — then fans ``submit_insert`` to every
    serving replica in that same order, so each replica's ``delta_seq``
    tracks the journal watermark. Boot replays the journal into every
    replica's delta; replicas added by ``scale_to`` replay the
    uncompacted tail, so they answer identically to day-one replicas.
    """

    def __init__(self, index,
                 service_config: Optional[service_mod.ServiceConfig] = None,
                 config: Optional[router_mod.RouterConfig] = None, *,
                 devices=None, version: int = 0,
                 journal_path: Optional[str] = None,
                 delta_cfg=None):
        self._journal = (lsm.DeltaJournal(journal_path)
                         if journal_path is not None else None)
        self._delta_cfg = delta_cfg
        boot = self._journal.records() if self._journal is not None else []
        self._tail: List[lsm.JournalRecord] = list(boot)
        self._wal_seq = boot[-1].seq if boot else 0
        super().__init__(index, service_config, config,
                         devices=devices, version=version)

    def _make_service(self, state) -> LiveGeneSearchService:
        live = lsm.LiveIndex(state, delta_cfg=self._delta_cfg,
                             base_version=self._version,
                             start_seq=self._wal_seq)
        if self._tail:
            live.replay(self._tail)      # uncompacted fleet tail -> delta
        return LiveGeneSearchService(live, self._svc_cfg)

    # -- the write path -----------------------------------------------------
    def insert(self, reads, file_ids=None) -> List[Future]:
        """Journal one write batch, then fan it to every serving replica.

        The router lock covers journal append + fan-out, so concurrent
        inserts hit every replica in one total order and the fleet-wide
        sequence in the journal equals each replica's ``delta_seq``.
        Returns one ``Future[InsertAck]`` per replica.
        """
        reads = np.asarray(reads, dtype=np.uint8)
        if reads.ndim == 1:
            reads = reads[None]
        fids = (None if file_ids is None
                else np.asarray(file_ids, dtype=np.int32).reshape(-1))
        trc = obs_trace.DEFAULT
        span = (trc.start("insert", tier="router", n_reads=len(reads))
                if trc.enabled else None)
        ctx = span.context() if span is not None else None
        with self._lock:
            serving = [r for r in self._replicas if r.serving]
            if not serving:
                if span is not None:
                    span.end(status="error", error="no serving replicas")
                raise RuntimeError("router has no serving replicas")
            seq = self._wal_seq + 1
            t_j = time.monotonic()
            if self._journal is not None:
                self._journal.append(seq, reads, fids)
            if ctx is not None:
                trc.emit("journal_append", ctx[0], ctx[1], t_j,
                         time.monotonic(),
                         attrs={"seq": seq,
                                "durable": self._journal is not None})
            self._wal_seq = seq
            self._tail.append(lsm.JournalRecord(
                seq=seq, reads=reads, file_ids=fids))
            # the fleet seq rides WITH the write: every replica applies it
            # at this exact journal coordinate, so (version, delta_seq)
            # watermarks can never drift replica-to-replica — a laggard
            # that publishes first simply no-ops the re-delivery later
            t_f = time.monotonic()
            futs = [r.scheduler.submit_insert(reads, fids, seq=seq,
                                              trace=ctx)
                    for r in serving]
            if ctx is not None:
                trc.emit("fanout", ctx[0], ctx[1], t_f, time.monotonic(),
                         attrs={"seq": seq, "n_replicas": len(futs)})
        router_mod._close_span_on_acks(span, futs)
        return futs

    def delta_batches(self) -> int:
        with self._lock:
            return len(self._tail)

    @property
    def wal_seq(self) -> int:
        with self._lock:
            return self._wal_seq

    # -- compaction ---------------------------------------------------------
    def compact(self, *, save_dir: Optional[str] = None) -> int:
        """Fold the fleet's delta into its base, publish everywhere.

        The merge computes ONCE from the lead replica's frozen plan (all
        replicas absorb the same ordered write stream, so any replica's
        plan describes the fleet); each replica then publishes inside its
        own pause window — in-flight batches finish, queued futures stay
        queued, and the merged state's unchanged ``StateMeta`` means every
        compiled step survives (zero recompiles, asserted in tests).
        ``save_dir`` writes the merged base through the versioned snapshot
        store before any replica swaps — and is the ONLY path that
        truncates the fleet journal: without a durable snapshot the
        journal keeps the folded writes, so a crash reboots from the
        previous snapshot + the full journal and loses nothing.
        """
        with self._admin_lock:
            with self._lock:
                reps = [r for r in self._replicas if r.serving]
                if not reps:
                    raise RuntimeError("router has no serving replicas")
            plan = reps[0].service.live.plan_compaction()
            merged = lsm.LiveIndex.compact(plan).block_until_ready()
            if save_dir is not None:
                store.save(merged, save_dir)
            for rep in reps:
                device = self._devices[rep.id % len(self._devices)]
                rep_merged = jax.device_put(merged, device)
                rep.scheduler.pause()     # in-flight batches finish first
                try:
                    rep.service.publish(rep_merged, plan.upto_seq)
                finally:
                    rep.scheduler.resume()
            with self._lock:
                self._state = merged
                self._version += 1
                self._tail = [r for r in self._tail
                              if r.seq > plan.upto_seq]
                version = self._version
            if save_dir is not None and self._journal is not None:
                self._journal.truncate_through(plan.upto_seq)
            return version

    def swap_state(self, index, *, version=None) -> int:
        raise NotImplementedError(
            "a live fleet's base only changes through compact(); swapping "
            "an arbitrary state would orphan every replica's delta and "
            "the write-ahead journal")

    def close(self) -> None:
        super().close()
        if self._journal is not None:
            self._journal.close()


class Compactor:
    """Background compaction loop over a live target.

    ``target`` is anything exposing ``delta_batches()`` and
    ``compact(**compact_kwargs)`` — a :class:`LiveReplicaRouter`, or a
    :class:`LiveGeneSearchService` (pass its scheduler through
    ``compact_kwargs`` so publishes run inside the pause window). Checks
    every ``interval_s`` and compacts once ``min_delta_batches`` writes
    have accumulated. Without a ``save_dir`` in ``compact_kwargs`` the
    compactions are in-memory only and the write-ahead journal keeps
    growing (by design — truncation requires a durable snapshot); pass
    one to reclaim it on every fold. A failed compaction stops the loop
    and surfaces on :attr:`error` (and re-raises from :meth:`close`) —
    silent write-path stalls are worse than a crash.
    """

    def __init__(self, target, *, interval_s: float = 0.25,
                 min_delta_batches: int = 8, compact_kwargs=None):
        if interval_s <= 0:
            raise ValueError("interval_s must be > 0")
        if min_delta_batches < 1:
            raise ValueError("min_delta_batches must be >= 1")
        self._target = target
        self._interval = float(interval_s)
        self._min = int(min_delta_batches)
        self._kwargs = dict(compact_kwargs or {})
        self._stop = threading.Event()
        self.compactions = 0
        self.error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name="idl-compactor")
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self._interval):
            try:
                if self._target.delta_batches() >= self._min:
                    self._target.compact(**self._kwargs)
                    self.compactions += 1
            except BaseException as e:  # noqa: BLE001 - surfaced on close
                self.error = e
                return

    def close(self, *, final_compaction: bool = False) -> int:
        """Stop the loop (optionally folding any remaining delta first).
        Returns the total number of compactions; re-raises a loop error."""
        self._stop.set()
        self._thread.join(timeout=30)
        if self.error is not None:
            raise self.error
        if final_compaction and self._target.delta_batches() > 0:
            self._target.compact(**self._kwargs)
            self.compactions += 1
        return self.compactions

    def __enter__(self) -> "Compactor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

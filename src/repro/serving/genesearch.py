"""Distributed gene-search service — the paper's system as a first-class arch.

The index is the bit-sliced COBS layout (rows = hash locations, columns =
files, packed 32 files/uint32 word). On the production mesh the file axis is
sharded over 'model' and the query batch over ('pod','data'); the per-query
row gather is device-local (every device holds all m rows for its file
slice), so the only collective is the output concatenation — the layout the
roofline analysis shows is optimal for MSMT.

``serve_step`` is the TPU-lowerable batched MSMT: queries arrive as raw
base-code arrays; kmerization, rolling MinHash and scheme locations all run
on-device on the registry's 32-bit lane path. Indexing goes through
``insert_read_batch`` — one jit-compiled, donated, dedup'd scatter per
batch of reads (``repro.index.packed``); ``repro.index.BitSlicedIndex`` is
the protocol-level engine over the same storage.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import idl as idl_mod
from repro.distributed.sharding import shard
from repro.index import packed


@dataclasses.dataclass(frozen=True)
class GeneSearchConfig:
    name: str = "idl-genesearch"
    n_files: int = 1024
    m: int = 1 << 26          # shared row count (bit-sliced index)
    k: int = 31
    t: int = 16
    L: int = 1 << 17          # DMA block (TPU) — ablated in fig8
    eta: int = 4
    read_len: int = 230       # query read length (200 kmers, paper's metric)
    scheme: str = "idl"       # "idl" | "rh"
    theta: float = 1.0        # kmer-coverage threshold for a file match

    @property
    def file_words(self) -> int:
        return self.n_files // 32

    @property
    def n_kmers(self) -> int:
        return self.read_len - self.k + 1

    def idl_config(self) -> idl_mod.IDLConfig:
        return idl_mod.IDLConfig(
            k=self.k, t=self.t, L=self.L, eta=self.eta, m=self.m, align=True
        )


def empty_index(cfg: GeneSearchConfig) -> jax.Array:
    """(m, n_files/32) uint32 bit-sliced index."""
    return jnp.zeros((cfg.m, cfg.file_words), dtype=jnp.uint32)


def insert_read_batch(
    index: jax.Array, cfg: GeneSearchConfig, reads: jax.Array,
    file_ids: jax.Array,
) -> jax.Array:
    """Index a (B, read_len) batch of reads into their files — ONE jit call.

    Locations for the whole batch are vmapped in-graph, duplicate (row, file)
    targets are dedup'd with a sort, and the index buffer is donated: no
    per-read Python loop and no full-matrix copy per read.
    """
    return packed.insert_batch_bitsliced(
        index, reads, jnp.asarray(file_ids),
        cfg=cfg.idl_config(), scheme=cfg.scheme, lane32=True,
    )


def insert_read(
    index: jax.Array, cfg: GeneSearchConfig, file_id: int, codes: jax.Array
) -> jax.Array:
    """Index one read into file ``file_id`` (B=1 case of the batched path)."""
    return insert_read_batch(
        index, cfg, codes[None, :], jnp.asarray([file_id], dtype=jnp.int32))


def _query_locations(cfg: GeneSearchConfig, codes: jax.Array) -> jax.Array:
    from repro.index import registry

    return registry.locations32(cfg.idl_config(), codes, cfg.scheme)


def serve_step(
    index: jax.Array, queries: jax.Array, cfg: GeneSearchConfig
) -> jax.Array:
    """Batched MSMT.

    index: (m, n_files/32) uint32; queries: (B, read_len) uint8 base codes.
    Returns (B, n_files/32) uint32 — bitmask of matching files per query
    (theta=1: AND over all kmers; theta<1: per-file kmer-coverage >= theta).
    """
    locs = jax.vmap(lambda q: _query_locations(cfg, q))(queries)  # (B, η, n_k)
    locs = shard(locs, ("batch", None, None))
    rows = index[locs.astype(jnp.int32)]       # (B, η, n_k, F/32) gather
    rows = shard(rows, ("batch", None, None, "files"))
    per_kmer = jax.lax.reduce(
        rows, jnp.uint32(0xFFFFFFFF), jax.lax.bitwise_and, dimensions=(1,)
    )                                           # AND over η -> (B, n_k, F/32)
    if cfg.theta >= 1.0:
        out = jax.lax.reduce(
            per_kmer, jnp.uint32(0xFFFFFFFF), jax.lax.bitwise_and, dimensions=(1,)
        )                                       # AND over kmers -> (B, F/32)
        return shard(out, ("batch", "files"))
    # fractional coverage: popcount per file via bit unpack, compared with
    # the exact integer threshold every engine uses (a float mean of n ones
    # != 1.0 in f32 for many n, which would flip boundary thetas)
    bits = (per_kmer[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    hits = jnp.sum(bits.astype(jnp.int32), axis=1)        # (B, F/32, 32)
    need = packed.coverage_need(cfg.theta, per_kmer.shape[1])
    match = (hits >= need).astype(jnp.uint32)
    out = jnp.sum(match << jnp.arange(32, dtype=jnp.uint32), axis=-1, dtype=jnp.uint32)
    return shard(out, ("batch", "files"))


def match_file_ids(bitmask_row: np.ndarray) -> list[int]:
    """Decode one query's (F/32,) bitmask into matching file ids (host)."""
    out = []
    for w, word in enumerate(np.asarray(bitmask_row)):
        for b in range(32):
            if (int(word) >> b) & 1:
                out.append(w * 32 + b)
    return out

"""v1 gene-search serving — now the compatibility layer under serving v2.

New code should use :mod:`repro.serving.service`: a typed, dynamic-batching
:class:`~repro.serving.service.GeneSearchService` over any engine's
:class:`~repro.index.state.IndexState`, with pow2 shape buckets (one
compile per bucket for ragged request streams), snapshot-backed startup
(:mod:`repro.index.store`) and per-batch stats. This module keeps the v1
functional surface — raw ``(m, F/32)`` matrix in, fixed-shape batch
``serve_step`` out — as thin calls into the same shared layers, and
re-exports the v2 names for discoverability.

The index is the bit-sliced COBS layout (rows = hash locations, columns =
files, packed 32 files/uint32 word). On the production mesh the file axis is
sharded over 'model' and the query batch over ('pod','data'); the per-query
row gather is device-local (every device holds all m rows for its file
slice), so the only collective is the output concatenation — the layout the
roofline analysis shows is optimal for MSMT.

``serve_step`` is the TPU-lowerable batched MSMT: queries arrive as raw
base-code arrays; kmerization, rolling MinHash and scheme locations all run
on-device on the registry's 32-bit lane path, and the probe itself routes
through the shared planner/executor layer (``repro.index.query``) — the
same planned Pallas / sharded backends every engine uses. Indexing routes
through the shared ingest layer (``repro.index.ingest``): a cached
``InsertPlan`` turns a batch of reads into one jit-compiled, donated,
dedup'd scatter — or one planned Pallas ``insert_runs`` launch, or a
``shard_map`` over the file-words axis — and ``build_archive`` streams a
whole archive through it. ``repro.index.BitSlicedIndex`` is the
protocol-level engine over the same storage.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import idl as idl_mod
from repro.distributed.sharding import shard
from repro.index import ingest, query


def _deprecated(name: str) -> None:
    warnings.warn(
        f"serving.genesearch.{name} is the deprecated v1 serving surface; "
        "use repro.serving.GeneSearchService (dynamic batching over any "
        "IndexState, snapshot startup) or the engines' own "
        "insert_batch/msmt — bit-identical through the same shared "
        "query/ingest layers.",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclasses.dataclass(frozen=True)
class GeneSearchConfig:
    name: str = "idl-genesearch"
    n_files: int = 1024
    m: int = 1 << 26          # shared row count (bit-sliced index)
    k: int = 31
    t: int = 16
    L: int = 1 << 17          # DMA block (TPU) — ablated in fig8
    eta: int = 4
    read_len: int = 230       # query read length (200 kmers, paper's metric)
    scheme: str = "idl"       # "idl" | "rh"
    theta: float = 1.0        # kmer-coverage threshold for a file match

    @property
    def file_words(self) -> int:
        return self.n_files // 32

    @property
    def n_kmers(self) -> int:
        return self.read_len - self.k + 1

    def idl_config(self) -> idl_mod.IDLConfig:
        return idl_mod.IDLConfig(
            k=self.k, t=self.t, L=self.L, eta=self.eta, m=self.m, align=True
        )


def empty_index(cfg: GeneSearchConfig) -> jax.Array:
    """(m, n_files/32) uint32 bit-sliced index."""
    _deprecated("empty_index")
    return jnp.zeros((cfg.m, cfg.file_words), dtype=jnp.uint32)


def insert_plan(
    cfg: GeneSearchConfig, batch: int, index_shape: tuple[int, int],
    read_len: Optional[int] = None,
) -> ingest.InsertPlan:
    """The cached shared-layer plan for this service's insert geometry.

    ``read_len`` defaults to the service's query read length; pass the
    actual sequence length when indexing whole genomes.
    """
    return ingest.plan_insert(
        cfg.idl_config(), cfg.scheme,
        (batch, cfg.read_len if read_len is None else read_len),
        tuple(index_shape), kind="cols", lane32=True,
    )


def insert_read_batch(
    index: jax.Array, cfg: GeneSearchConfig, reads: jax.Array,
    file_ids: jax.Array, *, backend: str = "jnp", **kw,
) -> jax.Array:
    """Index a (B, read_len) batch of reads into their files — ONE jit call.

    A thin call into :mod:`repro.index.ingest`: locations for the whole
    batch are vmapped in-graph, duplicate (row, file) targets are dedup'd
    with a sort, and the index buffer is donated — no per-read Python loop
    and no full-matrix copy per read. ``backend`` picks the shared
    executor: ``"jnp"`` (reference scatter), ``"idl_insert"`` (host-planned
    Pallas run kernel, one launch per batch) or ``"sharded"`` (``shard_map``
    splitting the file-words axis; kw ``mesh``).
    """
    _deprecated("insert_read_batch")
    plan = insert_plan(cfg, reads.shape[0], index.shape,
                       read_len=reads.shape[1])
    return plan.execute(
        index, reads, jnp.asarray(file_ids), backend=backend, **kw)


def build_archive(
    cfg: GeneSearchConfig, files, *, backend: str = "jnp", **kw
) -> jax.Array:
    """Stream a whole archive into a fresh serving index.

    Drives :func:`repro.index.ingest.build_archive` over the protocol-level
    ``BitSlicedIndex`` engine and returns the raw ``(m, n_files/32)``
    serving matrix. Accepts the builder's knobs (``chunk_reads``, ``mesh``,
    ``window_min``, ...).
    """
    _deprecated("build_archive")
    from repro.index.engines import BitSlicedIndex

    eng = BitSlicedIndex.build(cfg.idl_config(), cfg.scheme, cfg.n_files)
    eng = ingest.build_archive(
        eng, files, read_len=cfg.read_len, backend=backend, **kw)
    return eng.words


def insert_read(
    index: jax.Array, cfg: GeneSearchConfig, file_id: int, codes: jax.Array
) -> jax.Array:
    """Index one read into file ``file_id`` (B=1 case of the batched path)."""
    return insert_read_batch(
        index, cfg, codes[None, :], jnp.asarray([file_id], dtype=jnp.int32))


def query_plan(
    cfg: GeneSearchConfig, batch: int, index_shape: tuple[int, int]
) -> query.QueryPlan:
    """The cached shared-layer plan for this service's query geometry."""
    return query.plan_query(
        cfg.idl_config(), cfg.scheme, (batch, cfg.read_len),
        tuple(index_shape), bit_probe=False, lane32=True,
    )


def serve_step(
    index: jax.Array, queries: jax.Array, cfg: GeneSearchConfig,
    *, backend: str = "jnp",
) -> jax.Array:
    """Batched MSMT — a thin call into :mod:`repro.index.query`.

    index: (m, n_files/32) uint32; queries: (B, read_len) uint8 base codes.
    Returns (B, n_files/32) uint32 — bitmask of matching files per query
    (theta=1: AND over all kmers; theta<1: per-file kmer-coverage >= theta,
    with the exact integer threshold every engine uses). ``backend`` picks
    the shared executor: ``"jnp"`` (traceable — safe under an outer
    ``jax.jit``), ``"idl_probe"`` (host-planned Pallas run kernel) or
    ``"sharded"`` (``shard_map`` splitting the file-words axis).
    """
    _deprecated("serve_step")
    plan = query_plan(cfg, queries.shape[0], index.shape)
    per_kmer = plan.execute(index, queries, backend=backend)  # (B, n_k, F/32)
    per_kmer = shard(per_kmer, ("batch", None, "files"))
    out = query.file_match_mask(per_kmer, cfg.theta)
    return shard(out, ("batch", "files"))


def match_file_ids(bitmask_row: np.ndarray) -> list[int]:
    """Decode one query's (F/32,) bitmask into matching file ids (host)."""
    _deprecated("match_file_ids")
    out = []
    for w, word in enumerate(np.asarray(bitmask_row)):
        for b in range(32):
            if (int(word) >> b) & 1:
                out.append(w * 32 + b)
    return out


# -- serving v2 re-exports (canonical home: repro.serving.service) ----------
from repro.serving.service import (  # noqa: E402  (compat tail-import)
    BatchStats,
    GeneSearchService,
    SearchRequest,
    SearchResult,
    ServiceConfig,
)

"""v1 gene-search serving — config + plan helpers; the v1 bodies are gone.

New code should use :mod:`repro.serving.service`: a typed, dynamic-batching
:class:`~repro.serving.service.GeneSearchService` over any engine's
:class:`~repro.index.state.IndexState`, with pow2 shape buckets (one
compile per bucket for ragged request streams), snapshot-backed startup
(:mod:`repro.index.store`) and per-batch stats — and, for ingest under
traffic, :mod:`repro.serving.live` (:class:`LiveGeneSearchService` /
:class:`LiveReplicaRouter` over :class:`repro.index.lsm.LiveIndex`).

This module keeps the pieces of the v1 surface that are still the single
source of truth — :class:`GeneSearchConfig` (the serve-geometry dataclass
the config registry lowers) and the :func:`insert_plan` / :func:`query_plan`
helpers that map it onto the shared planner layers — plus the serving v2
re-exports. The six deprecated v1 entry points (``empty_index``,
``insert_read_batch``, ``build_archive``, ``insert_read``, ``serve_step``,
``match_file_ids``) spent two releases warning and are now call-time
``ImportError`` stubs carrying their migration target; the modules stay
importable so the package import smoke keeps passing.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import idl as idl_mod
from repro.index import ingest, query


def _removed(name: str, hint: str) -> "ImportError":
    return ImportError(
        f"serving.genesearch.{name} was removed after its deprecation "
        f"window; migrate: {hint} (see docs/API.md, 'Migration from the "
        "v1 serving surface')."
    )


@dataclasses.dataclass(frozen=True)
class GeneSearchConfig:
    name: str = "idl-genesearch"
    n_files: int = 1024
    m: int = 1 << 26          # shared row count (bit-sliced index)
    k: int = 31
    t: int = 16
    L: int = 1 << 17          # DMA block (TPU) — ablated in fig8
    eta: int = 4
    read_len: int = 230       # query read length (200 kmers, paper's metric)
    scheme: str = "idl"       # "idl" | "rh"
    theta: float = 1.0        # kmer-coverage threshold for a file match

    @property
    def file_words(self) -> int:
        return self.n_files // 32

    @property
    def n_kmers(self) -> int:
        return self.read_len - self.k + 1

    def idl_config(self) -> idl_mod.IDLConfig:
        return idl_mod.IDLConfig(
            k=self.k, t=self.t, L=self.L, eta=self.eta, m=self.m, align=True
        )


def empty_index(cfg: GeneSearchConfig):
    """Removed v1 entry point — raises ImportError with the migration."""
    raise _removed("empty_index", "BitSlicedIndex.build(cfg.idl_config(), "
                   "cfg.scheme, cfg.n_files) — or jnp.zeros((cfg.m, "
                   "cfg.file_words), jnp.uint32) for the raw matrix")


def insert_plan(
    cfg: GeneSearchConfig, batch: int, index_shape: tuple[int, int],
    read_len: Optional[int] = None,
) -> ingest.InsertPlan:
    """The cached shared-layer plan for this service's insert geometry.

    ``read_len`` defaults to the service's query read length; pass the
    actual sequence length when indexing whole genomes.
    """
    return ingest.plan_insert(
        cfg.idl_config(), cfg.scheme,
        (batch, cfg.read_len if read_len is None else read_len),
        tuple(index_shape), kind="cols", lane32=True,
    )


def insert_read_batch(index, cfg, reads, file_ids, **kw):
    """Removed v1 entry point — raises ImportError with the migration."""
    raise _removed("insert_read_batch", "insert_plan(cfg, B, index.shape)"
                   ".execute(index, reads, file_ids) or the engine's own "
                   "insert_batch")


def build_archive(cfg, files, **kw):
    """Removed v1 entry point — raises ImportError with the migration."""
    raise _removed("build_archive", "repro.index.ingest.build_archive over "
                   "BitSlicedIndex.build(...)")


def insert_read(index, cfg, file_id, codes):
    """Removed v1 entry point — raises ImportError with the migration."""
    raise _removed("insert_read", "batch the read and use insert_plan(...)"
                   ".execute / engine.insert_batch; streaming single reads "
                   "go through LiveReplicaRouter.insert")


def query_plan(
    cfg: GeneSearchConfig, batch: int, index_shape: tuple[int, int]
) -> query.QueryPlan:
    """The cached shared-layer plan for this service's query geometry."""
    return query.plan_query(
        cfg.idl_config(), cfg.scheme, (batch, cfg.read_len),
        tuple(index_shape), bit_probe=False, lane32=True,
    )


def serve_step(index, queries, cfg, **kw):
    """Removed v1 entry point — raises ImportError with the migration."""
    raise _removed("serve_step", "query_plan(cfg, B, index.shape)"
                   ".execute(index, queries) + query.file_match_mask(per_"
                   "kmer, cfg.theta), or GeneSearchService.search")


def match_file_ids(bitmask_row):
    """Removed v1 entry point — raises ImportError with the migration."""
    raise _removed("match_file_ids", "repro.index.packed.unpack_file_bits("
                   "mask, n_files).nonzero() or SearchResult.file_ids")


# -- serving v2 re-exports (canonical home: repro.serving.service) ----------
from repro.serving.service import (  # noqa: E402  (compat tail-import)
    BatchStats,
    GeneSearchService,
    SearchRequest,
    SearchResult,
    ServiceConfig,
)

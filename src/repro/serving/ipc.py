"""Wire layer for the process fabric: length-prefixed pickle frames.

One frame is ``<I little-endian byte count><pickle bytes>``. That is the
entire protocol — no negotiation, no compression, no partial frames: a
:class:`Wire` wraps one connected ``AF_UNIX`` stream socket and gives
both ends ``send(obj)`` / ``recv() -> obj`` with an internal lock per
direction, so the gateway's receiver thread can block in ``recv`` while
scheduler callback threads ``send`` replies concurrently.

Message vocabulary (all plain picklable dataclasses):

* :class:`Hello` — a freshly spawned worker introduces itself (id, pid).
* :class:`Request` — gateway -> worker: a correlation ``id``, a ``kind``
  from :data:`KINDS`, and a kind-specific payload (numpy arrays pickle
  fine; reads are small relative to the index, which never crosses the
  wire — workers mmap it from disk).
* :class:`Reply` — worker -> gateway: the request's ``id`` plus either a
  ``payload`` or a pickled exception in ``error`` (the gateway re-raises
  it into the caller's future, so a worker-side rejection reads exactly
  like an in-process one).

Why pickle and not a public serialization format: both ends of every
wire are processes the fabric itself spawned, talking over a private
``AF_UNIX`` socket in a mode-0700 runtime directory — the trust boundary
is the process boundary, not the wire. Nothing here accepts frames from
the network.
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import socket
import struct
import threading
from typing import Optional

__all__ = [
    "Hello",
    "Request",
    "Reply",
    "Wire",
    "WireClosed",
    "KINDS",
    "listen",
    "connect",
]

_LEN = struct.Struct("<I")
# a frame is a query batch, an ack, or a journal tail — never the index;
# anything past this is a protocol bug, not a big message
MAX_FRAME = 1 << 30

KINDS = ("replay", "query", "insert", "compact", "stats", "shutdown")


class WireClosed(ConnectionError):
    """The peer hung up (EOF mid-frame or a dead socket) — for the
    gateway this IS the worker-death signal."""


@dataclasses.dataclass(frozen=True)
class Hello:
    """First frame a worker sends after connecting."""

    worker_id: int
    pid: int


@dataclasses.dataclass(frozen=True)
class Request:
    """Gateway -> worker. ``id`` correlates the eventual :class:`Reply`.

    ``trace`` carries the observability trace context —
    ``(trace_id, parent_span_id)`` minted at the gateway — so spans the
    worker opens stitch as children of the gateway's dispatch span
    (:mod:`repro.obs.trace`). Defaulted for wire compatibility with
    frames from code that predates the field.
    """

    id: int
    kind: str
    payload: object = None
    trace: Optional[tuple] = None


@dataclasses.dataclass(frozen=True)
class Reply:
    """Worker -> gateway. Exactly one of payload / error is meaningful."""

    id: int
    payload: object = None
    error: Optional[BaseException] = None


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise :class:`WireClosed` on EOF.

    Short reads are the NORM on a stream socket — the kernel hands back
    whatever is buffered, so a frame (or even its 4-byte length prefix)
    can arrive in arbitrarily small pieces; this loop reassembles them.
    EINTR gets an explicit retry: ``InterruptedError`` is an ``OSError``
    subclass, so without its own clause a signal landing mid-frame would
    be misreported as peer death (regression-tested in
    ``tests/test_ipc.py``).
    """
    chunks = []
    while n:
        try:
            chunk = sock.recv(min(n, 1 << 20))
        except InterruptedError:
            continue              # EINTR: the peer is fine, just retry
        except OSError as e:
            raise WireClosed(f"socket died mid-frame: {e}") from e
        if not chunk:
            raise WireClosed("peer closed the wire")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


class Wire:
    """One framed, thread-safe duplex channel over a connected socket."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._send_lock = threading.Lock()
        self._recv_lock = threading.Lock()

    def send(self, obj) -> None:
        data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        if len(data) > MAX_FRAME:
            raise ValueError(
                f"refusing to send a {len(data)}-byte frame (> {MAX_FRAME}); "
                f"the index never crosses the wire — this is a protocol bug")
        with self._send_lock:
            try:
                # sendall retries EINTR internally (PEP 475); an exception
                # escaping it leaves the stream position unknown, so a
                # frame-level retry could desynchronize — fail the wire
                self._sock.sendall(_LEN.pack(len(data)) + data)
            except OSError as e:
                raise WireClosed(f"send on a dead wire: {e}") from e

    def recv(self):
        with self._recv_lock:
            n, = _LEN.unpack(_recv_exact(self._sock, _LEN.size))
            if n > MAX_FRAME:
                raise WireClosed(
                    f"peer announced a {n}-byte frame (> {MAX_FRAME}) — "
                    f"stream is desynchronized")
            return pickle.loads(_recv_exact(self._sock, n))

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()

    def __enter__(self) -> "Wire":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def listen(path: str, backlog: int = 16) -> socket.socket:
    """Bind + listen on an ``AF_UNIX`` socket at ``path`` (replacing a
    stale one from a previous run)."""
    if os.path.exists(path):
        os.unlink(path)
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.bind(path)
    sock.listen(backlog)
    return sock


def connect(path: str, timeout_s: float = 30.0) -> Wire:
    """Connect to the gateway's listener (worker boot path)."""
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.settimeout(timeout_s)
    sock.connect(path)
    sock.settimeout(None)
    return Wire(sock)

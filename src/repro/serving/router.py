"""Replica router: shard a request stream over K IndexState replicas.

An :class:`~repro.index.state.IndexState` is a pytree, so a replica is one
``jax.device_put`` — K replicas of a served index are K cheap copies (on a
multi-device host, one per device; on CPU they alias read-only buffers).
The router puts an :class:`~repro.serving.scheduler.AsyncScheduler` in
front of each replica and spreads submits across them:

* **Routing policies** — ``round_robin`` (stateless spread),
  ``least_outstanding`` (join the shortest queue — best under skewed
  batch walls), ``bucket_affinity`` (a kmer bucket always lands on the
  same replica, so each replica's compile cache and admission EWMAs stay
  hot for *its* buckets — the policy to pick when the bucket set is wider
  than one replica's compile budget).

* **Hot snapshot swap** — :meth:`swap_snapshot` loads + fully validates a
  new snapshot version (a corrupt / foreign / future-version directory
  raises :class:`~repro.index.store.SnapshotError` *before any replica is
  touched* — traffic never notices), then walks the replicas one at a
  time: pause (in-flight batches finish), swap state, resume. Requests
  queued on the paused replica are served by the new state after resume;
  the other replicas keep serving throughout. Zero futures are dropped
  and no result is mis-versioned: a result's ``version`` field is always
  the version of the state that computed it, because swaps only happen
  with zero batches in flight on that replica. Same-geometry snapshots
  reuse every compiled executable (the state is a pytree *argument* of
  the compiled step, not a constant) — zero recompiles under live swap.

* **Autoscaling** — with an :class:`~repro.serving.autoscale
  .ReplicaAutoscaler`, :meth:`autoscale_step` grows/shrinks the fleet
  between the configured bounds: new replicas boot from the current
  state + version; removed replicas stop receiving traffic, drain every
  queued future, then shut down.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.index import state as state_mod
from repro.index import store
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.serving import kmer_cache as kmer_cache_mod
from repro.serving import service as service_mod
from repro.serving.autoscale import (
    AdmissionPolicy,
    AutoscaleConfig,
    ReplicaAutoscaler,
)
from repro.serving.scheduler import AsyncScheduler, ClusterStats, \
    SchedulerConfig

__all__ = ["RouterConfig", "ReplicaRouter", "RoutingPolicy", "POLICIES"]

POLICIES = ("round_robin", "least_outstanding", "bucket_affinity")


def _close_span_on_acks(span, futures: Sequence[Future]) -> None:
    """End a write's root span when every replica ack resolves — the ack
    leg of the insert → journal-append → fan-out → ack chain. Any errored
    or cancelled ack closes the root with error status."""
    if span is None:
        return
    lock = threading.Lock()
    state = {"remaining": len(futures), "failed": False}

    def _done(f: Future) -> None:
        with lock:
            if f.cancelled() or f.exception() is not None:
                state["failed"] = True
            state["remaining"] -= 1
            last = state["remaining"] == 0
        if last:
            span.end(status="error" if state["failed"] else "ok",
                     n_replicas=len(futures))

    for f in futures:
        f.add_done_callback(_done)


class RoutingPolicy:
    """The routing decision itself, factored out of the router so the
    in-process replica fleet and the cross-process fabric gateway
    (:mod:`repro.serving.fabric`) spread load with ONE policy core.

    ``pick(members, bucket, load)`` chooses among the ordered serving
    members (anything with a stable integer ``.id``); ``load`` maps a
    member to its outstanding-work figure (used by ``least_outstanding``).
    Policy state (the round-robin cursor, the bucket->member affinity
    map) lives here. Not thread-safe on its own — callers hold their
    fleet lock across the pick, exactly as the router always did.
    """

    def __init__(self, policy: str):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown routing policy {policy!r} "
                f"(want one of {POLICIES})")
        self.policy = policy
        self._rr = itertools.count()
        self._affinity: Dict[int, int] = {}     # bucket -> member id

    def pick(self, members, bucket: int, load):
        if not members:
            raise RuntimeError("no serving members to route to")
        if self.policy == "round_robin":
            return members[next(self._rr) % len(members)]
        if self.policy == "least_outstanding":
            return min(members, key=load)
        # bucket_affinity: sticky bucket -> member map, assigned round-
        # robin on first sight so load still spreads; remapped only if
        # the pinned member was decommissioned (or crashed, in the fabric)
        by_id = {m.id: m for m in members}
        mid = self._affinity.get(bucket)
        if mid is None or mid not in by_id:
            member = members[next(self._rr) % len(members)]
            self._affinity[bucket] = member.id
            return member
        return by_id[mid]


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Replica fan-out knobs."""

    n_replicas: int = 2
    policy: str = "least_outstanding"
    scheduler: SchedulerConfig = dataclasses.field(
        default_factory=SchedulerConfig)
    autoscale: Optional[AutoscaleConfig] = None   # enables adaptive serving

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown routing policy {self.policy!r} "
                f"(want one of {POLICIES})")
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")


@dataclasses.dataclass
class _Replica:
    id: int
    service: service_mod.GeneSearchService
    scheduler: AsyncScheduler
    serving: bool = True       # False while being decommissioned


class ReplicaRouter:
    """K pipelined serving replicas behind one ``submit``."""

    def __init__(self, index,
                 service_config: Optional[service_mod.ServiceConfig] = None,
                 config: Optional[RouterConfig] = None, *,
                 devices: Optional[Sequence] = None,
                 version: int = 0):
        self.config = config or RouterConfig()
        self._svc_cfg = service_config or service_mod.ServiceConfig()
        self._state = state_mod.from_engine(index)
        self._version = int(version)
        self._devices = tuple(devices) if devices else tuple(jax.devices())
        self._autoscaler = (ReplicaAutoscaler(self.config.autoscale)
                            if self.config.autoscale is not None else None)
        self._lock = threading.Lock()
        self._as_lock = threading.Lock()   # autoscaler observation guard
        # serializes fleet mutations (swap / scale): a replica booted
        # mid-swap from the pre-swap state would serve a stale version
        # forever
        self._admin_lock = threading.Lock()
        self._replicas: List[_Replica] = []
        self._next_replica_id = 0
        self._policy = RoutingPolicy(self.config.policy)
        for _ in range(self.config.n_replicas):
            self._add_replica_locked()

    # -- construction -------------------------------------------------------
    @classmethod
    def from_snapshot(cls, directory: str,
                      service_config=None, config=None, *,
                      version: int = 0, **load_kw) -> "ReplicaRouter":
        """Boot a replica fleet straight from a versioned snapshot."""
        return cls(store.load(directory, **load_kw), service_config, config,
                   version=version)

    def _make_service(self, state) -> service_mod.GeneSearchService:
        """Build one replica's service over its device-local state. The
        subclass hook :class:`~repro.serving.live.LiveReplicaRouter` uses
        to wrap each replica's state in a writable live index."""
        return service_mod.GeneSearchService(state, self._svc_cfg,
                                             version=self._version)

    def _add_replica_locked(self) -> _Replica:
        rid = self._next_replica_id
        self._next_replica_id += 1
        device = self._devices[rid % len(self._devices)]
        state = jax.device_put(self._state, device)
        svc = self._make_service(state)
        admission = (AdmissionPolicy(self.config.autoscale)
                     if self.config.autoscale is not None else None)
        rep = _Replica(
            id=rid, service=svc,
            scheduler=AsyncScheduler(svc, self.config.scheduler,
                                     admission=admission,
                                     on_batch=self._observe_batch,
                                     replica_id=rid))
        self._replicas.append(rep)
        return rep

    def _observe_batch(self, stats: ClusterStats, now: float) -> None:
        """Completer-thread hook: feed batch telemetry to the autoscaler."""
        if self._autoscaler is not None:
            with self._as_lock:
                self._autoscaler.observe_batch(stats, now)

    # -- views --------------------------------------------------------------
    @property
    def version(self) -> int:
        return self._version

    @property
    def n_replicas(self) -> int:
        with self._lock:
            return len(self._replicas)

    def outstanding(self) -> int:
        with self._lock:
            reps = list(self._replicas)
        return sum(r.scheduler.outstanding for r in reps)

    def compile_counts(self) -> Dict[int, Dict[int, int]]:
        """Per-replica compile-once proof: {replica_id: {bucket: count}}."""
        with self._lock:
            reps = list(self._replicas)
        return {r.id: r.scheduler.compile_counts() for r in reps}

    def cluster_stats(self) -> List[ClusterStats]:
        """Merged telemetry across replicas (each ring-buffer bounded)."""
        with self._lock:
            reps = list(self._replicas)
        return [s for r in reps for s in list(r.scheduler.stats)]

    def cache_stats(self) -> Optional[Dict[str, float]]:
        """Fleet-wide kmer-cache view: per-replica ``KmerCache.stats()``
        aggregated (None when no replica carries a cache)."""
        with self._lock:
            reps = list(self._replicas)
        return kmer_cache_mod.merge_cache_stats(
            r.service.cache_stats() for r in reps)

    def requests_served(self) -> int:
        """Lifetime fleet total — a view over each replica's registry-
        backed service counter (not the windowed stats ring)."""
        with self._lock:
            reps = list(self._replicas)
        return sum(r.service.requests_served() for r in reps)

    def occupancy(self) -> float:
        """Fleet rows-served-per-row-dispatched, from the same registry
        counters the per-service view reads."""
        with self._lock:
            reps = list(self._replicas)
        rows = sum(r.service._obs_batch_rows.value for r in reps)
        reqs = sum(r.service._obs_requests.value for r in reps)
        return reqs / rows if rows else 0.0

    def obs_snapshot(self) -> dict:
        """Full process-local obs snapshot (metrics + finished spans).
        For the in-process fleet every replica already feeds the one
        process registry, so no per-replica merge is needed; the
        cross-process tiers (fabric, scatter) ship this same shape over
        IPC and fold with :func:`repro.obs.export.merge`."""
        return obs_export.snapshot()

    # -- routing ------------------------------------------------------------
    def _route(self, bucket: int) -> _Replica:
        """Pick a serving replica (caller holds the lock)."""
        serving = [r for r in self._replicas if r.serving]
        if not serving:
            raise RuntimeError("router has no serving replicas")
        return self._policy.pick(serving, bucket,
                                 lambda r: r.scheduler.outstanding)

    def submit(self, request: Union[service_mod.SearchRequest, np.ndarray]
               ) -> Future:
        """Route one read to a replica; returns its Future[SearchResult]."""
        with self._lock:
            if not self._replicas:
                raise RuntimeError("router is closed")
            any_svc = self._replicas[0].service
        req, n_kmers = any_svc._normalize(request)
        bucket = any_svc.bucket_for(n_kmers)
        with self._lock:
            rep = self._route(bucket)
        if self._autoscaler is not None:
            with self._as_lock:
                self._autoscaler.observe_arrival(time.monotonic())
        return rep.scheduler.submit(req)

    def search(self, reads: Sequence[np.ndarray]
               ) -> List[service_mod.SearchResult]:
        """Submit all, drain every replica, return results in order."""
        futures = [self.submit(r) for r in reads]
        self.drain()
        return [f.result() for f in futures]

    # -- the write path -----------------------------------------------------
    def insert(self, reads, file_ids=None) -> List[Future]:
        """Fan one write batch out to every serving replica.

        Unlike queries (which route to ONE replica), a write must reach
        them all — every replica answers from its own base+delta pair.
        The router lock is held across the fan-out, so concurrent inserts
        enqueue in the same total order on every replica and the
        per-replica ``delta_seq`` watermarks stay aligned. Returns one
        ``Future[InsertAck]`` per replica (all resolved = the write is
        searchable fleet-wide). Requires live-index replicas
        (:class:`~repro.serving.live.LiveReplicaRouter`); static replicas
        raise ``TypeError`` on the first fan-out.
        """
        trc = obs_trace.DEFAULT
        span = (trc.start("insert", tier="router") if trc.enabled else None)
        ctx = span.context() if span is not None else None
        with self._lock:
            serving = [r for r in self._replicas if r.serving]
            if not serving:
                if span is not None:
                    span.end(status="error", error="no serving replicas")
                raise RuntimeError("router has no serving replicas")
            t0 = time.monotonic()
            futs = [r.scheduler.submit_insert(reads, file_ids, trace=ctx)
                    for r in serving]
            if ctx is not None:
                trc.emit("fanout", ctx[0], ctx[1], t0, time.monotonic(),
                         attrs={"n_replicas": len(futs)})
        _close_span_on_acks(span, futs)
        return futs

    # -- hot snapshot swap --------------------------------------------------
    def swap_snapshot(self, directory: str, *,
                      version: Optional[int] = None, **load_kw) -> int:
        """Load a new snapshot version and swap every replica under load.

        Validation happens FIRST: ``store.load`` rejects corrupt, foreign,
        truncated and future-version snapshots with ``SnapshotError``
        before any replica is touched, so a bad snapshot offer leaves the
        fleet serving the old version untouched. Then replicas swap one at
        a time (pause -> swap -> resume); the rest keep serving.
        """
        new_state = store.load(directory, **load_kw)   # may raise — fleet
        return self.swap_state(new_state, version=version)  # still clean

    def swap_state(self, index, *, version: Optional[int] = None) -> int:
        """Swap an already-validated state/engine into every replica."""
        new_state = state_mod.from_engine(index)
        with self._admin_lock:
            return self._swap_state_admin(new_state, version)

    def _swap_state_admin(self, new_state, version: Optional[int]) -> int:
        """Fleet swap body (caller holds the admin lock, so no replica can
        be booted from the pre-swap state mid-walk)."""
        with self._lock:
            # geometry gate before touching ANY replica (per-replica
            # swap_state would re-check, but failing mid-fleet would leave
            # mixed versions forever)
            k_new = state_mod.kmer_size(new_state.meta)
            k_old = state_mod.kmer_size(self._state.meta)
            if k_new != k_old:
                raise ValueError(
                    f"cannot hot-swap to kmer size {k_new} over a fleet "
                    f"serving k={k_old}; boot a fresh router instead")
            new_version = (self._version + 1 if version is None
                           else int(version))
            reps = list(self._replicas)
        for rep in reps:
            device = self._devices[rep.id % len(self._devices)]
            replica_state = jax.device_put(new_state, device)
            rep.scheduler.pause()      # in-flight batches finish first
            try:
                rep.service.swap_state(replica_state, version=new_version)
            finally:
                rep.scheduler.resume()
        with self._lock:
            self._state = new_state
            self._version = new_version
        return new_version

    # -- scaling ------------------------------------------------------------
    def scale_to(self, n: int) -> int:
        """Grow/shrink the fleet to ``n`` replicas; returns the new count.

        Growth boots replicas from the current state + version (their
        compile caches start cold — each new replica compiles each bucket
        once, which is the per-replica compile-once guarantee, not a
        violation of it). Shrinking decommissions the most idle replicas:
        no new traffic, drain queued futures, shut down.
        """
        if n < 1:
            raise ValueError("cannot scale below 1 replica")
        to_close: List[_Replica] = []
        with self._admin_lock, self._lock:
            while len(self._replicas) < n:
                self._add_replica_locked()
            if len(self._replicas) > n:
                victims = sorted(
                    self._replicas,
                    key=lambda r: r.scheduler.outstanding,
                )[:len(self._replicas) - n]
                for rep in victims:
                    rep.serving = False       # stop routing immediately
                    to_close.append(rep)
                self._replicas = [r for r in self._replicas
                                  if r.serving]
        for rep in to_close:
            rep.scheduler.close()             # drains: zero dropped futures
        return self.n_replicas

    def autoscale_step(self, now: Optional[float] = None) -> int:
        """Apply one ReplicaAutoscaler recommendation (no-op without one).

        Pull-based by design: the serving loop (or a bench/ops cron) calls
        this at its own cadence, so scaling decisions are deterministic
        and testable instead of racing a hidden daemon thread.
        """
        if self._autoscaler is None:
            return self.n_replicas
        now = time.monotonic() if now is None else now
        rec = self._autoscaler.recommend(
            now, self.n_replicas, self.outstanding(),
            self._svc_cfg.max_batch)
        if rec != self.n_replicas:
            self.scale_to(rec)
        return self.n_replicas

    @property
    def autoscaler(self) -> Optional[ReplicaAutoscaler]:
        return self._autoscaler

    # -- lifecycle ----------------------------------------------------------
    def drain(self) -> None:
        with self._lock:
            reps = list(self._replicas)
        for rep in reps:
            rep.scheduler.drain()

    def close(self) -> None:
        with self._lock:
            reps = list(self._replicas)
            self._replicas = []
        for rep in reps:
            rep.scheduler.close()

    def __enter__(self) -> "ReplicaRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Adaptive serving policy: admission control + replica autoscaling.

PR 4 gave the service per-batch telemetry (:class:`BatchStats`: occupancy,
padding waste, wall time) but nothing *acted* on it. This module closes
the loop with two pure, clock-injected policies (no threads, no sleeps —
every decision is a function of observed events and an explicit ``now``,
so tests drive them deterministically):

* :class:`AdmissionPolicy` — per-bucket batching knobs for the async
  scheduler. It tracks an arrival-rate EWMA per kmer bucket plus an
  occupancy EWMA from executed batches, and derives (a) the **flush
  deadline** (how long the oldest request may wait for peers) and (b) the
  **admission target** (how many requests to wait for before flushing).
  The *physical* batch shape stays fixed at ``ServiceConfig.max_batch`` —
  that is what preserves the compile-once-per-(bucket, backend) guarantee;
  the policy only moves how full a batch must be before it launches.
  Busy buckets batch up (occupancy↑, amortized dispatch); idle buckets
  flush almost immediately (latency↓, pad waste accepted).

* :class:`ReplicaAutoscaler` — replica-count recommendation for the
  router. It estimates total arrival rate (EWMA over submits) and
  per-replica service rate (EWMA of ``n_requests / wall`` over executed
  batches), sizes the fleet for ``target_utilization``, forces a step up
  when the outstanding backlog exceeds ``backlog_per_replica`` batches per
  replica, and rate-limits changes with a cooldown + one-step hysteresis
  so a noisy minute cannot thrash replicas up and down.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

__all__ = [
    "AutoscaleConfig",
    "EwmaRate",
    "Ewma",
    "AdmissionPolicy",
    "ReplicaAutoscaler",
]


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Knobs shared by the admission policy and the replica autoscaler."""

    # -- EWMA horizons ------------------------------------------------------
    halflife_s: float = 0.5        # arrival-rate estimator memory
    # -- admission (per bucket) --------------------------------------------
    deadline_ms_min: float = 0.2   # never hold a lone request longer than
    deadline_ms_max: float = 20.0  # ... and never wait past this for peers
    fill_slack: float = 1.0        # fraction of the fill time to wait
    target_occupancy: float = 0.7  # occupancy below this shrinks deadlines
    # -- replica scaling ----------------------------------------------------
    min_replicas: int = 1
    max_replicas: int = 4
    target_utilization: float = 0.6   # size fleet for rate/(mu*this)
    backlog_per_replica: float = 2.0  # queued batches/replica forcing +1
    cooldown_s: float = 1.0           # min seconds between size changes

    def __post_init__(self):
        if self.deadline_ms_min > self.deadline_ms_max:
            raise ValueError("deadline_ms_min must be <= deadline_ms_max")
        if not (0 < self.target_utilization <= 1):
            raise ValueError("target_utilization must be in (0, 1]")
        if self.min_replicas < 1 or self.max_replicas < self.min_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")


# ---------------------------------------------------------------------------
# Clock-injected estimators.
# ---------------------------------------------------------------------------

class EwmaRate:
    """Exponentially decayed event counter -> events/sec estimate.

    ``observe(now)`` bumps a count that decays with time constant
    ``tau = halflife / ln 2``; at steady state the decayed count of a rate-r
    stream is ``r * tau``, so ``rate(now) = count / tau``. No windows, no
    buffers — O(1) state, exact decay between arbitrary timestamps.
    """

    def __init__(self, halflife_s: float):
        if halflife_s <= 0:
            raise ValueError("halflife_s must be > 0")
        self._tau = halflife_s / math.log(2.0)
        self._count = 0.0
        self._t = None  # type: Optional[float]

    def _decay_to(self, now: float) -> None:
        if self._t is not None and now > self._t:
            self._count *= math.exp(-(now - self._t) / self._tau)
        self._t = now if self._t is None else max(self._t, now)

    def observe(self, now: float, weight: float = 1.0) -> None:
        self._decay_to(now)
        self._count += weight

    def rate(self, now: float) -> float:
        """Estimated events/sec at ``now`` (decays while idle)."""
        if self._t is None:
            return 0.0
        count = self._count
        if now > self._t:
            count *= math.exp(-(now - self._t) / self._tau)
        return count / self._tau


class Ewma:
    """Plain exponentially weighted mean of a sampled value."""

    def __init__(self, alpha: float = 0.2):
        if not (0 < alpha <= 1):
            raise ValueError("alpha must be in (0, 1]")
        self._alpha = alpha
        self._value = None  # type: Optional[float]

    def observe(self, x: float) -> None:
        self._value = (x if self._value is None
                       else self._alpha * x + (1 - self._alpha) * self._value)

    def value(self, default: float = 0.0) -> float:
        return default if self._value is None else self._value


# ---------------------------------------------------------------------------
# Admission policy: per-bucket deadline + admission target.
# ---------------------------------------------------------------------------

class AdmissionPolicy:
    """Adapt per-bucket flush deadline and admission target to the load.

    The rule, per bucket:

    * ``deadline_ms`` — the time a full batch would take to fill at the
      current arrival rate (``max_batch / rate``), scaled by ``fill_slack``
      and an occupancy correction, clamped to
      ``[deadline_ms_min, deadline_ms_max]``. Fast streams fill batches
      before the deadline matters; slow streams are not held hostage.
    * ``target_batch`` — the number of requests the deadline is actually
      expected to gather (``rate * deadline``), clamped to
      ``[1, max_batch]``. An idle bucket therefore flushes at 1 request
      after ``deadline_ms_min`` — minimum latency — while a hot bucket
      waits for a full batch — maximum occupancy.

    Occupancy feedback (the BatchStats consumer): batches that keep
    flushing on deadline with occupancy below ``target_occupancy`` shrink
    the bucket's deadline scale (we waited and peers never came); full
    batches relax it back. The scale is bounded so one burst cannot wedge
    the knob at an extreme.
    """

    _SCALE_LO, _SCALE_HI = 0.25, 4.0

    def __init__(self, config: Optional[AutoscaleConfig] = None):
        self.config = config or AutoscaleConfig()
        self._rates: Dict[int, EwmaRate] = {}
        self._occ: Dict[int, Ewma] = {}
        self._scale: Dict[int, float] = {}

    # -- observations -------------------------------------------------------
    def observe_arrival(self, bucket: int, now: float) -> None:
        rate = self._rates.get(bucket)
        if rate is None:
            rate = self._rates[bucket] = EwmaRate(self.config.halflife_s)
        rate.observe(now)

    def observe_batch(self, stats, now: float) -> None:
        """Feed one executed batch (BatchStats/ClusterStats duck-typed)."""
        bucket = stats.bucket
        occ = self._occ.get(bucket)
        if occ is None:
            occ = self._occ[bucket] = Ewma()
        occ.observe(stats.n_requests / max(stats.batch_rows, 1))
        scale = self._scale.get(bucket, 1.0)
        reason = getattr(stats, "flush_reason", None)
        if stats.n_requests >= stats.batch_rows:
            scale = min(scale * 1.1, self._SCALE_HI)
        elif reason == "deadline" and \
                occ.value(1.0) < self.config.target_occupancy:
            scale = max(scale * 0.9, self._SCALE_LO)
        self._scale[bucket] = scale

    # -- recommendations ----------------------------------------------------
    def deadline_ms(self, bucket: int, now: float, max_batch: int) -> float:
        cfg = self.config
        rate = self._rates.get(bucket)
        r = rate.rate(now) if rate is not None else 0.0
        if r <= 1e-9:
            return cfg.deadline_ms_min          # idle: don't hold requests
        fill_ms = 1e3 * max_batch / r
        dl = fill_ms * cfg.fill_slack * self._scale.get(bucket, 1.0)
        return min(max(dl, cfg.deadline_ms_min), cfg.deadline_ms_max)

    def target_batch(self, bucket: int, now: float, max_batch: int) -> int:
        rate = self._rates.get(bucket)
        r = rate.rate(now) if rate is not None else 0.0
        expected = r * self.deadline_ms(bucket, now, max_batch) * 1e-3
        return min(max(int(math.ceil(expected)), 1), max_batch)


# ---------------------------------------------------------------------------
# Replica autoscaler: fleet sizing between min/max bounds.
# ---------------------------------------------------------------------------

class ReplicaAutoscaler:
    """Recommend a replica count from arrival rate, service rate, backlog."""

    def __init__(self, config: Optional[AutoscaleConfig] = None):
        self.config = config or AutoscaleConfig()
        self._arrivals = EwmaRate(self.config.halflife_s)
        self._mu = Ewma()                     # per-replica req/s while busy
        self._t_last_change = None            # type: Optional[float]

    def observe_arrival(self, now: float) -> None:
        self._arrivals.observe(now)

    def observe_batch(self, stats, now: float) -> None:
        if stats.wall_ms > 0:
            self._mu.observe(stats.n_requests / (stats.wall_ms * 1e-3))

    def recommend(self, now: float, n_replicas: int,
                  outstanding: int, max_batch: int) -> int:
        """Next replica count: one hysteresis step toward the demand size,
        clamped to ``[min_replicas, max_replicas]``, cooldown-gated."""
        cfg = self.config
        rate = self._arrivals.rate(now)
        mu = self._mu.value(0.0)
        if mu > 0:
            desired = math.ceil(rate / (mu * cfg.target_utilization))
        else:
            desired = n_replicas                 # no service-rate sample yet
        if outstanding > cfg.backlog_per_replica * max_batch * n_replicas:
            desired = max(desired, n_replicas + 1)   # queue is winning
        desired = min(max(desired, cfg.min_replicas), cfg.max_replicas)
        if desired == n_replicas:
            return n_replicas
        if self._t_last_change is not None and \
                now - self._t_last_change < cfg.cooldown_s:
            return n_replicas                    # cooling down
        self._t_last_change = now
        # one step at a time: a noisy estimate moves the fleet by 1, not 3
        return n_replicas + (1 if desired > n_replicas else -1)

"""Scatter-gather query tier over a sharded archive.

The second horizontal axis from the roadmap (replicas × shards): where
:mod:`repro.serving.router` / :mod:`repro.serving.fabric` put K copies of
ONE index behind a routing policy, the :class:`ScatterGatherRouter` puts
the N PIECES of one index (:mod:`repro.index.shards`) behind a fan-out.
Every normalized request goes to ALL shards; partial answers are merged
EXACTLY — integer coverage thresholds make the merge lossless, so the
gathered result is bit-identical to one service holding the unsharded
index (asserted across engines × schemes × thetas in
tests/test_shards.py and in-bench by benchmarks/shards_bench.py).

Shard members come in two flavors, mirroring the replica tier:

* **in-process** (default): each shard is an :class:`AsyncScheduler`
  over a :class:`ShardSearchService` — N flusher threads in this
  interpreter, sharing the GIL but overlapping device dispatch.
* **procs** (``ScatterConfig(procs=True)``): each shard is a spawned
  worker process (:func:`shard_worker_main`, the fabric's worker recipe
  over :mod:`repro.serving.ipc`) that mmap-boots ONLY its shard from the
  shard-set snapshot — the gateway never holds any index bytes, it
  learns geometry from the CRC-checked set manifest alone.

**Shard death** is where the two partition axes genuinely differ, and
the router refuses to blur them:

* row-probe shards (bit-sliced / cobs) own a file range. A dead shard
  means those files are unanswerable; every gathered result names them
  in ``SearchResult.missing_files`` and reports their entries of
  ``matches`` as vacuously False. Partial truth, honestly labeled.
* bit-probe shards (flat BF / rambo) own a word range — every kmer's
  probes land across ALL shards. Dropping one shard's miss counts can
  only turn misses into hits: silent false-positive inflation. The
  router fails LOUD instead: affected futures get
  :class:`ShardDeadError`. Zero futures are ever dropped either way —
  every submit resolves with a result or an exception.

Results are stamped with the shard set's ``set_version`` (the audit
trail the replica tier keeps via service versions).
"""

from __future__ import annotations

import dataclasses
import itertools
import multiprocessing
import os
import signal
import socket
import tempfile
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.index import query, shards as shards_mod
from repro.index import state as state_mod
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.serving import ipc
from repro.serving import service as service_mod
from repro.serving.scheduler import AsyncScheduler, SchedulerConfig

__all__ = [
    "ScatterConfig",
    "ScatterError",
    "ShardDeadError",
    "ShardSearchService",
    "ScatterGatherRouter",
    "shard_worker_main",
]


class ScatterError(RuntimeError):
    """A shard-set-level operation failed (boot, no live shards)."""


class ShardDeadError(ScatterError):
    """A bit-probe shard died: its word range is unanswerable, and
    answering without it would silently inflate the false-positive rate
    (a missing MISS count can only turn misses into hits)."""


@dataclasses.dataclass(frozen=True)
class ScatterConfig:
    """Scatter-tier knobs (static for the life of the router)."""

    procs: bool = False          # shard members: threads here vs processes
    service: service_mod.ServiceConfig = dataclasses.field(
        default_factory=service_mod.ServiceConfig)
    scheduler: SchedulerConfig = dataclasses.field(
        default_factory=SchedulerConfig)
    verify: str = "eager"        # shard snapshot verify mode (store.load)
    boot_timeout_s: float = 180.0   # spawn -> ready (child re-imports jax)


# ---------------------------------------------------------------------------
# The per-shard service.
# ---------------------------------------------------------------------------

class ShardSearchService(service_mod.GeneSearchService):
    """One shard's serving surface — a :class:`GeneSearchService` whose
    answers are the shard's EXACT mergeable partial.

    Row-probe shards are complete engines over their file range, so the
    base class already does the right thing: local verdicts (padding,
    theta and bucketing included) that the router concatenates / ORs.
    Bit-probe shards override the compiled step with
    ``shards.partial_prober`` — per-(kmer, slot) local MISS counts,
    trimmed to the request's true kmer count — and leave the one
    coverage threshold to the router's merge. Bit-probe partials are NOT
    client-facing verdicts; only the router should consume them.
    """

    def __init__(self, spec: shards_mod.ShardSpec, shard_id: int,
                 shard: state_mod.IndexState,
                 config: Optional[service_mod.ServiceConfig] = None,
                 *, version: int = 0):
        self._spec = spec
        self._shard_id = shard_id
        if not spec.row_probe and config is not None \
                and config.kmer_cache is not None:
            raise ValueError(
                "bit-probe shard services emit partial miss counts, not "
                "membership rows — the kmer cache caches the wrong thing "
                "here; cache at the gather tier instead")
        super().__init__(shard, config, version=version)

    @property
    def shard_id(self) -> int:
        return self._shard_id

    def _runner(self, bucket: int):
        if self._spec.row_probe:
            return super()._runner(bucket)
        r = self._runners.get(bucket)
        if r is None:
            lo, hi = self._spec.shard_units(self._shard_id)
            fn = shards_mod.partial_prober(
                self._spec.meta.cfgs[0], self._spec.meta.scheme, lo, hi,
                self._spec.meta.engine == "rambo")

            def step(state, reads, valid, need):
                del valid, need   # the router thresholds after the merge
                return fn(state.words[0], reads)

            r = self._runners[bucket] = (step, fn)
        return r

    def _finalize(self, take, bucket: int, out
                  ) -> List[service_mod.SearchResult]:
        if self._spec.row_probe:
            return super()._finalize(take, bucket, out)
        out = np.asarray(out)     # (max_batch, bucket, W') local misses
        return [service_mod.SearchResult(
            request_id=req.request_id,
            # trim pad kmers NOW: a pad slot has zero misses and would
            # alias a hit once partials are summed across shards
            matches=np.ascontiguousarray(out[i, :n_k]),
            file_ids=(), n_kmers=n_k, bucket=bucket,
            version=self._version)
            for i, (req, n_k) in enumerate(take)]


# ---------------------------------------------------------------------------
# The shard worker process.
# ---------------------------------------------------------------------------

def shard_worker_main(shard_id: int, socket_path: str, set_dir: str,
                      svc_cfg: service_mod.ServiceConfig,
                      sched_cfg: SchedulerConfig, verify: str,
                      flags: dict) -> None:
    """Entry point of one shard worker (``spawn`` target).

    The fabric worker's boot recipe (connect + Hello, boot barrier,
    loud-error reply, then the message loop), loading ONLY this worker's
    shard — validated against the set manifest by ``shards.load_shard``,
    so a foreign or rewritten shard dir kills the boot by name instead
    of serving wrong bits.
    """
    if flags.get("boot_fail_shard") == shard_id:
        os._exit(2)               # test hook: crash before Hello
    wire = ipc.connect(socket_path)
    wire.send(ipc.Hello(worker_id=shard_id, pid=os.getpid()))
    boot = wire.recv()            # Request(kind="replay"): the boot barrier
    assert boot.kind == "replay", boot
    try:
        sm, shard = shards_mod.load_shard(
            set_dir, shard_id, mmap=True, verify=verify)
        svc = ShardSearchService(sm.spec, shard_id, shard, svc_cfg,
                                 version=sm.set_version)
        sched = AsyncScheduler(svc, sched_cfg, replica_id=shard_id)
    except Exception as e:  # noqa: BLE001 - boot failure -> loud reply
        wire.send(ipc.Reply(boot.id, error=e))
        os._exit(3)
    wire.send(ipc.Reply(boot.id, payload="ready"))

    def _reply_when_done(mid: int, fut: Future) -> None:
        def _cb(f: Future) -> None:
            err = f.exception()
            try:
                wire.send(ipc.Reply(
                    mid, payload=None if err else f.result(), error=err))
            except ipc.WireClosed:
                pass              # gateway gone; recv loop exits on EOF
        fut.add_done_callback(_cb)

    while True:
        try:
            msg = wire.recv()
        except ipc.WireClosed:
            break                 # gateway died; nothing to serve for
        try:
            if msg.kind == "query":
                rid, read = msg.payload
                # msg.trace parents this shard's pipeline spans under the
                # router's dispatch span — same stitching as the fabric
                _reply_when_done(msg.id, sched.submit(
                    service_mod.SearchRequest(read=read, request_id=rid),
                    trace=msg.trace))
            elif msg.kind == "stats":
                wire.send(ipc.Reply(msg.id, payload={
                    "pid": os.getpid(),
                    "shard_id": shard_id,
                    "version": svc.version,
                    "compile_counts": sched.compile_counts(),
                    "obs": obs_export.snapshot(),
                }))
            elif msg.kind == "shutdown":
                sched.close()     # drains: zero dropped futures
                wire.send(ipc.Reply(msg.id, payload="bye"))
                break
            else:
                wire.send(ipc.Reply(msg.id, error=ValueError(
                    f"unknown request kind {msg.kind!r}")))
        except ipc.WireClosed:
            break
        except Exception as e:  # noqa: BLE001 - admission errors etc.
            try:
                wire.send(ipc.Reply(msg.id, error=e))
            except ipc.WireClosed:
                break
    wire.close()


# ---------------------------------------------------------------------------
# The gather.
# ---------------------------------------------------------------------------

class _Gather:
    """One request's fan-out: a slot per shard, merged when the last
    slot is accounted for (answer, hard error, or death)."""

    def __init__(self, router: "ScatterGatherRouter", request_id: int,
                 n_kmers: int):
        self.future: Future = Future()
        self.request_id = request_id
        self.n_kmers = n_kmers
        self.parts: Dict[int, service_mod.SearchResult] = {}
        self.lost: set = set()
        self._router = router
        self._lock = threading.Lock()
        self._sealed = False

    def _account(self) -> bool:
        """True exactly once, when every shard has landed."""
        if self._sealed:
            return False
        if len(self.parts) + len(self.lost) < self._router.n_shards:
            return False
        self._sealed = True
        return True

    def shard_done(self, shard_id: int,
                   result: service_mod.SearchResult) -> None:
        with self._lock:
            self.parts[shard_id] = result
            finish = self._account()
        if finish:
            self._finish()

    def shard_lost(self, shard_id: int) -> None:
        with self._lock:
            self.lost.add(shard_id)
            finish = self._account()
        if finish:
            self._finish()

    def shard_failed(self, shard_id: int, exc: BaseException) -> None:
        """A shard answered with a hard error (bad request reaches every
        shard identically, so one error speaks for the gather)."""
        with self._lock:
            if self._sealed:
                return
            self._sealed = True
        if not self.future.done():
            self.future.set_exception(exc)

    def _finish(self) -> None:
        try:
            self.future.set_result(self._router._merge(self))
        except Exception as e:  # noqa: BLE001 - incl. ShardDeadError
            self.future.set_exception(e)


@dataclasses.dataclass
class _Shard:
    id: int
    proc: Optional[multiprocessing.process.BaseProcess] = None
    wire: Optional[ipc.Wire] = None
    sched: Optional[AsyncScheduler] = None      # in-process member
    alive: bool = True
    retiring: bool = False    # planned shutdown: EOF is not a death
    last_error: Optional[BaseException] = None


# ---------------------------------------------------------------------------
# The router.
# ---------------------------------------------------------------------------

class ScatterGatherRouter:
    """Fan one request over every shard of a shard-set snapshot; gather
    and merge the partials exactly. ``submit`` returns a
    ``Future[SearchResult]`` stamped with the shard set's version."""

    def __init__(self, shard_set_dir: str,
                 config: Optional[ScatterConfig] = None):
        self.config = config or ScatterConfig()
        self._dir = shard_set_dir
        # O(manifest): geometry + version from the CRC-checked set
        # manifest; the gateway itself never pages shard bytes in
        sm = shards_mod.read_set_meta(shard_set_dir)
        self._set_meta = sm
        self._spec = sm.spec
        self._set_version = sm.set_version
        self._k = state_mod.kmer_size(sm.spec.meta)
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._next_rid = itertools.count()
        self._mid = itertools.count()
        # mid -> (shard_id, kind, ctx, open dispatch Span | None)
        self._pending: Dict[int, Tuple[int, str, object, object]] = {}
        self._shards: List[_Shard] = []
        self._closed = False
        self._test_flags: dict = {}
        self._listener = None
        self._rundir = None
        try:
            if self.config.procs:
                self._boot_procs()
            else:
                self._boot_threads()
        except Exception:
            self.close()
            raise

    # -- boot ----------------------------------------------------------------
    def _boot_threads(self) -> None:
        _, states = shards_mod.load_shard_set(
            self._dir, mmap=True, verify=self.config.verify)
        for s, st in enumerate(states):
            svc = ShardSearchService(self._spec, s, st,
                                     self.config.service,
                                     version=self._set_version)
            self._shards.append(_Shard(
                id=s, sched=AsyncScheduler(
                    svc, self.config.scheduler, replica_id=s)))

    def _boot_procs(self) -> None:
        ctx = multiprocessing.get_context("spawn")
        # AF_UNIX paths cap at ~107 bytes; a private dir in the default
        # tmp root stays short no matter where the caller runs
        self._rundir = tempfile.mkdtemp(prefix="idl-scatter-")
        self._socket_path = os.path.join(self._rundir, "gw.sock")
        self._listener = ipc.listen(self._socket_path)
        for s in range(self._spec.n_shards):
            proc = ctx.Process(
                target=shard_worker_main,
                args=(s, self._socket_path, self._dir,
                      self.config.service, self.config.scheduler,
                      self.config.verify, dict(self._test_flags)),
                daemon=True, name=f"idl-shard-{s}")
            proc.start()
            self._shards.append(_Shard(id=s, proc=proc))
        self._hello_all()
        for sh in self._shards:   # boot barrier: load + schedule, or die
            sh.wire.send(ipc.Request(next(self._mid), "replay"))
        for sh in self._shards:
            try:
                ready = sh.wire.recv()
            except ipc.WireClosed as e:
                raise ScatterError(
                    f"shard {sh.id} died while booting from "
                    f"{self._dir!r}") from e
            if ready.error is not None:
                raise ScatterError(
                    f"shard {sh.id} failed to boot from {self._dir!r}: "
                    f"{ready.error!r}")
            threading.Thread(target=self._receiver_loop, args=(sh,),
                             daemon=True,
                             name=f"idl-scatter-recv-{sh.id}").start()

    def _hello_all(self) -> None:
        """Accept until every spawned shard said Hello (spawns overlap,
        so the fleet pays ONE interpreter boot, not N)."""
        pending = {sh.id: sh for sh in self._shards}
        deadline = time.monotonic() + self.config.boot_timeout_s
        self._listener.settimeout(0.2)
        while pending:
            for sh in pending.values():
                if not sh.proc.is_alive():
                    raise ScatterError(
                        f"shard {sh.id} died during boot "
                        f"(exit code {sh.proc.exitcode})")
            if time.monotonic() > deadline:
                raise ScatterError(
                    f"shard boot timed out after "
                    f"{self.config.boot_timeout_s}s")
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            wire = ipc.Wire(conn)
            hello = wire.recv()
            pending.pop(hello.worker_id).wire = wire

    # -- views ---------------------------------------------------------------
    @property
    def spec(self) -> shards_mod.ShardSpec:
        return self._spec

    @property
    def n_shards(self) -> int:
        return self._spec.n_shards

    @property
    def set_version(self) -> int:
        return self._set_version

    def live_shards(self) -> List[int]:
        with self._lock:
            return [sh.id for sh in self._shards if sh.alive]

    def shard_pids(self) -> Dict[int, int]:
        """Live proc shards' OS pids (fault-injection hooks for tests)."""
        with self._lock:
            return {sh.id: sh.proc.pid for sh in self._shards
                    if sh.alive and sh.proc is not None}

    def stats(self) -> Dict[int, dict]:
        """Per-shard serving stats (gathered over the wire for procs)."""
        if not self.config.procs:
            with self._lock:
                members = [(sh.id, sh.sched) for sh in self._shards
                           if sh.alive]
            return {sid: {
                "shard_id": sid,
                "version": self._set_version,
                "compile_counts": sched.compile_counts(),
            } for sid, sched in members}
        futures: List[Tuple[int, Future]] = []
        with self._lock:
            for sh in self._shards:
                if not sh.alive:
                    continue
                fut: Future = Future()
                mid = next(self._mid)
                self._pending[mid] = (sh.id, "stats", fut, None)
                futures.append((sh.id, fut))
                try:
                    sh.wire.send(ipc.Request(mid, "stats"))
                except ipc.WireClosed:
                    pass          # death lands via the receiver thread
        out = {}
        for sid, fut in futures:
            try:
                out[sid] = fut.result(timeout=30)
            except Exception:  # noqa: BLE001 - died mid-gather: skip it
                pass
        return out

    def obs_snapshot(self) -> dict:
        """Fleet obs view. In-process shard members already feed this
        process's registry, so the local snapshot is the whole story;
        proc members ship their snapshots on the ``stats`` reply and the
        merge stitches their spans under the router's dispatch spans."""
        local = obs_export.snapshot()
        if not self.config.procs:
            return local
        per = self.stats()
        return obs_export.merge(
            [local] + [s["obs"] for s in per.values()
                       if isinstance(s, dict) and s.get("obs")])

    # -- admission -----------------------------------------------------------
    def submit(self, request) -> Future:
        """Fan one read to every live shard; Future[SearchResult].

        Admission mints the trace id: the router's root span covers the
        whole scatter-gather (closed when the gathered future resolves),
        one ``shard_exec`` child per dispatch, and — for proc shards —
        the shard's own pipeline spans stitch under that child across the
        process boundary.
        """
        req, n_kmers = service_mod.normalize_request(request, self._k)
        rid = req.request_id
        if rid is None:
            rid = next(self._next_rid)
        req = service_mod.SearchRequest(read=req.read, request_id=rid)
        g = _Gather(self, rid, n_kmers)
        trc = obs_trace.DEFAULT
        ctx = None
        if trc.enabled:
            root = trc.start("request", tier="scatter", rid=rid)
            ctx = root.context()
            g.future.add_done_callback(lambda f: root.end(
                status="error" if (f.cancelled() or f.exception())
                else "ok"))
        with self._lock:
            if self._closed:
                raise ScatterError("scatter router is closed")
            members = list(self._shards)
        if not any(sh.alive for sh in members):
            raise ScatterError("scatter router has no live shards")
        for sh in members:
            if not sh.alive:
                g.shard_lost(sh.id)
            elif sh.sched is not None:
                self._dispatch_local(sh, g, req, trace=ctx)
            else:
                self._dispatch_proc(sh, g, req, trace=ctx)
        return g.future

    def search(self, reads) -> List[service_mod.SearchResult]:
        """Synchronous convenience: submit all, results in order."""
        return [f.result() for f in [self.submit(r) for r in reads]]

    def _dispatch_local(self, sh: _Shard, g: _Gather,
                        req: service_mod.SearchRequest, *,
                        trace=None) -> None:
        def _cb(f: Future) -> None:
            err = f.exception()
            if err is not None:
                g.shard_failed(sh.id, err)
            else:
                g.shard_done(sh.id, f.result())
        try:
            sh.sched.submit(req, trace=trace).add_done_callback(_cb)
        except Exception as e:  # noqa: BLE001 - closed scheduler = dead
            g.shard_lost(sh.id) if isinstance(e, RuntimeError) \
                else g.shard_failed(sh.id, e)

    def _dispatch_proc(self, sh: _Shard, g: _Gather,
                       req: service_mod.SearchRequest, *,
                       trace=None) -> None:
        trc = obs_trace.DEFAULT
        span = (trc.start("shard_exec", trace=trace, shard=sh.id,
                          rid=req.request_id)
                if trc.enabled and trace is not None else None)
        with self._lock:
            if not sh.alive:
                if span is not None:
                    span.end(status="error", error="shard dead")
                g.shard_lost(sh.id)
                return
            mid = next(self._mid)
            self._pending[mid] = (sh.id, "query", g, span)
        try:
            sh.wire.send(ipc.Request(
                mid, "query", (req.request_id, req.read),
                trace=None if span is None else span.context()))
        except ipc.WireClosed:
            with self._lock:
                self._pending.pop(mid, None)
            if span is not None:
                span.end(status="error", error=f"shard {sh.id} died")
            self._on_shard_death(sh)
            g.shard_lost(sh.id)

    # -- gather --------------------------------------------------------------
    def _receiver_loop(self, sh: _Shard) -> None:
        while True:
            try:
                msg = sh.wire.recv()
            except Exception:  # noqa: BLE001 - any wire failure is death
                self._on_shard_death(sh)
                return
            if msg.id == -1:          # unsolicited fatal shard error
                sh.last_error = msg.error
                continue
            with self._lock:
                entry = self._pending.pop(msg.id, None)
                self._idle.notify_all()
            if entry is None:
                continue
            _, kind, ctx, span = entry
            if span is not None:
                span.end(status="ok" if msg.error is None else "error")
            if kind == "query":
                if msg.error is not None:
                    ctx.shard_failed(sh.id, msg.error)
                else:
                    ctx.shard_done(sh.id, msg.payload)
            elif msg.error is not None:
                ctx.set_exception(msg.error)
            else:
                ctx.set_result(msg.payload)

    def _on_shard_death(self, sh: _Shard) -> None:
        with self._lock:
            if not sh.alive:
                return
            sh.alive = False
            was_planned = sh.retiring
            orphaned = [(mid, e) for mid, e in self._pending.items()
                        if e[0] == sh.id]
            for mid, _ in orphaned:
                del self._pending[mid]
            self._idle.notify_all()
        try:
            sh.wire.close()
        except Exception:  # noqa: BLE001 - already dead
            pass
        if sh.proc is not None and not sh.proc.is_alive():
            sh.proc.join(timeout=1)   # reap, don't leave a zombie
        # orphaned dispatch spans close with ERROR — a kill -9'd shard's
        # in-flight work must show up in the trace, not leak open
        for _, (_, _, _, span) in orphaned:
            if span is not None:
                span.end(status="error", error=f"shard {sh.id} died")
        for _, (_, kind, ctx, _) in orphaned:
            if kind == "query":
                # no re-route exists: this shard held the ONLY copy of
                # its partition. The gather decides what its death means
                # (missing_files vs ShardDeadError) at merge time.
                ctx.shard_lost(sh.id)
            elif was_planned:
                if not ctx.done():
                    ctx.set_result(None)
            else:
                ctx.set_exception(ScatterError(
                    f"shard {sh.id} died before answering a {kind!r} "
                    f"request"))

    def _merge(self, g: _Gather) -> service_mod.SearchResult:
        """Combine per-shard partials into the oracle's exact answer."""
        spec, meta = self._spec, self._spec.meta
        bucket = service_mod.bucket_for(
            g.n_kmers, self.config.service.min_bucket_kmers)
        missing: Tuple[int, ...] = ()
        if spec.row_probe:
            matches = np.zeros(int(meta.n_files), dtype=bool)
            if meta.engine == "bitsliced":
                for s, res in g.parts.items():
                    owned = shards_mod.shard_files(spec, s)
                    row = np.asarray(res.matches, dtype=bool)
                    matches[owned[0]:owned[0] + len(owned)] = \
                        row[:len(owned)]
            else:                     # cobs: OR over disjoint file sets
                for res in g.parts.values():
                    matches |= np.asarray(res.matches, dtype=bool)
            missing = tuple(sorted(
                f for s in g.lost for f in shards_mod.shard_files(spec, s)))
            fids = tuple(int(f) for f in np.nonzero(matches)[0])
        else:
            if g.lost:
                dead = sorted(g.lost)
                ranges = [spec.shard_units(s) for s in dead]
                raise ShardDeadError(
                    f"bit-probe shard(s) {dead} (word ranges {ranges}) "
                    f"died; their probes are unanswerable — failing loud "
                    f"instead of silently inflating the FPR")
            total = None              # (n_k, W') summed miss counts
            for s in range(spec.n_shards):
                part = np.asarray(g.parts[s].matches, dtype=np.int64)
                total = part if total is None else total + part
            member = total == 0       # a hit is zero misses ANYWHERE
            need = query.coverage_need(
                self.config.service.theta, g.n_kmers)
            if meta.engine == "bloom":
                hit = int(member[:, 0].sum()) >= need
                matches = np.bool_(hit)
                fids = (0,) if hit else ()
            else:                     # rambo: bucket grid -> per-file AND
                grid = member.reshape(g.n_kmers, meta.n_rep,
                                      meta.n_buckets)
                asn = shards_mod.rambo_file_assignment(meta)   # (R, N)
                per_rep = grid[:, np.arange(meta.n_rep)[:, None], asn]
                matches = per_rep.all(axis=1).sum(axis=0) >= need
                fids = tuple(int(f) for f in np.nonzero(matches)[0])
        return service_mod.SearchResult(
            request_id=g.request_id, matches=matches, file_ids=fids,
            n_kmers=g.n_kmers, bucket=bucket, version=self._set_version,
            missing_files=missing)

    # -- fault injection / lifecycle -----------------------------------------
    def kill_shard(self, shard_id: int) -> None:
        """Take one shard down (test/ops hook). Proc shards are SIGKILLed
        — their in-flight gathers see a real mid-stream death. In-process
        shards retire gracefully (their scheduler drains first), then
        stop receiving traffic."""
        with self._lock:
            sh = self._shards[shard_id]
        if sh.proc is not None:
            os.kill(sh.proc.pid, signal.SIGKILL)
            return
        with self._lock:
            if not sh.alive:
                return
            sh.alive = False
        sh.sched.close()              # drains: zero dropped futures

    def drain(self) -> None:
        """Block until every in-flight request has its reply."""
        with self._lock:
            members = [sh.sched for sh in self._shards
                       if sh.alive and sh.sched is not None]
        for sched in members:
            sched.drain()
        with self._idle:
            while self._pending:
                self._idle.wait(timeout=1.0)

    def _shutdown_proc(self, sh: _Shard) -> None:
        sh.retiring = True
        fut: Future = Future()
        with self._lock:
            mid = next(self._mid)
            self._pending[mid] = (sh.id, "shutdown", fut, None)
        try:
            sh.wire.send(ipc.Request(mid, "shutdown"))
            fut.result(timeout=60)
        except Exception:  # noqa: BLE001 - escalate to terminate below
            with self._lock:
                self._pending.pop(mid, None)
                self._idle.notify_all()
        sh.proc.join(timeout=10)
        if sh.proc.is_alive():
            sh.proc.terminate()
            sh.proc.join(timeout=10)
        with self._lock:
            sh.alive = False

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            members = [sh for sh in self._shards if sh.alive]
        for sh in members:
            if sh.sched is not None:
                sh.sched.close()
            elif sh.wire is not None:
                self._shutdown_proc(sh)
            elif sh.proc is not None:
                sh.retiring = True
                sh.proc.terminate()
                sh.proc.join(timeout=10)
        if self._listener is not None:
            self._listener.close()
        if self._rundir is not None:
            try:
                os.unlink(self._socket_path)
                os.rmdir(self._rundir)
            except OSError:
                pass

    def __enter__(self) -> "ScatterGatherRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Process-parallel serving fabric: one gateway, N mmap-booted workers.

The in-process cluster (:mod:`repro.serving.router`) tops out at one
interpreter: K replicas share a GIL, so host-side batch assembly,
planning and decode serialize no matter how many cores the box has. The
fabric promotes the same architecture to real processes:

* **Gateway** (this process) owns admission and durability. It speaks
  the existing typed boundary — ``SearchRequest`` in, ``SearchResult``
  out, ``InsertAck`` for writes — validates reads with the SAME
  ``normalize_request`` the in-process service uses, buckets them with
  the SAME ``bucket_for`` geometry (learned from the snapshot manifest
  via :func:`repro.index.store.read_meta` — the gateway never holds the
  index), and spreads them over workers with the SAME
  :class:`~repro.serving.router.RoutingPolicy` the router uses. The
  fleet write-ahead journal lives HERE and is the single source of
  sequence truth: ``insert`` journals once (flush + fsync) under the
  gateway lock, then fans the batch — with its fleet ``seq`` riding
  along — to every serving worker.

* **Workers** (``multiprocessing`` ``spawn``) each boot a
  :class:`~repro.serving.live.LiveGeneSearchService` by loading ONE
  shared on-disk snapshot with ``store.load(mmap=True, verify="lazy")``
  — the checksum pass runs behind the boot, cold-start is O(manifest) +
  one data pass, and the page cache shares that single read across all
  K workers — then serve query/insert requests over a length-prefixed
  pickle socket (:mod:`repro.serving.ipc`). Inside a worker, all writes
  and query dispatch ride the scheduler's single flusher thread, which
  is what licenses the live index's donated delta scatter.

* **Fault model** — a worker that dies (crash, ``kill -9``, failed
  background verify) surfaces as EOF on its wire: the gateway marks it
  dead, re-routes its in-flight queries to surviving workers, and counts
  its unacked inserts as covered (the write is journaled; the worker's
  replacement replays it). A gateway reboot replays the journal into
  every worker — an acked write is never lost.

* **Rolling restart** — the fleet-wide generalization of hot snapshot
  swap. Workers swap one at a time, replacement-first: boot the new
  worker from the target snapshot, replay the journal tail, catch it up
  on writes that landed during its boot, and only then drain + retire
  the old one — queries keep flowing to the rest of the fleet the whole
  time (zero dropped requests, asserted under traffic in the bench). If
  a replacement fails to boot, the rollout aborts and already-swapped
  workers roll back: the fleet keeps serving the OLD version, never a
  mix. :meth:`ProcessFabric.compact` rides the same machinery: the lead
  worker folds base+delta and saves the merged snapshot, then the fleet
  rolls onto it and the journal truncates through the fold watermark.
"""

from __future__ import annotations

import dataclasses
import itertools
import multiprocessing
import os
import socket
import tempfile
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.index import lsm, store
from repro.index import state as state_mod
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.serving import ipc
from repro.serving import service as service_mod
from repro.serving.live import LiveGeneSearchService
from repro.serving.router import RoutingPolicy
from repro.serving.scheduler import AsyncScheduler, InsertAck, SchedulerConfig

__all__ = [
    "FabricConfig",
    "FabricError",
    "WorkerLost",
    "ProcessFabric",
    "worker_main",
]


class FabricError(RuntimeError):
    """A fleet-level operation failed (boot, rollout, no workers)."""


class WorkerLost(FabricError):
    """A request could not be served because its worker died and no
    surviving worker could take it."""


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Fleet knobs (static for the life of the fabric)."""

    n_workers: int = 2
    policy: str = "least_outstanding"
    service: service_mod.ServiceConfig = dataclasses.field(
        default_factory=service_mod.ServiceConfig)
    scheduler: SchedulerConfig = dataclasses.field(
        default_factory=SchedulerConfig)
    verify: str = "lazy"         # worker snapshot verify mode (store.load)
    boot_timeout_s: float = 180.0   # spawn -> ready (child re-imports jax)

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        RoutingPolicy(self.policy)        # validates the policy name


# ---------------------------------------------------------------------------
# The worker process.
# ---------------------------------------------------------------------------

def worker_main(worker_id: int, socket_path: str, snapshot_dir: str,
                base_version: int, start_seq: int,
                svc_cfg: service_mod.ServiceConfig,
                sched_cfg: SchedulerConfig, verify: str,
                flags: dict) -> None:
    """Entry point of one worker process (``spawn`` target).

    Boot order matters: connect + Hello first (so the gateway can watch
    the boot), then receive the journal tail, THEN build the live index
    and replay it — all single-threaded — and only then start the
    scheduler and reply ready. After ready, every write reaches the
    delta through the scheduler's flusher thread (the single-writer
    discipline donated delta buffers require), so the gateway catches a
    booted worker up via ordinary ``insert`` requests, never a raw
    replay.
    """
    if flags.get("boot_fail_snapshot") == snapshot_dir:
        os._exit(2)               # test hook: crash before Hello
    wire = ipc.connect(socket_path)
    wire.send(ipc.Hello(worker_id=worker_id, pid=os.getpid()))
    boot = wire.recv()            # Request(kind="replay", payload=tail)
    assert boot.kind == "replay", boot
    try:
        live = lsm.LiveIndex(
            store.load(snapshot_dir, mmap=True, verify=verify),
            base_version=base_version, start_seq=start_seq)
        if boot.payload:
            live.replay(boot.payload)
        svc = LiveGeneSearchService(live, svc_cfg)
        sched = AsyncScheduler(svc, sched_cfg)
    except Exception as e:  # noqa: BLE001 - boot failure -> loud reply
        wire.send(ipc.Reply(boot.id, error=e))
        os._exit(3)
    wire.send(ipc.Reply(boot.id, payload="ready"))

    stop = threading.Event()

    def _watchdog() -> None:
        # a lazily verified snapshot must fail LOUDLY: report the
        # corruption to the gateway, then die (EOF completes the signal)
        while not stop.wait(0.25):
            try:
                store.check_verified(snapshot_dir, wait=False)
            except store.SnapshotError as e:
                try:
                    wire.send(ipc.Reply(-1, error=e))
                finally:
                    os._exit(4)

    threading.Thread(target=_watchdog, daemon=True,
                     name=f"idl-worker-{worker_id}-verify").start()

    def _reply_when_done(mid: int, fut: Future) -> None:
        def _cb(f: Future) -> None:
            err = f.exception()
            try:
                wire.send(ipc.Reply(
                    mid, payload=None if err else f.result(), error=err))
            except ipc.WireClosed:
                pass              # gateway gone; recv loop exits on EOF
        fut.add_done_callback(_cb)

    def _compact_to(mid: int, save_dir: str) -> None:
        # plan under the live lock, merge + save off every hot path; the
        # worker keeps serving base+delta — the fold only becomes the
        # fleet's base through the gateway's rolling restart
        try:
            plan = svc.live.plan_compaction()
            merged = lsm.LiveIndex.compact(plan).block_until_ready()
            store.save(merged, save_dir)
            wire.send(ipc.Reply(mid, payload=plan.upto_seq))
        except Exception as e:  # noqa: BLE001 - forward to the gateway
            wire.send(ipc.Reply(mid, error=e))

    while True:
        try:
            msg = wire.recv()
        except ipc.WireClosed:
            break                 # gateway died; nothing to serve for
        try:
            if msg.kind == "query":
                rid, read = msg.payload
                # msg.trace = the gateway's dispatch-span context: the
                # request/queue_wait/... spans this worker emits become
                # its children, so the gateway stitches ONE tree
                _reply_when_done(msg.id, sched.submit(
                    service_mod.SearchRequest(read=read, request_id=rid),
                    trace=msg.trace))
            elif msg.kind == "insert":
                seq, reads, fids = msg.payload
                _reply_when_done(msg.id, sched.submit_insert(
                    reads, fids, seq=seq, trace=msg.trace))
            elif msg.kind == "compact":
                threading.Thread(
                    target=_compact_to, args=(msg.id, msg.payload),
                    daemon=True, name=f"idl-worker-{worker_id}-compact",
                ).start()
            elif msg.kind == "stats":
                wire.send(ipc.Reply(msg.id, payload={
                    "pid": os.getpid(),
                    "version": svc.version,
                    "delta_seq": svc.live.delta_seq,
                    "requests_served": svc.requests_served(),
                    "compile_counts": sched.compile_counts(),
                    "kmer_cache": svc.cache_stats(),
                    # the whole process-local obs state rides the same
                    # reply: metrics for the fleet merge, finished span
                    # records for cross-process trace stitching
                    "obs": obs_export.snapshot(),
                }))
            elif msg.kind == "shutdown":
                sched.close()     # drains: zero dropped futures
                wire.send(ipc.Reply(msg.id, payload="bye"))
                break
            else:
                wire.send(ipc.Reply(msg.id, error=ValueError(
                    f"unknown request kind {msg.kind!r}")))
        except ipc.WireClosed:
            break
        except Exception as e:  # noqa: BLE001 - admission errors etc.
            try:
                wire.send(ipc.Reply(msg.id, error=e))
            except ipc.WireClosed:
                break
    stop.set()
    wire.close()


# ---------------------------------------------------------------------------
# The gateway.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Worker:
    id: int
    proc: multiprocessing.process.BaseProcess
    wire: Optional[ipc.Wire] = None
    version: int = 0
    serving: bool = False     # receives new queries + write fan-out
    alive: bool = True
    retiring: bool = False    # planned shutdown: EOF is not a death
    outstanding: int = 0      # requests sent, replies not yet received
    last_error: Optional[BaseException] = None


@dataclasses.dataclass
class _PendingMsg:
    worker_id: int
    kind: str
    future: Future
    ctx: object = None        # query: (SearchRequest, n_kmers)
    # the OPEN gateway-side dispatch span: closed ok by the receiver when
    # the worker replies, closed with error status by _on_worker_death —
    # this is how a kill -9 shows up in the trace instead of leaking
    span: Optional[obs_trace.Span] = None


class _FleetAck:
    """Resolves one ``Future[InsertAck]`` once every fanned-out copy of a
    write is acked — or its worker died (the write is journaled; the
    replacement replays it, so a death counts as covered)."""

    def __init__(self, future: Future, n_workers: int, ack: InsertAck):
        self.future = future
        self.ack = ack
        self._remaining = n_workers
        self._lock = threading.Lock()

    def _done(self) -> None:
        if self._remaining == 0 and not self.future.done():
            self.future.set_result(self.ack)

    def worker_acked(self) -> None:
        with self._lock:
            self._remaining -= 1
            self._done()

    def worker_lost(self) -> None:
        with self._lock:
            self._remaining -= 1
            self._done()

    def worker_error(self, e: BaseException) -> None:
        with self._lock:
            self._remaining -= 1
            if not self.future.done():
                self.future.set_exception(e)


class ProcessFabric:
    """N worker processes behind one gateway — ``submit`` / ``insert`` /
    ``compact`` with the same types and guarantees as the in-process
    router, but scaling with cores instead of the GIL."""

    def __init__(self, snapshot_dir: str,
                 config: Optional[FabricConfig] = None, *,
                 journal_path: Optional[str] = None,
                 base_version: int = 0):
        self.config = config or FabricConfig()
        # O(manifest): the gateway learns kmer size + bucket geometry
        # without ever paging the index in
        self._meta = store.read_meta(snapshot_dir)
        self._k = state_mod.kmer_size(self._meta)
        self._snapshot_dir = snapshot_dir
        self._version = int(base_version)
        self._journal = (lsm.DeltaJournal(journal_path)
                         if journal_path is not None else None)
        boot = self._journal.records() if self._journal is not None else []
        self._tail: List[lsm.JournalRecord] = list(boot)
        self._wal_seq = boot[-1].seq if boot else 0
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._admin_lock = threading.Lock()   # serializes restart/compact
        self._policy = RoutingPolicy(self.config.policy)
        self._pending: Dict[int, _PendingMsg] = {}
        self._mid = itertools.count()
        self._next_rid = itertools.count()
        self._next_wid = itertools.count()
        self._workers: List[_Worker] = []
        self._closed = False
        self._test_flags: dict = {}           # worker boot hooks (tests)
        self._ctx = multiprocessing.get_context("spawn")
        # AF_UNIX paths cap at ~107 bytes; a private mode-0700 dir in the
        # default tmp root stays short no matter where the caller runs
        self._rundir = tempfile.mkdtemp(prefix="idl-fabric-")
        self._socket_path = os.path.join(self._rundir, "gw.sock")
        self._listener = ipc.listen(self._socket_path)
        try:
            procs = [self._launch(snapshot_dir, self._version)
                     for _ in range(self.config.n_workers)]
            for w in self._hello_all(procs):
                self._finish_boot(w, snapshot_dir)
                with self._lock:
                    w.serving = True
        except Exception:
            self.close()
            raise

    # -- worker lifecycle ----------------------------------------------------
    def _launch(self, snapshot_dir: str, version: int) -> _Worker:
        wid = next(self._next_wid)
        with self._lock:
            start_seq = self._wal_seq
        proc = self._ctx.Process(
            target=worker_main,
            args=(wid, self._socket_path, snapshot_dir, version, start_seq,
                  self.config.service, self.config.scheduler,
                  self.config.verify, dict(self._test_flags)),
            daemon=True, name=f"idl-worker-{wid}")
        proc.start()
        w = _Worker(id=wid, proc=proc, version=version)
        with self._lock:
            self._workers.append(w)
        return w

    def _hello_all(self, procs: List[_Worker]) -> List[_Worker]:
        """Accept until every launched worker has said Hello (spawns run
        concurrently, so the fleet pays ONE interpreter boot, not N)."""
        pending = {w.id: w for w in procs}
        deadline = time.monotonic() + self.config.boot_timeout_s
        self._listener.settimeout(0.2)
        while pending:
            for w in pending.values():
                if not w.proc.is_alive():
                    self._abandon(list(pending.values()))
                    raise FabricError(
                        f"worker {w.id} died during boot "
                        f"(exit code {w.proc.exitcode})")
            if time.monotonic() > deadline:
                self._abandon(list(pending.values()))
                raise FabricError(
                    f"worker boot timed out after "
                    f"{self.config.boot_timeout_s}s")
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            wire = ipc.Wire(conn)
            hello = wire.recv()
            w = pending.pop(hello.worker_id)
            w.wire = wire
        return procs

    def _finish_boot(self, w: _Worker, snapshot_dir: str) -> None:
        """Replay the journal tail into a Hello'd worker, await ready,
        start its receiver, and catch it up on writes that landed while
        it booted. The caller flips ``serving`` when it wants traffic."""
        with self._lock:
            tail0 = tuple(self._tail)
            seq0 = self._wal_seq
        w.wire.send(ipc.Request(next(self._mid), "replay", tail0))
        try:
            ready = w.wire.recv()          # blocks through load + replay
        except ipc.WireClosed as e:
            raise FabricError(
                f"worker {w.id} died while booting from "
                f"{snapshot_dir!r}") from e
        if ready.error is not None:
            raise FabricError(
                f"worker {w.id} failed to boot from {snapshot_dir!r}: "
                f"{ready.error!r}")
        threading.Thread(target=self._receiver_loop, args=(w,),
                         daemon=True, name=f"idl-gw-recv-{w.id}").start()
        with self._lock:
            # writes that landed after the tail snapshot fan to the
            # worker as ordinary inserts — through its scheduler, on its
            # flusher thread, exactly like live traffic (a raw replay
            # would race the single-writer delta)
            for rec in [r for r in self._tail if r.seq > seq0]:
                self._send_insert_locked(
                    w, rec.seq, rec.reads, rec.file_ids, fleet=None)

    def _abandon(self, workers: List[_Worker]) -> None:
        for w in workers:
            w.retiring = True
            if w.proc.is_alive():
                w.proc.terminate()
            if w.wire is not None:
                w.wire.close()
            w.alive = False
        with self._lock:
            self._workers = [x for x in self._workers if x.alive]

    def _receiver_loop(self, w: _Worker) -> None:
        while True:
            try:
                msg = w.wire.recv()
            except Exception:  # noqa: BLE001 - any wire failure is death
                self._on_worker_death(w)
                return
            if msg.id == -1:              # unsolicited fatal worker error
                w.last_error = msg.error  # (e.g. background verify); the
                continue                  # process exit follows as EOF
            with self._lock:
                entry = self._pending.pop(msg.id, None)
                if entry is not None:
                    w.outstanding -= 1
                self._idle.notify_all()
            if entry is None:
                continue
            if entry.span is not None:
                entry.span.end(
                    status="ok" if msg.error is None else "error")
            if entry.kind == "insert":
                fleet = entry.ctx
                if fleet is None:
                    pass                  # boot catch-up: fire and forget
                elif msg.error is not None:
                    fleet.worker_error(msg.error)
                else:
                    fleet.worker_acked()
            elif msg.error is not None:
                entry.future.set_exception(msg.error)
            else:
                entry.future.set_result(msg.payload)

    def _on_worker_death(self, w: _Worker) -> None:
        with self._lock:
            if not w.alive:
                return
            w.alive = False
            w.serving = False
            was_planned = w.retiring
            orphaned = [(mid, p) for mid, p in self._pending.items()
                        if p.worker_id == w.id]
            for mid, _ in orphaned:
                del self._pending[mid]
            w.outstanding = 0
            self._idle.notify_all()
        try:
            w.wire.close()
        except Exception:  # noqa: BLE001 - already dead
            pass
        if not w.proc.is_alive():
            w.proc.join(timeout=1)        # reap, don't leave a zombie
        if was_planned:
            # a retiring worker's EOF is expected — resolve anything still
            # pending (its shutdown ack) instead of stranding the caller
            for _, p in orphaned:
                if p.span is not None:
                    p.span.end(status="ok", retired=True)
                if not p.future.done():
                    p.future.set_result(None)
            return
        # orphaned dispatch spans close with ERROR status — the worker
        # died (crash / kill -9) with this work in flight, and the trace
        # must say so instead of leaking an open span
        for _, p in orphaned:
            if p.span is not None:
                p.span.end(status="error",
                           error=f"worker {w.id} died")
        # re-route: the dead worker never replied, so every orphaned
        # query is safe to re-dispatch; orphaned inserts are journaled —
        # the replacement worker replays them, which is the ack contract
        for _, p in orphaned:
            if p.kind == "query":
                req, n_kmers = p.ctx
                trace = (None if p.span is None
                         else (p.span.trace_id, p.span.parent_id))
                try:
                    self._dispatch(req, n_kmers, p.future, trace=trace)
                except FabricError as e:
                    p.future.set_exception(WorkerLost(
                        f"worker {w.id} died and no survivor could take "
                        f"request {req.request_id}: {e}"))
            elif p.kind == "insert":
                if p.ctx is not None:
                    p.ctx.worker_lost()
            else:
                p.future.set_exception(WorkerLost(
                    f"worker {w.id} died before answering a {p.kind!r} "
                    f"request"))

    # -- views ---------------------------------------------------------------
    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    @property
    def wal_seq(self) -> int:
        with self._lock:
            return self._wal_seq

    @property
    def n_workers(self) -> int:
        with self._lock:
            return sum(1 for w in self._workers if w.alive and w.serving)

    def worker_pids(self) -> Dict[int, int]:
        """Live workers' OS pids (fault-injection hooks for tests)."""
        with self._lock:
            return {w.id: w.proc.pid for w in self._workers if w.alive}

    def stats(self) -> Dict[int, dict]:
        """Per-worker serving stats, gathered over the wire."""
        futures: List[Tuple[int, Future]] = []
        with self._lock:
            for w in self._workers:
                if not (w.alive and w.serving):
                    continue
                fut: Future = Future()
                mid = next(self._mid)
                self._pending[mid] = _PendingMsg(w.id, "stats", fut)
                w.outstanding += 1
                futures.append((w.id, fut))
                try:
                    w.wire.send(ipc.Request(mid, "stats"))
                except ipc.WireClosed:
                    pass          # death lands via the receiver thread
        out = {}
        for wid, fut in futures:
            try:
                out[wid] = fut.result(timeout=30)
            except Exception:  # noqa: BLE001 - died mid-gather: skip it
                pass
        return out

    def requests_served(self) -> int:
        return sum(s["requests_served"] for s in self.stats().values())

    def obs_snapshot(self) -> dict:
        """Fleet obs view: the gateway's own process snapshot merged with
        every serving worker's (each worker ships its full obs state on
        the ``stats`` reply). The span concatenation in the merge is
        where gateway dispatch spans and worker pipeline spans stitch
        into one tree per trace id."""
        per = self.stats()
        return obs_export.merge(
            [obs_export.snapshot()]
            + [s["obs"] for s in per.values()
               if isinstance(s, dict) and s.get("obs")])

    def cache_stats(self) -> Optional[dict]:
        """Fleet-wide kmer-cache view, derived from the merged registry
        snapshot (None = caches off everywhere) — same shape the
        per-worker ``KmerCache.stats()`` merge used to produce."""
        snap = self.obs_snapshot()
        if "kmer_cache.capacity" not in snap["metrics"].get("gauges", {}):
            return None
        return obs_export.cache_stats_view(snap)

    # -- admission -----------------------------------------------------------
    def _dispatch(self, req: service_mod.SearchRequest, n_kmers: int,
                  fut: Future, *,
                  trace: Optional[obs_trace.TraceContext] = None) -> None:
        bucket = service_mod.bucket_for(
            n_kmers, self.config.service.min_bucket_kmers)
        trc = obs_trace.DEFAULT
        with self._lock:
            if self._closed:
                raise FabricError("fabric is closed")
            serving = [w for w in self._workers if w.serving and w.alive]
            if not serving:
                raise FabricError("fabric has no serving workers")
            w = self._policy.pick(serving, bucket,
                                  lambda x: x.outstanding)
            # an OPEN span per dispatch: the worker's spans parent under
            # it via the wire's trace context; the receiver closes it ok,
            # a worker death closes it with error status
            span = (trc.start("worker_exec", trace=trace, worker=w.id,
                              rid=req.request_id)
                    if trc.enabled and trace is not None else None)
            mid = next(self._mid)
            self._pending[mid] = _PendingMsg(
                w.id, "query", fut, (req, n_kmers), span=span)
            w.outstanding += 1
        try:
            w.wire.send(ipc.Request(
                mid, "query", (req.request_id, req.read),
                trace=None if span is None else span.context()))
        except ipc.WireClosed:
            self._on_worker_death(w)      # re-routes this very request

    def submit(self, request) -> Future:
        """Route one read to a worker; returns a Future[SearchResult].

        Admission mints the trace id HERE: the gateway's root span covers
        the whole request lifetime (closed when the future resolves), the
        per-dispatch ``worker_exec`` child rides the wire, and the
        worker's pipeline spans stitch under it — one trace id across
        processes.
        """
        req, n_kmers = service_mod.normalize_request(request, self._k)
        rid = req.request_id
        if rid is None:
            rid = next(self._next_rid)
        req = service_mod.SearchRequest(read=req.read, request_id=rid)
        fut: Future = Future()
        trc = obs_trace.DEFAULT
        ctx = None
        if trc.enabled:
            root = trc.start("request", tier="gateway", rid=rid)
            ctx = root.context()
            fut.add_done_callback(lambda f: root.end(
                status="error" if (f.cancelled() or f.exception())
                else "ok"))
        self._dispatch(req, n_kmers, fut, trace=ctx)
        return fut

    def search(self, reads) -> List[service_mod.SearchResult]:
        """Synchronous convenience: submit all, results in order."""
        return [f.result() for f in [self.submit(r) for r in reads]]

    # -- the write path ------------------------------------------------------
    def _send_insert_locked(self, w: _Worker, seq: int, reads, fids,
                            fleet: Optional[_FleetAck], *,
                            trace: Optional[obs_trace.TraceContext] = None
                            ) -> List[_Worker]:
        """Register + send one insert to one worker (caller holds the
        lock — sends stay inside it so every worker sees one total write
        order). Returns the workers whose wires died (death handling
        needs the lock, so the caller runs it after releasing)."""
        trc = obs_trace.DEFAULT
        span = (trc.start("worker_insert", trace=trace, worker=w.id,
                          seq=seq)
                if trc.enabled and trace is not None else None)
        mid = next(self._mid)
        self._pending[mid] = _PendingMsg(w.id, "insert", Future(), fleet,
                                         span=span)
        w.outstanding += 1
        try:
            w.wire.send(ipc.Request(
                mid, "insert", (seq, reads, fids),
                trace=None if span is None else span.context()))
            return []
        except ipc.WireClosed:
            return [w]

    def insert(self, reads, file_ids=None) -> Future:
        """Journal one write batch, fan it to every serving worker.

        Returns ONE ``Future[InsertAck]`` that resolves when the write is
        searchable fleet-wide (every serving worker acked — or died,
        which the journal covers: the replacement replays the record).
        The gateway lock spans journal append + fan-out, so concurrent
        inserts reach every worker in one total order and per-worker
        ``delta_seq`` watermarks never stamp a write the worker has not
        actually absorbed.
        """
        reads = np.asarray(reads, dtype=np.uint8)
        if reads.ndim == 1:
            reads = reads[None]
        fids = (None if file_ids is None
                else np.asarray(file_ids, dtype=np.int32).reshape(-1))
        fut: Future = Future()
        dead: List[_Worker] = []
        trc = obs_trace.DEFAULT
        root = (trc.start("insert", tier="gateway", n_reads=len(reads))
                if trc.enabled else None)
        ctx = root.context() if root is not None else None
        if root is not None:
            fut.add_done_callback(lambda f: root.end(
                status="error" if (f.cancelled() or f.exception())
                else "ok"))
        with self._lock:
            if self._closed:
                raise FabricError("fabric is closed")
            serving = [w for w in self._workers if w.serving and w.alive]
            if not serving:
                raise FabricError("fabric has no serving workers")
            seq = self._wal_seq + 1
            t_j = time.monotonic()
            if self._journal is not None:
                self._journal.append(seq, reads, fids)
            if ctx is not None:
                trc.emit("journal_append", ctx[0], ctx[1], t_j,
                         time.monotonic(),
                         attrs={"seq": seq,
                                "durable": self._journal is not None})
            self._wal_seq = seq
            self._tail.append(lsm.JournalRecord(
                seq=seq, reads=reads, file_ids=fids))
            fleet = _FleetAck(fut, len(serving), InsertAck(
                base_version=self._version, delta_seq=seq,
                n_reads=int(reads.shape[0])))
            t_f = time.monotonic()
            for w in serving:
                dead.extend(self._send_insert_locked(
                    w, seq, reads, fids, fleet, trace=ctx))
            if ctx is not None:
                trc.emit("fanout", ctx[0], ctx[1], t_f, time.monotonic(),
                         attrs={"seq": seq, "n_workers": len(serving)})
        for w in dead:
            self._on_worker_death(w)
        return fut

    # -- rolling restart + compaction ---------------------------------------
    def _boot_replacement(self, snapshot_dir: str, version: int) -> _Worker:
        w = self._launch(snapshot_dir, version)
        self._hello_all([w])
        self._finish_boot(w, snapshot_dir)
        return w

    def _drain_worker(self, w: _Worker) -> None:
        with self._idle:
            while any(p.worker_id == w.id for p in self._pending.values()):
                self._idle.wait(timeout=1.0)

    def _shutdown_worker(self, w: _Worker) -> None:
        w.retiring = True
        fut: Future = Future()
        with self._lock:
            mid = next(self._mid)
            self._pending[mid] = _PendingMsg(w.id, "shutdown", fut)
            w.outstanding += 1
        try:
            w.wire.send(ipc.Request(mid, "shutdown"))
            fut.result(timeout=60)
        except Exception:  # noqa: BLE001 - escalate to terminate below
            with self._lock:
                if self._pending.pop(mid, None) is not None:
                    w.outstanding -= 1
                self._idle.notify_all()
        w.proc.join(timeout=10)
        if w.proc.is_alive():
            w.proc.terminate()
            w.proc.join(timeout=10)
        with self._lock:
            w.alive = False
            self._workers = [x for x in self._workers if x is not w]

    def _swap_one(self, old: _Worker, replacement: _Worker) -> None:
        """Replacement-first swap: traffic shifts, the old worker drains
        its in-flight replies, then shuts down — zero dropped requests."""
        with self._lock:
            replacement.serving = True
            old.serving = False
        self._drain_worker(old)
        self._shutdown_worker(old)

    def rolling_restart(self, snapshot_dir: Optional[str] = None, *,
                        version: Optional[int] = None) -> int:
        """Swap every worker onto ``snapshot_dir``, one at a time.

        The fleet version only advances when EVERY worker swapped. If a
        replacement fails to boot, the rollout aborts, already-swapped
        workers roll BACK onto the old snapshot, and the fleet keeps
        serving the old version — never a mixed fleet.
        """
        with self._admin_lock:
            target = snapshot_dir or self._snapshot_dir
            with self._lock:
                old_dir, old_version = self._snapshot_dir, self._version
                new_version = (old_version + 1 if version is None
                               else int(version))
                targets = [w for w in self._workers
                           if w.alive and w.serving]
            swapped: List[_Worker] = []
            for old in targets:
                try:
                    replacement = self._boot_replacement(target, new_version)
                except FabricError as e:
                    for s in swapped:     # back out: fleet stays on OLD
                        rb = self._boot_replacement(old_dir, old_version)
                        self._swap_one(s, rb)
                    raise FabricError(
                        f"rolling restart onto {target!r} aborted "
                        f"(fleet still serving version {old_version}): "
                        f"{e}") from e
                self._swap_one(old, replacement)
                swapped.append(replacement)
            with self._lock:
                self._snapshot_dir = target
                self._version = new_version
            return new_version

    def compact(self, save_dir: str) -> int:
        """Fold the fleet's delta into its base and roll onto the result.

        The lead worker freezes a plan, merges OFF the hot path and
        writes the merged snapshot; the fleet then rolling-restarts onto
        it (queries keep flowing throughout) and the journal truncates
        through the fold watermark — the merged snapshot is the durable
        copy now. Returns the new fleet version.
        """
        fut: Future = Future()
        with self._admin_lock:
            with self._lock:
                serving = [w for w in self._workers
                           if w.serving and w.alive]
                if not serving:
                    raise FabricError("fabric has no serving workers")
                lead = serving[0]
                mid = next(self._mid)
                self._pending[mid] = _PendingMsg(lead.id, "compact", fut)
                lead.outstanding += 1
                try:
                    lead.wire.send(ipc.Request(mid, "compact", save_dir))
                except ipc.WireClosed:
                    pass          # surfaces as WorkerLost on the future
            upto_seq = fut.result(timeout=600)
            with self._lock:
                # replacements must not re-apply folded writes: trim the
                # tail BEFORE the roll (re-applying is idempotent but the
                # smaller replay is the point of compaction)
                self._tail = [r for r in self._tail if r.seq > upto_seq]
        new_version = self.rolling_restart(save_dir)
        if self._journal is not None:
            self._journal.truncate_through(upto_seq)
        return new_version

    # -- lifecycle -----------------------------------------------------------
    def drain(self) -> None:
        """Block until every in-flight request has its reply."""
        with self._idle:
            while self._pending:
                self._idle.wait(timeout=1.0)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            workers = [w for w in self._workers if w.alive]
        for w in workers:
            if w.wire is not None:
                self._shutdown_worker(w)
            else:
                w.retiring = True
                w.proc.terminate()
                w.proc.join(timeout=10)
        self._listener.close()
        if self._journal is not None:
            self._journal.close()
        try:
            os.unlink(self._socket_path)
            os.rmdir(self._rundir)
        except OSError:
            pass

    def __enter__(self) -> "ProcessFabric":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

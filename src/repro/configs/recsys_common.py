"""RecSys-family plumbing: shared shapes + sharding rules.

Shapes (assignment):
  train_batch     batch=65,536     -> train_step
  serve_p99       batch=512        -> forward scoring (online)
  serve_bulk      batch=262,144    -> forward scoring (offline)
  retrieval_cand  batch=1 x 1M candidates -> batched-dot retrieval scoring

Embedding tables are row-sharded over 'model' (the assignment's hot path);
batches over ('pod','data'); tower/MLP weights FSDP over ('pod','data').
"""

from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.train import optimizer as opt_mod, train_state as ts

DP = base.DP_AXES


def recsys_shapes() -> dict[str, base.ShapeCell]:
    return {
        "train_batch": base.ShapeCell(
            "train_batch", "train", {"batch": 65536}),
        "serve_p99": base.ShapeCell(
            "serve_p99", "serve", {"batch": 512, "mode": "score"}),
        "serve_bulk": base.ShapeCell(
            "serve_bulk", "serve", {"batch": 262144, "mode": "score"}),
        "retrieval_cand": base.ShapeCell(
            "retrieval_cand", "serve",
            {"batch": 1, "candidates": 1_000_000, "mode": "retrieval"}),
    }


def state_spec(cfg, path: str, shape: tuple) -> P:
    parts = [p for p in path.split("/") if p]
    if parts and parts[-1] == "step" or len(shape) == 0:
        return P()
    name = parts[-1]
    if name == "m" and len(parts) >= 2:
        name = parts[-2]
    if ("table" in name or name == "linear" or name == "pos") and len(shape) >= 2:
        return P("model", *([None] * (len(shape) - 1)))   # row-sharded tables
    if len(shape) >= 2:
        return P(*((None,) * (len(shape) - 2) + (DP, "model")))
    return P()


def batch_spec(cfg, path: str, shape: tuple) -> P:
    if len(shape) == 0:
        return P()
    return P(DP, *([None] * (len(shape) - 1)))


def make_recsys_spec(
    name: str, full_cfg, smoke_cfg, *,
    init_fn: Callable, loss_fn: Callable,
    score_fn: Callable, retrieval_fn: Callable,
    train_inputs: Callable, score_inputs: Callable, retrieval_inputs: Callable,
    model_flops_fn=None,
) -> base.ArchSpec:
    """Assemble an ArchSpec from the per-arch fns.

    All fns take (cfg, ...): init_fn(key, cfg); loss_fn(params, batch, cfg);
    score_fn(params, batch, cfg) -> scores; retrieval_fn(params, batch, cfg).
    *_inputs(cfg, cell) -> dict of ShapeDtypeStructs.
    """

    def input_specs(cfg, cell):
        if cell.kind == "train":
            return train_inputs(cfg, cell)
        if cell.meta["mode"] == "score":
            return score_inputs(cfg, cell)
        return retrieval_inputs(cfg, cell)

    def abstract_state(cfg, cell):
        params = jax.eval_shape(
            lambda k: init_fn(k, cfg), jax.random.PRNGKey(0)
        )
        if cell.kind == "train":
            return jax.eval_shape(
                lambda p: ts.TrainState.create(p, opt_mod.adamw(1e-3)), params
            )
        return params

    def step_fn(cfg, cell):
        if cell.kind == "train":
            return ts.make_train_step(
                lambda p, b: loss_fn(p, b, cfg), opt_mod.adamw(1e-3)
            )
        if cell.meta["mode"] == "score":
            return lambda params, batch: score_fn(params, batch, cfg)
        return lambda params, batch: retrieval_fn(params, batch, cfg)

    return base.register(base.ArchSpec(
        name=name, family="recsys",
        make_config=full_cfg, make_smoke_config=smoke_cfg,
        shapes=recsys_shapes(),
        input_specs=input_specs,
        abstract_state=abstract_state,
        step_fn=step_fn,
        state_spec_fn=state_spec,
        batch_spec_fn=batch_spec,
        model_flops_fn=model_flops_fn,
    ))

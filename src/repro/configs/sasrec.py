"""sasrec [recsys] — self-attentive sequential recommendation.

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50. [arXiv:1808.09781; paper]
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import base, recsys_common
from repro.models import recsys


def full_config() -> recsys.SASRecConfig:
    return recsys.SASRecConfig(
        name="sasrec", embed_dim=50, n_blocks=2, n_heads=1, seq_len=50,
        n_items=1 << 20,
    )


def smoke_config() -> recsys.SASRecConfig:
    return recsys.SASRecConfig(
        name="sasrec-smoke", embed_dim=16, n_blocks=2, n_heads=1, seq_len=12,
        n_items=1 << 10,
    )


def score(params, batch, cfg):
    """Per-request next-item scores against the given candidate set."""
    h = recsys.sasrec_forward(params, batch["seq"], cfg)[:, -1, :]  # (B, d)
    rows = recsys.hash_rows(batch["cands"], cfg.n_items, cfg.hash_scheme)
    ce = jnp.take(params["item_table"], rows, axis=0)               # (B, C, d)
    return jnp.einsum("bd,bcd->bc", h, ce).astype(jnp.float32)


def retrieval(params, batch, cfg):
    """One session vs 1M candidates: single gather + matvec, not a loop."""
    h = recsys.sasrec_forward(params, batch["seq"], cfg)[0, -1, :]  # (d,)
    rows = recsys.hash_rows(batch["cands"], cfg.n_items, cfg.hash_scheme)
    ce = jnp.take(params["item_table"], rows, axis=0)               # (N, d)
    return (ce @ h).astype(jnp.float32)


def train_inputs(cfg, cell):
    b, s = cell.meta["batch"], cfg.seq_len
    i32 = jnp.int32
    return {
        "seq": jax.ShapeDtypeStruct((b, s), i32),
        "pos": jax.ShapeDtypeStruct((b, s), i32),
        "neg": jax.ShapeDtypeStruct((b, s), i32),
    }


def score_inputs(cfg, cell):
    b = cell.meta["batch"]
    return {
        "seq": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
        "cands": jax.ShapeDtypeStruct((b, 100), jnp.int32),
    }


def retrieval_inputs(cfg, cell):
    return {
        "seq": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32),
        "cands": jax.ShapeDtypeStruct((cell.meta["candidates"],), jnp.int32),
    }


def model_flops(cfg: recsys.SASRecConfig, cell) -> float:
    b = cell.meta["batch"]
    s, d = cfg.seq_len, cfg.embed_dim
    per_tok = cfg.n_blocks * (4 * d * d + 8 * d * d) * 2   # attn + 4x mlp
    attn = cfg.n_blocks * 2 * s * s * d * 2
    fwd = b * (s * per_tok + attn)
    if cell.kind == "train":
        return 3.0 * fwd
    if cell.meta.get("mode") == "retrieval":
        return fwd + 2.0 * cell.meta["candidates"] * d
    return fwd + 2.0 * b * 100 * d


SPEC = recsys_common.make_recsys_spec(
    "sasrec", full_config, smoke_config,
    init_fn=recsys.sasrec_init,
    loss_fn=recsys.sasrec_loss,
    score_fn=score, retrieval_fn=retrieval,
    train_inputs=train_inputs, score_inputs=score_inputs,
    retrieval_inputs=retrieval_inputs,
    model_flops_fn=model_flops,
)

"""granite-moe-1b-a400m [moe] — IBM Granite 3.0 1B-A400M base.

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""

from repro.configs import lm_common
from repro.models import moe as moe_mod, transformer as tf


def full_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="granite-moe-1b-a400m",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=0, vocab=49155, act="silu", gated_mlp=True,
        tie_embeddings=True,   # granite ties input/output embeddings
        moe=moe_mod.MoeConfig(
            d_model=1024, d_ff=512, n_experts=32, top_k=8,
            capacity_factor=1.25, act="silu", gated=True,
            dispatch_groups=32,   # group-local dispatch (§Perf)
        ),
    )


def smoke_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="granite-moe-1b-a400m-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab=128, act="silu", gated_mlp=True,
        tie_embeddings=True, remat=False,
        moe=moe_mod.MoeConfig(
            d_model=64, d_ff=32, n_experts=4, top_k=2,
            capacity_factor=1.25, act="silu", gated=True,
        ),
    )


SPEC = lm_common.make_lm_spec("granite-moe-1b-a400m", full_config, smoke_config)

"""two-tower-retrieval [recsys] — sampled-softmax retrieval (YouTube '19).

embed_dim=256 tower_mlp=1024-512-256 interaction=dot. [RecSys'19; unverified]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import recsys_common
from repro.models import recsys


def full_config() -> recsys.TwoTowerConfig:
    return recsys.TwoTowerConfig(
        name="two-tower-retrieval", embed_dim=256,
        tower_dims=(1024, 512, 256), n_users=1 << 23, n_items=1 << 23,
    )


def smoke_config() -> recsys.TwoTowerConfig:
    return recsys.TwoTowerConfig(
        name="two-tower-smoke", embed_dim=16, tower_dims=(32, 16),
        n_users=1 << 10, n_items=1 << 10,
    )


def score(params, batch, cfg):
    u, it = recsys.twotower_embed(params, batch, cfg)
    return jnp.sum(u * it, axis=-1).astype(jnp.float32)


def train_inputs(cfg, cell):
    b = cell.meta["batch"]
    return {
        "user_feats": jax.ShapeDtypeStruct((b, cfg.n_user_feats), jnp.int32),
        "item_feats": jax.ShapeDtypeStruct((b, cfg.n_item_feats), jnp.int32),
    }


score_inputs = train_inputs


def retrieval_inputs(cfg, cell):
    return {
        "user_feats": jax.ShapeDtypeStruct((1, cfg.n_user_feats), jnp.int32),
        "cand_feats": jax.ShapeDtypeStruct(
            (cell.meta["candidates"], cfg.n_item_feats), jnp.int32
        ),
    }


def model_flops(cfg: recsys.TwoTowerConfig, cell) -> float:
    def tower_flops(d_in):
        f, prev = 0, d_in
        for d in cfg.tower_dims:
            f += 2 * prev * d
            prev = d
        return f
    ut = tower_flops(cfg.n_user_feats * cfg.embed_dim)
    it = tower_flops(cfg.n_item_feats * cfg.embed_dim)
    if cell.kind == "train":
        b = cell.meta["batch"]
        return 3.0 * b * (ut + it + 2 * b * cfg.tower_dims[-1])
    if cell.meta.get("mode") == "retrieval":
        n = cell.meta["candidates"]
        return float(ut + n * it + 2 * n * cfg.tower_dims[-1])
    b = cell.meta["batch"]
    return float(b * (ut + it + 2 * cfg.tower_dims[-1]))


SPEC = recsys_common.make_recsys_spec(
    "two-tower-retrieval", full_config, smoke_config,
    init_fn=recsys.twotower_init,
    loss_fn=recsys.twotower_loss,
    score_fn=score,
    retrieval_fn=lambda p, b, c: recsys.twotower_score_candidates(p, b, c),
    train_inputs=train_inputs, score_inputs=score_inputs,
    retrieval_inputs=retrieval_inputs,
    model_flops_fn=model_flops,
)

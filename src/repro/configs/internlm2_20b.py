"""internlm2-20b [dense] — InternLM2 20B.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544, SwiGLU.
[arXiv:2403.17297; hf]
"""

from repro.configs import lm_common
from repro.models import transformer as tf


def full_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="internlm2-20b",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=92544, act="silu", gated_mlp=True,
        rope_theta=1000000.0,
    )


def smoke_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="internlm2-20b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=128, act="silu", gated_mlp=True, remat=False,
        rope_theta=1000000.0,
    )


SPEC = lm_common.make_lm_spec("internlm2-20b", full_config, smoke_config)

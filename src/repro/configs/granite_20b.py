"""granite-20b [dense] — IBM Granite 20B code model.

52L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576 vocab=49152.
GPT-BigCode lineage: non-gated GELU MLP, multi-query attention.
[arXiv:2405.04324; hf]
"""

from repro.configs import lm_common
from repro.models import transformer as tf


def full_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="granite-20b",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152, act="gelu", gated_mlp=False,
    )


def smoke_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="granite-20b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=256, vocab=128, act="gelu", gated_mlp=False, remat=False,
    )


SPEC = lm_common.make_lm_spec("granite-20b", full_config, smoke_config)

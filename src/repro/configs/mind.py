"""mind [recsys] — multi-interest dynamic-routing capsule network.

embed_dim=64 n_interests=4 capsule_iters=3. [arXiv:1904.08030; unverified]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import recsys_common
from repro.models import recsys


def full_config() -> recsys.MINDConfig:
    return recsys.MINDConfig(
        name="mind", embed_dim=64, n_interests=4, capsule_iters=3,
        seq_len=50, n_items=1 << 20,
    )


def smoke_config() -> recsys.MINDConfig:
    return recsys.MINDConfig(
        name="mind-smoke", embed_dim=16, n_interests=2, capsule_iters=2,
        seq_len=12, n_items=1 << 10,
    )


def score(params, batch, cfg):
    """Max-over-interests dot against per-request candidates."""
    v = recsys.mind_interests(params, batch["seq"], batch["mask"], cfg)
    rows = recsys.hash_rows(batch["cands"], cfg.n_items, cfg.hash_scheme)
    ce = jnp.take(params["item_table"], rows, axis=0)          # (B, C, d)
    s = jnp.einsum("bkd,bcd->bkc", v, ce)
    return jnp.max(s, axis=1).astype(jnp.float32)


def retrieval(params, batch, cfg):
    v = recsys.mind_interests(params, batch["seq"], batch["mask"], cfg)[0]
    rows = recsys.hash_rows(batch["cands"], cfg.n_items, cfg.hash_scheme)
    ce = jnp.take(params["item_table"], rows, axis=0)          # (N, d)
    return jnp.max(ce @ v.T, axis=-1).astype(jnp.float32)


def train_inputs(cfg, cell):
    b, s = cell.meta["batch"], cfg.seq_len
    i32, f32 = jnp.int32, jnp.float32
    return {
        "seq": jax.ShapeDtypeStruct((b, s), i32),
        "mask": jax.ShapeDtypeStruct((b, s), f32),
        "pos": jax.ShapeDtypeStruct((b,), i32),
        "negs": jax.ShapeDtypeStruct((b, 10), i32),
    }


def score_inputs(cfg, cell):
    b = cell.meta["batch"]
    return {
        "seq": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.float32),
        "cands": jax.ShapeDtypeStruct((b, 100), jnp.int32),
    }


def retrieval_inputs(cfg, cell):
    return {
        "seq": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32),
        "mask": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.float32),
        "cands": jax.ShapeDtypeStruct((cell.meta["candidates"],), jnp.int32),
    }


def model_flops(cfg: recsys.MINDConfig, cell) -> float:
    b = cell.meta["batch"]
    s, d, k = cfg.seq_len, cfg.embed_dim, cfg.n_interests
    routing = cfg.capsule_iters * (2 * k * s * d * 2)
    fwd = b * (s * 2 * d * d + routing)
    if cell.kind == "train":
        return 3.0 * fwd
    if cell.meta.get("mode") == "retrieval":
        return fwd + 2.0 * cell.meta["candidates"] * d * k
    return fwd + 2.0 * b * 100 * d * k


SPEC = recsys_common.make_recsys_spec(
    "mind", full_config, smoke_config,
    init_fn=recsys.mind_init,
    loss_fn=recsys.mind_loss,
    score_fn=score, retrieval_fn=retrieval,
    train_inputs=train_inputs, score_inputs=score_inputs,
    retrieval_inputs=retrieval_inputs,
    model_flops_fn=model_flops,
)

"""Architecture registry — one module per assigned architecture.

    from repro.configs import get, all_archs
    spec = get("arctic-480b")
    cfg = spec.make_config()
"""

from repro.configs.base import ArchSpec, ShapeCell, all_archs, get  # noqa: F401

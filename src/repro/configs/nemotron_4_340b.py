"""nemotron-4-340b [dense] — NVIDIA Nemotron-4 340B.

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000, squared-ReLU
non-gated MLP. [arXiv:2402.16819; unverified]
"""

from repro.configs import lm_common
from repro.models import transformer as tf


def full_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="nemotron-4-340b",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
        d_ff=73728, vocab=256000, act="relu2", gated_mlp=False,
    )


def smoke_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="nemotron-4-340b-smoke",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
        d_ff=384, vocab=128, act="relu2", gated_mlp=False, remat=False,
    )


SPEC = lm_common.make_lm_spec("nemotron-4-340b", full_config, smoke_config)

"""fm [recsys] — factorization machine (Rendle, ICDM'10).

n_sparse=39 embed_dim=10, pairwise interactions via the O(nk) sum-square
trick. [ICDM'10; paper]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import recsys_common
from repro.models import recsys


def full_config() -> recsys.FMConfig:
    return recsys.FMConfig(name="fm", n_sparse=39, embed_dim=10,
                           vocab_per_field=1 << 20)


def smoke_config() -> recsys.FMConfig:
    return recsys.FMConfig(name="fm-smoke", n_sparse=8, embed_dim=4,
                           vocab_per_field=256)


def score(params, batch, cfg):
    return recsys.fm_forward(params, batch["feats"], cfg)


def retrieval(params, batch, cfg):
    """Fixed user context × 1M candidate items: candidate id fills the last
    field, the other 38 fields broadcast — one batched forward."""
    ctx = jnp.broadcast_to(
        batch["context"], (batch["cands"].shape[0], cfg.n_sparse)
    )
    feats = ctx.at[:, -1].set(batch["cands"])
    return recsys.fm_forward(params, feats, cfg)


def train_inputs(cfg, cell):
    b = cell.meta["batch"]
    return {
        "feats": jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


def score_inputs(cfg, cell):
    b = cell.meta["batch"]
    return {"feats": jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32)}


def retrieval_inputs(cfg, cell):
    return {
        "context": jax.ShapeDtypeStruct((1, cfg.n_sparse), jnp.int32),
        "cands": jax.ShapeDtypeStruct((cell.meta["candidates"],), jnp.int32),
    }


def model_flops(cfg: recsys.FMConfig, cell) -> float:
    b = cell.meta.get("candidates", cell.meta["batch"])
    fwd = b * cfg.n_sparse * cfg.embed_dim * 4     # sum-square trick
    return 3.0 * fwd if cell.kind == "train" else float(fwd)


SPEC = recsys_common.make_recsys_spec(
    "fm", full_config, smoke_config,
    init_fn=recsys.fm_init,
    loss_fn=recsys.fm_loss,
    score_fn=score, retrieval_fn=retrieval,
    train_inputs=train_inputs, score_inputs=score_inputs,
    retrieval_inputs=retrieval_inputs,
    model_flops_fn=model_flops,
)

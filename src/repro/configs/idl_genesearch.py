"""idl-genesearch — the paper's own system as a first-class architecture.

Bit-sliced COBS-style index over 1024 files, queried with batched MSMT
through the shared query planner. The hashing scheme is selectable
"idl" | "rh" — the dry-run
lowers the IDL variant; benchmarks compare both. This is the cell most
representative of the paper's technique (perf-hillclimbed in §Perf).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.distributed.sharding import shard
from repro.index import query
from repro.serving import genesearch as gs

DP = base.DP_AXES


def full_config() -> gs.GeneSearchConfig:
    return gs.GeneSearchConfig(
        name="idl-genesearch", n_files=1024, m=1 << 26,
        k=31, t=16, L=1 << 17, eta=4, read_len=230, scheme="idl",
    )


def smoke_config() -> gs.GeneSearchConfig:
    return gs.GeneSearchConfig(
        name="idl-genesearch-smoke", n_files=64, m=1 << 18,
        k=31, t=12, L=1 << 10, eta=2, read_len=100, scheme="idl",
    )


def shapes() -> dict[str, base.ShapeCell]:
    return {
        "serve_p99": base.ShapeCell(
            "serve_p99", "serve", {"batch": 256}),
        "serve_bulk": base.ShapeCell(
            "serve_bulk", "serve", {"batch": 16384}),
    }


def input_specs(cfg: gs.GeneSearchConfig, cell: base.ShapeCell) -> dict:
    b = cell.meta["batch"]
    return {"queries": jax.ShapeDtypeStruct((b, cfg.read_len), jnp.uint8)}


def abstract_state(cfg: gs.GeneSearchConfig, cell: base.ShapeCell):
    return jax.ShapeDtypeStruct((cfg.m, cfg.file_words), jnp.uint32)


def step_fn(cfg: gs.GeneSearchConfig, cell: base.ShapeCell):
    # batched MSMT through the shared planner (the body the removed v1
    # serve_step used to wrap): per-kmer probe, then the exact integer
    # coverage threshold, with the serve-layout sharding annotations
    def serve(index, batch):
        queries = batch["queries"]
        plan = gs.query_plan(cfg, queries.shape[0], index.shape)
        per_kmer = plan.execute(index, queries)       # (B, n_k, F/32)
        per_kmer = shard(per_kmer, ("batch", None, "files"))
        out = query.file_match_mask(per_kmer, cfg.theta)
        return shard(out, ("batch", "files"))
    return serve


def state_spec(cfg, path: str, shape: tuple) -> P:
    # index (m, n_files/32): rows replicated, file slice over 'model' — the
    # per-query row gather is then device-local (see serving/genesearch.py)
    return P(None, "model")


def batch_spec(cfg, path: str, shape: tuple) -> P:
    return P(DP, None)


def model_flops(cfg: gs.GeneSearchConfig, cell: base.ShapeCell) -> float:
    b = cell.meta["batch"]
    n_k = cfg.n_kmers
    # per kmer: ~w hash rounds of a few ALU ops + η gathers of F/32 words
    hash_ops = b * n_k * (cfg.k - cfg.t + 1) * 16
    and_ops = b * n_k * cfg.eta * cfg.file_words
    return float(hash_ops + and_ops)


SPEC = base.register(base.ArchSpec(
    name="idl-genesearch",
    family="genesearch",
    make_config=full_config,
    make_smoke_config=smoke_config,
    shapes=shapes(),
    input_specs=input_specs,
    abstract_state=abstract_state,
    step_fn=step_fn,
    state_spec_fn=state_spec,
    batch_spec_fn=batch_spec,
    model_flops_fn=model_flops,
))

"""arctic-480b [moe] — Snowflake Arctic base.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2
plus a dense residual FFN path (Arctic's dense-MoE hybrid).
[hf:Snowflake/snowflake-arctic-base; hf]
"""

from repro.configs import lm_common
from repro.models import moe as moe_mod, transformer as tf


def full_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="arctic-480b",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=0, vocab=32000, act="silu", gated_mlp=True,
        moe=moe_mod.MoeConfig(
            d_model=7168, d_ff=4864, n_experts=128, top_k=2,
            capacity_factor=1.25, act="silu", gated=True,
            residual_d_ff=4864,
            dispatch_groups=32,   # group-local dispatch (§Perf)
        ),
    )


def smoke_config() -> tf.LMConfig:
    return tf.LMConfig(
        name="arctic-480b-smoke",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=0, vocab=128, act="silu", gated_mlp=True, remat=False,
        moe=moe_mod.MoeConfig(
            d_model=64, d_ff=32, n_experts=8, top_k=2,
            capacity_factor=1.25, act="silu", gated=True, residual_d_ff=32,
        ),
    )


SPEC = lm_common.make_lm_spec("arctic-480b", full_config, smoke_config)

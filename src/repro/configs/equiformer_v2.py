"""equiformer-v2 [gnn] — SO(2)-eSCN equivariant graph attention.

n_layers=12 d_hidden=128 l_max=6 m_max=2 n_heads=8. [arXiv:2306.12059]

Shapes (assignment):
  full_graph_sm  2,708 nodes / 10,556 edges / d_feat 1,433  (Cora-like, 7 cls)
  minibatch_lg   232,965-node graph, fanout 15-10 from 1,024 seeds — the
                 dry-run cell is the PADDED SAMPLED SUBGRAPH:
                 nodes <= 1024·(1+15+15·10) = 169,984, edges <= 168,960
  ogb_products   2,449,029 nodes / 61,859,140 edges / d_feat 100 (47 cls)
  molecule       batch=128 graphs x (30 nodes / 64 edges), energy regression

Non-geometric datasets carry synthetic 3D positions (DESIGN.md): the
equivariant backbone is unchanged, positions are an input like any other.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.models import equiformer as eq
from repro.train import optimizer as opt_mod, train_state as ts

DP = base.DP_AXES
ALL = ("pod", "data", "model")   # edge axis shards over the whole mesh


def full_config() -> eq.EquiformerConfig:
    return eq.EquiformerConfig(
        name="equiformer-v2", n_layers=12, d_hidden=128,
        l_max=6, m_max=2, n_heads=8,
    )


def smoke_config() -> eq.EquiformerConfig:
    return eq.EquiformerConfig(
        name="equiformer-v2-smoke", n_layers=2, d_hidden=16,
        l_max=2, m_max=1, n_heads=2, remat=False,
    )


def shapes() -> dict[str, base.ShapeCell]:
    return {
        "full_graph_sm": base.ShapeCell(
            "full_graph_sm", "train",
            {"nodes": 2708, "edges": 10556, "d_feat": 1433, "classes": 7,
             "task": "node_cls"}),
        "minibatch_lg": base.ShapeCell(
            "minibatch_lg", "train",
            {"nodes": 169984, "edges": 168960, "d_feat": 0, "classes": 41,
             "task": "node_cls"}),
        "ogb_products": base.ShapeCell(
            "ogb_products", "train",
            {"nodes": 2449029, "edges": 61859140, "d_feat": 100,
             "classes": 47, "task": "node_cls"}),
        "molecule": base.ShapeCell(
            "molecule", "train",
            {"nodes": 30 * 128, "edges": 64 * 128, "d_feat": 0, "classes": 0,
             "graphs": 128, "task": "regression"}),
    }


def cell_config(cfg: eq.EquiformerConfig, cell: base.ShapeCell) -> eq.EquiformerConfig:
    return dataclasses.replace(
        cfg, d_feat=cell.meta["d_feat"], n_classes=cell.meta["classes"]
    )


def input_specs(cfg: eq.EquiformerConfig, cell: base.ShapeCell) -> dict:
    n, e = cell.meta["nodes"], cell.meta["edges"]
    f32, i32 = jnp.float32, jnp.int32
    batch = {
        "positions": jax.ShapeDtypeStruct((n, 3), f32),
        "src": jax.ShapeDtypeStruct((e,), i32),
        "dst": jax.ShapeDtypeStruct((e,), i32),
        "edge_mask": jax.ShapeDtypeStruct((e,), f32),
        "node_mask": jax.ShapeDtypeStruct((n,), f32),
        "node_type": jax.ShapeDtypeStruct((n,), i32),
    }
    if cell.meta["d_feat"]:
        batch["node_feat"] = jax.ShapeDtypeStruct((n, cell.meta["d_feat"]), f32)
    if cell.meta["task"] == "node_cls":
        batch["labels"] = jax.ShapeDtypeStruct((n,), i32)
    else:
        g = cell.meta["graphs"]
        batch["graph_id"] = jax.ShapeDtypeStruct((n,), i32)
        batch["targets"] = jax.ShapeDtypeStruct((g,), f32)
    return batch


def abstract_state(cfg: eq.EquiformerConfig, cell: base.ShapeCell):
    ccfg = cell_config(cfg, cell)
    params = jax.eval_shape(
        lambda k: eq.equiformer_init(k, ccfg), jax.random.PRNGKey(0)
    )
    return jax.eval_shape(
        lambda p: ts.TrainState.create(p, opt_mod.adamw(1e-3)), params
    )


def step_fn(cfg: eq.EquiformerConfig, cell: base.ShapeCell):
    ccfg = cell_config(cfg, cell)
    loss = lambda p, b: eq.equiformer_loss(p, b, ccfg)
    return ts.make_train_step(loss, opt_mod.adamw(1e-3))


def state_spec(cfg, path: str, shape: tuple) -> P:
    parts = [p for p in path.split("/") if p]
    if parts and parts[-1] == "step" or len(shape) <= 1:
        return P()
    name = parts[-1] if parts[-1] not in ("m",) else (
        parts[-2] if len(parts) >= 2 else parts[-1]
    )
    # so2 mixing weights (L, d, d) / ffn (L+1? no: (l_max+1, C, C) stacked
    # under layers => (n_layers, l_max+1, C, C)); shard trailing matmul dims
    if name.startswith("w") and len(shape) >= 2:
        return P(*((None,) * (len(shape) - 2) + (DP, "model")))
    if name in ("embed", "head"):
        return P(DP, None)
    return P()


def batch_spec(cfg, path: str, shape: tuple) -> P:
    name = path.split("/")[-1]
    if name in ("src", "dst", "edge_mask"):
        return P(ALL)
    if name in ("positions", "node_mask", "node_type", "node_feat", "labels",
                "graph_id"):
        return P((*DP,) if len(shape) >= 1 else None,
                 *([None] * (len(shape) - 1)))
    if name == "targets":
        return P(DP)
    return P()


def model_flops(cfg: eq.EquiformerConfig, cell: base.ShapeCell) -> float:
    # dominant terms: 2 Wigner rotations + SO(2) mixes per edge per layer
    e = cell.meta["edges"]
    k = cfg.n_coeff
    c = cfg.d_hidden
    rot = 2 * e * k * k * c * 2            # two (K,K)@(K,C) einsums
    n_l = sum(cfg.l_max + 1 - m for m in range(cfg.m_max + 1))
    so2 = e * (n_l * c) ** 2 * 2 // (cfg.m_max + 1)  # per-m block mixes (approx)
    fwd = cfg.n_layers * (rot + so2)
    return 3.0 * fwd                        # fwd + bwd


SPEC = base.register(base.ArchSpec(
    name="equiformer-v2",
    family="gnn",
    make_config=full_config,
    make_smoke_config=smoke_config,
    shapes=shapes(),
    input_specs=input_specs,
    abstract_state=abstract_state,
    step_fn=step_fn,
    state_spec_fn=state_spec,
    batch_spec_fn=batch_spec,
    model_flops_fn=model_flops,
))

"""LM-family plumbing shared by the five assigned transformer archs.

Shapes (assignment):
  train_4k     seq 4,096  × global_batch 256   -> train_step
  prefill_32k  seq 32,768 × global_batch 32    -> serve (prefill)
  decode_32k   seq 32,768 KV × global_batch 128 -> serve (one-token decode)
  long_500k    SKIPPED for all five archs: each is pure full-attention GQA
               per its public config (sub-quadratic attention required).
               Recorded in DESIGN.md §Shape-skips.

Sharding: TP over 'model' (heads/mlp/experts/vocab), FSDP over ('pod','data')
(params' d_model dim), sequence-parallel residual stream for the big archs,
batch over ('pod','data'). KV caches shard batch over DP and seq over
'model'.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.models import transformer as tf
from repro.train import optimizer as opt_mod, train_state as ts

DP = base.DP_AXES


def lm_shapes() -> dict[str, base.ShapeCell]:
    return {
        "train_4k": base.ShapeCell(
            "train_4k", "train", {"seq": 4096, "batch": 256}),
        "prefill_32k": base.ShapeCell(
            "prefill_32k", "serve", {"seq": 32768, "batch": 32, "mode": "prefill"}),
        "decode_32k": base.ShapeCell(
            "decode_32k", "serve", {"seq": 32768, "batch": 128, "mode": "decode"}),
        "long_500k": base.ShapeCell(
            "long_500k", "serve", {"seq": 524288, "batch": 1, "mode": "decode"},
            skip_reason=(
                "pure full-attention GQA arch (public config); long_500k "
                "requires sub-quadratic attention — skip sanctioned by the "
                "assignment, noted in DESIGN.md"
            )),
    }


def param_dtype(cfg: tf.LMConfig):
    # all full-size archs train in bf16 compute (production mixed precision);
    # optimizer moments stay f32. Smoke configs (<10B) stay f32 for CPU tests.
    return jnp.bfloat16 if cfg.param_count() > 0.5e9 else jnp.float32


def choose_optimizer(cfg: tf.LMConfig) -> opt_mod.Optimizer:
    if cfg.param_count() > 30e9:
        return opt_mod.adafactor(lr=1e-2)
    return opt_mod.adamw(lr=3e-4)


def _serve_cfg(cfg: tf.LMConfig, cell: base.ShapeCell) -> tf.LMConfig:
    # 32k prefill: full (S, S) scores would not fit; use the chunked path
    if cell.meta.get("mode") == "prefill" and cell.meta["seq"] > 8192:
        return dataclasses.replace(cfg, attn_chunk=1024, remat=False)
    return dataclasses.replace(cfg, remat=False)


# --------------------------------------------------------------------------
# input specs / abstract state / step fns
# --------------------------------------------------------------------------

def input_specs(cfg: tf.LMConfig, cell: base.ShapeCell) -> dict:
    b, s = cell.meta["batch"], cell.meta["seq"]
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cell.kind == "train":
        return {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cell.meta["mode"] == "prefill":
        return {"tokens": tok}
    return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}


def abstract_params(cfg: tf.LMConfig):
    dt = param_dtype(cfg)
    return jax.eval_shape(
        lambda k: tf.lm_init(k, cfg, dtype=dt), jax.random.PRNGKey(0)
    )


def abstract_state(cfg: tf.LMConfig, cell: base.ShapeCell):
    params = abstract_params(cfg)
    if cell.kind == "train":
        opt = choose_optimizer(cfg)
        return jax.eval_shape(lambda p: ts.TrainState.create(p, opt), params)
    if cell.meta["mode"] == "prefill":
        return params
    b, s = cell.meta["batch"], cell.meta["seq"]
    # KV caches are bf16 regardless of param dtype (production practice)
    cache = jax.eval_shape(
        lambda: tf.init_kv_cache(cfg, b, s, dtype=jnp.bfloat16)
    )
    return {"params": params, "cache": cache}


def loss_chunks_for(cell: base.ShapeCell) -> int:
    """CE chunk count: ~16k tokens per chunk so the per-chip logits buffer
    stays tens of MB even at vocab 256k (power-of-two, divides seq)."""
    b, s = cell.meta["batch"], cell.meta["seq"]
    target = max(1, (b * s) // 16384)
    n = 1
    while n * 2 <= min(target, s):
        n *= 2
    return max(n, 8) if s % max(n, 8) == 0 else n


def microbatch_for(cfg: tf.LMConfig, cell: base.ShapeCell) -> int:
    """Gradient-accumulation microbatches. DEFAULT 0: measured on nemotron
    train_4k, microbatch=4 made things WORSE under FSDP — the per-layer
    weight all-gathers repeat per microbatch (T_coll 103 -> 226 s) and the
    f32 grad accumulator keeps peak temp flat (75 -> 82 GB). Refutation
    logged in EXPERIMENTS.md §Perf; the knob stays for DP-dominant
    configs where it does help."""
    return 0


def step_fn(cfg: tf.LMConfig, cell: base.ShapeCell):
    if cell.kind == "train":
        opt = choose_optimizer(cfg)
        nchunks = loss_chunks_for(cell)
        loss = lambda p, b: tf.lm_loss(p, b, cfg, loss_chunks=nchunks)
        return ts.make_train_step(loss, opt,
                                  microbatch=microbatch_for(cfg, cell))
    scfg = _serve_cfg(cfg, cell)
    if cell.meta["mode"] == "prefill":
        def prefill(params, batch):
            return tf.lm_prefill(params, batch["tokens"], scfg)
        return prefill

    def decode(state, batch):
        logits, cache = tf.lm_decode_step(
            state["params"], state["cache"], batch["tokens"], scfg
        )
        return {"logits": logits, "cache": cache}
    return decode


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------

_PARAM_RULES: list[tuple[str, Any]] = [
    # (terminal name, spec for the trailing dims; leading dims -> None)
    ("embed", (DP, "model")),
    ("unembed", (DP, "model")),
    ("wq", (DP, "model")),
    ("wk", (DP, "model")),
    ("wv", (DP, "model")),
    ("wi", (DP, "model")),
    ("wg", (DP, "model")),
    ("wo", ("model", DP)),
    ("router", (DP, None)),
    ("pos", (None, None)),
]
_MOE_RULES: list[tuple[str, Any]] = [
    # stacked expert weights (L, E, a, b): experts over 'model' (EP)
    ("wi", ("model", DP, None)),
    ("wg", ("model", DP, None)),
    ("wo", ("model", None, DP)),
]


def param_spec(path: str, shape: tuple) -> P:
    """Partition spec for one LM param leaf, by terminal name."""
    parts = path.split("/")
    name = parts[-1]
    if len(shape) <= 1:
        return P()
    rules = _PARAM_RULES
    if "moe" in parts and "residual" not in parts and len(shape) == 4:
        rules = _MOE_RULES
    for key, trailing in rules:
        if name == key:
            lead = len(shape) - len(trailing)
            if lead < 0:
                trailing = trailing[-len(shape):]
                lead = 0
            return P(*((None,) * lead + tuple(trailing)))
    return P()  # ln scales etc: replicate


def state_spec(cfg: tf.LMConfig, path: str, shape: tuple) -> P:
    """Spec for TrainState / serve-state leaves (optimizer state mirrors its
    param's spec; Adafactor's factored stats drop the corresponding axis)."""
    parts = [p for p in path.split("/") if p]
    if parts and parts[-1] in ("step", "len", "bias"):
        return P()
    if parts and parts[-1] == "k" or parts and parts[-1] == "v":
        if len(shape) == 5:  # KV cache (L, B, S, kv, dh)
            return P(None, DP, "model", None, None)
    suffix = None
    if parts and parts[-1] in ("vr", "vc", "m"):
        suffix = parts[-1]
        parts = parts[:-1]
    # strip state wrappers so the param path remains
    parts = [p for p in parts
             if p not in ("params", "opt_state", "per_param", "mu", "nu", "v",
                          "cache", "state")]
    ppath = "/".join(parts)
    if suffix is None:
        return param_spec(ppath, shape)
    pspec = tuple(param_spec(ppath, shape + (1,)))  # parent has one more dim
    pspec = pspec + (None,) * (len(shape) + 1 - len(pspec))
    if suffix == "m":
        return P(*pspec[:-1]) if len(pspec) == len(shape) + 1 else P(*pspec)
    if suffix == "vr":   # parent shape[:-1]
        return P(*pspec[:-1])
    # vc: parent shape[:-2] + shape[-1:]
    return P(*(pspec[:-2] + pspec[-1:]))


def fix_m_spec(cfg, path, shape) -> P:
    """Momentum has the SAME shape as the param — specialize here."""
    parts = [p for p in path.split("/") if p]
    parts = [p for p in parts
             if p not in ("params", "opt_state", "per_param", "mu", "nu",
                          "cache", "state")]
    if parts and parts[-1] == "m":
        parts = parts[:-1]
    return param_spec("/".join(parts), shape)


def lm_state_spec(cfg: tf.LMConfig, path: str, shape: tuple) -> P:
    parts = [p for p in path.split("/") if p]
    if parts and parts[-1] in ("m", "mu", "nu") or (
        len(parts) >= 2 and parts[-2] in ("mu", "nu")
    ):
        return fix_m_spec(cfg, path, shape)
    return state_spec(cfg, path, shape)


def lm_batch_spec(cfg: tf.LMConfig, path: str, shape: tuple) -> P:
    if len(shape) == 2:
        return P(DP, None)
    if len(shape) == 1:
        return P(DP)
    return P()


def lm_model_flops(cfg: tf.LMConfig, cell: base.ShapeCell) -> float:
    n = cfg.active_param_count()
    b, s = cell.meta["batch"], cell.meta["seq"]
    hd = cfg.head_dim * cfg.n_heads
    if cell.kind == "train":
        attn = 6 * cfg.n_layers * b * s * s * hd * 0.5 * 2
        return 6.0 * n * b * s + attn
    if cell.meta["mode"] == "prefill":
        attn = 2 * cfg.n_layers * b * s * s * hd * 0.5 * 2
        return 2.0 * n * b * s + attn
    attn = 4 * cfg.n_layers * b * s * hd
    return 2.0 * n * b + attn


def make_lm_spec(name: str, full_cfg, smoke_cfg) -> base.ArchSpec:
    return base.register(base.ArchSpec(
        name=name,
        family="lm",
        make_config=full_cfg,
        make_smoke_config=smoke_cfg,
        shapes=lm_shapes(),
        input_specs=input_specs,
        abstract_state=abstract_state,
        step_fn=step_fn,
        state_spec_fn=lm_state_spec,
        batch_spec_fn=lm_batch_spec,
        model_flops_fn=lm_model_flops,
    ))

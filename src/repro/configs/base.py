"""Arch registry: every assigned architecture is a selectable config.

An :class:`ArchSpec` bundles, per architecture:
  * the FULL published config (exact numbers from the assignment),
  * a REDUCED smoke config (same family, tiny sizes) for CPU tests,
  * ``shapes``: the architecture's own input-shape set,
  * ``input_specs(shape)`` — ShapeDtypeStruct stand-ins for every input
    (weak-type-correct, shardable, no device allocation),
  * ``abstract_state(shape)`` — ShapeDtypeStructs of the lowered function's
    carried state (params / TrainState / KV cache / index),
  * ``step_fn(shape)`` — the function the dry-run lowers (train_step or
    serve_step, as the shape's kind dictates),
  * ``sharding_rules(mesh)`` + per-leaf partition specs for state and batch.

The dry-run (launch/dryrun.py) iterates the registry × shapes × meshes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture × input-shape) dry-run cell."""

    name: str
    kind: str                 # "train" | "serve"
    meta: dict[str, Any]
    skip_reason: str | None = None


@dataclasses.dataclass
class ArchSpec:
    name: str
    family: str               # "lm" | "gnn" | "recsys" | "genesearch"
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: dict[str, ShapeCell]
    # fns taking (config, shape_cell) — family modules fill these in
    input_specs: Callable[[Any, ShapeCell], dict]
    abstract_state: Callable[[Any, ShapeCell], Any]
    step_fn: Callable[[Any, ShapeCell], Callable]
    state_spec_fn: Callable[[Any, str, tuple], P]   # (cfg, path, shape) -> spec
    batch_spec_fn: Callable[[Any, str, tuple], P]
    model_flops_fn: Callable[[Any, ShapeCell], float] | None = None

    def cells(self) -> list[tuple[str, ShapeCell]]:
        return [(n, c) for n, c in self.shapes.items()]


_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"duplicate arch {spec.name}")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> ArchSpec:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import side-effect registration, deferred to avoid cycles
    from repro.configs import (  # noqa: F401
        arctic_480b, equiformer_v2, fm, granite_20b, granite_moe_1b_a400m,
        idl_genesearch, internlm2_20b, mind, nemotron_4_340b, sasrec,
        two_tower_retrieval,
    )


# --------------------------------------------------------------------------
# sharding helpers shared by family modules
# --------------------------------------------------------------------------

DP_AXES = ("pod", "data")


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def valid_spec(mesh: Mesh, shape: tuple, spec: P) -> P:
    """Drop mesh axes absent from this mesh (e.g. 'pod' on single-pod) and
    sharded dims the axis size doesn't divide (GSPMD-safe fallback)."""
    fixed = []
    for i, ax in enumerate(spec):
        if i >= len(shape):
            break
        if ax is not None:
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            axes = tuple(a for a in axes if a in mesh.axis_names)
            ax = None if not axes else (axes[0] if len(axes) == 1 else axes)
        if ax is None:
            fixed.append(None)
            continue
        if shape[i] % axis_size(mesh, ax):
            fixed.append(None)
        else:
            fixed.append(ax)
    return P(*fixed)


def tree_shardings(
    mesh: Mesh, tree, spec_fn: Callable[[str, tuple], P]
):
    """Build a NamedSharding pytree for ``tree`` of ShapeDtypeStructs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        pstr = "/".join(_pp(p) for p in path)
        spec = spec_fn(pstr, tuple(leaf.shape))
        out.append(NamedSharding(mesh, valid_spec(mesh, tuple(leaf.shape), spec)))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), out
    )


def _pp(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):
        return str(p.name)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def generic_state_spec(path: str, shape: tuple) -> P:
    """Fallback FSDP heuristic: biggest dim over (pod,data), next over model.

    Used by families without bespoke rules; exact-name rules in the family
    modules take precedence.
    """
    if len(shape) == 0 or max(shape) == 1 or len(shape) == 1:
        return P()
    order = np.argsort(shape)[::-1]
    spec: list = [None] * len(shape)
    spec[int(order[0])] = DP_AXES
    if len(shape) >= 2 and shape[int(order[1])] > 1:
        spec[int(order[1])] = "model"
    return P(*spec)

"""Versioned on-disk snapshots of :class:`~repro.index.state.IndexState`.

A snapshot is one directory::

    snapshot/
      manifest.json      # format tag + version + StateMeta + array specs
      words_0.npy        # raw packed uint32 word matrices, one per leaf
      words_1.npy        # (COBS: one per size group)

``save(state_or_engine, dir)`` / ``load(dir)`` round-trip every engine
bit-exactly (``tests/test_store.py`` proves save→load→query parity for
all four engines × schemes). Design points:

* the word matrices are plain ``.npy`` files — ``load`` reads them with
  ``np.load(mmap_mode="r")`` so a multi-GB serving index pages in lazily
  and the host never holds a second copy while the device upload streams;
* the manifest carries a format tag and an integer version; any mismatch
  (foreign directory, future version) is rejected with a clear
  :class:`SnapshotError` instead of garbage answers;
* every array records shape, dtype and a CRC-32: truncated or bit-rotted
  words files fail loudly. ``verify`` picks WHEN the checksum pass runs:
  ``"eager"`` (default — every byte read before ``load`` returns),
  ``"lazy"`` (a background thread checksums the files while the caller
  already serves; :func:`check_verified` surfaces a failure), or
  ``"off"``. Manifest shape/dtype specs are always checked — they cost
  one ``.npy`` header read, not a data pass — so a truncated file still
  fails at open time even with verification off.

Cold-start cost is the reason the knobs exist: a process fleet booting K
workers from ONE on-disk snapshot (:mod:`repro.serving.fabric`) wants
each worker's open to be O(manifest), not O(index bytes). ``mmap=True``
+ ``verify="lazy"`` reads the data exactly once (the page cache shares
that single read across all K workers); ``device=False`` additionally
keeps the word matrices as memory-mapped numpy leaves, deferring even
the page-in until first use (the first computation converts — and pays
the upload — once).

``load`` returns an :class:`IndexState`; ``load_engine`` rebuilds the
engine view in one call. Serving startup
(:meth:`repro.serving.service.GeneSearchService.from_snapshot`) is a thin
wrapper over ``load``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import zlib
from typing import Dict, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core import idl as idl_mod
from repro.index import state as state_mod

FORMAT = "idl-index-snapshot"
VERSION = 1
MANIFEST = "manifest.json"


class SnapshotError(ValueError):
    """A snapshot directory is missing, foreign, corrupt, or from an
    incompatible format version."""


# ---------------------------------------------------------------------------
# Meta <-> JSON.
# ---------------------------------------------------------------------------

def _cfg_to_json(cfg: idl_mod.IDLConfig) -> dict:
    return dataclasses.asdict(cfg)


def _cfg_from_json(d: dict) -> idl_mod.IDLConfig:
    try:
        return idl_mod.IDLConfig(**d)
    except TypeError as e:
        raise SnapshotError(
            f"snapshot IDLConfig does not match this build's fields: {e}"
        ) from e


def meta_to_json(meta: state_mod.StateMeta) -> dict:
    return {
        "engine": meta.engine,
        "scheme": meta.scheme,
        "cfgs": [_cfg_to_json(c) for c in meta.cfgs],
        "n_files": meta.n_files,
        "k": meta.k,
        "group_file_ids": (
            None if meta.group_file_ids is None
            else [list(g) for g in meta.group_file_ids]),
        "n_buckets": meta.n_buckets,
        "n_rep": meta.n_rep,
    }


def meta_from_json(d: dict) -> state_mod.StateMeta:
    try:
        return state_mod.StateMeta(
            engine=d["engine"],
            scheme=d["scheme"],
            cfgs=tuple(_cfg_from_json(c) for c in d["cfgs"]),
            n_files=d.get("n_files"),
            k=d.get("k"),
            group_file_ids=(
                None if d.get("group_file_ids") is None
                else tuple(tuple(int(i) for i in g)
                           for g in d["group_file_ids"])),
            n_buckets=d.get("n_buckets"),
            n_rep=d.get("n_rep"),
        )
    except (KeyError, TypeError, ValueError) as e:
        raise SnapshotError(f"snapshot meta is malformed: {e!r}") from e


# ---------------------------------------------------------------------------
# Save / load.
# ---------------------------------------------------------------------------

def save(index: Union[state_mod.IndexState, object], directory: str) -> str:
    """Write a versioned snapshot of an ``IndexState`` (or engine view).

    Creates ``directory`` if needed and (over)writes ``manifest.json`` plus
    one ``words_<i>.npy`` per state leaf. Returns ``directory``.
    """
    state = state_mod.from_engine(index)
    state_mod.ensure_live(state, *state.words, what="IndexState")
    os.makedirs(directory, exist_ok=True)
    arrays = []
    for i, w in enumerate(state.words):
        arr = np.ascontiguousarray(np.asarray(w))
        fname = f"words_{i}.npy"
        np.save(os.path.join(directory, fname), arr)
        arrays.append({
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        })
    manifest = {
        "format": FORMAT,
        "version": VERSION,
        "meta": meta_to_json(state.meta),
        "arrays": arrays,
    }
    tmp = os.path.join(directory, MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(directory, MANIFEST))  # atomic publish
    return directory


def _read_manifest(directory: str) -> dict:
    path = os.path.join(directory, MANIFEST)
    if not os.path.exists(path):
        from repro.index import shards as shards_mod

        if shards_mod.is_shard_set(directory):
            raise SnapshotError(
                f"{directory!r} is a SHARD-SET snapshot, not a single-index "
                f"snapshot — load it with repro.index.shards.load_shard_set "
                f"(or read its meta with store.read_meta)")
        raise SnapshotError(f"no {MANIFEST} in {directory!r} — not a snapshot")
    try:
        with open(path) as f:
            manifest = json.load(f)
    except json.JSONDecodeError as e:
        raise SnapshotError(f"corrupt {MANIFEST} in {directory!r}: {e}") from e
    if manifest.get("format") != FORMAT:
        raise SnapshotError(
            f"{directory!r} is not an index snapshot "
            f"(format tag {manifest.get('format')!r}, want {FORMAT!r})")
    version = manifest.get("version")
    if version != VERSION:
        raise SnapshotError(
            f"snapshot format version {version!r} is not supported by this "
            f"build (reads version {VERSION}); rebuild the snapshot or "
            f"upgrade the reader")
    return manifest


VERIFY_MODES = ("eager", "lazy", "off")


class _LazyVerify:
    """Handle for one background checksum pass over a snapshot."""

    def __init__(self, directory: str, specs: list):
        self.directory = directory
        self.error: Optional[SnapshotError] = None
        self._thread = threading.Thread(
            target=self._run, args=(specs,), daemon=True,
            name=f"idl-snapshot-verify")
        self._thread.start()

    def _run(self, specs: list) -> None:
        try:
            for spec in specs:
                path = os.path.join(self.directory, spec["file"])
                arr = np.load(path, mmap_mode="r")
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != spec["crc32"]:
                    raise SnapshotError(
                        f"array {spec['file']!r} failed its background "
                        f"checksum (crc32 {crc} != manifest "
                        f"{spec['crc32']}) — snapshot is corrupt")
        except SnapshotError as e:
            self.error = e
        except Exception as e:  # noqa: BLE001 - any read failure is corrupt
            self.error = SnapshotError(
                f"background verify of {self.directory!r} failed: {e!r}")

    def check(self, *, wait: bool = True) -> bool:
        if wait:
            self._thread.join()
        elif self._thread.is_alive():
            return False
        if self.error is not None:
            raise self.error
        return True


_lazy_verifies: Dict[str, _LazyVerify] = {}
_lazy_lock = threading.Lock()


def check_verified(directory: str, *, wait: bool = True) -> bool:
    """Outcome of a ``verify="lazy"`` load's background checksum pass.

    Returns True once the pass finished clean (or no lazy load of
    ``directory`` is registered — nothing to distrust); False while it is
    still running and ``wait=False``. Raises :class:`SnapshotError` if
    the snapshot failed its checksums — the loud failure a lazily booted
    worker must surface instead of serving bit-rotted words forever.
    """
    with _lazy_lock:
        handle = _lazy_verifies.get(os.path.abspath(directory))
    if handle is None:
        return True
    return handle.check(wait=wait)


def read_meta(directory: str) -> state_mod.StateMeta:
    """Read just the snapshot's :class:`StateMeta` — O(manifest), no array
    bytes touched. The fabric gateway uses this to learn kmer size and
    bucket geometry without ever holding the index itself. Shard-set
    snapshots (see :mod:`repro.index.shards`) answer with the FULL
    unsharded meta from their CRC-checked set manifest."""
    if not os.path.exists(os.path.join(directory, MANIFEST)):
        from repro.index import shards as shards_mod

        if shards_mod.is_shard_set(directory):
            return shards_mod.read_set_meta(directory).spec.meta
    return meta_from_json(_read_manifest(directory)["meta"])


def _normalize_verify(verify) -> str:
    if verify is True:
        return "eager"
    if verify is False:
        return "off"
    if verify not in VERIFY_MODES:
        raise ValueError(
            f"verify must be one of {VERIFY_MODES} (or a legacy bool), "
            f"got {verify!r}")
    return verify


def load(directory: str, *, mmap: bool = True,
         verify: Union[str, bool] = "eager",
         device: bool = True) -> state_mod.IndexState:
    """Load a snapshot back into an :class:`IndexState`.

    ``mmap=True`` opens the word files memory-mapped, so bytes page in as
    the device upload consumes them. ``verify`` schedules the CRC-32
    checksum pass: ``"eager"`` checks every array before returning (reads
    all bytes — the default, and what a cold trust boundary wants),
    ``"lazy"`` starts a background thread and returns immediately
    (:func:`check_verified` reports/raises its outcome — the fabric
    worker boot path), ``"off"`` skips it. Legacy ``True``/``False`` map
    to eager/off. Manifest shape/dtype specs are ALWAYS validated — one
    header read per file, so a truncated or reshaped array still fails
    loudly at open time in every mode. ``device=False`` keeps the word
    matrices as memory-mapped numpy leaves instead of uploading them:
    the open is O(manifest) and the first computation over the state
    pays the page-in + conversion (use for metadata tooling or when the
    caller controls materialization). Raises :class:`SnapshotError` on
    any mismatch.
    """
    verify = _normalize_verify(verify)
    manifest = _read_manifest(directory)
    meta = meta_from_json(manifest["meta"])
    specs = manifest.get("arrays", [])
    if len(specs) != len(meta.cfgs):
        raise SnapshotError(
            f"snapshot has {len(specs)} arrays but meta describes "
            f"{len(meta.cfgs)} — manifest is inconsistent")
    words = []
    for spec in specs:
        fname = spec["file"]
        if os.path.basename(fname) != fname or fname in ("", ".", ".."):
            # a crafted manifest must not read outside the snapshot dir
            raise SnapshotError(
                f"snapshot array file {fname!r} is not a plain file name")
        path = os.path.join(directory, fname)
        if not os.path.exists(path):
            raise SnapshotError(f"snapshot array file missing: {path!r}")
        try:
            arr = np.load(path, mmap_mode="r" if mmap else None)
        except ValueError as e:
            raise SnapshotError(f"corrupt array file {path!r}: {e}") from e
        if list(arr.shape) != list(spec["shape"]) or \
                str(arr.dtype) != spec["dtype"]:
            raise SnapshotError(
                f"array {spec['file']!r} is {arr.dtype}{arr.shape}, "
                f"manifest says {spec['dtype']}{tuple(spec['shape'])}")
        if verify == "eager":
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != spec["crc32"]:
                raise SnapshotError(
                    f"array {spec['file']!r} failed its checksum "
                    f"(crc32 {crc} != manifest {spec['crc32']}) — "
                    f"snapshot is corrupt")
        words.append(jnp.asarray(arr) if device else arr)
    if verify == "lazy":
        with _lazy_lock:
            _lazy_verifies[os.path.abspath(directory)] = _LazyVerify(
                directory, list(specs))
    return state_mod.IndexState(words=tuple(words), meta=meta)


def load_engine(directory: str, **kw):
    """Load a snapshot and rebuild the engine view in one call."""
    return state_mod.to_engine(load(directory, **kw))
